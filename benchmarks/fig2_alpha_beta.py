"""Paper Fig. 2 analog: LogP ping between two JAX devices -> (L, beta).
Runs under --xla_force_host_platform_device_count>=2 (see common.py)."""

import json


def main() -> dict:
    from repro.core.calibration import bench_ping, fit_alpha_beta
    ping = bench_ping(sizes_words=(1 << 10, 1 << 14, 1 << 18, 1 << 21, 1 << 23))
    L, beta = fit_alpha_beta(ping)
    return {"latency_s": L, "beta_s_per_word": beta,
            "bandwidth_GBps": 8.0 / beta / 1e9,
            "ping": {str(k): v for k, v in ping.items()}}


if __name__ == "__main__":
    print(json.dumps(main()))
