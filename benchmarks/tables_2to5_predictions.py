"""Paper Tables II-V: prediction tables from the Hopper-fitted models,
validated against the published values + the qualitative claims
(ranking / 2.5D-overlap crossover), and the TPU-v5e adaptation tables."""

import json


def main() -> dict:
    import numpy as np
    from repro.core import AlgoContext, CommModel, ComputeModel, TPU_V5E
    from repro.core.algorithms import ALGOS, USEFUL_FLOPS, VARIANTS
    from repro.core.calibration import (hopper_fitted_ctx,
                                        joint_validation_report)
    from repro.sim import derive_calibration, v5e_pod_topology
    from repro.core.machine import HOPPER
    from repro.core.paper_data import (CLAIMED_CROSSOVER, CORE_COUNTS,
                                       PAPER_TABLES, table_best_variant)
    from repro.core.perfmodel import TPU_EFFICIENCY
    from repro.core.predictor import best_variant, crossover_core_count, \
        prediction_table

    ctx = hopper_fitted_ctx()
    out = {"hopper": {}, "validation": {}, "claims": {}, "tpu_v5e": {}}

    # --- reproduce the tables ----------------------------------------------
    for algo in ALGOS:
        sizes = list(PAPER_TABLES[algo].keys())
        tbl = prediction_table(ctx, algo, sizes, CORE_COUNTS)
        out["hopper"][algo] = {
            str(n): {str(c): {v: round(p, 2) for v, p in row.items()}
                     for c, row in by.items()}
            for n, by in tbl.items()}

    # --- held-out accuracy ---------------------------------------------------
    out["validation"] = joint_validation_report(ctx)

    # --- qualitative claims ---------------------------------------------------
    # (1) ranking: does our best variant match the table's best per cell?
    match, total = 0, 0
    for algo in ALGOS:
        for size in PAPER_TABLES[algo]:
            for cores in CORE_COUNTS:
                p = cores // HOPPER.threads_per_unit
                ours = best_variant(ctx, algo, size, p)
                our_best = max(ours, key=lambda v: -ours[v].result.total)
                our_best = min(ours, key=lambda v: ours[v].result.total)
                total += 1
                match += (our_best == table_best_variant(algo, size, cores))
    out["claims"]["best_variant_agreement"] = match / total
    # (2) crossover: 2.5D+ovlp overtakes 2D+ovlp as cores grow
    for algo in ALGOS:
        size = max(PAPER_TABLES[algo].keys())
        cx = crossover_core_count(ctx, algo, size, CORE_COUNTS)
        out["claims"][f"crossover_{algo}"] = cx
        out["claims"][f"crossover_{algo}_expected"] = CLAIMED_CROSSOVER[algo]

    # --- TPU v5e adaptation: same methodology, v5e machine + simulator ------
    cal = derive_calibration(v5e_pod_topology(), ps=[16, 64, 256],
                             distances=[1, 2, 4, 8, 16])
    tpu_ctx = AlgoContext(CommModel(TPU_V5E, cal),
                          ComputeModel(TPU_V5E, TPU_EFFICIENCY))
    for algo in ALGOS:
        tbl = prediction_table(tpu_ctx, algo, [65536, 131072], [64, 256, 1024])
        out["tpu_v5e"][algo] = {
            str(n): {str(c): {v: round(p, 2) for v, p in row.items()}
                     for c, row in by.items()}
            for n, by in tbl.items()}
    return out


if __name__ == "__main__":
    print(json.dumps(main()))
