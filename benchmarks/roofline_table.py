"""§Roofline: aggregate the dry-run artifacts into the per-(arch x shape x
mesh) three-term table + dominant-bottleneck identification."""

import glob
import json
import os

from .common import ART


def load_cells(mesh: str = "pod", base: str = None):
    base = base or os.path.join(ART, "dryrun")
    cells = []
    for f in sorted(glob.glob(os.path.join(base, mesh, "*.json"))):
        cells.append(json.load(open(f)))
    return cells


def markdown_table(cells) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful | roofline frac | GB/dev | fits |")
    sep = "|" + "---|" * 11
    lines = [hdr, sep]
    for c in cells:
        ma = c.get("memory_analysis", {})
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_term']:.3g} | "
            f"{c['memory_term']:.3g} | {c['collective_term']:.3g} | "
            f"{c['dominant']} | {c['model_flops']:.3g} | "
            f"{c['useful_flops_fraction']:.2f} | "
            f"{c['roofline_fraction']:.3f} | "
            f"{ma.get('total_bytes', 0)/1e9:.1f} | "
            f"{'y' if c.get('fits_hbm') else 'n'} |")
    return "\n".join(lines)


def main() -> dict:
    out = {}
    for mesh in ("pod", "multipod"):
        cells = load_cells(mesh)
        if not cells:
            continue
        out[mesh] = {
            "n_cells": len(cells),
            "dominant_counts": {},
            "worst_fraction": None,
            "most_collective_bound": None,
        }
        for c in cells:
            d = c["dominant"]
            out[mesh]["dominant_counts"][d] = \
                out[mesh]["dominant_counts"].get(d, 0) + 1
        trains = [c for c in cells if c["kind"] == "train"]
        if trains:
            worst = min(trains, key=lambda c: c["roofline_fraction"])
            out[mesh]["worst_fraction"] = (
                f"{worst['arch']}@{worst['shape']}",
                worst["roofline_fraction"])
            collb = max(trains, key=lambda c: c["collective_term"]
                        / max(c["compute_term"], 1e-12))
            out[mesh]["most_collective_bound"] = (
                f"{collb['arch']}@{collb['shape']}",
                collb["collective_term"] / max(collb["compute_term"], 1e-12))
    return out


if __name__ == "__main__":
    print(json.dumps(main()))
    print(markdown_table(load_cells("pod")))
