"""Paper Fig. 1 analog: local-routine efficiency vs block size on this
host, plus the fitted EfficiencyCurve parameters used by the CPU-host
performance model."""

import json
import sys


def main() -> dict:
    from repro.core.calibration import bench_routines, fit_efficiency
    sizes = (128, 256, 512, 1024)
    bench = bench_routines(sizes)
    peak = max(bench["dgemm"].values())
    out = {"peak_gflops": peak / 1e9, "routines": {}}
    for rout, vals in bench.items():
        curve = fit_efficiency(vals, peak)
        out["routines"][rout] = {
            "gflops": {str(k): v / 1e9 for k, v in vals.items()},
            "eff_max": curve.eff_max, "n0": curve.n0,
        }
    return out


if __name__ == "__main__":
    print(json.dumps(main()))
