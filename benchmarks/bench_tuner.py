"""Tuner dispatch benchmark (subprocess; 8 forced host devices).

Measures the three costs the autotuning layer introduces or removes:

* model evaluation (cold plan: enumerate grids + evaluate variants),
* plan-cache hit latency (in-memory and from-disk JSON),
* end-to-end dispatch overhead of ``linalg.matmul`` over invoking the
  pre-built executor directly,

plus the model-predicted and measured speedup of the auto-selected variant
against the worst feasible one — the paper's variant-selection payoff —
and (``model_eval`` key, also emitted as ``BENCH_model_eval.json``) the
throughput of one vectorized cost-IR pass over a >=200-scenario
``(n, p, c)`` grid versus the same grid evaluated with per-scenario
scalar calls.

Prints a single JSON object on the last stdout line.
"""

import dataclasses
import json
import sys
import tempfile
import time

import numpy as np


def _best_of(fn, reps: int = 5) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_model_eval(tuner) -> dict:
    """Vectorized-vs-scalar model-evaluation throughput on a Hopper-scale
    scenario grid (no jax involvement: pure numpy model math)."""
    reg = tuner.registry
    ctx = reg.context("hopper-cray-xe6")
    ns = np.array([4096.0, 8192.0, 16384.0, 32768.0, 65536.0, 131072.0,
                   262144.0, 524288.0])
    ps = np.array([16.0, 64.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0])
    cs = np.array([1.0, 2.0, 4.0, 8.0])
    Ng, Pg, Cg = (a.ravel() for a in np.meshgrid(ns, ps, cs, indexing="ij"))
    out = {"scenarios": int(Ng.size), "models": {}}
    for algo, variant in (("cannon", "2.5d_ovlp"), ("summa", "2.5d"),
                          ("trsm", "2.5d"), ("cholesky", "2.5d_ovlp"),
                          ("lu", "2.5d")):
        vec_s = _best_of(lambda: reg.evaluate_grid(
            ctx, algo, variant, Ng, Pg, Cg, 2.0), reps=3)
        scal_s = _best_of(lambda: [
            reg.evaluate(ctx, algo, variant, int(n), int(p), c=int(c), r=2)
            for n, p, c in zip(Ng, Pg, Cg)], reps=3)
        out["models"][f"{algo}/{variant}"] = {
            "vectorized_us": vec_s * 1e6,
            "scalar_loop_us": scal_s * 1e6,
            "speedup": scal_s / vec_s,
        }
    speedups = [m["speedup"] for m in out["models"].values()]
    out["min_speedup"] = min(speedups)
    out["geomean_speedup"] = float(np.exp(np.mean(np.log(speedups))))
    return out


def main() -> dict:
    import jax
    import jax.numpy as jnp
    from repro import linalg
    from repro.tuner import PlanCache, Tuner
    from repro.tuner import dispatch as disp

    devices = jax.devices()
    plan_dir = tempfile.mkdtemp(prefix="plans-")
    n = 256
    out = {"n": n, "devices": len(devices)}

    # --- model evaluation vs cache hit ------------------------------------
    tuner = Tuner(cache=PlanCache(plan_dir))
    out["model_eval_us"] = _best_of(
        lambda: tuner.plan("matmul", n, devices=devices, use_cache=False)) * 1e6
    plan = tuner.plan("matmul", n, devices=devices)      # populate the cache
    out["cache_hit_mem_us"] = _best_of(
        lambda: tuner.plan("matmul", n, devices=devices)) * 1e6

    cold = Tuner(cache=PlanCache(plan_dir))              # fresh process stand-in
    out["cache_hit_disk_us"] = _best_of(
        lambda: (cold.cache.clear_memory(),
                 cold.plan("matmul", n, devices=devices))) * 1e6
    assert cold.stats["model_evals"] == 0, "disk hit must skip the models"

    # --- dispatch overhead -------------------------------------------------
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    jax.block_until_ready(linalg.matmul(A, B, tuner=tuner))  # warm compile
    total = _best_of(lambda: jax.block_until_ready(
        linalg.matmul(A, B, tuner=tuner)))
    devs = disp._resolve(devices, plan.p)
    mesh = disp._mesh_for(plan.g, plan.c, devs)
    fn = disp._executor(plan, mesh, devs, interpret=True)
    from jax.sharding import PartitionSpec as P
    m = disp._round_up(n, plan.g)
    Ad = linalg.distribute(disp._pad_zero(A, m, m), mesh, P("row", "col"))
    Bd = linalg.distribute(disp._pad_zero(B, m, m), mesh, P("row", "col"))
    jax.block_until_ready(fn(Ad, Bd))
    raw = _best_of(lambda: jax.block_until_ready(fn(Ad, Bd)))
    out["exec_us"] = raw * 1e6
    out["dispatch_total_us"] = total * 1e6
    out["dispatch_overhead_us"] = max(0.0, (total - raw) * 1e6)

    # --- auto-selected vs worst feasible variant ---------------------------
    from repro.tuner.autotune import feasible_grids
    from repro.core import predictor
    ctx = tuner.registry.context(plan.machine)
    worst_plan, worst_total = None, -1.0
    for algo in ("cannon", "summa"):
        for p, c, g in feasible_grids(len(devices), algo):
            kind = "2d" if c == 1 else "2.5d"
            for variant in tuner.registry.variants(algo):
                if not variant.startswith(kind):
                    continue
                res = tuner.registry.evaluate(ctx, algo, variant, n, p, c=c)
                if res.total > worst_total:
                    worst_total = res.total
                    worst_plan = dataclasses.replace(
                        plan, algo=algo, variant=variant, p=p, c=c, g=g,
                        predicted={"total": res.total, "comm": res.comm,
                                   "comp": res.comp})
    out["predicted_speedup_auto_vs_worst"] = worst_total / plan.predicted["total"]
    jax.block_until_ready(disp.execute(worst_plan, A, B, devices=devices))
    worst_meas = _best_of(lambda: jax.block_until_ready(
        disp.execute(worst_plan, A, B, devices=devices)))
    out["measured_speedup_auto_vs_worst"] = worst_meas / total
    out["auto"] = f"{plan.algo}/{plan.variant} p={plan.p} c={plan.c}"
    out["worst"] = f"{worst_plan.algo}/{worst_plan.variant} p={worst_plan.p} c={worst_plan.c}"

    # --- vectorized vs scalar model-evaluation throughput ------------------
    out["model_eval"] = bench_model_eval(tuner)
    return out


if __name__ == "__main__":
    print(json.dumps(main()))
    sys.stdout.flush()
