"""Observability-layer overhead and throughput, emitted as
``artifacts/bench/BENCH_obs.json``.

Five measurements, all pure CPU:

* **spans/sec** — raw tracer throughput (`span()` open/close into the
  ring buffer);
* **dispatch overhead** — the same model-guided matmul dispatch loop
  timed with tracing off and tracing on; CI gates the enabled-path
  overhead at <= 5% (min-of-batches on both sides, so scheduler noise
  cancels);
* **exporter** — wall milliseconds to render a 10k-span buffer to the
  paired Chrome/Perfetto JSON (saved under ``artifacts/traces/``);
* **serving trace** — a cost-model trace replay exported through
  ``obs.serving_trace``; CI checks the paired predicted/measured flow
  events are present;
* **watch** — streaming-detector throughput on the incremental path
  (CI gates >= 100k obs/s) and observatory-dashboard render time for a
  10k-span session (CI gates < 1 s).
"""

import json
import os
import shutil
import tempfile
import time


def _batch_seconds(fn, calls: int = 8) -> float:
    t0 = time.perf_counter()
    for _ in range(calls):
        fn()
    return (time.perf_counter() - t0) / calls


def main() -> dict:
    import numpy as np

    from repro import obs, telemetry

    out = {}

    # --- (A) tracer throughput -------------------------------------------
    tr = obs.Tracer(capacity=16384)
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tr.span("work", cat="dispatch"):
            pass
    dt = time.perf_counter() - t0
    out["spans_per_sec"] = n / dt
    out["span_us_per_call"] = dt / n * 1e6

    # --- (B) dispatch-loop overhead, tracing off vs on --------------------
    from repro.tuner import PlanCache, Tuner, build_default_registry
    from repro.tuner import dispatch

    tmp = tempfile.mkdtemp(prefix="bench_obs_")
    try:
        tuner = Tuner(registry=build_default_registry(),
                      cache=PlanCache(os.path.join(tmp, "plans")))
        rng = np.random.default_rng(0)
        a = np.asarray(rng.standard_normal((320, 320)), dtype=np.float32)
        import jax

        dispatch.matmul(a, a, tuner=tuner)       # warm: compile + plan

        # block on both sides: the traced path blocks inside the execute
        # phase (so the span covers real work), and an unblocked baseline
        # would make the comparison async-vs-sync instead of off-vs-on
        def call():
            jax.block_until_ready(dispatch.matmul(a, a, tuner=tuner))

        telemetry.disable()
        # warm both modes (first traced call builds the tracer and the
        # PhaseTimer path), then interleave off/on batches so clock and
        # scheduler drift hit both sides equally; min-of-batches each
        obs.disable()
        call()
        obs.enable(capacity=16384)
        call()
        base_s = traced_s = float("inf")
        for _ in range(16):
            obs.disable()
            base_s = min(base_s, _batch_seconds(call))
            obs.enable()
            traced_s = min(traced_s, _batch_seconds(call))
        n_spans_per_call = 4                      # plan + root + 2 phases
        out["dispatch_base_us"] = base_s * 1e6
        out["dispatch_traced_us"] = traced_s * 1e6
        out["enabled_overhead_pct"] = max(0.0, traced_s / base_s - 1.0) * 100
        out["enabled_overhead_us_per_span"] = (
            max(0.0, traced_s - base_s) / n_spans_per_call * 1e6)
    finally:
        obs.reset()
        telemetry.reset()
        shutil.rmtree(tmp, ignore_errors=True)

    # --- (C) exporter time on a 10k-span trace ----------------------------
    big = obs.Tracer(capacity=16384)
    for i in range(10_000):
        big.complete(f"op{i % 7}", 1e-4, cat="dispatch",
                     predicted_s=(9e-5 if i % 2 else None),
                     args={"n": i})
    spans = big.spans()
    t0 = time.perf_counter()
    doc = obs.export_spans(spans)
    payload = json.dumps(doc)
    out["export_10k_span_ms"] = (time.perf_counter() - t0) * 1e3
    out["export_events"] = len(doc["traceEvents"])
    os.makedirs(os.path.join("artifacts", "traces"), exist_ok=True)
    with open(os.path.join("artifacts", "traces",
                           "obs_bench_trace.json"), "w") as f:
        f.write(payload)

    # --- (D) serving replay -> paired trace -------------------------------
    from repro.configs import get
    from repro.core.machine import CPU_HOST
    from repro.serving.cost import cost_model_for
    from repro.serving.trace import TraceConfig, replay_traced, \
        synthesize_trace

    cfg = get("qwen1.5-4b").reduced()
    cost = cost_model_for(cfg, CPU_HOST)
    trace = synthesize_trace(TraceConfig(n_requests=300, seed=3))
    t0 = time.perf_counter()
    rep, reports, reg = replay_traced(trace, cost, policy="model")
    out["replay_wall_s"] = time.perf_counter() - t0
    out["replay_steps"] = rep.steps
    out["replay_goodput_rps"] = rep.goodput_rps
    doc = obs.serving_trace(reports, other_data=rep.to_dict())
    flows = sum(1 for ev in doc["traceEvents"] if ev.get("ph") == "s")
    out["serving_trace_events"] = len(doc["traceEvents"])
    out["serving_trace_flow_events"] = flows
    with open(os.path.join("artifacts", "traces",
                           "serving_paired_trace.json"), "w") as f:
        json.dump(doc, f)

    # --- (E) watch: detector throughput + dashboard render ----------------
    from repro.obs import watch

    watcher = watch.StreamWatcher(emit_alerts=False)
    rng = np.random.default_rng(7)
    vals = 0.05 + 0.01 * rng.standard_normal(100_000)
    sw = watcher.series("rel_err/op/dgemm", tier="op")
    fires = 0
    t0 = time.perf_counter()
    observe = sw.observe
    for v in vals:
        fires += len(observe(v))
    dt = time.perf_counter() - t0
    out["watch_obs_per_sec"] = len(vals) / dt
    out["watch_obs_us"] = dt / len(vals) * 1e6
    out["watch_firings_in_control"] = fires
    out["watch_outlier_fires"] = len(sw.observe(10.0))

    # dashboard render over the (C) 10k-span session + a synthetic
    # SLO/history payload — the gate is < 1 s wall
    slo = watch.SLOWatcher()
    for i in range(2000):
        slo.record_outcomes(float(i), ttft=(i % 17 != 0),
                            tpot=True, goodput=(i % 17 != 0))
        slo.check(float(i))
    hist_runs = [watch.BenchRun("BENCH_obs", f"c{i}", "bench", float(i),
                                {"spans_per_sec": 5e5 * (1 + 0.01 * i)})
                 for i in range(12)]
    t0 = time.perf_counter()
    data = watch.collect_data(
        summary=obs.summary(spans=spans), accuracy=None,
        watch=watcher, slo=slo, history=hist_runs)
    html = watch.render_dashboard(data)
    out["dashboard_render_s"] = time.perf_counter() - t0
    out["dashboard_bytes"] = len(html)
    os.makedirs(os.path.join("artifacts", "obs"), exist_ok=True)
    with open(os.path.join("artifacts", "obs",
                           "dashboard_bench.html"), "w") as f:
        f.write(html)
    return out


if __name__ == "__main__":
    print(json.dumps(main(), indent=1))
