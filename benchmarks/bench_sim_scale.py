"""Paper-scale simulator benchmark: the folded sparse engine vs the PR-3
reference event loop (pure numpy; no jax devices needed).

Emitted as ``artifacts/bench/BENCH_sim_scale.json``:

* ``events_per_sec_p256`` — the SUMMA 2D replay on a warm 16x16 torus
  (the BENCH_sim workload), CI-gated at >= 10x the PR-3 baseline
  throughput recorded before this engine landed.  Two caveats make this
  a trajectory number, not a pure engine-speed ratio: the vector engine
  counts two logical endpoints per message (including messages simulated
  by a folded representative) where the PR-3 contended loop counted one
  per event-loop iteration (~4x fewer on this replay), and the PR-3
  number included its own cold-route-construction warm-up bug;
* ``speedup_vs_reference_p256`` — wall-clock of the identical warm replay
  through ``engine="reference"`` divided by the folded engine's wall:
  the honest same-machine, same-workload engine comparison (gated >= 1,
  so an engine-speed regression cannot hide behind the event counter);
* ``wall_p4096_s`` / ``wall_p24576_s`` — SUMMA 2.5D at the paper's
  validation scales: 4096 ranks on a 16^3 torus and 24,576 ranks on a
  (24, 32, 32) torus (exactly one rank per node, the shape symmetry
  folding wants).  The 24,576 cold wall — route construction, symmetry
  detection and simulation from scratch — is the paper-scale acceptance
  gate (< 30 s CPU);
* ``max_rel_err_vs_reference`` — the folded engine (and its ``fold=False``
  sparse fallback) against the reference engine across every registered
  program on both a torus and a crossbar, gated at 1e-6 relative.
"""

import json
import time

#: events/sec of the PR-3 engine on the p=256 SUMMA replay as recorded by
#: its own BENCH_sim.json (cold-construction bug and all) — the baseline
#: the >= 10x throughput gate multiplies.
PR3_BASELINE_EVENTS_PER_SEC = 360_000.0


def main() -> dict:
    from repro.perf import PROGRAMS
    from repro.sim import Crossbar, Torus, simulate_program
    from repro.tuner import DEFAULT_REGISTRY

    ctx = DEFAULT_REGISTRY.context("hopper-cray-xe6")

    # --- p=256 throughput: folded engine vs reference on warm caches -------
    prog2d = PROGRAMS[("summa", "2d")]
    n256, p256 = 65536.0, 256

    def timed(topology, repeats: int = 5, **kw):
        """Best-of-N timing: the replay is ~ms-scale, so a single run is
        at the mercy of scheduler noise on shared CI runners."""
        simulate_program(prog2d, ctx, topology, n256, p256, **kw)  # warm
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            res = simulate_program(prog2d, ctx, topology, n256, p256, **kw)
            best = min(best, time.perf_counter() - t0)
        return res, best

    res_v, wall_v = timed(Torus((16, 16)))
    res_r, wall_r = timed(Torus((16, 16)), engine="reference")

    # --- paper scale: SUMMA 2.5D at 4096 and 24,576 ranks ------------------
    prog25d = PROGRAMS[("summa", "2.5d")]
    t0 = time.perf_counter()
    res_4k = simulate_program(prog25d, ctx, Torus((16, 16, 16)),
                              262144.0, 4096, 4)
    wall_4k = time.perf_counter() - t0
    topo_24k = Torus((24, 32, 32))  # 24,576 nodes: one per rank
    t0 = time.perf_counter()
    res_24k = simulate_program(prog25d, ctx, topo_24k, 786432.0, 24576, 6)
    wall_24k = time.perf_counter() - t0
    t0 = time.perf_counter()
    simulate_program(prog25d, ctx, topo_24k, 786432.0, 24576, 6)
    wall_24k_warm = time.perf_counter() - t0

    # --- agreement: folded + unfolded engines vs the PR-3 reference --------
    max_rel = 0.0
    per_program = {}
    for (algo, variant), program in sorted(PROGRAMS.items()):
        c = 2 if program.uses_c else 1
        r = 2 if program.uses_r else 1
        worst = 0.0
        for topo in (Torus((4, 4)), Crossbar(16)):
            ref = simulate_program(program, ctx, topo, 8192.0, 16, c, r,
                                   engine="reference")
            for kw in ({}, {"fold": False}):
                got = simulate_program(program, ctx, topo, 8192.0, 16, c, r,
                                       **kw)
                worst = max(worst, abs(got.total - ref.total) / ref.total)
        max_rel = max(max_rel, worst)
        per_program[f"{algo}/{variant}"] = worst
    # the flagship workload at pod scale too
    rel256 = abs(res_v.total - res_r.total) / res_r.total
    max_rel = max(max_rel, rel256)

    return {
        "p256": {
            "program": "summa/2d", "topology": "Torus(16, 16)",
            "n": n256, "p": p256,
            "wall_vector_s": wall_v, "wall_reference_s": wall_r,
            "events": int(res_v.events),
        },
        "events_per_sec_p256": res_v.events / wall_v,
        "pr3_baseline_events_per_sec": PR3_BASELINE_EVENTS_PER_SEC,
        "throughput_vs_pr3_baseline":
            (res_v.events / wall_v) / PR3_BASELINE_EVENTS_PER_SEC,
        "events_metric_note":
            "events = 2 logical endpoints per message (incl. folded / "
            "fast-forwarded); the PR-3 baseline counted event-loop "
            "iterations and charged cold route construction — "
            "speedup_vs_reference_p256 is the engine-speed comparison",
        "speedup_vs_reference_p256": wall_r / wall_v,
        "wall_p4096_s": wall_4k,
        "sim_total_p4096_s": float(res_4k.total),
        "wall_p24576_s": wall_24k,
        "wall_p24576_warm_s": wall_24k_warm,
        "sim_total_p24576_s": float(res_24k.total),
        "events_p24576": int(res_24k.events),
        "events_per_sec_p24576": res_24k.events / wall_24k,
        "max_rel_err_vs_reference": max_rel,
        "agreement_vs_reference": per_program,
    }


if __name__ == "__main__":
    print(json.dumps(main()))
