"""Telemetry-subsystem throughput (pure CPU; no jax devices needed).

The feedback loop has to keep up with a serving system that dispatches
thousands of operations per second, so each stage is measured on a
synthetic-but-realistic workload and emitted as
``artifacts/bench/BENCH_telemetry.json``:

* record  — ``RunStore.append`` throughput (runs/sec, fsync-free JSONL);
* load    — full-store parse throughput (runs/sec);
* join    — residual rows/sec joining measured runs against the model's
  per-phase predictions (the per-scenario eval cache is what makes many
  repeated scenarios cheap);
* refit   — wall seconds of one online recalibration over the joined rows;
* compact — runs/sec rewriting the store with a per-scenario history cap.
"""

import shutil
import tempfile
import time


def main() -> dict:
    import numpy as np

    from repro import telemetry
    from repro.tuner import build_default_registry

    registry = build_default_registry()
    ctx = registry.machine("cpu-host").context()

    # --- synthesize a realistic store: 32 scenarios x 64 repeats -----------
    scenarios = []
    rng = np.random.default_rng(0)
    for algo, variant in (("summa", "2d"), ("cannon", "2d"),
                          ("summa", "2.5d"), ("trsm", "2d")):
        for n in (1024, 4096, 16384, 65536):
            for p in (16, 64):
                c = 4 if variant == "2.5d" else 1
                res = registry.evaluate_grid(ctx, algo, variant, float(n),
                                             float(p), float(c), 1.0)
                scenarios.append((algo, variant, n, p, c, float(res.total)))
    reps = 64
    records = []
    for i in range(reps):
        for algo, variant, n, p, c, total in scenarios:
            noise = float(np.exp(rng.normal(np.log(2.0), 0.2)))
            records.append(telemetry.RunRecord(
                fingerprint="bench-fp", machine="cpu-host", op=algo,
                variant=variant, n=n, p=p, c=c,
                phases={"execute": total * noise},
                timestamp=1000.0 + i))
    n_runs = len(records)

    tmp = tempfile.mkdtemp(prefix="bench_telemetry_")
    try:
        store = telemetry.RunStore(tmp)
        t0 = time.perf_counter()
        store.extend(records)
        record_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        loaded = store.load()
        load_s = time.perf_counter() - t0
        assert len(loaded) == n_runs

        t0 = time.perf_counter()
        rows = telemetry.join(loaded, registry)
        join_s = time.perf_counter() - t0
        assert len(rows) == n_runs

        t0 = time.perf_counter()
        result = telemetry.refit(rows, registry)
        refit_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        dropped = store.compact(keep_last=16)
        compact_s = time.perf_counter() - t0
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    # --- disabled-path overhead ------------------------------------------
    # The hot-path guard (enabled() + a no-op phase_scope) is what every
    # dispatch pays when recording is off; it must stay sub-microsecond.
    from repro.telemetry import phase_scope
    telemetry.disable()
    try:
        n_calls = 200_000
        t0 = time.perf_counter()
        for _ in range(n_calls):
            if telemetry.enabled():
                pass
            with phase_scope(None, "execute"):
                pass
        disabled_us = (time.perf_counter() - t0) / n_calls * 1e6
    finally:
        telemetry.reset()
    assert disabled_us < 1.0, (
        f"disabled telemetry path costs {disabled_us:.3f}us/call (>= 1us)")

    return {
        "disabled_path_us_per_call": disabled_us,
        "runs": n_runs,
        "scenarios": len(scenarios),
        "record_runs_per_sec": n_runs / record_s,
        "load_runs_per_sec": n_runs / load_s,
        "join_rows_per_sec": len(rows) / join_s,
        "refit_seconds": refit_s,
        "refit_speed_scale": result.speed_scale,
        "compact_runs_per_sec": n_runs / compact_s,
        "compact_dropped": dropped,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(main(), indent=1))
