"""Serving scheduler benchmark — the paper's predict/measure/refit loop
applied to continuous batching, emitted as
``artifacts/bench/BENCH_serving.json``.

Phase A (calibrate): run real scheduler steps over a tiny model with
telemetry recording on, so every step carries (predicted, measured)
prefill/decode phases; ``refit_serving`` fits the per-phase scales and
the post-refit mean relative error on serving steps is the accuracy
gate (CI requires <= 0.35, the paper-style "model matches machine" bar).

Phase B (replay): a >= 10k-request skewed synthetic trace replayed on
the simulated clock under FIFO and under the model-guided policy —
same trace, same (calibrated) cost model, same SLOs.  CI gates on the
model-guided policy achieving >= FIFO goodput and strictly better p95
TTFT.

Phase C (re-key): a drift-style machine revision bump must retire the
calibrated serving cost table exactly like it retires tuner plans.
"""

import dataclasses
import shutil
import tempfile
import time


def _calibrate(n_requests: int = 16) -> dict:
    """Phase A: measured serve steps -> telemetry -> refit_serving."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import telemetry
    from repro.configs import get
    from repro.core.machine import CPU_HOST
    from repro.models import build_model
    from repro.serving.cost import cost_model_for, refit_serving
    from repro.serving.policy import ModelGuidedPolicy
    from repro.serving.scheduler import (ModelBackend, Request, Scheduler,
                                         SchedulerConfig)
    from repro.telemetry import residuals

    cfg = get("qwen1.5-4b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cost = cost_model_for(cfg, CPU_HOST)
    rng = np.random.default_rng(0)

    def workload(tag: str) -> Scheduler:
        backend = ModelBackend(model, params, max_cache_len=128)
        sched = Scheduler(backend, cost,
                          SchedulerConfig(max_cache_len=128, max_batch=8),
                          policy=ModelGuidedPolicy(step_budget_s=0.05))
        r = np.random.default_rng(1)
        # long-ish decodes keep the decode batch shape stable between
        # steps, so measured step times are dominated by real work, not
        # by batch-churn noise — exactly the rows the affine refit wants
        for i in range(n_requests):
            plen = int(r.integers(8, 48))
            sched.submit(Request(
                rid=f"{tag}{i}",
                prompt=jnp.asarray(r.integers(1, cfg.vocab_size, (1, plen)),
                                   jnp.int32),
                max_new_tokens=int(r.integers(16, 33)),
                arrival_s=0.002 * i))
        return sched

    workload("warm").run()          # compile every step shape off the record

    tmp = tempfile.mkdtemp(prefix="bench_serving_")
    try:
        telemetry.enable(telemetry.RunStore(tmp))
        t0 = time.perf_counter()
        sched = workload("c")
        reports = sched.run()
        wall = time.perf_counter() - t0
        records = [r for r in telemetry.default_store().load()
                   if r.kind == "serve_step"]
    finally:
        telemetry.reset()
        shutil.rmtree(tmp, ignore_errors=True)

    refit = refit_serving(records, cost)
    rows = residuals.join(records)
    del rng
    return {
        "requests": n_requests,
        "steps": len(reports),
        "wall_s": wall,
        "serve_step_records": len(records),
        "residual_rows": len(rows),
        "refit": refit.to_dict(),
        "mean_rel_err_after_refit": refit.mean_rel_err_after,
        "scales": refit.scales.to_dict(),
    }


def _replay(scales, n_requests: int = 10_000) -> dict:
    """Phase B: big-trace policy comparison on the simulated clock."""
    from repro.configs import get
    from repro.core.machine import CPU_HOST
    from repro.serving.cost import ServeCostModel
    from repro.serving.trace import TraceConfig, compare_policies, \
        synthesize_trace

    cfg = get("qwen1.5-4b").reduced()
    cost = ServeCostModel(cfg, CPU_HOST, scales)
    # arrival rate just past the calibrated capacity knee (~4 req/s on
    # cpu-host scales): the regime where composition matters — lighter
    # and FIFO is fine, heavier and nobody meets SLOs
    trace = synthesize_trace(TraceConfig(n_requests=n_requests, seed=0,
                                         arrival_rate=4.5))
    t0 = time.perf_counter()
    reps = compare_policies(trace, cost, step_budget_s=0.06)
    wall = time.perf_counter() - t0
    fifo, model = reps["fifo"], reps["model"]
    return {
        "n_requests": n_requests,
        "replay_wall_s": wall,
        "fifo": fifo.to_dict(),
        "model": model.to_dict(),
        "goodput_ratio_model_over_fifo":
            (model.goodput_rps / fifo.goodput_rps
             if fifo.goodput_rps > 0 else float("inf")),
        "ttft_p95_fifo_s": fifo.ttft_p95_s,
        "ttft_p95_model_s": model.ttft_p95_s,
        "model_beats_fifo_p95_ttft": model.ttft_p95_s < fifo.ttft_p95_s,
        "model_goodput_ge_fifo": model.goodput_rps >= fifo.goodput_rps,
    }


def _rekey() -> dict:
    """Phase C: a revision bump retires the calibrated cost table."""
    from repro.configs import get
    from repro.core.machine import CPU_HOST
    from repro.serving.cost import ServeScales, cost_model_for, install_scales

    cfg = get("qwen1.5-4b").reduced()
    install_scales(cfg, CPU_HOST, ServeScales(prefill_scale=2.0,
                                              decode_scale=2.0,
                                              overhead_s=1e-4))
    calibrated = cost_model_for(cfg, CPU_HOST).scales.prefill_scale
    bumped = dataclasses.replace(CPU_HOST, revision=CPU_HOST.revision + 1)
    fresh = cost_model_for(cfg, bumped).scales.prefill_scale
    return {
        "calibrated_scale": calibrated,
        "post_bump_scale": fresh,
        "rekey_ok": calibrated == 2.0 and fresh == 1.0,
    }


def main() -> dict:
    from repro.serving.cost import ServeScales

    cal = _calibrate()
    # the replay gate uses a *pinned* cpu-host calibration (a refit
    # output captured once) rather than this run's fitted scales, so the
    # FIFO-vs-model comparison is bit-deterministic in CI — Phase A
    # above is where live measurement noise is allowed to show up
    replay = _replay(ServeScales(prefill_scale=0.357, decode_scale=2.497,
                                 overhead_s=7.5e-4))
    rekey = _rekey()
    return {
        "calibration": cal,
        "replay": replay,
        "rekey": rekey,
        "gates": {
            "post_refit_mean_rel_err_le_035":
                cal["mean_rel_err_after_refit"] <= 0.35,
            "model_goodput_ge_fifo": replay["model_goodput_ge_fifo"],
            "model_beats_fifo_p95_ttft":
                replay["model_beats_fifo_p95_ttft"],
            "rekey_ok": rekey["rekey_ok"],
        },
    }


if __name__ == "__main__":
    import json

    print(json.dumps(main(), indent=1))
