"""Kernel-tier microbenchmark: measured tile sweep -> constants refit ->
model-guided tile choice, on the Pallas interpret path.

This is the kernel-tier analogue of the paper's portable-benchmark fitting
(and the seed source for ``Machine.kernel_constants``):

1. sweep a candidate tile grid for the matmul kernel at a fixed shape,
   timing each tile on the interpret path (the hardware this container
   actually has);
2. feed the measurements through the telemetry loop —
   ``kernel_timer`` records -> ``refit_kernels`` -> a revision-bumped
   machine whose constants reproduce the measured sweep;
3. let the refitted :class:`~repro.perf.kernel.KernelModel` shortlist
   near-optimal candidates (within ``SHORTLIST_SLACK`` of its fitted
   best, the default blocks always included as the stand-down option) and
   pick the measured-best inside the shortlist — the two-stage idiom
   ``Tuner.plan(refine="sim")`` uses one tier up.

The emitted ``tuned_over_default`` ratio (default-tile time over
chosen-tile time, >= 1.0 by construction since the default is always a
candidate) is CI-gated.  TRSM/Cholesky interpret timings ride along for
the per-family baseline table.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import cholesky, matmul, trsm
from repro.perf.kernel import KernelModel, TilePlan, heuristic_plan
from repro.telemetry import kernel_timer, refit_kernels
from repro.tuner.registry import build_default_registry

#: matmul problem edge for the sweep (big enough that tiles differ, small
#: enough that the interpreter sweep stays CI-sized)
N = 512

#: (bm, bn, bk) candidates — the square-ish corner of the model's candidate
#: grid that fits an interpret-path sweep budget
SWEEP_TILES = [
    (128, 128, 128),
    (128, 128, 512),
    (256, 256, 128),
    (256, 256, 256),
    (256, 256, 512),   # the historical default
    (512, 512, 512),
]

#: fitted-time slack for the model shortlist (stage two measures these)
SHORTLIST_SLACK = 1.25

MACHINE = "cpu-host"


def _time_call(fn, *args, repeats: int = 2) -> float:
    jax.block_until_ready(fn(*args))          # compile outside the clock
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> dict:
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal((N, N)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((N, N)), jnp.float32)
    itemsize = 4

    registry = build_default_registry()
    machine0 = registry.machine(MACHINE).machine
    model0 = KernelModel(machine0)

    # -- stage 1: measured sweep, recorded through the telemetry layer -----
    records = []
    measured: Dict[tuple, float] = {}
    for bm, bn, bk in SWEEP_TILES:
        tp = TilePlan.make("matmul", bm=bm, bn=bn, bk=bk)
        secs = _time_call(lambda x, y, t=tp: matmul(x, y, tiles=t), a, b)
        measured[(bm, bn, bk)] = secs
        pt = kernel_timer("matmul", (N, N, N), tp, dtype="float32",
                          machine=MACHINE, itemsize=itemsize,
                          predicted={"total": model0.time(
                              "matmul", (N, N, N), tp, itemsize)})
        pt.add("execute", secs)
        records.append(pt.record())

    # -- stage 2: refit the kernel constants from the recorded sweep -------
    refit = refit_kernels(records, registry, MACHINE)
    machine1 = refit.apply(registry)
    model1 = KernelModel(machine1)

    # -- stage 3: model-guided two-stage choice ----------------------------
    fitted = {t: model1.time("matmul", (N, N, N),
                             TilePlan.make("matmul", bm=t[0], bn=t[1],
                                           bk=t[2]), itemsize)
              for t in SWEEP_TILES}
    default = heuristic_plan("matmul", (N, N, N), itemsize)
    default_t = (default["bm"], default["bn"], default["bk"])
    best_fit = min(fitted.values())
    shortlist = sorted(t for t, s in fitted.items()
                       if s <= SHORTLIST_SLACK * best_fit)
    if default_t not in shortlist:
        shortlist.append(default_t)       # the stand-down option always runs
    chosen = min(shortlist, key=lambda t: measured[t])
    ratio = measured[default_t] / measured[chosen]

    rows: List[dict] = [
        {"tile": {"bm": t[0], "bn": t[1], "bk": t[2]},
         "measured_us": measured[t] * 1e6,
         "fitted_us": fitted[t] * 1e6,
         "in_shortlist": t in shortlist}
        for t in SWEEP_TILES]

    # per-family interpret baselines (the pre-existing bench table)
    u = jnp.asarray(np.triu(rng.standard_normal((N, N))) + 40 * np.eye(N),
                    jnp.float32)
    spd = jnp.asarray(np.asarray(a) @ np.asarray(a).T + N * np.eye(N),
                      jnp.float32)
    family_us = {
        "matmul": measured[default_t] * 1e6,
        "trsm": _time_call(trsm, u, a) * 1e6,
        "cholesky": _time_call(cholesky, spd) * 1e6,
    }

    kc0, kc1 = machine0.kernel_constants, machine1.kernel_constants
    return {
        "machine": MACHINE,
        "n": N,
        "itemsize": itemsize,
        "sweep": rows,
        "default_tile": {"bm": default_t[0], "bn": default_t[1],
                         "bk": default_t[2]},
        "chosen_tile": {"bm": chosen[0], "bn": chosen[1], "bk": chosen[2]},
        "shortlist_size": len(shortlist),
        "tuned_over_default": ratio,
        "refit": {
            "compute_scale": refit.compute_scale,
            "loop_scale": refit.loop_scale,
            "n_rows": refit.n_rows,
            "revision": machine1.revision,
            "overhead_factor": [kc0.overhead_factor, kc1.overhead_factor],
            "loop_overhead": [kc0.loop_overhead, kc1.loop_overhead],
        },
        "family_interpret_us": family_us,
    }


if __name__ == "__main__":
    print(json.dumps(main()))
