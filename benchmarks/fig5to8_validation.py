"""Paper Figs. 5-8 analog: model estimates vs *measured* execution of the
executable algorithms — on the only machine physically present (host CPU
devices).  This is the live end-to-end validation of the methodology:

  1. benchmark the machine (Fig. 1/2/3-4 ingredients) -> model parameters;
  2. run each algorithm variant, measure wall time;
  3. compare est_Cal vs est_NoCal (paper's punchline: the calibration
     factor is what makes estimates rank variants correctly).

Host-device caveat (documented in EXPERIMENTS.md): all p "devices" share
one physical core, so per-unit peak is measured_core_peak / p and the
"network" is shared memcpy — the methodology is what's validated, not TPU
numbers.
"""

import dataclasses
import json
import time


def _measure(fn, *args, reps=3):
    import jax
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import (AlgoContext, CommModel, CalibrationTable,
                            evaluate)
    from repro.perf import EvalOptions
    from repro.core.calibration import (bench_ping, fit_alpha_beta,
                                        measured_compute_model)
    from repro.linalg import ALGORITHMS, distribute
    from repro.linalg.grid import make_grid_mesh

    n_dev = len(jax.devices())
    g = int(np.sqrt(n_dev))  # 2D grid g x g
    p2d = g * g

    # --- 1. machine parameters (the portable benchmarks) -------------------
    comp = measured_compute_model(sizes=(128, 256, 512))
    comp = dataclasses.replace(
        comp, machine=dataclasses.replace(
            comp.machine,
            peak_flops_per_unit=comp.machine.peak_flops_per_unit / p2d,
            threads_per_unit=1))
    # include small messages so the latency intercept is identifiable
    ping = bench_ping(sizes_words=(64, 1 << 10, 1 << 14, 1 << 18, 1 << 21),
                      reps=7)
    L, beta = fit_alpha_beta(ping)
    machine = dataclasses.replace(comp.machine, latency=L, inv_bandwidth=beta)
    comp = dataclasses.replace(comp, machine=machine)

    # contention: measured factor at two distances -> small table
    from repro.core.calibration import bench_contention
    words = 1 << 19
    ideal = L + beta * words
    avg, mx = {}, {}
    for d in (1, max(2, g)):
        wall = bench_contention(p2d, d, words=words)
        avg[float(d)] = max(1.0, wall / ideal)
        mx[(float(p2d), float(d))] = max(1.0, wall / ideal)
    cal = CalibrationTable(avg=avg, mx=mx, extrapolation_degree=1)
    # One context; est_Cal vs est_NoCal are evaluation options, not
    # rebuilt calibration surfaces.
    ctx_cal = AlgoContext(CommModel(machine, cal), comp)

    # --- 2. run + 3. compare ------------------------------------------------
    # block size must be large enough that compute amortizes dispatch
    n = 512 * g
    rng = np.random.default_rng(0)
    mesh = make_grid_mesh(g, g)
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    U = jnp.asarray(np.triu(rng.standard_normal((n, n))) + 3 * np.eye(n),
                    jnp.float32)
    SPD = jnp.asarray(np.asarray(A) @ np.asarray(A).T + n * np.eye(n),
                      jnp.float32)
    Ad, Bd = distribute(A, mesh), distribute(B, mesh)
    Ud, Sd = distribute(U, mesh), distribute(SPD, mesh)

    results = {}
    for (algo, variant), fn in ALGORITHMS.items():
        if variant.startswith("2.5d"):
            continue  # 2D grid here; 2.5D measured in the multi-layer bench
        if algo in ("cannon", "summa"):
            meas = _measure(lambda: fn(Ad, Bd, mesh=mesh))
        elif algo == "trsm":
            meas = _measure(lambda: fn(Ud, Bd, mesh=mesh))
        else:
            meas = _measure(lambda: fn(Sd, mesh=mesh))
        est_c = evaluate(ctx_cal, algo, variant, n, p2d, r=1).total
        est_n = evaluate(ctx_cal, algo, variant, n, p2d, r=1,
                         options=EvalOptions("nocal")).total
        results[f"{algo}_{variant}"] = {
            "measured_s": meas, "est_cal_s": est_c, "est_nocal_s": est_n,
            "cal_rel_err": abs(est_c - meas) / meas,
            "nocal_rel_err": abs(est_n - meas) / meas,
        }

    cal_errs = [v["cal_rel_err"] for v in results.values()]
    nocal_errs = [v["nocal_rel_err"] for v in results.values()]
    return {"n": n, "p": p2d, "machine_peak_per_unit": machine.peak_flops_per_unit,
            "latency_s": L, "beta": beta,
            "measured_factors": {str(k): v for k, v in avg.items()},
            "results": results,
            "geomean_rel_err_cal": float(np.exp(np.mean(np.log(np.maximum(cal_errs, 1e-9))))),
            "geomean_rel_err_nocal": float(np.exp(np.mean(np.log(np.maximum(nocal_errs, 1e-9)))))}


if __name__ == "__main__":
    print(json.dumps(main()))
