"""Chaos benchmark: the fault & degradation loop end to end (pure
numpy/CPU; no jax devices needed).

Four measurements, emitted as ``artifacts/bench/BENCH_faults.json``:

* agreement — the faulted vector engine (degraded link + slow rank +
  dead-link reroute on a 4x4x4 torus) against the per-transfer reference
  oracle; ``max_rel_err_vs_reference`` is a CI gate (<= 1e-6);
* detect -> diagnose -> re-plan — inject a degraded link, localize it by
  shift-pattern probes, emit the degraded machine revision and re-plan:
  the gates are exact localization and the re-planned candidate strictly
  beating the stale plan when both are simulated under the fault;
* serving overload — an overloaded bounded-queue replay with deadlines
  and graceful degradation: the gates are shed > 0 and deadline
  evictions counted;
* recovery planner — the model-guided continue/checkpoint/reschedule
  decision on a synthetic straggler sweep.
"""

import time


def main() -> dict:
    import tempfile

    import numpy as np

    from repro.perf import PROGRAMS
    from repro.sim import (DeadLink, DegradedLink, FaultSpec, Network,
                           SlowRank, Torus, simulate_program,
                           simulate_programs, topology_for, torus_link)
    from repro.telemetry import emit_degraded_profile, probe_links
    from repro.tuner import Tuner
    from repro.tuner.registry import build_default_registry
    from repro.training import RecoveryPlanner

    # --- agreement: faulted vector engine vs reference oracle --------------
    reg = build_default_registry()
    ctx = reg.context("hopper-cray-xe6")
    topo = Torus((4, 4, 4))
    fs = FaultSpec(
        degraded_links=(DegradedLink(torus_link(topo, 8, 2, +1), 6.0),),
        slow_ranks=(SlowRank(11, 2.5),),
        dead_links=(DeadLink(torus_link(topo, 5, 0, +1)),))
    max_rel = 0.0
    agreement = {}
    t0 = time.perf_counter()
    for algo, variant in (("lu", "2d"), ("cannon", "2d"), ("summa", "2d")):
        prog = PROGRAMS[(algo, variant)]
        kw = dict(n=4096.0, p=64, c=1, faults=fs)
        vec = simulate_program(prog, ctx, topo, **kw)
        ref = simulate_program(prog, ctx, topo, engine="reference", **kw)
        rel = abs(vec.total - ref.total) / ref.total
        agreement[f"{algo}/{variant}"] = rel
        max_rel = max(max_rel, rel)
    agreement_wall = time.perf_counter() - t0

    # --- detect -> diagnose -> re-plan -------------------------------------
    surf = reg.machine("hopper-cray-xe6")
    topo64 = topology_for(surf.machine, 64)
    link = torus_link(topo64, 8, 2, +1)
    inject = FaultSpec(degraded_links=(DegradedLink(link, 8.0),))
    measured = Network(topo64, surf.machine.latency,
                       surf.machine.inv_bandwidth, faults=inject)
    t0 = time.perf_counter()
    diag = probe_links(measured)
    probe_wall = time.perf_counter() - t0

    with tempfile.TemporaryDirectory() as td:
        tuner = Tuner(registry=reg, plan_dir=td)
        kw = dict(device_count=64, platform="cpu", machine="hopper-cray-xe6")
        healthy = tuner.plan("matmul", 8192, refine="sim", **kw)
        emit_degraded_profile(reg, "hopper-cray-xe6", diag.to_fault_spec(),
                              diagnosis=diag)
        t0 = time.perf_counter()
        degraded = tuner.plan("matmul", 8192, **kw)
        replan_wall = time.perf_counter() - t0
        surf2 = reg.machine("hopper-cray-xe6")
        totals = {}
        for name, pl in (("stale", healthy), ("replan", degraded)):
            sim = simulate_programs(
                reg.program(pl.algo, pl.variant), surf2.context(),
                [{"n": 8192.0, "p": pl.p, "c": pl.c, "r": 1}],
                topology=topology_for(surf2.machine, 64),
                faults=diag.to_fault_spec())[0]
            totals[name] = float(sim.total)

    replan = {
        "injected_link": int(link),
        "localized_link": int(diag.component),
        "localized_correct": bool(diag.component == link),
        "injected_scale": 8.0,
        "estimated_severity": float(diag.severity),
        "probe_wall_s": probe_wall,
        "healthy_plan": f"{healthy.algo}/{healthy.variant}/c{healthy.c}",
        "degraded_plan": f"{degraded.algo}/{degraded.variant}/c{degraded.c}",
        "plan_flipped": bool((healthy.algo, healthy.variant, healthy.c)
                             != (degraded.algo, degraded.variant,
                                 degraded.c)),
        "replan_wall_s": replan_wall,
        "stale_under_fault_s": totals["stale"],
        "replan_under_fault_s": totals["replan"],
        "makespan_improvement": totals["stale"] / totals["replan"],
    }

    # --- serving overload: shed + deadlines + degradation ------------------
    import dataclasses

    from repro.configs import get
    from repro.core.machine import CPU_HOST
    from repro.serving import (SchedulerConfig, TraceConfig, cost_model_for,
                               replay_traced, synthesize_trace)

    cost = cost_model_for(get("qwen1.5-4b").reduced(), CPU_HOST)
    trace = synthesize_trace(TraceConfig(n_requests=400, arrival_rate=200.0,
                                         seed=3))
    trace = [dataclasses.replace(r, deadline_s=2.0) for r in trace]
    t0 = time.perf_counter()
    rep, _, _ = replay_traced(trace, cost, policy="model",
                              scheduler_cfg=SchedulerConfig(max_queue=16),
                              degrade=True)
    serve_wall = time.perf_counter() - t0
    serving = {
        "n_requests": len(trace),
        "n_finished": rep.n_finished,
        "n_shed": rep.n_shed,
        "n_deadline_missed": rep.n_deadline_missed,
        "makespan_s": rep.makespan_s,
        "goodput_rps": rep.goodput_rps,
        "replay_wall_s": serve_wall,
    }

    # --- recovery planner decision sweep -----------------------------------
    planner = RecoveryPlanner(1.0, restart_overhead_s=20.0, checkpoint_s=2.0)
    decisions = {}
    for ratio in (1.2, 2.0, 4.0):
        for remaining in (5, 50, 500):
            d = planner.decide(ratio, remaining)
            decisions[f"ratio{ratio}_rem{remaining}"] = d.action

    return {
        "agreement": {
            "max_rel_err_vs_reference": max_rel,
            "per_program": agreement,
            "wall_s": agreement_wall,
        },
        "replan": replan,
        "serving": serving,
        "recovery_decisions": decisions,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(main(), indent=1))
