"""Discrete-event simulator benchmark (pure numpy; no jax devices needed).

Two measurements, emitted as ``artifacts/bench/BENCH_sim.json``:

* throughput — events/second replaying the SUMMA 2D program on a 16x16
  torus (256 ranks, the v5e-pod shape), plus the per-phase makespan and a
  Chrome trace dumped under ``artifacts/traces/`` for visual inspection;
* agreement — for every registered cost-IR program, the relative error of
  the contention-free (crossbar) simulation against the closed-form
  ``est_NoCal`` evaluator.  ``max_rel_err_nocal`` over the paper's 16
  (algo, variant) programs is the CI gate (<= 1e-6); LU rides along in
  ``agreement_nocal`` for completeness.
"""

import json
import time


def main() -> dict:
    import numpy as np

    from repro.perf import EvalOptions, PROGRAMS, evaluate_program
    from repro.sim import Crossbar, Torus, simulate_program
    from repro.tuner import DEFAULT_REGISTRY

    ctx = DEFAULT_REGISTRY.context("hopper-cray-xe6")

    # --- throughput: SUMMA 2D on a 16x16 torus -----------------------------
    torus = Torus((16, 16))
    prog = PROGRAMS[("summa", "2d")]
    n, p = 65536.0, 256
    # warm the route/fold caches on the SAME instance the timed run uses
    # (timing a fresh Torus would charge cold route construction to the
    # reported events/sec)
    simulate_program(prog, ctx, torus, n, p)
    t0 = time.perf_counter()
    res = simulate_program(prog, ctx, torus, n, p)
    wall = time.perf_counter() - t0
    trace_path = res.dump_chrome_trace()
    est_cal = evaluate_program(prog, ctx, n, p)
    est_nocal = evaluate_program(prog, ctx, n, p,
                                 options=EvalOptions(mode="nocal"))

    # --- agreement: contention-free sim vs est_NoCal per variant -----------
    xbar = Crossbar(16)
    agreement = {}
    max_rel_paper = 0.0
    for (algo, variant), program in sorted(PROGRAMS.items()):
        c = 2 if program.uses_c else 1
        r = 2 if program.uses_r else 1
        est = float(evaluate_program(program, ctx, 8192.0, 16, c, r,
                                     options=EvalOptions(mode="nocal")).total)
        sim = simulate_program(program, ctx, xbar, 8192.0, 16, c, r)
        rel = abs(sim.total - est) / est
        agreement[f"{algo}/{variant}"] = rel
        if algo != "lu":  # the paper's 16 golden programs gate CI
            max_rel_paper = max(max_rel_paper, rel)

    return {
        "topology": "Torus(16, 16)",
        "program": "summa/2d", "n": n, "p": p,
        "wall_s": wall,
        "events": int(res.events),
        "events_per_sec": res.events / wall,
        "sim_total_s": float(res.total),
        "est_cal_s": float(est_cal.total),
        "est_nocal_s": float(est_nocal.total),
        "sim_over_nocal": float(res.total / est_nocal.total),
        "critical_rank": res.critical_rank,
        "overlap_efficiency": res.overlap_efficiency,
        "link_utilization": res.utilization_histogram(),
        "trace": trace_path,
        "agreement_nocal": agreement,
        "max_rel_err_nocal": max_rel_paper,
    }


if __name__ == "__main__":
    print(json.dumps(main()))
