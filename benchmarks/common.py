"""Shared benchmark plumbing: CSV emission (name,us_per_call,derived),
subprocess running for benches that need multiple host devices, and the
run-metadata stamp every emitted ``BENCH_*.json`` carries (commit SHA,
timestamp, machine fingerprint, repeat count) so the bench-history
sentinel can join runs across commits and keep noise bands per-machine."""

from __future__ import annotations

import hashlib
import json
import os
import platform
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(REPO, "artifacts")

#: repeat count the emitters report in their stamp (env-overridable so a
#: CI matrix leg that runs each bench N times can say so).
DEFAULT_REPEATS = int(os.environ.get("REPRO_BENCH_REPEATS", "1"))


def git_commit() -> str:
    """Current commit SHA — CI env vars first (works in shallow/exported
    checkouts), then git, else ""."""
    for var in ("REPRO_BENCH_COMMIT", "GITHUB_SHA"):
        sha = os.environ.get(var)
        if sha:
            return sha
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=REPO,
                             capture_output=True, text=True, timeout=10)
        if out.returncode == 0:
            return out.stdout.strip()
    except OSError:
        pass
    return ""


def machine_fingerprint() -> str:
    """Short stable id of the *host* running the benches (distinct from
    the model's ``Machine.fingerprint()``, which names a calibrated
    profile).  Same host + toolchain -> same id; history noise bands are
    only computed within one id.  ``REPRO_BENCH_FINGERPRINT`` overrides
    (CI sets one per runner class)."""
    env = os.environ.get("REPRO_BENCH_FINGERPRINT")
    if env:
        return env
    blob = "|".join([
        platform.machine(), platform.system(), platform.processor(),
        str(os.cpu_count()), platform.python_version(),
    ])
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def run_meta(repeats: int = DEFAULT_REPEATS) -> dict:
    """The ``_meta`` stamp written into every bench JSON."""
    return {
        "commit": git_commit(),
        "timestamp": time.time(),
        "fingerprint": machine_fingerprint(),
        "repeats": int(repeats),
        "python": platform.python_version(),
        "platform": f"{platform.system()}-{platform.machine()}",
    }


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def run_subprocess_bench(module: str, n_devices: int = 8,
                         timeout: int = 560) -> dict:
    """Run `python -m {module}` with forced host devices; the module prints
    a single JSON object on its last stdout line."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-m", module], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
