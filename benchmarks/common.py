"""Shared benchmark plumbing: CSV emission (name,us_per_call,derived) and
subprocess running for benches that need multiple host devices."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(REPO, "artifacts")


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def run_subprocess_bench(module: str, n_devices: int = 8,
                         timeout: int = 560) -> dict:
    """Run `python -m {module}` with forced host devices; the module prints
    a single JSON object on its last stdout line."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={n_devices}").strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-m", module], env=env, cwd=REPO,
                         capture_output=True, text=True, timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
