"""Benchmark harness — one entry per paper table/figure + the TPU-side
roofline/dry-run aggregates.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only fig1 tables ...]

Multi-device benches run in subprocesses with their own
--xla_force_host_platform_device_count (the main process stays 1-device).
Results are also written to artifacts/bench/*.json.
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from benchmarks.common import (ART, emit, run_meta,  # noqa: E402
                               run_subprocess_bench)

OUT = os.path.join(ART, "bench")


def _save(name: str, obj: dict):
    os.makedirs(OUT, exist_ok=True)
    if isinstance(obj, dict):
        # run-metadata stamp: commit + timestamp + machine fingerprint +
        # repeat count — what the bench-history sentinel keys runs by
        obj.setdefault("_meta", run_meta())
    with open(os.path.join(OUT, f"{name}.json"), "w") as f:
        json.dump(obj, f, indent=1)


def bench_fig1():
    t0 = time.perf_counter()
    from benchmarks.fig1_blas_efficiency import main as fig1
    res = fig1()
    _save("fig1", res)
    emit("fig1_blas_efficiency", (time.perf_counter() - t0) * 1e6,
         f"peak={res['peak_gflops']:.1f}GF "
         f"dgemm_effmax={res['routines']['dgemm']['eff_max']:.2f}")


def bench_fig2():
    t0 = time.perf_counter()
    res = run_subprocess_bench("benchmarks.fig2_alpha_beta", n_devices=2)
    _save("fig2", res)
    emit("fig2_alpha_beta", (time.perf_counter() - t0) * 1e6,
         f"L={res['latency_s']:.2e}s bw={res['bandwidth_GBps']:.2f}GB/s")


def bench_fig34():
    t0 = time.perf_counter()
    res = run_subprocess_bench("benchmarks.fig34_calibration", n_devices=8)
    _save("fig34", res)
    m = res["measured_factor_vs_distance"]
    emit("fig34_calibration", (time.perf_counter() - t0) * 1e6,
         "measured_factors=" + ";".join(f"d{k}:{v:.2f}" for k, v in m.items()))


def bench_fig5to8():
    t0 = time.perf_counter()
    res = run_subprocess_bench("benchmarks.fig5to8_validation", n_devices=9)
    _save("fig5to8", res)
    emit("fig5to8_validation", (time.perf_counter() - t0) * 1e6,
         f"geo_err_cal={res['geomean_rel_err_cal']:.2f} "
         f"geo_err_nocal={res['geomean_rel_err_nocal']:.2f}")


def bench_tables():
    t0 = time.perf_counter()
    from benchmarks.tables_2to5_predictions import main as tables
    res = tables()
    _save("tables_2to5", res)
    cl = res["claims"]
    emit("tables_2to5_predictions", (time.perf_counter() - t0) * 1e6,
         f"best_variant_agreement={cl['best_variant_agreement']:.2f} "
         f"crossover_cannon={cl['crossover_cannon']} "
         f"crossover_trsm={cl['crossover_trsm']}")
    for algo, rep in res["validation"].items():
        emit(f"table_validation_{algo}", 0.0,
             f"heldout_rel={rep['geo_mean_rel_err']:.1%} "
             f"mean_abs={rep['mean_abs_pct_points']:.2f}pts")


def bench_roofline():
    t0 = time.perf_counter()
    from benchmarks.roofline_table import load_cells, main as roof
    res = roof()
    _save("roofline", res)
    for mesh, agg in res.items():
        emit(f"roofline_{mesh}", (time.perf_counter() - t0) * 1e6,
             f"cells={agg['n_cells']} dominant={agg['dominant_counts']} "
             f"worst={agg['worst_fraction']}")
    for c in load_cells("pod"):
        if c["kind"] == "train":
            emit(f"roofline_cell_{c['arch']}@{c['shape']}", 0.0,
                 f"compute={c['compute_term']:.3g}s "
                 f"collective={c['collective_term']:.3g}s "
                 f"frac={c['roofline_fraction']:.3f}")


def bench_lm_model():
    from repro.configs import SHAPES, get
    from repro.core.lm_model import predict_train_step
    rows = {}
    for arch in ("qwen1.5-110b", "arctic-480b", "granite-20b"):
        t0 = time.perf_counter()
        cfg = get(arch)
        est = predict_train_step(cfg, SHAPES["train_4k"],
                                 {"data": 16, "model": 16},
                                 fsdp=cfg.param_count() * 2 / 16 > 4e9)
        rows[arch] = est.to_dict()
        emit(f"lm_model_{arch}", (time.perf_counter() - t0) * 1e6,
             f"step={est.total_overlapped:.3f}s compute={est.compute_s:.3f}s "
             f"coll={est.collective_s:.3f}s")
    _save("lm_model", rows)


def bench_tuner():
    t0 = time.perf_counter()
    res = run_subprocess_bench("benchmarks.bench_tuner", n_devices=8)
    _save("tuner", res)
    emit("tuner_dispatch", (time.perf_counter() - t0) * 1e6,
         f"model_eval={res['model_eval_us']:.0f}us "
         f"cache_mem={res['cache_hit_mem_us']:.0f}us "
         f"cache_disk={res['cache_hit_disk_us']:.0f}us "
         f"overhead={res['dispatch_overhead_us']:.0f}us "
         f"pred_speedup={res['predicted_speedup_auto_vs_worst']:.2f} "
         f"auto={res['auto']}")
    me = res["model_eval"]
    _save("BENCH_model_eval", me)
    emit("model_eval_vectorized", 0.0,
         f"scenarios={me['scenarios']} "
         f"min_speedup={me['min_speedup']:.1f}x "
         f"geomean_speedup={me['geomean_speedup']:.1f}x")


def bench_sim():
    t0 = time.perf_counter()
    from benchmarks.bench_sim import main as sim
    res = sim()
    _save("BENCH_sim", res)
    emit("sim_summa_16x16_torus", (time.perf_counter() - t0) * 1e6,
         f"events={res['events']} "
         f"events_per_sec={res['events_per_sec']:.0f} "
         f"sim_over_nocal={res['sim_over_nocal']:.2f} "
         f"max_rel_err_nocal={res['max_rel_err_nocal']:.1e}")


def bench_sim_scale():
    t0 = time.perf_counter()
    from benchmarks.bench_sim_scale import main as sim_scale
    res = sim_scale()
    _save("BENCH_sim_scale", res)
    emit("sim_scale", (time.perf_counter() - t0) * 1e6,
         f"p256={res['events_per_sec_p256']:.2e}ev/s "
         f"({res['throughput_vs_pr3_baseline']:.0f}x PR-3 baseline, "
         f"{res['speedup_vs_reference_p256']:.1f}x reference) "
         f"p4096={res['wall_p4096_s']:.2f}s "
         f"p24576={res['wall_p24576_s']:.2f}s "
         f"agree={res['max_rel_err_vs_reference']:.1e}")


def bench_telemetry():
    t0 = time.perf_counter()
    from benchmarks.bench_telemetry import main as tele
    res = tele()
    _save("BENCH_telemetry", res)
    emit("telemetry_loop", (time.perf_counter() - t0) * 1e6,
         f"record={res['record_runs_per_sec']:.0f}/s "
         f"join={res['join_rows_per_sec']:.0f}/s "
         f"refit={res['refit_seconds']:.2f}s "
         f"compact={res['compact_runs_per_sec']:.0f}/s")


def bench_kernels():
    t0 = time.perf_counter()
    from benchmarks.bench_kernels import main as kern
    res = kern()
    _save("BENCH_kernels", res)
    ch, df = res["chosen_tile"], res["default_tile"]
    emit("kernels_tile_autotune", (time.perf_counter() - t0) * 1e6,
         f"tuned_over_default={res['tuned_over_default']:.2f}x "
         f"chosen={ch['bm']}x{ch['bn']}x{ch['bk']} "
         f"default={df['bm']}x{df['bn']}x{df['bk']} "
         f"shortlist={res['shortlist_size']} "
         f"refit_rev={res['refit']['revision']}")
    for name, us in res["family_interpret_us"].items():
        emit(f"kernel_{name}_interpret_n{res['n']}", us,
             "interpret-mode (CPU validation; TPU is the target)")


def bench_obs():
    t0 = time.perf_counter()
    from benchmarks.bench_obs import main as obs_bench
    res = obs_bench()
    _save("BENCH_obs", res)
    emit("obs_tracing", (time.perf_counter() - t0) * 1e6,
         f"spans={res['spans_per_sec']:.0f}/s "
         f"overhead={res['enabled_overhead_pct']:.2f}% "
         f"export10k={res['export_10k_span_ms']:.0f}ms "
         f"flow_events={res['serving_trace_flow_events']}")
    emit("obs_watch", 0.0,
         f"detector_obs={res['watch_obs_per_sec']:.0f}/s "
         f"dashboard={res['dashboard_render_s'] * 1e3:.0f}ms "
         f"outlier_fires={res['watch_outlier_fires']}")


def bench_serving():
    t0 = time.perf_counter()
    from benchmarks.bench_serving import main as serve
    res = serve()
    _save("BENCH_serving", res)
    rp, cal = res["replay"], res["calibration"]
    emit("serving_scheduler", (time.perf_counter() - t0) * 1e6,
         f"refit_err={cal['mean_rel_err_after_refit']:.2f} "
         f"goodput_ratio={rp['goodput_ratio_model_over_fifo']:.2f} "
         f"p95ttft_fifo={rp['ttft_p95_fifo_s']:.2f}s "
         f"p95ttft_model={rp['ttft_p95_model_s']:.2f}s "
         f"replayed={rp['n_requests']}")


def bench_faults():
    t0 = time.perf_counter()
    from benchmarks.bench_faults import main as faults
    res = faults()
    _save("BENCH_faults", res)
    rp, sv = res["replan"], res["serving"]
    emit("faults_chaos", (time.perf_counter() - t0) * 1e6,
         f"agree={res['agreement']['max_rel_err_vs_reference']:.1e} "
         f"localized={rp['localized_correct']} "
         f"flipped={rp['plan_flipped']} "
         f"improve={rp['makespan_improvement']:.2f}x "
         f"shed={sv['n_shed']} deadline={sv['n_deadline_missed']}")


BENCHES = {
    "fig1": bench_fig1,
    "fig2": bench_fig2,
    "fig34": bench_fig34,
    "fig5to8": bench_fig5to8,
    "tables": bench_tables,
    "roofline": bench_roofline,
    "lm_model": bench_lm_model,
    "kernels": bench_kernels,
    "tuner": bench_tuner,
    "sim": bench_sim,
    "sim_scale": bench_sim_scale,
    "telemetry": bench_telemetry,
    "serving": bench_serving,
    "obs": bench_obs,
    "faults": bench_faults,
}


def check_regressions() -> int:
    """Bench-history sentinel: verdict the freshly-written BENCH_*.json
    files against prior same-machine history, then append them to the
    history (so the *next* run sees this one).  Exit 1 only on a
    regression with sufficient history — the first runs that merely
    build the baseline are warn-only by construction."""
    from repro.obs.watch import history as hist

    h = hist.BenchHistory()          # REPRO_BENCH_HISTORY_DIR-aware
    prior = h.load()
    runs_now = h.ingest_dir(OUT)
    if not runs_now:
        print(f"check-regressions: no BENCH_*.json under {OUT} "
              "(run the benches first)")
        return 0
    current = {r.bench: r.metrics for r in runs_now}
    fp = runs_now[0].fingerprint or None
    report = hist.check_regressions(current, prior, fingerprint=fp)
    print(hist.format_report(report))
    report_path = os.path.join(OUT, "regression_report.json")
    with open(report_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"report: {report_path}  history: {h.path} "
          f"({len(prior)} prior + {len(runs_now)} new lines)")
    if not report["sufficient_history"]:
        print("check-regressions: no metric has enough same-machine "
              "history yet - warn-only")
        return 0
    return 1 if report["counts"]["regression"] else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None, choices=list(BENCHES))
    ap.add_argument("--check-regressions", action="store_true",
                    help="don't run benches; verdict artifacts/bench/"
                         "BENCH_*.json against the bench history and "
                         "append this run to it")
    args = ap.parse_args()
    if args.check_regressions:
        sys.exit(check_regressions())
    print("name,us_per_call,derived")
    failures = []
    for name, fn in BENCHES.items():
        if args.only and name not in args.only:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            emit(f"{name}_FAILED", 0.0, repr(e)[:120])
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
