"""Paper Figs. 3-4 analog: contention calibration factors.

Two sources, reported side by side:
* measured — all p host devices ppermute simultaneously at distance d;
  factor = wall / ideal (the C_max-style observation; an SPMD jit exposes
  only the slowest rank, exactly the paper's "synchronized" case);
* simulated — the torus link-load model (Hopper-like 3D torus and a v5e
  2D pod), which also supplies C_avg and extends to p we cannot host.
"""

import json


def main() -> dict:
    import jax
    from repro.core.calibration import (bench_contention, bench_ping,
                                        fit_alpha_beta, hopper_like_simulator,
                                        v5e_pod_simulator)
    n = len(jax.devices())
    ping = bench_ping(sizes_words=(1 << 18, 1 << 21))
    L, beta = fit_alpha_beta(ping)
    words = 1 << 20
    ideal = L + beta * words
    measured = {}
    for d in (1, 2, n // 2):
        wall = bench_contention(n, d, words=words)
        measured[str(d)] = wall / ideal
    sim_h = hopper_like_simulator()
    sim_v = v5e_pod_simulator()
    sim = {}
    for name, s, ps in (("hopper3d", sim_h, (64, 1024, 4096)),
                        ("v5e2d", sim_v, (16, 64, 256))):
        rows = {}
        for d in (1, 4, 16, 32):
            for p in ps:
                cavg, cmax = s.factors(p, d)
                rows[f"p{p}_d{d}"] = {"c_avg": cavg, "c_max": cmax}
        sim[name] = rows
    return {"measured_factor_vs_distance": measured,
            "ideal_s": ideal, "simulated": sim}


if __name__ == "__main__":
    print(json.dumps(main()))
