"""Paper Figs. 3-4 analog: contention calibration factors.

Two sources, reported side by side:
* measured — all p host devices ppermute simultaneously at distance d;
  factor = wall / ideal (the C_max-style observation; an SPMD jit exposes
  only the slowest rank, exactly the paper's "synchronized" case);
* simulated — the torus link-load model (Hopper-like 3D torus and a v5e
  2D pod), which also supplies C_avg and extends to p we cannot host.
"""

import json


def main() -> dict:
    import jax
    from repro.core.calibration import (bench_contention, bench_ping,
                                        fit_alpha_beta)
    from repro.sim import (hopper_like_topology, shift_factors,
                           v5e_pod_topology)
    n = len(jax.devices())
    ping = bench_ping(sizes_words=(1 << 18, 1 << 21))
    L, beta = fit_alpha_beta(ping)
    words = 1 << 20
    ideal = L + beta * words
    measured = {}
    for d in (1, 2, n // 2):
        wall = bench_contention(n, d, words=words)
        measured[str(d)] = wall / ideal
    sim = {}
    for name, topo, ps in (("hopper3d", hopper_like_topology(),
                            (64, 1024, 4096)),
                           ("v5e2d", v5e_pod_topology(), (16, 64, 256))):
        rows = {}
        for d in (1, 4, 16, 32):
            for p in ps:
                cavg, cmax = shift_factors(topo, p, d)
                rows[f"p{p}_d{d}"] = {"c_avg": cavg, "c_max": cmax}
        sim[name] = rows
    return {"measured_factor_vs_distance": measured,
            "ideal_s": ideal, "simulated": sim}


if __name__ == "__main__":
    print(json.dumps(main()))
