"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel ships three files: the pallas_call + BlockSpec kernel, ops.py
(jit'd public wrapper, interpret=True default for CPU validation), and
ref.py (pure-jnp oracle used by the allclose test sweeps).
"""

from .common import TilePlan, heuristic_plan, pad_axes, round_up
from .matmul import matmul, matmul_pallas, matmul_ref
from .trsm import trsm, trsm_diag_pallas, trsm_ref
from .cholesky import cholesky, cholesky_block_pallas, cholesky_ref
from .flash_attention import (flash_attention, flash_attention_pallas,
                              flash_attention_ref)
from .ssm_scan import ssm_scan, ssm_scan_pallas, ssm_scan_ref
