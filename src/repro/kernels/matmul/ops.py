"""jit'd public wrapper for the matmul kernel: pads arbitrary shapes to
block multiples, resolves block sizes from an explicit :class:`TilePlan`
(or the VMEM-fitting heuristic when none is given), falls back to the
oracle for tiny problems where padding would dominate."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from ..common import TilePlan, VMEM_BUDGET, heuristic_matmul_blocks, pad_axes
from .matmul import matmul_pallas
from .ref import matmul_ref

_VMEM_BUDGET = VMEM_BUDGET  # historical name, kept for callers/tests


def _pick_blocks(m: int, n: int, k: int, bytes_per_el: int,
                 vmem_budget: Optional[int] = None):
    """Heuristic block choice (start 256x256x512, shrink to fit).  The
    budget is overridable per call; the shrink loop bails at the 128 floor
    instead of spinning when even the floor blocks exceed the budget."""
    return heuristic_matmul_blocks(m, n, k, bytes_per_el,
                                   vmem_budget=vmem_budget)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "out_dtype", "tiles"))
def matmul(a: jax.Array, b: jax.Array, *, interpret: bool = True,
           out_dtype=None, tiles: Optional[TilePlan] = None) -> jax.Array:
    """C = A @ B for any (M, K) x (K, N).

    ``interpret=True`` (the default here) runs the kernel body in the Pallas
    interpreter — the CPU-validation mode; on TPU pass interpret=False.
    ``tiles`` is a matmul :class:`TilePlan` (dims bm/bn/bk); omitted, the
    historical heuristic blocks are used.
    """
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    if min(m, n, k) < 128:
        return matmul_ref(a, b, out_dtype=out_dtype)
    if tiles is not None:
        if tiles.kernel != "matmul":
            raise ValueError(f"TilePlan for {tiles.kernel!r} passed to matmul")
        bm, bn, bk = tiles["bm"], tiles["bn"], tiles["bk"]
    else:
        bm, bn, bk = _pick_blocks(m, n, k, a.dtype.itemsize)
    ap = pad_axes(a, {0: bm, 1: bk})
    bp = pad_axes(b, {0: bk, 1: bn})
    out = matmul_pallas(ap, bp, bm=bm, bn=bn, bk=bk, interpret=interpret,
                        out_dtype=out_dtype)
    return out[:m, :n]
