"""jit'd public wrapper for the matmul kernel: pads arbitrary shapes to
block multiples, picks block sizes that fit VMEM, falls back to the oracle
for tiny problems where padding would dominate."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .matmul import matmul_pallas
from .ref import matmul_ref

_VMEM_BUDGET = 96 * 1024 * 1024  # leave headroom out of ~128 MB


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_blocks(m: int, n: int, k: int, bytes_per_el: int):
    bm, bn, bk = 256, 256, 512
    while (bm * bk + bk * bn) * bytes_per_el + bm * bn * 4 > _VMEM_BUDGET:
        bk = max(128, bk // 2)
        if (bm * bk + bk * bn) * bytes_per_el + bm * bn * 4 <= _VMEM_BUDGET:
            break
        bm, bn = max(128, bm // 2), max(128, bn // 2)
    return bm, bn, bk


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def matmul(a: jax.Array, b: jax.Array, *, interpret: bool = True,
           out_dtype=None) -> jax.Array:
    """C = A @ B for any (M, K) x (K, N).

    ``interpret=True`` (the default here) runs the kernel body in the Pallas
    interpreter — the CPU-validation mode; on TPU pass interpret=False.
    """
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    if min(m, n, k) < 128:
        return matmul_ref(a, b, out_dtype=out_dtype)
    bm, bn, bk = _pick_blocks(m, n, k, a.dtype.itemsize)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    ap = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    bp = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = matmul_pallas(ap, bp, bm=bm, bn=bn, bk=bk, interpret=interpret,
                        out_dtype=out_dtype)
    return out[:m, :n]
