"""Pure-jnp oracle for the matmul kernel."""

import jax.numpy as jnp
from jax import lax


def matmul_ref(a, b, out_dtype=None):
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, precision=lax.Precision.HIGHEST,
                   preferred_element_type=jnp.float32).astype(out_dtype)
