from .matmul import matmul_pallas
from .ops import matmul
from .ref import matmul_ref
