"""MXU-tiled blocked matmul Pallas kernel (the framework's dgemm).

Tiling: grid (M/bm, N/bn, K/bk) with the contraction dimension innermost —
TPU grids execute sequentially, so a VMEM f32 scratch accumulator carries
partial sums across the K steps of one (i, j) tile; the output is written
once, on the last K step (revisiting semantics).

Block sizes default to (256, 256, 512): A-block 256x512 + B-block 512x256
bf16 = 0.5 MB and the f32 accumulator 0.25 MB comfortably fit VMEM while
keeping every matmul dimension a multiple of the 128x128 MXU tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(a: jax.Array, b: jax.Array, *,
                  bm: int = 256, bn: int = 256, bk: int = 512,
                  interpret: bool = False,
                  out_dtype=None) -> jax.Array:
    """C = A @ B; shapes (M, K) x (K, N), dimensions multiples of blocks
    (the ops.py wrapper pads arbitrary shapes)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
