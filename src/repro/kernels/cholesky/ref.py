"""Pure-jnp oracle for the Cholesky kernel."""

import jax.numpy as jnp


def cholesky_ref(a):
    return jnp.linalg.cholesky(a.astype(jnp.float32)).astype(a.dtype)
