"""Blocked Cholesky (right-looking) composed from all three linalg kernels:
diagonal factor (cholesky kernel), panel solve (trsm kernel: L_ij L_jj^T =
A_ij), trailing syrk update (matmul kernel)."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import TilePlan, tile_block
from ..matmul.ops import matmul
from ..trsm.ops import trsm
from .cholesky import cholesky_block_pallas
from .ref import cholesky_ref


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block", "tiles",
                                    "mm_tiles"))
def cholesky(a: jax.Array, *, block: int = 256, interpret: bool = True,
             tiles: Optional[TilePlan] = None,
             mm_tiles: Optional[TilePlan] = None) -> jax.Array:
    """L with L L^T = A (A SPD, (n, n)).

    ``tiles`` (a cholesky :class:`TilePlan`, dim ``block``) overrides the
    panel width (the panel trsm necessarily solves at that width);
    ``mm_tiles`` is threaded to the dgemm-shaped trailing updates.
    """
    block = tile_block(tiles, "cholesky", "block", block)
    n = a.shape[0]
    if n % block != 0 or n <= block:
        if n <= block and n >= 8:
            return cholesky_block_pallas(a, interpret=interpret)
        return cholesky_ref(a)
    nb = n // block
    acc = a
    l_cols = []
    for j in range(nb):
        jj = j * block
        ajj = jax.lax.slice(acc, (jj, jj), (jj + block, jj + block))
        ljj = cholesky_block_pallas(ajj, interpret=interpret)
        if j + 1 < nb:
            # panel: L_ij = A_ij (L_jj^T)^{-1}  =>  X U = B with U = L_jj^T
            a_panel = jax.lax.slice(acc, (jj + block, jj), (n, jj + block))
            l_panel = trsm(ljj.T, a_panel, block=block, interpret=interpret,
                           mm_tiles=mm_tiles)
            # trailing syrk: A_trail -= L_panel @ L_panel^T
            upd = matmul(l_panel, l_panel.T, interpret=interpret,
                         out_dtype=acc.dtype, tiles=mm_tiles)
            trail = jax.lax.slice(acc, (jj + block, jj + block), (n, n)) - upd
            acc = jax.lax.dynamic_update_slice(acc, trail,
                                               (jj + block, jj + block))
            col = jnp.concatenate([ljj, l_panel], axis=0)
        else:
            col = ljj
        col_full = jnp.pad(col, ((jj, 0), (0, 0)))
        l_cols.append(col_full)
    return jnp.concatenate(l_cols, axis=1)
