"""Single-block Cholesky factorization Pallas kernel.

TPU adaptation: dpotrf's scalar column recurrence has no MXU shape, so —
as with dtrsm — the kernel factors only a VMEM-resident diagonal block
(rank-1 updates on the VPU, one column per step), and ops.py blocks the
full factorization so panel solves and trailing (syrk) updates run through
the trsm/matmul kernels on the MXU.

One grid step per call (the block is the whole problem for the kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _chol_kernel(a_ref, l_ref, *, nb: int):
    a = a_ref[...].astype(jnp.float32)
    row = lax.broadcasted_iota(jnp.int32, (nb, 1), 0)

    def body(k, a):
        akk = lax.dynamic_slice(a, (k, k), (1, 1))
        d = jnp.sqrt(akk)
        col = lax.dynamic_slice(a, (0, k), (nb, 1)) / d
        col = jnp.where(row >= k, col, jnp.zeros_like(col))  # col[k] = d
        a = lax.dynamic_update_slice(a, col, (0, k))
        # trailing rank-1 update; (col*mask)[k] == 0 keeps column k intact
        colm = jnp.where(row > k, col, jnp.zeros_like(col))
        a = a - jnp.dot(colm, colm.T, preferred_element_type=jnp.float32)
        return a

    a = lax.fori_loop(0, nb, body, a)
    colj = lax.broadcasted_iota(jnp.int32, (nb, nb), 1)
    rowi = lax.broadcasted_iota(jnp.int32, (nb, nb), 0)
    l_ref[...] = jnp.where(rowi >= colj, a, jnp.zeros_like(a)).astype(l_ref.dtype)


def cholesky_block_pallas(a: jax.Array, *, interpret: bool = False) -> jax.Array:
    """L with L L^T = A for one SPD block (nb x nb, nb <= ~512)."""
    nb = a.shape[0]
    assert a.shape == (nb, nb)
    return pl.pallas_call(
        functools.partial(_chol_kernel, nb=nb),
        grid=(1,),
        in_specs=[pl.BlockSpec((nb, nb), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((nb, nb), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, nb), a.dtype),
        interpret=interpret,
    )(a)
