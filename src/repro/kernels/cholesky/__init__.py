from .cholesky import cholesky_block_pallas
from .ops import cholesky
from .ref import cholesky_ref
