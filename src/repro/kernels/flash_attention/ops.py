"""jit'd wrapper: (B, H, S, D) API, head-dim padding to 128-multiples,
sequence padding to the tile plan's block multiples, GQA folding."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from ..common import TilePlan, pad_axes, tile_block
from .flash_attention import flash_attention_pallas
from .ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "interpret", "tiles"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, interpret: bool = True,
                    tiles: Optional[TilePlan] = None) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, KV, S, D).  Returns (B, H, S, D).

    ``tiles`` is a flash_attention :class:`TilePlan` (dims bq/bkv);
    sequences are padded to its block multiples so the chosen blocks run
    as-is (without a plan, padding stops at the 128 lane tile and the
    kernel halves its default 256 blocks until they divide).
    """
    b, h, s, d = q.shape
    _, kv, skv, _ = k.shape
    scale = d ** -0.5  # scale by the *true* head dim before padding
    if s < 128 or skv < 128 or (causal and s != skv):
        # tiny shapes, or causal cross-length (decode) -> oracle path
        return flash_attention_ref(q.reshape(b * h, s, d),
                                   k.reshape(b * kv, skv, d),
                                   v.reshape(b * kv, skv, d),
                                   causal=causal, scale=scale).reshape(b, h, s, d)
    bq = tile_block(tiles, "flash_attention", "bq", 256)
    bkv = tile_block(tiles, "flash_attention", "bkv", 256)
    # pad sequences to the plan's blocks (plain 128 when no plan — the
    # kernel's divisibility halving then recovers today's behaviour)
    sq_mult = bq if tiles is not None else 128
    skv_mult = bkv if tiles is not None else 128
    qp = pad_axes(q, {2: sq_mult, 3: 128})
    kp = pad_axes(k, {2: skv_mult, 3: 128})
    vp = pad_axes(v, {2: skv_mult, 3: 128})
    sp, dp = qp.shape[2], qp.shape[3]
    skvp = kp.shape[2]
    out = flash_attention_pallas(
        qp.reshape(b * h, sp, dp), kp.reshape(b * kv, skvp, dp),
        vp.reshape(b * kv, skvp, dp), causal=causal, scale=scale,
        bq=bq, bkv=bkv, kv_len=skv, interpret=interpret)
    return out.reshape(b, h, sp, dp)[:, :, :s, :d]
