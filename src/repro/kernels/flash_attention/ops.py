"""jit'd wrapper: (B, H, S, D) API, head-dim padding to 128-multiples,
sequence padding, GQA folding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_pallas
from .ref import flash_attention_ref


def _round_up(x, m):
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, interpret: bool = True) -> jax.Array:
    """q: (B, H, S, D); k, v: (B, KV, S, D).  Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    _, kv, skv, _ = k.shape
    scale = d ** -0.5  # scale by the *true* head dim before padding
    if s < 128 or skv < 128 or (causal and s != skv):
        # tiny shapes, or causal cross-length (decode) -> oracle path
        return flash_attention_ref(q.reshape(b * h, s, d),
                                   k.reshape(b * kv, skv, d),
                                   v.reshape(b * kv, skv, d),
                                   causal=causal, scale=scale).reshape(b, h, s, d)
    dp = _round_up(d, 128)
    sp = _round_up(s, 128)
    skvp = _round_up(skv, 128)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sp - s), (0, dp - d)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skvp - skv), (0, dp - d)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skvp - skv), (0, dp - d)))
    out = flash_attention_pallas(
        qp.reshape(b * h, sp, dp), kp.reshape(b * kv, skvp, dp),
        vp.reshape(b * kv, skvp, dp), causal=causal, scale=scale,
        kv_len=skv, interpret=interpret)
    return out.reshape(b, h, sp, dp)[:, :, :s, :d]
