"""Pure-jnp oracle for flash attention (GQA, optional causal)."""

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, scale=None):
    bh, sq, d = q.shape
    bkv, skv, _ = k.shape
    group = bh // bkv
    scale = scale if scale is not None else d ** -0.5
    kf = jnp.repeat(k, group, axis=0).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=0).astype(jnp.float32)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32) * scale, kf)
    if causal:
        mask = jnp.tril(jnp.ones((sq, skv), bool), k=skv - sq)
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
