"""FlashAttention Pallas kernel (prefill path), GQA-aware.

Grid: (B*H, Sq/bq, Skv/bkv) with the KV dimension innermost; the online-
softmax statistics (running max m, running sum l) and the f32 output
accumulator live in VMEM scratch and carry across the sequential KV steps.
GQA maps query head -> kv head in the K/V index_map (bh // group), so K/V
blocks are fetched once per group from HBM.

Causal blocks entirely above the diagonal are skipped with pl.when (no MXU
work issued); the partially-masked diagonal block applies an element mask.
Stats are kept (bq, 128)-shaped — the minimum VMEM tile — with every lane
holding the row value (standard TPU flash layout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bkv: int, n_kv: int,
                  kv_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = (ik * bkv <= (iq + 1) * bq - 1) if causal else (ik * bkv < kv_len)

    @pl.when(run)
    def _attend():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bkv)
        cols = ik * bkv + lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        valid = cols < kv_len                             # mask KV padding
        if causal:
            rows = iq * bq + lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            valid = valid & (rows >= cols)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                               # (bq, 128)
        m_cur = jnp.max(s, axis=1, keepdims=True)         # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)                # broadcast
        p = jnp.exp(s - m_new[:, :1])                     # (bq, bkv)
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])     # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _finalize():
        l = l_ref[:, :1]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, scale: float | None = None,
                           bq: int = 256, bkv: int = 256,
                           kv_len: int | None = None,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BKV, Skv, D) with BH % BKV == 0.
    D and the sequence lengths must be multiples of 128 (ops.py pads);
    ``kv_len`` is the unpadded KV length (padding columns are masked)."""
    bh, sq, d = q.shape
    bkv_heads, skv, _ = k.shape
    assert bh % bkv_heads == 0
    group = bh // bkv_heads
    bq = min(bq, sq)
    bkv = min(bkv, skv)
    while sq % bq != 0 and bq > 128:
        bq //= 2
    while skv % bkv != 0 and bkv > 128:
        bkv //= 2
    assert sq % bq == 0 and skv % bkv == 0, (sq, skv, bq, bkv)
    scale = scale if scale is not None else d ** -0.5
    n_kv = skv // bkv
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bkv=bkv, n_kv=n_kv,
                               kv_len=kv_len if kv_len is not None else skv)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
