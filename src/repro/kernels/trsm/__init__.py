from .ops import trsm
from .ref import trsm_ref
from .trsm import trsm_diag_pallas
