"""Pure-jnp oracle for the TRSM kernel: X U = B."""

import jax


def trsm_ref(u, b):
    return jax.scipy.linalg.solve_triangular(
        u.T.astype(b.dtype), b.T, lower=True).T
