"""Triangular-solve Pallas kernel:  X U = B  with U upper-triangular.

TPU adaptation (DESIGN.md §3): a triangular solve's column recurrence maps
poorly onto the MXU, so the kernel only performs the *diagonal-block*
back-substitution (a ``bu x bu`` block held in VMEM, column loop on the
VPU), while the ops.py wrapper blocks the full solve so that all O(n^3)
off-diagonal work runs through the MXU matmul kernel.  This mirrors how
LibSci's dtrsm spends its flops in dgemm-shaped updates (paper Fig. 1 shows
dtrsm below dgemm efficiency for the same reason).

Grid: (M/bm,) row blocks of B, each solved independently against U.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _trsm_diag_kernel(u_ref, b_ref, x_ref, acc_ref, *, nb: int):
    """Back-substitution of one (bm, nb) block of B against (nb, nb) U."""
    u = u_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] = jnp.zeros_like(acc_ref)

    def body(k, _):
        # s_i = sum_{j<k} x_ij * u_jk  — column k of U is zero below the
        # diagonal and x[:, k:] is still zero, so a full matvec is exact.
        ucol = lax.dynamic_slice(u, (0, k), (nb, 1))            # (nb, 1)
        s = jnp.dot(acc_ref[...], ucol,
                    preferred_element_type=jnp.float32)         # (bm, 1)
        bcol = lax.dynamic_slice(b, (0, k), (b.shape[0], 1))
        ukk = lax.dynamic_slice(u, (k, k), (1, 1))
        xcol = (bcol - s) / ukk
        acc_ref[:, pl.ds(k, 1)] = xcol
        return 0

    lax.fori_loop(0, nb, body, 0)
    x_ref[...] = acc_ref[...].astype(x_ref.dtype)


def trsm_diag_pallas(u: jax.Array, b: jax.Array, *, bm: int = 256,
                     interpret: bool = False) -> jax.Array:
    """Solve X U = B for one diagonal block U (nb x nb, upper-triangular,
    nb <= ~512 so U fits VMEM); B is (M, nb) with M % bm == 0."""
    nb = u.shape[0]
    m = b.shape[0]
    bm = min(bm, m)
    while m % bm != 0 and bm > 8:       # largest row block dividing M
        bm //= 2
    assert u.shape == (nb, nb) and b.shape[1] == nb and m % bm == 0
    return pl.pallas_call(
        functools.partial(_trsm_diag_kernel, nb=nb),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((nb, nb), lambda i: (0, 0)),
            pl.BlockSpec((bm, nb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, nb), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        scratch_shapes=[pltpu.VMEM((bm, nb), jnp.float32)],
        interpret=interpret,
    )(u, b)
