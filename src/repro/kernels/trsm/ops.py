"""Blocked triangular solve built from the diagonal-block kernel + the MXU
matmul kernel: all O(n^3) off-diagonal work is dgemm-shaped."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..common import TilePlan, tile_block
from ..matmul.ops import matmul
from .ref import trsm_ref
from .trsm import trsm_diag_pallas


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block", "tiles",
                                    "mm_tiles"))
def trsm(u: jax.Array, b: jax.Array, *, block: int = 256,
         interpret: bool = True, tiles: Optional[TilePlan] = None,
         mm_tiles: Optional[TilePlan] = None) -> jax.Array:
    """Solve X U = B; U (n, n) upper-triangular, B (m, n).

    ``tiles`` (a trsm :class:`TilePlan`, dim ``block``) overrides the block
    size; ``mm_tiles`` is threaded to the trailing-update dgemms.
    """
    block = tile_block(tiles, "trsm", "block", block)
    n = u.shape[0]
    m = b.shape[0]
    if n % block != 0 or m % 128 != 0 or n < block:
        return trsm_ref(u, b)
    nb = n // block
    x_blocks = []
    b_cur = b
    for j in range(nb):
        ujj = jax.lax.slice(u, (j * block, j * block),
                            ((j + 1) * block, (j + 1) * block))
        bj = jax.lax.slice(b_cur, (0, j * block), (m, (j + 1) * block))
        xj = trsm_diag_pallas(ujj, bj, interpret=interpret)
        x_blocks.append(xj)
        if j + 1 < nb:
            # trailing update: B_:,k -= X_:,j @ U_j,k  for k > j (one dgemm)
            u_panel = jax.lax.slice(u, (j * block, (j + 1) * block),
                                    ((j + 1) * block, n))
            upd = matmul(xj, u_panel, interpret=interpret,
                         out_dtype=b_cur.dtype, tiles=mm_tiles)
            tail = jax.lax.slice(b_cur, (0, (j + 1) * block), (m, n)) - upd
            b_cur = jnp.concatenate(
                [jax.lax.slice(b_cur, (0, 0), (m, (j + 1) * block)), tail], axis=1)
    return jnp.concatenate(x_blocks, axis=1)
