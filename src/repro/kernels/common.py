"""Shared wrapper plumbing for the Pallas kernel families.

The per-family ``ops.py`` wrappers all did the same three things with
copy-pasted code: round dimensions up to a block multiple, ``jnp.pad``
operands out to the rounded shape (unconditionally, even when already
aligned), and hard-code the block sizes.  This module centralizes the
first two and routes the third through ``repro.perf.kernel``: a wrapper
takes an optional :class:`TilePlan` (frozen/hashable, so it rides along
as a jit-static argument) and falls back to the historical heuristic
blocks when none is given.
"""

from __future__ import annotations

from typing import Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

# the model layer is pure numpy — importing it pulls no jax machinery in
from ..perf.kernel import (MIN_TILE, TilePlan, VMEM_BUDGET,
                           heuristic_matmul_blocks, heuristic_plan)

__all__ = [
    "MIN_TILE", "TilePlan", "VMEM_BUDGET", "heuristic_matmul_blocks",
    "heuristic_plan", "pad_axes", "round_up", "tile_block",
]


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return (x + m - 1) // m * m


def pad_axes(x: jax.Array,
             multiples: Mapping[int, int]) -> jax.Array:
    """Zero-pad ``x`` so every listed axis is a multiple of its block.

    ``multiples`` maps axis index -> block size.  Returns ``x`` unchanged
    (no ``jnp.pad`` issued at all) when every axis is already aligned.
    """
    width: list = [(0, 0)] * x.ndim
    any_pad = False
    for axis, m in multiples.items():
        extent = x.shape[axis]
        pad = round_up(extent, m) - extent
        if pad:
            width[axis] = (0, pad)
            any_pad = True
    if not any_pad:
        return x
    return jnp.pad(x, width)


def tile_block(tiles: Optional[TilePlan], kernel: str, dim: str,
               default: Union[int, Tuple[int, ...]]):
    """Block size for ``dim`` out of a plan, or the caller's default.

    Raises if the plan targets a different kernel family — a swapped
    plan would otherwise silently run with nonsense blocks.
    """
    if tiles is None:
        return default
    if tiles.kernel != kernel:
        raise ValueError(f"TilePlan for {tiles.kernel!r} passed to "
                         f"{kernel!r} wrapper")
    return tiles[dim]
