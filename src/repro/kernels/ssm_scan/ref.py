"""Pure-jnp oracle: the sequential recurrence, step by step."""

import jax
import jax.numpy as jnp


def ssm_scan_ref(q, k, v, log_a):
    """q, k: (BH, S, DK); v: (BH, S, DV); log_a: (BH, S)."""
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    a = jnp.exp(log_a.astype(jnp.float32))

    def step(S, inp):
        qt, kt, vt, at = inp
        S = at * S + kt[:, None] * vt[None, :]
        return S, qt @ S

    def per_head(qh, kh, vh, ah):
        S0 = jnp.zeros((q.shape[-1], v.shape[-1]), jnp.float32)
        _, y = jax.lax.scan(step, S0, (qh, kh, vh, ah))
        return y

    y = jax.vmap(per_head)(qf, kf, vf, a)
    return y.astype(q.dtype)
