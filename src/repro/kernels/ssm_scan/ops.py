"""jit'd wrapper: (B, H, S, D) API, sequence padding (log_a padding uses 0
= no decay, k padding 0 contributes nothing), head folding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import ssm_scan_ref
from .ssm_scan import ssm_scan_pallas


def _round_up(x, m):
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssm_scan(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array, *,
             interpret: bool = True) -> jax.Array:
    """q, k: (B, H, S, DK); v: (B, H, S, DV); log_a: (B, H, S)."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if s < 128:
        return ssm_scan_ref(q.reshape(b * h, s, dk), k.reshape(b * h, s, dk),
                            v.reshape(b * h, s, dv),
                            log_a.reshape(b * h, s)).reshape(b, h, s, dv)
    sp = _round_up(s, 128)
    pad = ((0, 0), (0, 0), (0, sp - s), (0, 0))
    qp = jnp.pad(q, pad).reshape(b * h, sp, dk)
    kp = jnp.pad(k, pad).reshape(b * h, sp, dk)
    vp = jnp.pad(v, pad).reshape(b * h, sp, dv)
    lap = jnp.pad(log_a, ((0, 0), (0, 0), (0, sp - s))).reshape(b * h, sp)
    y = ssm_scan_pallas(qp, kp, vp, lap, interpret=interpret)
    return y.reshape(b, h, sp, dv)[:, :, :s, :]
