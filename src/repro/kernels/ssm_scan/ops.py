"""jit'd wrapper: (B, H, S, D) API, sequence padding (log_a padding uses 0
= no decay, k padding 0 contributes nothing), head folding."""

from __future__ import annotations

import functools
from typing import Optional

import jax

from ..common import TilePlan, pad_axes, tile_block
from .ref import ssm_scan_ref
from .ssm_scan import ssm_scan_pallas


@functools.partial(jax.jit, static_argnames=("interpret", "tiles"))
def ssm_scan(q: jax.Array, k: jax.Array, v: jax.Array, log_a: jax.Array, *,
             interpret: bool = True,
             tiles: Optional[TilePlan] = None) -> jax.Array:
    """q, k: (B, H, S, DK); v: (B, H, S, DV); log_a: (B, H, S).

    ``tiles`` is an ssm_scan :class:`TilePlan` (dim bs); the sequence is
    padded to its chunk multiple so the chosen chunk runs as-is.
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if s < 128:
        return ssm_scan_ref(q.reshape(b * h, s, dk), k.reshape(b * h, s, dk),
                            v.reshape(b * h, s, dv),
                            log_a.reshape(b * h, s)).reshape(b, h, s, dv)
    bs = tile_block(tiles, "ssm_scan", "bs", 256)
    s_mult = bs if tiles is not None else 128
    qp = pad_axes(q, {2: s_mult})
    kp = pad_axes(k, {2: s_mult})
    vp = pad_axes(v, {2: s_mult})
    lap = pad_axes(log_a, {2: s_mult})
    sp = qp.shape[2]
    y = ssm_scan_pallas(qp.reshape(b * h, sp, dk), kp.reshape(b * h, sp, dk),
                        vp.reshape(b * h, sp, dv),
                        lap.reshape(b * h, sp), bs=bs, interpret=interpret)
    return y.reshape(b, h, sp, dv)[:, :, :s, :]
