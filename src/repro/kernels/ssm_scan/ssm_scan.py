"""Chunked decayed-linear-attention scan kernel (the mLSTM / SSD core).

Computes, per head, the recurrence

    S_t = a_t * S_{t-1} + k_t v_t^T          (state: D x D)
    y_t = q_t . S_t

in chunk-parallel form (Mamba-2/SSD, mLSTM): the sequence is cut into
chunks of ``bs``; within a chunk the contribution is a (bs x bs) masked
matmul (MXU-shaped), across chunks a D x D state carried in VMEM scratch
over the sequential innermost grid dimension.

Numerical safety: all decay exponentials are of non-positive arguments —
pairwise terms use exp(A_i - A_j) (j <= i), the state decay uses
exp(A_total - A_j) — so nothing can overflow even for long chunks.

Normalization trick (used by models/xlstm.py): append a ones-column to V;
then y[..., D] accumulates the normalizer  q . n_t  with
n_t = a_t n_{t-1} + k_t, at zero extra kernel cost.

log_a must be <= 0 (forget gates in log space).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(q_ref, k_ref, v_ref, la_ref, y_ref, state_ref, *,
                bs: int, dk: int, dv: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[0].astype(jnp.float32)              # (bs, dk)
    k = k_ref[0].astype(jnp.float32)              # (bs, dk)
    v = v_ref[0].astype(jnp.float32)              # (bs, dv)
    la = la_ref[0].astype(jnp.float32)            # (bs,)
    A = jnp.cumsum(la)                            # inclusive cumsum, (bs,)
    total = A[-1]

    # inter-chunk: y_i += (q_i * exp(A_i)) . S_prev
    q_dec = q * jnp.exp(A)[:, None]
    y = jnp.dot(q_dec, state_ref[...], preferred_element_type=jnp.float32)

    # intra-chunk: s_ij = (q_i . k_j) * exp(A_i - A_j), j <= i
    rel = A[:, None] - A[None, :]                 # (bs, bs), <= 0 for j <= i
    rows = lax.broadcasted_iota(jnp.int32, (bs, bs), 0)
    cols = lax.broadcasted_iota(jnp.int32, (bs, bs), 1)
    dec = jnp.where(rows >= cols, jnp.exp(rel), 0.0)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * dec
    y = y + jnp.dot(scores, v, preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S <- exp(total) * S + sum_j exp(total - A_j) k_j v_j^T
    k_dec = k * jnp.exp(total - A)[:, None]
    state_ref[...] = state_ref[...] * jnp.exp(total) + jnp.dot(
        k_dec.T, v, preferred_element_type=jnp.float32)


def ssm_scan_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                    log_a: jax.Array, *, bs: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q, k: (BH, S, DK); v: (BH, S, DV); log_a: (BH, S) with values <= 0.
    Returns y: (BH, S, DV).  S % bs == 0 (ops.py pads)."""
    bh, s, dk = q.shape
    dv = v.shape[-1]
    bs = min(bs, s)
    while s % bs != 0 and bs > 128:
        bs //= 2
    assert s % bs == 0, (s, bs)
    kernel = functools.partial(_ssm_kernel, bs=bs, dk=dk, dv=dv)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // bs),
        in_specs=[
            pl.BlockSpec((1, bs, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, bs, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, bs, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, bs), lambda b, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, bs, dv), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_a)
