from .ops import ssm_scan
from .ref import ssm_scan_ref
from .ssm_scan import ssm_scan_pallas
