"""Distribution utilities: logical-axis sharding rules, overlap helpers."""

from .sharding import (constrain, param_spec, tree_param_specs,
                       tree_shardings, use_mesh)
