"""Logical-axis sharding rules.

Model code annotates activations with *logical* axes ("batch", "seq",
"heads", "ff", "vocab", "experts", ...); this module maps them onto the
physical mesh axes and applies with_sharding_constraint when a mesh is
active (set by the launcher / dry-run).  Without an active mesh every
constraint is a no-op, so the same model code runs single-device tests.

Parameter shardings are derived from leaf names via ``param_spec`` —
Megatron-style TP over 'model', experts over 'model' (EP), vocab over
'model'; the data/pod axes only ever shard the batch and optimizer state
(ZeRO-1, see training/optimizer.py).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()

# logical axis -> mesh axis (or tuple); None = replicated
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "batch_nopod": "data",
    "seq": None,
    "seq_shard": "data",          # long-context sequence parallelism
    "dmodel": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "capacity": None,
    "state": None,
    "frames": None,
    "kv_seq": "model",            # decode KV-cache sequence dim
    "zero": ("data",),            # axes ZeRO-shards optimizer state over
}


def mesh_axes(mesh) -> set:
    return set(mesh.shape.keys())


@contextlib.contextmanager
def use_mesh(mesh, rules: Optional[dict] = None):
    """Activate (mesh, rules) for constrain()/param_spec() below."""
    prev = getattr(_state, "ctx", None)
    rules = dict(DEFAULT_RULES, **(rules or {}))
    # drop references to axes the mesh doesn't have (single-pod: no 'pod')
    names = mesh_axes(mesh)

    def fix(v):
        if isinstance(v, tuple):
            t = tuple(a for a in v if a in names)
            return t if t else None
        return v if (v is None or v in names) else None

    _state.ctx = (mesh, {k: fix(v) for k, v in rules.items()})
    try:
        with jax.set_mesh(mesh):
            yield
    finally:
        _state.ctx = prev


def active():
    return getattr(_state, "ctx", None)


def logical_spec(*axes: Optional[str]) -> Optional[P]:
    ctx = active()
    if ctx is None:
        return None
    _, rules = ctx
    return P(*(rules.get(a) if a is not None else None for a in axes))


def constrain(x, *axes: Optional[str]):
    """with_sharding_constraint by logical axes; validated against the
    array's shape (indivisible / duplicate axes are dropped); no-op
    without an active mesh."""
    ctx = active()
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = logical_spec(*axes)
    if spec is None:
        return x
    spec = valid_spec(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter shardings by leaf path
# ---------------------------------------------------------------------------

#: (path substring, array rank) -> logical axes, scanned in order.
#: Stacked layer params have a leading layer axis (rank + 1) — handled by
#: prepending None in param_spec.
_PARAM_RULES = [
    ("embed", ("vocab", None)),
    ("lm_head", (None, "vocab")),
    ("router", (None, None)),
    # MoE expert banks (E, D, F) / (E, F, D): expert-parallel
    ("w_up", ("experts", None, "ff")),
    ("w_gate", ("experts", None, "ff")),
    ("w_down", ("experts", "ff", None)),
    # attention
    ("wq", (None, "heads")),
    ("wk", (None, "kv_heads")),
    ("wv", (None, "kv_heads")),
    ("wo_gate", (None, "heads")),
    ("wo", ("heads", None)),
    # mlp
    ("up", (None, "ff")),
    ("gate", (None, "ff")),
    ("down", ("ff", None)),
    # ssm projections
    ("wB", (None, "heads")),
    ("wC", (None, "heads")),
    ("wx", (None, "heads")),
    ("wz", (None, "heads")),
    ("wdt", (None, None)),
    ("wf", (None, None)),
    ("wi", (None, "heads")),
    ("wog", (None, "heads")),
    ("pos_table", (None, None)),
    # decode caches (stacked over layers by the caller -> rank+1 handling):
    # KV cache (B, KV, S, hd): batch over data axes, *sequence* over model
    # (kv-head counts like 1/2/5/8/20 rarely divide TP=16; seq always does;
    # softmax over the sharded kv axis becomes a cheap psum pair)
    ("k", ("batch", None, "kv_seq", None)),
    ("v", ("batch", None, "kv_seq", None)),
    # SSM matrix state (B, H, DK, DV) and normalizer (B, H, DK)
    ("S", ("batch", "heads", None, None)),
    ("n", ("batch", "heads", None)),
    # sLSTM scalar states (B, H*hd)
    ("c", ("batch", "heads")),
    ("m", ("batch", "heads")),
]


def param_logical_axes(path: str, ndim: int):
    """Logical axes for a parameter leaf (path: '/'-joined key path)."""
    leaf = path.split("/")[-1]
    for frag, axes in _PARAM_RULES:
        if frag == leaf or frag in path.split("/"):
            if len(axes) == ndim:
                return axes
            if len(axes) == ndim - 1:
                return (None,) + tuple(axes)     # stacked layer dim
            if len(axes) == ndim - 2:
                return (None, None) + tuple(axes)
    return (None,) * ndim


def param_spec(path: str, ndim: int) -> P:
    ctx = active()
    axes = param_logical_axes(path, ndim)
    if ctx is None:
        return P(*(None for _ in range(ndim)))
    _, rules = ctx
    return P(*(rules.get(a) for a in axes))


def tree_paths(tree, prefix=""):
    """Flatten a nested dict/NamedTuple pytree into (path, leaf) pairs.
    None nodes are empty subtrees (jax semantics) and are skipped."""
    out = []
    if tree is None:
        return out
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.extend(tree_paths(v, f"{prefix}/{k}" if prefix else str(k)))
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            v = getattr(tree, k)
            out.extend(tree_paths(v, f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(tree_paths(v, f"{prefix}/{i}" if prefix else str(i)))
    else:
        out.append((prefix, tree))
    return out


def tree_param_specs(tree):
    """Pytree of PartitionSpecs matching ``tree``'s structure."""
    leaves_with_paths = tree_paths(tree)
    specs = {path: param_spec(path, getattr(leaf, "ndim", 0))
             for path, leaf in leaves_with_paths}

    def rebuild(subtree, prefix=""):
        if subtree is None:
            return None
        if isinstance(subtree, dict):
            return {k: rebuild(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in subtree.items()}
        if hasattr(subtree, "_fields"):
            return type(subtree)(*(rebuild(getattr(subtree, k),
                                           f"{prefix}/{k}" if prefix else str(k))
                                   for k in subtree._fields))
        if isinstance(subtree, (list, tuple)):
            return type(subtree)(rebuild(v, f"{prefix}/{i}" if prefix else str(i))
                                 for i, v in enumerate(subtree))
        return specs[prefix]

    return rebuild(tree)


def valid_spec(spec: P, shape, mesh) -> P:
    """Drop mesh axes that don't divide the dim or are already used in an
    earlier dim (a mesh axis may appear at most once per spec) — e.g.
    granite's single KV head cannot shard over model=16, and qwen2-moe's
    60 experts don't divide 16 so the expert-FF dim takes TP instead."""
    used = set()
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, parts):
        axes = ax if isinstance(ax, tuple) else (ax,) if ax else ()
        keep = []
        size = 1
        for a in axes:
            if a in used or a not in mesh.shape:
                continue
            if dim % (size * mesh.shape[a]) == 0:
                keep.append(a)
                size *= mesh.shape[a]
        for a in keep:
            used.add(a)
        out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
    return P(*out)


def tree_shardings(mesh, tree):
    """NamedShardings for a pytree of arrays/ShapeDtypeStructs, validated
    against dim divisibility."""
    specs = tree_param_specs(tree)
    return jax.tree.map(
        lambda leaf, s: NamedSharding(mesh, valid_spec(s, leaf.shape, mesh)),
        tree, specs)


def zero_spec(spec: P, shape, mesh, data_axes=("data",)) -> P:
    """ZeRO-1: add the data axes to the first replicated, divisible dim.
    If no dim is divisible by the full axis product, fall back to axis
    subsets (e.g. 1600-wide params on a ("data","model") request shard
    16-way instead of staying replicated)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = {a for p in parts for a in
            ((p,) if isinstance(p, str) else (p or ()))}
    axes = tuple(a for a in data_axes if a in mesh.shape and a not in used)
    if not axes:
        return valid_spec(P(*parts), shape, mesh)
    # Prefer non-leading dims: dim 0 of a stacked-layer parameter is the
    # scan axis — sharding it makes XLA window-buffer whole layer groups.
    order = list(range(1, len(shape))) + [0] if len(shape) >= 3 \
        else list(range(len(shape)))
    candidates = [axes] + [(a,) for a in axes]
    for axes_try in candidates:
        dsize = 1
        for a in axes_try:
            dsize *= mesh.shape[a]
        if dsize == 1:
            continue
        for i in order:
            if parts[i] is None and shape[i] % dsize == 0 and shape[i] >= dsize:
                parts[i] = axes_try if len(axes_try) > 1 else axes_try[0]
                return valid_spec(P(*parts), shape, mesh)
    return valid_spec(P(*parts), shape, mesh)


def tree_zero_shardings(mesh, tree, data_axes=("data",)):
    specs = tree_param_specs(tree)
    return jax.tree.map(
        lambda leaf, s: NamedSharding(
            mesh, zero_spec(s, leaf.shape, mesh, data_axes)),
        tree, specs)
