"""Serving launcher: batched greedy decode against a (reduced or
checkpointed) model.

    PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --smoke
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    import numpy as np
    from ..configs import get
    from ..models import build_model
    from ..serving import Engine, ServeConfig
    from ..training import checkpoint as ckpt

    cfg = get(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt_dir:
        trees, _ = ckpt.restore(args.ckpt_dir, {"params": params})
        params = trees["params"]
    engine = Engine(model, params, ServeConfig(
        max_new_tokens=args.new_tokens,
        max_cache_len=args.prompt_len + args.new_tokens + 8))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    out = engine.generate(prompts)
    for i, row in enumerate(np.asarray(out)):
        print(f"[{i}] {row.tolist()}")


if __name__ == "__main__":
    main()
