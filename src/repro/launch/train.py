"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
        --steps 50
    # on a real slice: jax.distributed.initialize() is called when
    # JAX_COORDINATOR_ADDRESS is set, and the production mesh is used.
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="pods,data,model (elastic override)")
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR_ADDRESS"):
        import jax
        jax.distributed.initialize()

    from ..configs import get
    from ..distributed import sharding as shd
    from ..training import AdamWConfig, DataConfig, TrainConfig, Trainer
    import jax

    cfg_m = get(args.arch)
    if args.smoke:
        cfg_m = cfg_m.reduced()

    tc = TrainConfig(
        model=cfg_m,
        opt=AdamWConfig(lr=3e-4, warmup_steps=min(20, args.steps // 5 + 1),
                        total_steps=args.steps),
        data=DataConfig(vocab_size=cfg_m.vocab_size, seq_len=args.seq,
                        global_batch=args.batch),
        n_steps=args.steps, checkpoint_dir=args.ckpt_dir)

    n_dev = len(jax.devices())
    if args.mesh:
        from .mesh import make_mesh
        pods, data, model = (int(x) for x in args.mesh.split(","))
        mesh = make_mesh(pods, data, model)
    elif n_dev >= 256:
        from .mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = None

    if mesh is not None:
        with shd.use_mesh(mesh):
            trainer = Trainer(tc, mesh=mesh)
            report = trainer.run()
    else:
        trainer = Trainer(tc)
        report = trainer.run()
    for h in report["logged"][-5:]:
        print(h)
    print(f"steps={report['steps']} restarts={report['restarts']}")


if __name__ == "__main__":
    main()
