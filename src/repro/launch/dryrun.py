import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x input-shape) cell on the
production meshes and record memory_analysis / cost_analysis / collective
bytes as JSON artifacts for §Dry-run and §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts land in artifacts/dryrun/<mesh>/<arch>@<shape>.json; existing
artifacts are skipped unless --force.
"""

import argparse
import json
import time
import traceback

import jax

from ..configs import ALL_CELLS, SHAPES
from ..core import hlo as hlo_mod
from ..core import roofline as rl
from ..distributed import sharding as shd
from .mesh import make_production_mesh, mesh_chips
from .specs import step_and_specs

ARTDIR = os.environ.get("REPRO_ARTIFACTS", "artifacts")


def run_cell(arch: str, shape: str, mesh, mesh_name: str, out_dir: str,
             force: bool = False, verbose: bool = True,
             profile: str = "baseline") -> dict:
    from .specs import rules_for
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}@{shape}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    with shd.use_mesh(mesh, rules=rules_for(arch, profile)):
        step_fn, args, model_flops, meta = step_and_specs(arch, shape, mesh)
        # donate params/opt (train) or caches (decode) — matches the real
        # runtime and lets outputs alias inputs in memory_analysis
        donate = (0, 1) if meta["kind"] == "train" else \
            ((2,) if meta["kind"] == "decode" else ())
        # None entries (absent cross-attn memory) are valid empty pytrees
        lowered = jax.jit(step_fn, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    chips = mesh_chips(mesh)
    terms = rl.analyze_compiled(compiled, arch=arch, shape=shape,
                                mesh_name=mesh_name, chips=chips,
                                model_flops=model_flops)
    record = terms.to_dict()
    record.update(meta)
    record["lower_s"] = round(t_lower, 2)
    record["compile_s"] = round(t_compile, 2)
    ma = record.get("memory_analysis", {})
    record["fits_hbm"] = bool(ma.get("total_bytes", 0) <= 16e9)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        print(f"[{mesh_name}] {arch}@{shape}: compile {t_compile:.1f}s  "
              f"args {ma.get('argument_bytes', 0)/1e9:.2f} GB/dev  "
              f"temp {ma.get('temp_bytes', 0)/1e9:.2f} GB/dev  "
              f"dominant={record['dominant']}  "
              f"roofline_frac={record['roofline_fraction']:.3f}", flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", type=str, default="pod",
                    choices=("pod", "multipod", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--profile", type=str, default="baseline",
                    choices=("baseline", "dp_sp", "seq_sp"))
    ap.add_argument("--out", type=str, default=os.path.join(ARTDIR, "dryrun"))
    args = ap.parse_args()

    cells = ALL_CELLS if args.all else [
        (a, s) for (a, s) in ALL_CELLS
        if (args.arch is None or a == args.arch)
        and (args.shape is None or s == args.shape)]
    meshes = {"pod": False, "multipod": True}
    names = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)

    failures = []
    for mesh_name in names:
        mesh = make_production_mesh(multi_pod=meshes[mesh_name])
        for arch, shape in cells:
            try:
                run_cell(arch, shape, mesh, mesh_name,
                         os.path.join(args.out, mesh_name), force=args.force,
                         profile=args.profile)
            except Exception as e:  # noqa: BLE001 — report all cells
                failures.append((mesh_name, arch, shape, repr(e)))
                print(f"[{mesh_name}] {arch}@{shape}: FAIL {e}", flush=True)
                traceback.print_exc()
    print(f"\ndone: {len(cells) * len(names) - len(failures)} ok, "
          f"{len(failures)} failed")
    for f in failures:
        print("  FAIL:", *f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
