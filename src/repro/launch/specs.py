"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell —
weak-type-correct, shardable, zero device allocation.

``step_and_specs(arch, shape, mesh)`` returns:
    step_fn    — the function to lower (train_step / prefill_step / serve_step)
    args       — tuple of ShapeDtypeStructs with NamedShardings attached
    model_flops— 6*N_active*D for train, 2*N_active*D for inference cells
    meta       — notes (precision policy, skips, cache bytes, ...)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get
from ..configs.base import ModelConfig, ShapeConfig
from ..distributed import sharding as shd
from ..models import build_model
from ..training.optimizer import AdamWConfig, init_adamw
from ..training.trainer import make_train_step
from ..serving.engine import make_serve_step


def _sds(tree, mesh, *, zero_data_axes=None):
    """Attach validated NamedShardings to an eval_shape pytree."""
    if zero_data_axes:
        sh = shd.tree_zero_shardings(mesh, tree, data_axes=zero_data_axes)
    else:
        sh = shd.tree_shardings(mesh, tree)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, sh)


def wants_fsdp(cfg: ModelConfig, mesh) -> bool:
    """FSDP is *required* (params sharded over the data axes too — the
    ZeRO-3 / 2.5D-style comm-for-memory trade) when TP alone leaves
    > 4 GB/chip of parameters."""
    model_ways = mesh.shape.get("model", 1)
    per_dev = cfg.param_count() * 2 / model_ways
    return per_dev > 4e9


def choose_fsdp(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                required: bool) -> bool:
    """Layout choice routed through the tuner: FSDP when memory requires
    it, else — for train shapes — when the LM-step model predicts the
    per-layer all-gathers pay for themselves (cached in the plan cache
    like any linalg plan).  Serving shapes only get FSDP when required:
    the consulted model prices a *training* step and does not apply."""
    if shape.kind != "train":
        return required
    from ..tuner import default_tuner
    try:
        return default_tuner().recommend_fsdp(cfg, shape, dict(mesh.shape),
                                              required=required)
    except Exception:  # the model consult must never break a dry-run
        return required


#: sharding profiles (§Perf iterations) — applied via use_mesh(rules=...)
PROFILES = {
    # the default TP(+EP) x DP layout
    "baseline": lambda cfg: {},
    # pure data/fully-sharded parallelism + per-sequence locality: no tensor
    # parallelism at all.  The right layout for models whose head counts
    # don't divide TP=16 (qwen1.5-4b: 20 heads) — hypothesis: removes the
    # per-layer seq<->batch resharding all-gathers entirely.
    "dp_sp": lambda cfg: {"batch": ("pod", "data", "model"), "heads": None,
                          "kv_heads": None, "ff": None, "vocab": None,
                          "experts": None, "zero": ("data", "model")},
    # Megatron-SP: keep activations sequence-sharded over 'model' between
    # layers (norm/residual in SP), all-gather into TP blocks.
    "seq_sp": lambda cfg: {"seq": "model"},
}


def rules_for(arch: str, profile: str = "baseline") -> dict:
    return PROFILES[profile](get(arch))


def _batch_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, *, kind: str):
    ctx = shd.active()
    batch_axes = (ctx[1].get("batch") if ctx else None) or ("pod", "data")
    bspec = shd.valid_spec(P(batch_axes), (shape.global_batch,), mesh)
    b = shape.global_batch
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]

    def tok(s):
        return jax.ShapeDtypeStruct(
            (b, s), jnp.int32,
            sharding=NamedSharding(mesh, shd.valid_spec(
                P(batch_axes, None), (b, s), mesh)))

    out: Dict[str, Any] = {}
    if kind in ("train", "prefill"):
        out["tokens"] = tok(shape.seq_len)
        if kind == "train":
            out["labels"] = tok(shape.seq_len)
    else:  # decode: one new token
        out["tokens"] = tok(1)
    if cfg.block_pattern == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder.n_frames, cfg.d_model), dt,
            sharding=NamedSharding(mesh, shd.valid_spec(
                P(batch_axes, None, None),
                (b, cfg.encoder.n_frames, cfg.d_model), mesh)))
    if cfg.block_pattern == "vlm":
        out["images"] = jax.ShapeDtypeStruct(
            (b, cfg.vision.n_image_tokens, cfg.d_model), dt,
            sharding=NamedSharding(mesh, shd.valid_spec(
                P(batch_axes, None, None),
                (b, cfg.vision.n_image_tokens, cfg.d_model), mesh)))
    return out


def opt_config_for(cfg: ModelConfig) -> AdamWConfig:
    """Optimizer/precision policy by scale (recorded per cell in
    EXPERIMENTS.md §Dry-run):
      < 80B params:   AdamW, f32 moments
      80-250B:        AdamW, bf16 moments (fits 16 GB/chip)
      >= 250B (moe):  Adafactor (factored 2nd moment — the PaLM-style
                      production choice; Adam states alone would be
                      ~7.4 GB/chip for arctic-480b on one pod)."""
    n = cfg.param_count()
    if n >= 250e9:
        return AdamWConfig(kind="adafactor")
    return AdamWConfig(state_dtype="bfloat16" if n >= 80e9 else "float32")


def step_and_specs(arch: str, shape_name: str, mesh):
    cfg = get(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    meta: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                            "kind": shape.kind}

    params_shape = jax.eval_shape(model.init, key)
    ctx = shd.active()
    zero_axes = tuple((ctx[1].get("zero") if ctx else None) or ("data",))
    no_tp = bool(ctx and ctx[1].get("heads") is None)
    fsdp_required = wants_fsdp(cfg, mesh) or (no_tp and
                                              cfg.param_count() * 2 > 4e9)
    fsdp = choose_fsdp(cfg, shape, mesh, required=fsdp_required)
    meta["fsdp"] = fsdp
    params_specs = _sds(params_shape, mesh,
                        zero_data_axes=zero_axes if fsdp else None)
    n_active = cfg.active_param_count()

    if shape.kind == "train":
        opt_cfg = opt_config_for(cfg)
        meta["opt_state_dtype"] = opt_cfg.state_dtype
        opt_shape = jax.eval_shape(
            functools.partial(init_adamw, opt_cfg), params_shape)
        opt_specs = _sds(opt_shape, mesh, zero_data_axes=zero_axes)
        batch = _batch_specs(cfg, shape, mesh, kind="train")
        # microbatching: target <= ~2 GB/chip of rematerialization stash;
        # big models accumulate in bf16 (the accumulator is param-sized)
        chips = 1
        for v in mesh.shape.values():
            chips *= v
        stash = (cfg.n_layers * shape.global_batch * shape.seq_len
                 * cfg.d_model * 2 / chips)
        micro = 1
        while stash / micro > 2.2e9 and micro < shape.global_batch:
            micro *= 2
        meta["microbatches"] = micro
        accum = jnp.bfloat16 if opt_cfg.state_dtype == "bfloat16" else None
        meta["grad_accum_dtype"] = "bfloat16" if accum else "float32"
        step_fn = make_train_step(model, opt_cfg, microbatches=micro,
                                  accum_dtype=accum)
        args = (params_specs, opt_specs, batch)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        batch = _batch_specs(cfg, shape, mesh, kind="prefill")

        def prefill_step(params, batch):
            memory = model.encode_memory(params, batch)
            from ..models import transformer as tf
            from ..models import encdec as ed
            if cfg.block_pattern == "encdec":
                hidden, _ = ed.encdec_forward_train(params, cfg,
                                                    batch["frames"],
                                                    batch["tokens"])
            else:
                hidden, _ = tf.decoder_forward_train(params, cfg,
                                                     batch["tokens"],
                                                     memory=memory)
            # last-position logits (the serving prefill output)
            from ..models.transformer import lm_logits
            return lm_logits(params, cfg, hidden[:, -1:, :])

        step_fn = prefill_step
        args = (params_specs, batch)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:  # decode
        batch = _batch_specs(cfg, shape, mesh, kind="decode")
        cache_len = min(shape.seq_len, cfg.sliding_window) \
            if cfg.sliding_window else shape.seq_len
        meta["cache_len"] = cache_len
        cache_shape = jax.eval_shape(
            functools.partial(model.init_cache, shape.global_batch,
                              cache_len))
        cache_specs = _sds(cache_shape, mesh)
        memory_specs = None
        if cfg.block_pattern == "encdec":
            memory_specs = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.encoder.n_frames, cfg.d_model),
                {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype],
                sharding=NamedSharding(mesh, shd.valid_spec(
                    P(("pod", "data"), None, None),
                    (shape.global_batch, cfg.encoder.n_frames, cfg.d_model),
                    mesh)))
        elif cfg.block_pattern == "vlm":
            memory_specs = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.vision.n_image_tokens, cfg.d_model),
                {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype],
                sharding=NamedSharding(mesh, shd.valid_spec(
                    P(("pod", "data"), None, None),
                    (shape.global_batch, cfg.vision.n_image_tokens,
                     cfg.d_model), mesh)))
        serve = make_serve_step(model)

        def serve_step(params, tokens, caches, memory=None):
            return serve(params, tokens, caches, memory)

        step_fn = serve_step
        args = (params_specs, batch["tokens"], cache_specs, memory_specs)
        model_flops = 2.0 * n_active * shape.global_batch
    meta["params"] = int(cfg.param_count())
    meta["active_params"] = int(n_active)
    return step_fn, args, model_flops, meta
