"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  Shapes:

    single-pod:  (16, 16)      axes ("data", "model")   = 256 chips
    multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

On real hardware the same function is used with jax.distributed initialized
(devices() returns the global TPU slice); in the dry-run the devices are
512 forced host devices (see dryrun.py lines 1-2).
"""

from __future__ import annotations

import jax

from .. import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(pods: int, data: int, model: int):
    """Elastic-scale builder: any (pods, data, model) factorization whose
    product matches the available device count."""
    if pods > 1:
        return compat.make_mesh((pods, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
