"""The metrics registry: counters, gauges, fixed-bucket histograms.

Everything the trace layer can't express as a span lands here: queue
depths, KV-block occupancy, TTFT/TPOT distributions, residual
relative-error histograms.  The registry is label-aware (one metric
object per (name, sorted label set)), snapshot-able to JSON/JSONL, and
renders Prometheus text exposition (`metric{label="v"} value` with the
cumulative ``_bucket``/``_sum``/``_count`` histogram convention) so an
external scraper needs no custom glue.  :func:`parse_prometheus_text`
is the matching reader — the exposition round-trips, and the test
suite pins that.

Histograms use *fixed* bucket bounds chosen at creation (bounded
memory, mergeable across processes).  ``keep_values=True`` additionally
retains raw observations so exact nearest-rank percentiles are
available — the serving replay harness uses this so its reported
TTFT/TPOT percentiles and the obs summary agree by construction.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import threading
import time
from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple

#: wall-seconds latency buckets (spans, step times).
LATENCY_BUCKETS = (1e-5, 1e-4, 1e-3, 1e-2, 0.1, 0.5, 1.0, 5.0, 30.0)
#: relative-error buckets (predicted-vs-measured residual roll-ups).
REL_ERR_BUCKETS = (0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 5.0)

LabelSet = Tuple[Tuple[str, str], ...]


def _labels(labels: Dict[str, object]) -> LabelSet:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape_label_value(v: str) -> str:
    # Prometheus text exposition: backslash, double-quote and newline
    # must be escaped inside label values (in this order — backslash
    # first, or the other escapes get double-escaped).
    return (v.replace("\\", "\\\\").replace('"', '\\"')
             .replace("\n", "\\n"))


def _unescape_label_value(v: str) -> str:
    out: List[str] = []
    it = iter(v)
    for ch in it:
        if ch != "\\":
            out.append(ch)
            continue
        nxt = next(it, "")
        out.append({"n": "\n", '"': '"', "\\": "\\"}.get(nxt, "\\" + nxt))
    return "".join(out)


def _label_str(labels: LabelSet) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{_escape_label_value(v)}"'
                          for k, v in labels) + "}"


class Counter:
    """Monotone float counter."""

    kind = "counter"

    def __init__(self, name: str, labels: LabelSet = (), help: str = ""):
        self.name, self.labels, self.help = name, labels, help
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        if v < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self.value += v

    def to_dict(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "kind": self.kind, "value": self.value}


class Gauge:
    """Last-value gauge; also tracks the max ever set (free high-water
    marks for queue depth / occupancy / makespan)."""

    kind = "gauge"

    def __init__(self, name: str, labels: LabelSet = (), help: str = ""):
        self.name, self.labels, self.help = name, labels, help
        self._lock = threading.Lock()
        self.value = 0.0
        self.max_value = -math.inf

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)
            if v > self.max_value:
                self.max_value = float(v)

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self.value += v
            if self.value > self.max_value:
                self.max_value = self.value

    def dec(self, v: float = 1.0) -> None:
        self.inc(-v)

    def to_dict(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "kind": self.kind, "value": self.value,
                "max": self.max_value if self.max_value > -math.inf else None}


class Histogram:
    """Fixed-bucket histogram (Prometheus ``le`` convention: an
    observation lands in the first bucket whose upper bound is >= it;
    values above every bound land in the +Inf overflow bucket)."""

    kind = "histogram"

    def __init__(self, name: str, labels: LabelSet = (),
                 buckets: Sequence[float] = LATENCY_BUCKETS,
                 keep_values: bool = False, help: str = ""):
        self.name, self.labels, self.help = name, labels, help
        self.bounds: Tuple[float, ...] = tuple(sorted(float(b)
                                                      for b in buckets))
        if not self.bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._lock = threading.Lock()
        self.counts: List[int] = [0] * (len(self.bounds) + 1)  # +Inf last
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf
        self._values: Optional[List[float]] = [] if keep_values else None

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.counts[bisect_left(self.bounds, v)] += 1
            self.sum += v
            self.count += 1
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if self._values is not None:
                self._values.append(v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def percentile(self, q: float) -> Optional[float]:
        """Nearest-rank percentile: exact when raw values are kept
        (identical to the serving replay's historical formula), else the
        upper bound of the bucket holding that rank (``max`` for the
        overflow bucket).  ``None`` for an empty histogram — a made-up
        0.0 is indistinguishable from a real zero-latency sample, and
        callers that want a default can coalesce."""
        with self._lock:
            if self.count == 0:
                return None
            if self._values is not None:
                s = sorted(self._values)
                k = min(len(s) - 1,
                        max(0, int(round(q / 100.0 * (len(s) - 1)))))
                return float(s[k])
            rank = min(self.count - 1,
                       max(0, int(round(q / 100.0 * (self.count - 1)))))
            cum = 0
            for bound, c in zip(self.bounds, self.counts):
                cum += c
                if rank < cum:
                    return float(bound)
            return float(self.max)

    def to_dict(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "kind": self.kind, "count": self.count, "sum": self.sum,
                "mean": self.mean,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "buckets": [{"le": b, "count": c}
                            for b, c in zip(self.bounds, self.counts)]
                + [{"le": "+Inf", "count": self.counts[-1]}]}


class MetricsRegistry:
    """Get-or-create registry of metrics keyed by (name, labels)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, LabelSet], object] = {}

    def _get(self, cls, name: str, labels: Dict[str, object],
             **kwargs):
        key = (name, _labels(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], **kwargs)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"{name} already registered as "
                                f"{type(m).__name__}, wanted {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, labels, help=help)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, labels, help=help)

    def histogram(self, name: str, buckets: Sequence[float] = LATENCY_BUCKETS,
                  keep_values: bool = False, help: str = "",
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets,
                         keep_values=keep_values, help=help)

    def metrics(self) -> List[object]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- output ---------------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-ready state of every metric."""
        return {"metrics": [m.to_dict() for m in self.metrics()]}

    def dump_jsonl(self, path: str) -> str:
        """Append one timestamped snapshot line (the obs analog of the
        telemetry run store: cheap, append-only, machine-readable)."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        line = json.dumps({"ts": time.time(), **self.snapshot()},
                          sort_keys=True)
        with open(path, "a") as f:
            f.write(line + "\n")
        return path

    def prometheus_text(self) -> str:
        """Prometheus text exposition of the whole registry."""
        by_name: Dict[str, List[object]] = {}
        for m in self.metrics():
            by_name.setdefault(m.name, []).append(m)
        out: List[str] = []
        for name in sorted(by_name):
            group = by_name[name]
            first = group[0]
            if first.help:
                out.append(f"# HELP {name} {first.help}")
            out.append(f"# TYPE {name} {first.kind}")
            for m in group:
                ls = m.labels
                if isinstance(m, Histogram):
                    cum = 0
                    for bound, c in zip(m.bounds, m.counts):
                        cum += c
                        bl = ls + (("le", format(bound, "g")),)
                        out.append(f"{name}_bucket{_label_str(bl)} {cum}")
                    bl = ls + (("le", "+Inf"),)
                    out.append(f"{name}_bucket{_label_str(bl)} {m.count}")
                    out.append(f"{name}_sum{_label_str(ls)} {m.sum:.9g}")
                    out.append(f"{name}_count{_label_str(ls)} {m.count}")
                else:
                    out.append(f"{name}{_label_str(ls)} "
                               f"{format(m.value, '.9g')}")
        return "\n".join(out) + "\n"


def parse_prometheus_text(text: str) -> Dict[str, float]:
    """Inverse of :meth:`MetricsRegistry.prometheus_text` for the subset
    this module emits: ``{'name{k="v",...}': value}`` (comment and blank
    lines skipped).  Exists so the exposition format is round-trip
    tested, not write-only."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        body, _, value = line.rpartition(" ")
        if "{" in body:
            name, _, rest = body.partition("{")
            # label values are quoted and may contain escaped quotes,
            # backslashes, newlines — and literal commas — so a naive
            # split on "," mangles them; scan quote-aware instead.
            pairs = [(k, _unescape_label_value(v)) for k, v in
                     _LABEL_RE.findall(rest.rsplit("}", 1)[0])]
            key = name + _label_str(tuple(sorted(pairs)))
        else:
            key = body
        out[key] = float(value)
    return out


#: one ``key="value"`` pair; the value is any run of non-quote,
#: non-backslash characters or backslash escapes.
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


@dataclasses.dataclass(frozen=True)
class MetricKey:
    """Convenience for tests: the canonical exposition key of a sample."""

    name: str
    labels: Tuple[Tuple[str, str], ...] = ()

    def __str__(self) -> str:
        return self.name + _label_str(self.labels)
