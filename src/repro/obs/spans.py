"""Hierarchical span tracing: who did what, when, inside what.

A :class:`Span` is one timed region — name, category, (trace, span,
parent) ids, wall start/duration, free-form args — optionally carrying
the model's *predicted* duration for the same region
(``predicted_s``), which is what lets the exporter draw the predicted
twin track and annotate the signed residual.

The :class:`Tracer` keeps a bounded ring buffer of closed spans (a
``deque`` with ``maxlen``: always-on tracing can never grow without
bound — old spans fall off the back and ``dropped`` counts them) and a
per-thread open-span stack that supplies parent/trace ids, so nesting
is free for callers: whichever span is innermost on this thread when a
new one opens becomes its parent.

Closing is exception-safe by construction: the ``span()`` context
manager records the duration and tags ``error=True`` in its
``finally``, so a region exited via ``raise`` still lands in the
buffer with real timing.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Deque, Dict, List, Optional

#: bump when the span field set changes incompatibly.
SPAN_SCHEMA = 1

#: default ring capacity — ~30 MB of spans at worst, hours of serving
#: steps, and a hard memory bound either way.
DEFAULT_CAPACITY = 65536


@dataclasses.dataclass
class Span:
    """One closed (or still-open, while ``dur_s < 0``) traced region."""

    name: str
    cat: str = ""
    trace_id: int = 0
    span_id: int = 0
    parent_id: Optional[int] = None
    start_s: float = 0.0            # time.perf_counter() domain
    dur_s: float = -1.0             # -1 while open
    predicted_s: Optional[float] = None
    error: bool = False
    kind: str = "span"              # "span" | "instant"
    thread: int = 0
    args: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def residual_s(self) -> Optional[float]:
        """Signed measured-minus-predicted seconds (None when unpaired)."""
        if self.predicted_s is None or self.predicted_s <= 0 \
                or self.dur_s < 0:
            return None
        return self.dur_s - self.predicted_s

    @property
    def rel_err(self) -> Optional[float]:
        """|predicted - measured| / measured, the paper's accuracy metric
        (None when unpaired or the measurement is empty)."""
        r = self.residual_s
        if r is None or self.dur_s <= 0:
            return None
        return abs(r) / self.dur_s

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = SPAN_SCHEMA
        return d


class Tracer:
    """Ring-buffered span recorder; see module docstring.

    Thread-safe: the buffer append is locked, the open-span stack is
    per-thread.  ``begin``/``end`` are the primitives (used by callers
    that measure time themselves, like ``telemetry.PhaseTimer``);
    ``span()`` is the context-manager form; ``complete``/``instant``
    record externally-timed or zero-duration events directly.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.capacity = int(capacity)
        self._buf: Deque[Span] = deque(maxlen=self.capacity)
        self._ids = itertools.count(1)
        self._tls = threading.local()
        self._lock = threading.Lock()
        self.n_closed = 0

    # -- open-span stack ------------------------------------------------------
    def _stack(self) -> List[Span]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def current(self) -> Optional[Span]:
        """The innermost open span on this thread, if any."""
        stack = self._stack()
        return stack[-1] if stack else None

    # -- primitives -----------------------------------------------------------
    def begin(self, name: str, cat: str = "",
              args: Optional[Dict[str, object]] = None,
              predicted_s: Optional[float] = None) -> Span:
        stack = self._stack()
        sid = next(self._ids)
        if stack:
            parent, trace = stack[-1].span_id, stack[-1].trace_id
        else:
            parent, trace = None, sid
        sp = Span(name=name, cat=cat, trace_id=trace, span_id=sid,
                  parent_id=parent, start_s=time.perf_counter(),
                  predicted_s=predicted_s, thread=threading.get_ident(),
                  args=dict(args) if args else {})
        stack.append(sp)
        return sp

    def end(self, span: Span, error: bool = False,
            dur_s: Optional[float] = None) -> Span:
        """Close ``span``: duration from the wall clock (or explicit
        ``dur_s`` for externally-timed regions) and append to the ring.
        Any spans opened under it and left open are closed too (crash
        hygiene: an exception that skipped inner ``end`` calls must not
        corrupt the stack for the next span)."""
        span.dur_s = (time.perf_counter() - span.start_s
                      if dur_s is None else float(dur_s))
        span.error = span.error or error
        stack = self._stack()
        while stack:
            top = stack.pop()
            if top.span_id == span.span_id:
                break
        with self._lock:
            self._buf.append(span)
            self.n_closed += 1
        return span

    @contextmanager
    def span(self, name: str, cat: str = "",
             predicted_s: Optional[float] = None, **args):
        """``with tracer.span("execute", cat="dispatch", n=4096) as sp:``
        — exception-safe: a ``raise`` inside still records the duration
        and tags ``error=True``."""
        sp = self.begin(name, cat, args or None, predicted_s)
        try:
            yield sp
        except BaseException:
            sp.error = True
            raise
        finally:
            self.end(sp)

    def complete(self, name: str, dur_s: float, cat: str = "",
                 args: Optional[Dict[str, object]] = None,
                 predicted_s: Optional[float] = None,
                 start_s: Optional[float] = None) -> Span:
        """Record an already-measured region (it ran just now, for
        ``dur_s`` seconds).  Parent is whatever is open on this thread."""
        now = time.perf_counter()
        sp = self.begin(name, cat, args, predicted_s)
        sp.start_s = now - float(dur_s) if start_s is None else float(start_s)
        return self.end(sp, dur_s=float(dur_s))

    def instant(self, name: str, cat: str = "",
                args: Optional[Dict[str, object]] = None) -> Span:
        """A zero-duration marker event (drift alerts, admissions...)."""
        sp = self.begin(name, cat, args)
        sp.kind = "instant"
        return self.end(sp, dur_s=0.0)

    # -- buffer access --------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Closed spans that have already fallen off the ring."""
        with self._lock:
            return self.n_closed - len(self._buf)

    def spans(self) -> List[Span]:
        """Snapshot of the buffered (closed) spans, oldest first."""
        with self._lock:
            return list(self._buf)

    def drain(self) -> List[Span]:
        """Return the buffered spans and clear the ring (counters kept)."""
        with self._lock:
            out = list(self._buf)
            self._buf.clear()
        return out

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self.n_closed = 0
