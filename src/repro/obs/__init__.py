"""`repro.obs` — unified span tracing + metrics, predicted vs measured.

One switch (:func:`enable` / env ``REPRO_OBS=1``), one process-global
:class:`~repro.obs.spans.Tracer` and :class:`MetricsRegistry`, and one
hot-path guard — :func:`enabled` is a single global read and
:func:`maybe_span` returns a shared no-op context manager when tracing
is off, so the instrumented layers (telemetry PhaseTimer, tuner
dispatch, kernel timers, the serving scheduler) pay nothing measurable
when nobody is watching.  When tracing is on, every timed region that
knows its model-predicted duration carries it on the span, and
:mod:`repro.obs.export` renders measured and predicted timelines
side-by-side with flow links and signed residuals.
"""

from __future__ import annotations

import os
import threading
from contextlib import nullcontext
from typing import Optional

from .spans import DEFAULT_CAPACITY, Span, Tracer
from .metrics import (Counter, Gauge, Histogram, LATENCY_BUCKETS,
                      MetricsRegistry, REL_ERR_BUCKETS,
                      parse_prometheus_text)
from .export import (TraceBuilder, export_spans, save_trace, serving_trace,
                     sim_trace)
from .summary import save_summary, summary, tier_of

__all__ = [
    "Span", "Tracer", "TraceBuilder", "MetricsRegistry",
    "Counter", "Gauge", "Histogram",
    "LATENCY_BUCKETS", "REL_ERR_BUCKETS", "DEFAULT_CAPACITY",
    "enabled", "enable", "disable", "reset", "tracer", "default_registry",
    "maybe_span", "alert",
    "export_spans", "sim_trace", "serving_trace", "save_trace",
    "summary", "save_summary", "tier_of", "parse_prometheus_text",
    "watch",
]


def __getattr__(name):
    # `watch` is loaded lazily: its modules use ``from .. import alert``,
    # which needs this module fully initialized first.
    if name == "watch":
        import importlib
        return importlib.import_module(".watch", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

_LOCK = threading.Lock()
_ENABLED: Optional[bool] = None     # None -> consult the environment
_TRACER: Optional[Tracer] = None
_REGISTRY: Optional[MetricsRegistry] = None

#: shared no-op context manager — ``nullcontext`` is reentrant and
#: reusable, so one instance serves every disabled ``maybe_span`` call
#: without an allocation.
_NULL = nullcontext()


def enabled() -> bool:
    """Is span/metric recording on?  Lock-free single global read on
    the hot path (CPython global loads are atomic); only the first call
    ever consults the environment."""
    e = _ENABLED
    if e is None:
        e = os.environ.get("REPRO_OBS", "") not in ("", "0", "false")
        _set_enabled(e)
    return e


def _set_enabled(v: Optional[bool]) -> None:
    global _ENABLED
    with _LOCK:
        _ENABLED = v


def enable(capacity: Optional[int] = None) -> Tracer:
    """Turn recording on (optionally resizing the ring) and return the
    process tracer."""
    global _TRACER
    with _LOCK:
        if capacity is not None and (_TRACER is None
                                     or _TRACER.capacity != capacity):
            _TRACER = Tracer(capacity)
    _set_enabled(True)
    return tracer()


def disable() -> None:
    _set_enabled(False)


def reset() -> None:
    """Forget everything: enabled flag back to env-derived, fresh tracer
    and registry on next use.  Tests lean on this."""
    global _TRACER, _REGISTRY
    with _LOCK:
        global _ENABLED
        _ENABLED = None
        _TRACER = None
        _REGISTRY = None


def tracer() -> Tracer:
    """The process-global tracer (created on first use)."""
    global _TRACER
    tr = _TRACER
    if tr is None:
        with _LOCK:
            if _TRACER is None:
                _TRACER = Tracer()
            tr = _TRACER
    return tr


def default_registry() -> MetricsRegistry:
    """The process-global metrics registry (created on first use)."""
    global _REGISTRY
    reg = _REGISTRY
    if reg is None:
        with _LOCK:
            if _REGISTRY is None:
                _REGISTRY = MetricsRegistry()
            reg = _REGISTRY
    return reg


def maybe_span(name: str, cat: str = "",
               predicted_s: Optional[float] = None, **args):
    """``tracer().span(...)`` when recording, a shared no-op context
    manager when not — the one-line instrumentation hook every layer
    uses."""
    if not enabled():
        return _NULL
    return tracer().span(name, cat, predicted_s, **args)


def alert(name: str, **args) -> Optional[Span]:
    """Emit a structured alert: an instant event in the trace stream
    plus an ``obs_alerts_total{kind=...}`` counter.  No-op when
    disabled."""
    if not enabled():
        return None
    default_registry().counter("obs_alerts_total", kind=name).inc()
    return tracer().instant(name, cat="alert", args=args or None)
