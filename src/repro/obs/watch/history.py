"""Bench-history store + statistical regression sentinel.

Every benchmark run emits ``artifacts/bench/BENCH_*.json`` — a snapshot
with no memory: a PR that halves simulator throughput sails through as
long as the absolute gates still pass.  This module gives the bench
trajectory a history:

* :class:`BenchHistory` — an append-only JSONL file under
  ``artifacts/bench/history/`` (same discipline as the telemetry
  :class:`~repro.telemetry.store.RunStore`: schema-versioned lines,
  skip-don't-crash reads).  Each line is one benchmark's flattened
  numeric metrics stamped with the run metadata the emitters now carry
  (commit SHA, timestamp, machine fingerprint, repeat count) — so runs
  are joinable across commits *and* noise bands are computed per
  machine, never mixing a laptop's numbers with CI's.
* :func:`check_regressions` — for each (bench, metric) with enough
  same-machine history: baseline = median of past runs, noise band =
  ``band_sigmas`` robust standard deviations (MAD-scaled) of past runs
  floored at ``rel_floor`` of the baseline.  A current value outside the
  band *in the bad direction* is a regression; the good direction is
  reported as an improvement.  Direction comes from metric-name
  conventions (throughputs up, errors/latencies down) with an explicit
  override table for the exceptions.

``python -m benchmarks.run --check-regressions`` runs the sentinel over
the freshly-written ``BENCH_*.json`` files and appends them to history;
CI treats "insufficient history" as warn-only (the first runs build the
baseline) and a verdicted regression as a failure.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import statistics
from typing import Dict, List, Optional, Sequence

#: bump when the history line format changes incompatibly.
HISTORY_SCHEMA = 1

#: minimum same-machine history runs before the sentinel may fail a metric.
MIN_HISTORY = 3


def history_dir() -> str:
    env = os.environ.get("REPRO_BENCH_HISTORY_DIR")
    if env:
        return env
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))))))
    return os.path.join(repo, "artifacts", "bench", "history")


@dataclasses.dataclass
class BenchRun:
    """One benchmark's numbers from one run, joinable by commit+machine."""

    bench: str                       # "BENCH_obs", "fig5to8", ...
    commit: str
    fingerprint: str                 # machine fingerprint
    timestamp: float
    metrics: Dict[str, float]        # flattened numeric leaves
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = HISTORY_SCHEMA
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "BenchRun":
        d = dict(d)
        if d.pop("schema", None) != HISTORY_SCHEMA:
            raise ValueError("bench history schema mismatch")
        return cls(**d)


def flatten_metrics(obj, prefix: str = "",
                    out: Optional[Dict[str, float]] = None,
                    max_depth: int = 6) -> Dict[str, float]:
    """Numeric leaves of a bench JSON as dotted paths.  Booleans become
    0/1 (they are go/no-go claims worth tracking); strings, nulls and
    list-of-dict internals are skipped; lists of numbers get indexed
    entries (small ones only — bench payloads keep these short)."""
    if out is None:
        out = {}
    if max_depth < 0:
        return out
    if isinstance(obj, dict):
        for k, v in obj.items():
            if str(k).startswith("_"):
                continue                      # _meta and friends
            flatten_metrics(v, prefix + str(k) + ".", out, max_depth - 1)
    elif isinstance(obj, bool):
        out[prefix[:-1]] = 1.0 if obj else 0.0
    elif isinstance(obj, (int, float)):
        v = float(obj)
        if math.isfinite(v):
            out[prefix[:-1]] = v
    elif isinstance(obj, (list, tuple)) and len(obj) <= 16:
        for i, v in enumerate(obj):
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                flatten_metrics(v, f"{prefix}{i}.", out, max_depth - 1)
    return out


class BenchHistory:
    """Append-only JSONL history of :class:`BenchRun` lines."""

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory or history_dir()
        self.skipped_lines = 0

    @property
    def path(self) -> str:
        return os.path.join(self.directory, "history.jsonl")

    def append(self, run: BenchRun) -> None:
        os.makedirs(self.directory, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(run.to_dict(), sort_keys=True) + "\n")

    def load(self, bench: Optional[str] = None,
             fingerprint: Optional[str] = None) -> List[BenchRun]:
        try:
            with open(self.path) as f:
                lines = f.readlines()
        except OSError:
            return []
        out: List[BenchRun] = []
        for line in lines:
            line = line.strip()
            if not line:
                continue
            try:
                run = BenchRun.from_dict(json.loads(line))
            except (ValueError, TypeError):
                self.skipped_lines += 1
                continue
            if bench is not None and run.bench != bench:
                continue
            if fingerprint is not None and run.fingerprint != fingerprint:
                continue
            out.append(run)
        out.sort(key=lambda r: r.timestamp)
        return out

    def ingest_dir(self, bench_dir: str,
                   meta: Optional[dict] = None) -> List[BenchRun]:
        """Append one :class:`BenchRun` per readable ``BENCH_*.json`` in
        ``bench_dir``.  Run metadata comes from each file's stamped
        ``_meta`` block (benchmarks/common.run_meta), overridable by the
        ``meta`` argument; unstamped files get empty commit/fingerprint
        (still stored, never joined into a noise band)."""
        runs: List[BenchRun] = []
        try:
            names = sorted(os.listdir(bench_dir))
        except OSError:
            return runs
        for name in names:
            m = re.fullmatch(r"(BENCH_[A-Za-z0-9_]+)\.json", name)
            if not m:
                continue
            try:
                with open(os.path.join(bench_dir, name)) as f:
                    payload = json.load(f)
            except (OSError, ValueError):
                continue
            stamped = dict(payload.get("_meta") or {})
            if meta:
                stamped.update(meta)
            run = BenchRun(
                bench=m.group(1),
                commit=str(stamped.get("commit", "")),
                fingerprint=str(stamped.get("fingerprint", "")),
                timestamp=float(stamped.get("timestamp", 0.0)),
                metrics=flatten_metrics(payload),
                meta=stamped)
            self.append(run)
            runs.append(run)
        return runs


# -- regression verdicts ------------------------------------------------------

#: explicit direction overrides: +1 higher-is-better, -1 lower-is-better,
#: 0 two-sided.  Everything else goes through the name heuristics below.
DIRECTION_OVERRIDES: Dict[str, int] = {
    "revision": 0,
    "n": 0,
}

_HIGHER = ("per_sec", "per_s", "speedup", "goodput", "throughput",
           "events", "spans", "rps", "_ok", "agreement", "eff", "ratio",
           "flow_events", "n_requests", "n_rows", "peak")
_LOWER = ("err", "_us", "_ms", "_s", "seconds", "overhead", "wall",
          "dropped", "p95", "p99", "latency", "rel", "bytes")


def metric_direction(name: str) -> int:
    """+1 regression-if-lower, -1 regression-if-higher, 0 two-sided."""
    leaf = name.rsplit(".", 1)[-1]
    if leaf in DIRECTION_OVERRIDES:
        return DIRECTION_OVERRIDES[leaf]
    low = name.lower()
    # higher-is-better tokens win (a "goodput_ratio" is a ratio to grow;
    # "events_per_sec" contains "_s" only via "per_sec")
    if any(t in low for t in _HIGHER):
        return 1
    if any(t in low for t in _LOWER):
        return -1
    return 0


@dataclasses.dataclass
class Finding:
    bench: str
    metric: str
    verdict: str          # "regression" | "improvement" | "ok" | "no_history"
    current: float
    baseline: Optional[float] = None
    band: Optional[float] = None
    n_history: int = 0
    direction: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def check_regressions(current: Dict[str, Dict[str, float]],
                      history: Sequence[BenchRun], *,
                      fingerprint: Optional[str] = None,
                      min_history: int = MIN_HISTORY,
                      band_sigmas: float = 4.0,
                      rel_floor: float = 0.10) -> dict:
    """Verdict every metric of ``current`` against same-machine history.

    ``current`` maps bench name -> flattened metrics (what
    :meth:`BenchHistory.ingest_dir` stores).  The noise band per metric is
    ``max(band_sigmas * 1.4826 * MAD(past), rel_floor * |median|)`` — the
    MAD term tracks each metric's own run-to-run jitter, the relative
    floor keeps near-deterministic metrics from flagging on roundoff.
    """
    by_bench: Dict[str, List[BenchRun]] = {}
    for run in history:
        if fingerprint is not None and run.fingerprint != fingerprint:
            continue
        by_bench.setdefault(run.bench, []).append(run)

    findings: List[Finding] = []
    for bench, metrics in sorted(current.items()):
        past_runs = by_bench.get(bench, [])
        for metric, value in sorted(metrics.items()):
            past = [r.metrics[metric] for r in past_runs
                    if metric in r.metrics]
            if len(past) < min_history:
                findings.append(Finding(bench, metric, "no_history",
                                        value, n_history=len(past)))
                continue
            med = statistics.median(past)
            mad = statistics.median(abs(x - med) for x in past)
            band = max(band_sigmas * 1.4826 * mad, rel_floor * abs(med))
            direction = metric_direction(metric)
            delta = value - med
            if direction > 0 and delta < -band:
                verdict = "regression"
            elif direction < 0 and delta > band:
                verdict = "regression"
            elif direction == 0 and abs(delta) > band:
                verdict = "regression"
            elif abs(delta) > band:
                verdict = "improvement"
            else:
                verdict = "ok"
            findings.append(Finding(bench, metric, verdict, value,
                                    baseline=med, band=band,
                                    n_history=len(past),
                                    direction=direction))

    n = {"regression": 0, "improvement": 0, "ok": 0, "no_history": 0}
    for f in findings:
        n[f.verdict] += 1
    gated = n["ok"] + n["regression"] + n["improvement"]
    return {
        "counts": n,
        "gated_metrics": gated,
        "sufficient_history": gated > 0,
        "regressions": [f.to_dict() for f in findings
                        if f.verdict == "regression"],
        "improvements": [f.to_dict() for f in findings
                         if f.verdict == "improvement"],
        "findings": [f.to_dict() for f in findings],
    }


def format_report(report: dict, max_rows: int = 20) -> str:
    """Human-readable sentinel verdict (CI log output)."""
    c = report["counts"]
    lines = [f"bench-history sentinel: {report['gated_metrics']} gated "
             f"metrics ({c['ok']} ok, {c['improvement']} improved, "
             f"{c['regression']} regressed; {c['no_history']} without "
             f"history yet)"]
    for f in report["regressions"][:max_rows]:
        arrow = "^" if f["direction"] < 0 else "v"
        lines.append(
            f"  REGRESSION {arrow} {f['bench']}:{f['metric']} = "
            f"{f['current']:.6g} vs baseline {f['baseline']:.6g} "
            f"(band +/-{f['band']:.3g}, n={f['n_history']})")
    for f in report["improvements"][:max_rows]:
        lines.append(
            f"  improvement  {f['bench']}:{f['metric']} = "
            f"{f['current']:.6g} vs baseline {f['baseline']:.6g}")
    return "\n".join(lines)
