"""Streaming change-point and outlier detection over observation streams.

The PR-4 drift check is a *batch* statistic: a rolling window of residual
rows whose mean relative error must cross a threshold — with the default
window of 10 that means a degraded link keeps mispredicting for most of a
window before anyone notices.  This module is the *streaming* complement:
three incremental detectors, each O(1) state and O(1)-ish update, run over
every observation as it happens —

* **EWMA** — exponentially weighted mean/variance; fires when a new
  observation sits more than ``ewma_k`` EW standard deviations from the
  EW mean.  Catches level shifts and single gross outliers.
* **CUSUM** — two-sided tabular cumulative sum with reference slack
  ``cusum_k`` and decision threshold ``cusum_h`` (both in units of the
  warm-up standard deviation).  The classic small-persistent-shift
  detector: a mean shift of ``delta`` fires after roughly
  ``h / (delta - k)`` observations — for the residual streams this is a
  handful of observations, well inside the PR-4 drift window.
* **Rolling quantile** — a sorted sliding window (``bisect`` insert /
  remove, so the window stays small and the update cheap); fires when an
  observation exceeds ``quantile_factor`` times the window's
  ``quantile`` — scale-free outlier detection for heavy-tailed series
  (step times, queue depths) where a sigma rule misfires.

Detectors warm up on the first ``min_obs`` observations (estimating the
in-control mean/scale) and never fire during warm-up.  After a firing the
detector re-baselines (CUSUM resets its sums; EWMA keeps tracking), so a
genuine regime change fires once, not on every subsequent observation —
the same latch discipline :class:`~repro.telemetry.drift.DriftLatch`
applies to the batch path.

Tier configs: the paper's regime split (Bienz et al. 1806.02030 —
injection-limited vs network-limited residuals behave differently)
motivates per-tier tuning: kernel-launch residuals are tight and
high-rate, op-dispatch residuals are medium, serving-step residuals are
noisy and bursty.  :data:`TIER_CONFIGS` carries one
:class:`DetectorConfig` per tier; :class:`StreamWatcher` resolves the
config from the span/residual tier automatically.
"""

from __future__ import annotations

import dataclasses
import math
from bisect import bisect_left, insort
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

from .. import alert as _obs_alert
from ..summary import tier_of


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Knobs for one series' detector bank (all three run side by side)."""

    ewma_alpha: float = 0.15     # EW weight of the newest observation
    ewma_k: float = 5.0          # fire at |x - mean| > k * ew_std
    cusum_k: float = 0.5         # reference slack, in warm-up std units
    cusum_h: float = 5.0         # decision threshold, in warm-up std units
    quantile: float = 0.99       # rolling-quantile reference rank
    quantile_factor: float = 3.0  # fire at x > factor * window quantile
    quantile_window: int = 128   # sliding-window length
    min_obs: int = 8             # warm-up observations before arming
    min_std: float = 1e-12       # scale floor (constant warm-up series)
    adapt_alpha: float = 0.02    # CUSUM in-control baseline adaptation


#: per-tier detector configs for the rel-err residual streams.  Kernel
#: launches are many and tight (small alpha, long memory); op dispatches
#: are the paper's own validation tier (defaults); serving steps are
#: bursty (looser sigma, heavier quantile guard).
TIER_CONFIGS: Dict[str, DetectorConfig] = {
    "kernel": DetectorConfig(ewma_alpha=0.08, ewma_k=6.0, cusum_k=0.5,
                             cusum_h=6.0, quantile_window=256),
    "op": DetectorConfig(),
    "serve": DetectorConfig(ewma_alpha=0.2, ewma_k=6.0, cusum_k=1.0,
                            cusum_h=8.0, quantile=0.995,
                            quantile_factor=4.0),
}


@dataclasses.dataclass
class Firing:
    """One detector trigger: which detector, on which series, and the
    statistic/threshold pair that crossed."""

    series: str
    detector: str               # "ewma" | "cusum" | "quantile"
    value: float                # the observation that fired
    stat: float                 # detector statistic at fire time
    threshold: float            # what it crossed
    n_obs: int                  # observations seen on this series so far
    tier: Optional[str] = None
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class EWMADetector:
    """EW mean/variance sigma-rule detector (O(1) state)."""

    name = "ewma"

    def __init__(self, cfg: DetectorConfig):
        self.alpha = cfg.ewma_alpha
        self.k = cfg.ewma_k
        self.min_obs = cfg.min_obs
        self.min_std = cfg.min_std
        self.mean = 0.0
        self.var = 0.0
        self.n = 0

    def update(self, x: float) -> Optional[tuple]:
        """Feed one observation; returns (stat, threshold) on fire."""
        n = self.n = self.n + 1
        mean, var = self.mean, self.var
        fired = None
        if n <= self.min_obs:
            # warm-up: plain running moments (Welford)
            d = x - mean
            mean += d / n
            var += d * (x - mean)
            if n == self.min_obs:
                var = max(var / max(n - 1, 1), self.min_std ** 2)
        else:
            std = math.sqrt(var) if var > 0 else self.min_std
            dev = abs(x - mean)
            if dev > self.k * std:
                fired = (dev / std, self.k)
            a = self.alpha
            d = x - mean
            mean += a * d
            # EW variance of the residual around the EW mean
            var = (1 - a) * (var + a * d * d)
            if var < self.min_std ** 2:
                var = self.min_std ** 2
        self.mean, self.var = mean, var
        return fired


class CUSUMDetector:
    """Two-sided tabular CUSUM in warm-up-standardized units."""

    name = "cusum"

    def __init__(self, cfg: DetectorConfig):
        self.k = cfg.cusum_k
        self.h = cfg.cusum_h
        self.min_obs = cfg.min_obs
        self.min_std = cfg.min_std
        self.adapt_alpha = cfg.adapt_alpha
        self.target = 0.0
        self.scale = 1.0
        self.s_pos = 0.0
        self.s_neg = 0.0
        self.n = 0
        self._m = 0.0
        self._v = 0.0

    def update(self, x: float) -> Optional[tuple]:
        n = self.n = self.n + 1
        if n <= self.min_obs:
            d = x - self._m
            self._m += d / n
            self._v += d * (x - self._m)
            if n == self.min_obs:
                self.target = self._m
                self.scale = max(math.sqrt(self._v / max(n - 1, 1)),
                                 self.min_std)
            return None
        z = (x - self.target) / self.scale
        if abs(z) < 3.0:
            # in-control: slowly re-estimate the baseline.  The warm-up
            # scale comes from only ``min_obs`` samples — frozen, an
            # underestimate inflates every future z and the false-fire
            # rate explodes (~2% observed on clean Gaussian streams).
            # Shifted observations (|z| >= 3) never feed the baseline,
            # so a genuine regime change still accumulates.
            a = self.adapt_alpha
            self.target += a * (x - self.target)
            # sqrt(pi/2) converts EW mean absolute deviation to sigma
            self.scale = max(self.scale + a * (abs(x - self.target)
                                               * 1.2533 - self.scale),
                             self.min_std)
        self.s_pos = max(0.0, self.s_pos + z - self.k)
        self.s_neg = max(0.0, self.s_neg - z - self.k)
        stat = max(self.s_pos, self.s_neg)
        if stat > self.h:
            # re-baseline: a persistent shift fires once, and the next
            # regime is judged from a clean slate
            self.s_pos = self.s_neg = 0.0
            return (stat, self.h)
        return None


class RollingQuantileDetector:
    """Sliding-window quantile outlier guard (sorted window, bisect)."""

    name = "quantile"

    def __init__(self, cfg: DetectorConfig):
        self.q = cfg.quantile
        self.factor = cfg.quantile_factor
        self.window = cfg.quantile_window
        self.min_obs = min(cfg.min_obs, cfg.quantile_window)
        self._fifo: deque = deque()
        self._sorted: List[float] = []

    def update(self, x: float) -> Optional[tuple]:
        s = self._sorted
        fired = None
        if len(s) >= self.min_obs:
            k = min(len(s) - 1, max(0, int(round(self.q * (len(s) - 1)))))
            ref = s[k]
            thr = self.factor * ref
            # the reference must be a real positive scale: a window of
            # zeros (e.g. residuals of a perfectly-predicted phase) makes
            # any nonzero observation "infinite" — treat that as no scale
            if ref > 0 and x > thr:
                fired = (x / ref, self.factor)
        self._fifo.append(x)
        insort(s, x)
        if len(self._fifo) > self.window:
            old = self._fifo.popleft()
            del s[bisect_left(s, old)]
        return fired


class SeriesWatch:
    """The three detectors side by side over one named series."""

    def __init__(self, series: str, cfg: DetectorConfig,
                 tier: Optional[str] = None):
        self.series = series
        self.cfg = cfg
        self.tier = tier
        self.n_obs = 0
        self.detectors = (EWMADetector(cfg), CUSUMDetector(cfg),
                          RollingQuantileDetector(cfg))

    def observe(self, value: float) -> List[Firing]:
        """Feed one observation through every detector; the incremental
        hot path (bench-gated >= 100k obs/s)."""
        self.n_obs += 1
        out: List[Firing] = []
        for det in self.detectors:
            hit = det.update(value)
            if hit is not None:
                out.append(Firing(self.series, det.name, float(value),
                                  float(hit[0]), float(hit[1]),
                                  self.n_obs, tier=self.tier))
        return out


class StreamWatcher:
    """Incremental anomaly watch over named observation streams.

    One :class:`SeriesWatch` per series key, created lazily with the
    config for its tier.  Feed it three ways:

    * :meth:`observe` — any named scalar stream;
    * :meth:`observe_span` — a closed :class:`~repro.obs.spans.Span`
      whose ``rel_err`` pairs prediction with measurement (series key
      ``rel_err/<tier>/<op>``);
    * :meth:`observe_residual` — a telemetry
      :class:`~repro.telemetry.residuals.Residual` row (series key
      ``rel_err/op/<op>``), the closed-loop entry point;
    * :meth:`poll_gauges` — sample every gauge of a
      :class:`~repro.obs.metrics.MetricsRegistry` as one observation
      each (queue depths, KV utilization...).

    Firings are returned, kept in :attr:`firings` (bounded), emitted as
    structured ``obs.alert("watch", ...)`` instants (feeding the existing
    ``obs_alerts_total`` counter), and passed to ``on_fire`` — wire
    :class:`RevisionResponder` there to close the loop into the tuner.
    """

    def __init__(self, configs: Optional[Dict[str, DetectorConfig]] = None,
                 default: Optional[DetectorConfig] = None,
                 on_fire: Optional[Callable[[Firing], object]] = None,
                 emit_alerts: bool = True, max_firings: int = 1024):
        self.configs = dict(TIER_CONFIGS if configs is None else configs)
        self.default = default or DetectorConfig()
        self.on_fire = on_fire
        self.emit_alerts = emit_alerts
        self.firings: deque = deque(maxlen=max_firings)
        self._series: Dict[str, SeriesWatch] = {}

    def config_for(self, tier: Optional[str]) -> DetectorConfig:
        return self.configs.get(tier, self.default)

    def series(self, name: str, tier: Optional[str] = None) -> SeriesWatch:
        sw = self._series.get(name)
        if sw is None:
            sw = self._series[name] = SeriesWatch(
                name, self.config_for(tier), tier=tier)
        return sw

    # -- feeds ---------------------------------------------------------------
    def observe(self, series: str, value: float,
                tier: Optional[str] = None, **meta) -> List[Firing]:
        fires = self.series(series, tier).observe(float(value))
        for f in fires:
            if meta:
                f.meta.update(meta)
            self._fired(f)
        return fires

    def observe_span(self, span) -> List[Firing]:
        """Residual watch on one closed span (no-op for unpaired spans)."""
        err = span.rel_err
        if err is None:
            return []
        tier = tier_of(span.cat) or "op"
        op = span.args.get("op", span.name)
        return self.observe(f"rel_err/{tier}/{op}", err, tier=tier,
                            span=span.name)

    def observe_residual(self, row) -> List[Firing]:
        """Residual watch on one telemetry join row — the stream the
        PR-4 drift window consumes in batch."""
        return self.observe(f"rel_err/op/{row.op}", row.rel_err, tier="op",
                            op=row.op, phase=row.phase,
                            machine=row.machine)

    def poll_gauges(self, registry, prefix: str = "") -> List[Firing]:
        """Sample every (matching) gauge's current value as one
        observation — call once per scheduler step / scrape tick."""
        from ..metrics import Gauge
        out: List[Firing] = []
        for m in registry.metrics():
            if not isinstance(m, Gauge) or not m.name.startswith(prefix):
                continue
            key = "gauge/" + m.name
            if m.labels:
                key += "{" + ",".join(f"{k}={v}" for k, v in m.labels) + "}"
            tier = "serve" if m.name.startswith("serve") else None
            out.extend(self.observe(key, m.value, tier=tier))
        return out

    # -- accounting ----------------------------------------------------------
    def _fired(self, f: Firing) -> None:
        self.firings.append(f)
        if self.emit_alerts:
            _obs_alert("watch", series=f.series, detector=f.detector,
                       value=f.value, stat=f.stat, threshold=f.threshold,
                       n_obs=f.n_obs, tier=f.tier)
        if self.on_fire is not None:
            self.on_fire(f)

    @property
    def n_series(self) -> int:
        return len(self._series)

    def summary(self) -> dict:
        """JSON-ready roll-up (feeds the observatory dashboard)."""
        return {
            "n_series": len(self._series),
            "n_obs": sum(s.n_obs for s in self._series.values()),
            "n_firings": len(self.firings),
            "firings": [f.to_dict() for f in self.firings],
        }


class RevisionResponder:
    """Close the loop: a watch firing retires the machine profile through
    the *same* revision-bump/re-key path the batch drift detector uses —
    ``telemetry.bump_revision`` changes ``Machine.fingerprint()`` and
    with it every tuner plan-cache key and telemetry store file.

    One bump per revision: after firing, further firings are swallowed
    until something else moves the revision (mirroring
    :class:`~repro.telemetry.drift.DriftLatch` — without this, a burst of
    detector firings would bump the revision once per firing).
    """

    def __init__(self, registry, machine_name: str,
                 series_filter: Optional[Callable[[Firing], bool]] = None):
        self.registry = registry
        self.machine_name = machine_name
        self.series_filter = series_filter
        self.bumps: List[dict] = []
        self._fired_at_revision: Optional[int] = None

    def __call__(self, firing: Firing):
        if self.series_filter is not None and not self.series_filter(firing):
            return None
        from ...telemetry.drift import bump_revision
        current = self.registry.machine(self.machine_name).machine.revision
        if self._fired_at_revision is not None \
                and current == self._fired_at_revision:
            return None                      # already responded; latched
        machine = bump_revision(self.registry, self.machine_name)
        self._fired_at_revision = machine.revision
        self.bumps.append({"series": firing.series,
                           "detector": firing.detector,
                           "revision": machine.revision})
        return machine
