"""repro.obs.watch — streaming anomaly detection, SLO burn-rate
alerting, bench-history regression sentinel, and the observatory
dashboard.

Four pieces, one loop:

* :mod:`~repro.obs.watch.detect` — incremental EWMA / CUSUM /
  rolling-quantile detectors over any observation stream (span residuals,
  telemetry rows, registry gauges), with per-tier configs and a
  :class:`RevisionResponder` that closes firings into the tuner's
  revision-bump/re-key path.
* :mod:`~repro.obs.watch.slo` — multi-window burn-rate rules over the
  serving TTFT/TPOT/goodput outcome streams, emitting structured
  ``obs.alert("slo_burn", ...)`` instants.
* :mod:`~repro.obs.watch.history` — append-only bench-history JSONL
  keyed by commit + machine fingerprint, and the statistical regression
  sentinel behind ``python -m benchmarks.run --check-regressions``.
* :mod:`~repro.obs.watch.dashboard` — the self-contained HTML
  observatory rendered from all of the above.
"""

from .detect import (DetectorConfig, TIER_CONFIGS, Firing, EWMADetector,
                     CUSUMDetector, RollingQuantileDetector, SeriesWatch,
                     StreamWatcher, RevisionResponder)
from .slo import (BurnRateRule, SERVING_RULES, SLOAlert, SLOWatcher,
                  watch_replay)
from .history import (HISTORY_SCHEMA, BenchRun, BenchHistory,
                      flatten_metrics, metric_direction, check_regressions,
                      format_report, history_dir)
from .dashboard import (collect_data, render_dashboard, save_dashboard,
                        history_series)

__all__ = [
    "DetectorConfig", "TIER_CONFIGS", "Firing", "EWMADetector",
    "CUSUMDetector", "RollingQuantileDetector", "SeriesWatch",
    "StreamWatcher", "RevisionResponder",
    "BurnRateRule", "SERVING_RULES", "SLOAlert", "SLOWatcher",
    "watch_replay",
    "HISTORY_SCHEMA", "BenchRun", "BenchHistory", "flatten_metrics",
    "metric_direction", "check_regressions", "format_report",
    "history_dir",
    "collect_data", "render_dashboard", "save_dashboard",
    "history_series",
]
