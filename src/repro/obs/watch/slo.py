"""Multi-window SLO burn-rate alerting over the serving stream.

An SLO is an objective over a rolling fraction of good events ("99% of
requests get their first token within the TTFT SLO").  The *burn rate*
over a window is how fast the error budget is being consumed:

    burn = (bad / total within the window) / (1 - objective)

burn == 1 means "exactly on budget"; burn == 14.4 over an hour means the
whole 30-day budget would be gone in ~2 days.  A single window either
pages too slowly (long window) or flaps on noise (short window); the
standard multi-window rule fires only when **both** a fast and a slow
window exceed their thresholds — the slow window confirms the problem is
real, the fast window confirms it is *still happening* (the alert
self-clears once the fast window drains).

The serving scheduler streams per-request outcomes here (one
``record(...)`` per evicted request, on the scheduler's own clock — the
simulated clock during trace replay, so replays exercise the exact alert
path production would).  ``check()`` evaluates every rule, emits
structured ``obs.alert("slo_burn", ...)`` instants into the existing
alert stream/counter, keeps a sampled burn-rate timeline for the
observatory dashboard, and re-arms only after the rule stops firing
(hysteresis — one alert per violation episode, not one per request).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from .. import alert as _obs_alert


@dataclasses.dataclass(frozen=True)
class BurnRateRule:
    """One multi-window burn-rate rule over a named good/bad stream."""

    name: str                    # "ttft", "tpot", "goodput"...
    objective: float = 0.99      # target good fraction (budget = 1 - obj)
    fast_window_s: float = 60.0
    slow_window_s: float = 600.0
    fast_burn: float = 14.4      # page-grade defaults (SRE workbook)
    slow_burn: float = 6.0
    min_events: int = 10         # slow-window events before the rule arms

    @property
    def budget(self) -> float:
        return max(1.0 - self.objective, 1e-9)


#: serving defaults: TTFT and TPOT latency objectives plus a combined
#: goodput objective (the SLO-met flag the scheduler already computes).
SERVING_RULES: Tuple[BurnRateRule, ...] = (
    BurnRateRule("ttft", objective=0.95),
    BurnRateRule("tpot", objective=0.95),
    # wide budget -> page-grade burns would need a bad-ratio > 1 (a burn
    # of 14.4 on a 10% budget is unreachable); scale the thresholds so
    # the rule can actually fire while keeping the fast/slow shape.
    BurnRateRule("goodput", objective=0.90, fast_burn=6.0, slow_burn=3.0),
)


@dataclasses.dataclass
class SLOAlert:
    rule: str
    clock: float
    fast_burn: float
    slow_burn: float
    fast_threshold: float
    slow_threshold: float
    n_fast: int
    n_slow: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class _WindowedRatio:
    """Bad/total counts over a sliding time window of (t, good) events."""

    __slots__ = ("window_s", "events", "bad")

    def __init__(self, window_s: float):
        self.window_s = window_s
        self.events: Deque[Tuple[float, bool]] = deque()
        self.bad = 0

    def add(self, t: float, good: bool) -> None:
        self.events.append((t, good))
        if not good:
            self.bad += 1
        self.trim(t)

    def trim(self, now: float) -> None:
        cut = now - self.window_s
        ev = self.events
        while ev and ev[0][0] < cut:
            _, good = ev.popleft()
            if not good:
                self.bad -= 1

    def ratio(self) -> float:
        n = len(self.events)
        return self.bad / n if n else 0.0


class SLOWatcher:
    """Streams (clock, rule, good) outcomes into multi-window burn rates.

    ``record`` is O(1) amortized per event per rule; ``check`` is O(rules)
    and safe to call every scheduler step.  ``timeline`` keeps a bounded
    sample of (clock, rule, fast, slow, firing) points — the observatory
    dashboard's burn-rate chart reads it directly.
    """

    def __init__(self, rules: Sequence[BurnRateRule] = SERVING_RULES,
                 on_fire: Optional[Callable[[SLOAlert], object]] = None,
                 emit_alerts: bool = True, max_timeline: int = 4096,
                 sample_every_s: float = 0.0):
        self.rules = {r.name: r for r in rules}
        self.on_fire = on_fire
        self.emit_alerts = emit_alerts
        self.alerts: List[SLOAlert] = []
        self.timeline: Deque[dict] = deque(maxlen=max_timeline)
        self.sample_every_s = sample_every_s
        self._last_sample: Dict[str, float] = {}
        self._firing: Dict[str, bool] = {}
        self._win: Dict[str, Tuple[_WindowedRatio, _WindowedRatio]] = {
            name: (_WindowedRatio(r.fast_window_s),
                   _WindowedRatio(r.slow_window_s))
            for name, r in self.rules.items()}

    # -- ingestion -----------------------------------------------------------
    def record(self, clock: float, rule: str, good: bool) -> None:
        """One request outcome against one rule (unknown rules ignored so
        callers can stream superset outcomes)."""
        win = self._win.get(rule)
        if win is None:
            return
        win[0].add(clock, good)
        win[1].add(clock, good)

    def record_outcomes(self, clock: float, **outcomes: bool) -> None:
        """``record_outcomes(t, ttft=True, tpot=False, goodput=False)``"""
        for rule, good in outcomes.items():
            self.record(clock, rule, good)

    # -- evaluation ----------------------------------------------------------
    def burn_rates(self, clock: float, rule: str) -> Tuple[float, float,
                                                           int, int]:
        r = self.rules[rule]
        fast, slow = self._win[rule]
        fast.trim(clock)
        slow.trim(clock)
        return (fast.ratio() / r.budget, slow.ratio() / r.budget,
                len(fast.events), len(slow.events))

    def check(self, clock: float) -> List[SLOAlert]:
        """Evaluate every rule at ``clock``; returns (and emits) new
        alerts.  A rule that keeps burning stays in the "firing" state
        and does not re-alert until it first clears (hysteresis)."""
        out: List[SLOAlert] = []
        for name, r in self.rules.items():
            fb, sb, n_fast, n_slow = self.burn_rates(clock, name)
            firing = (n_slow >= r.min_events
                      and fb >= r.fast_burn and sb >= r.slow_burn)
            self._sample(clock, name, fb, sb, firing)
            was = self._firing.get(name, False)
            self._firing[name] = firing
            if not firing or was:
                continue
            al = SLOAlert(name, clock, fb, sb, r.fast_burn, r.slow_burn,
                          n_fast, n_slow)
            self.alerts.append(al)
            out.append(al)
            if self.emit_alerts:
                _obs_alert("slo_burn", rule=name, clock=clock,
                           fast_burn=fb, slow_burn=sb,
                           fast_threshold=r.fast_burn,
                           slow_threshold=r.slow_burn)
            if self.on_fire is not None:
                self.on_fire(al)
        return out

    def _sample(self, clock: float, rule: str, fast: float, slow: float,
                firing: bool) -> None:
        last = self._last_sample.get(rule)
        if last is not None and clock - last < self.sample_every_s \
                and not firing:
            return
        self._last_sample[rule] = clock
        self.timeline.append({"t": round(float(clock), 6), "rule": rule,
                              "fast": round(float(fast), 4),
                              "slow": round(float(slow), 4),
                              "firing": firing})

    def firing(self) -> List[str]:
        """Rules currently in the firing state (as of the last ``check``).
        ``check`` only *returns* an alert on the clear->firing edge; a
        degradation controller needs the level, not the edge."""
        return [name for name, f in self._firing.items() if f]

    # -- output --------------------------------------------------------------
    def summary(self) -> dict:
        """JSON-ready state (dashboard + CI consumption)."""
        rules = {}
        for name, r in self.rules.items():
            fast, slow = self._win[name]
            rules[name] = {
                "objective": r.objective,
                "fast_window_s": r.fast_window_s,
                "slow_window_s": r.slow_window_s,
                "fast_burn_threshold": r.fast_burn,
                "slow_burn_threshold": r.slow_burn,
                "firing": self._firing.get(name, False),
                "n_alerts": sum(1 for a in self.alerts if a.rule == name),
            }
        return {"rules": rules,
                "n_alerts": len(self.alerts),
                "alerts": [a.to_dict() for a in self.alerts[-64:]],
                "timeline": list(self.timeline)}


def watch_replay(reports, scheduler, watcher: Optional[SLOWatcher] = None,
                 ) -> SLOWatcher:
    """Post-hoc burn-rate pass over finished scheduler state — for runs
    that did not attach a watcher live.  Uses each request's recorded
    finish clock and the scheduler's SLO thresholds."""
    w = watcher or SLOWatcher()
    outcomes = []
    for rs in scheduler.finished.values():
        m = rs.metrics()
        ttft_ok = (scheduler.ttft_slo_s is None or
                   (m["ttft_s"] is not None
                    and m["ttft_s"] <= scheduler.ttft_slo_s))
        tpot_ok = (scheduler.tpot_slo_s is None or m["n_out"] <= 1
                   or m["tpot_s"] <= scheduler.tpot_slo_s)
        outcomes.append((rs.finish_s, ttft_ok, tpot_ok))
    outcomes.sort(key=lambda x: x[0])
    for t, ttft_ok, tpot_ok in outcomes:
        w.record_outcomes(t, ttft=ttft_ok, tpot=tpot_ok,
                          goodput=ttft_ok and tpot_ok)
        w.check(t)
    return w
