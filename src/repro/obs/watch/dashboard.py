"""Observatory dashboard: one self-contained HTML file, no server.

:func:`collect_data` assembles a single JSON blob from the pieces the
repo already produces — ``obs.summary()`` (per-tier residual roll-ups,
alert counters, metric snapshot), the telemetry accuracy report
(per-algorithm mean/max rel-err), the watch/SLO watcher summaries, and
the bench history.  :func:`render_dashboard` embeds that blob verbatim
into a static template (inline CSS + vanilla JS, zero external
requests) that renders:

* stat tiles — paired spans, overall mean rel-err, active alerts;
* the per-algorithm accuracy table (the paper's Tables II-V view);
* per-tier rel-err residual histograms;
* SLO burn-rate timelines with the firing thresholds drawn in;
* bench-history sparklines (one per tracked metric, per machine);
* the alert feed (drift / watch / SLO burn, newest first).

The data contract is §11 of DESIGN.md: everything the JS reads lives
under the single ``window.DATA`` object, so any other consumer (CI, a
notebook) can reuse :func:`collect_data` output directly.  Generation is
pure string assembly — rendering a 10k-span session is bounded by the
``json.dumps`` of its summary, well under the 1 s bench gate.
"""

from __future__ import annotations

import json
import os
import time
from typing import Optional, Sequence

DEFAULT_PATH = os.path.join("artifacts", "obs", "dashboard.html")

#: cap on sparkline series per bench — the flattener can emit dozens of
#: leaves; the dashboard shows the first N alphabetically and says so.
MAX_SPARKS_PER_BENCH = 12


def _maybe_summary(obj):
    if obj is None or isinstance(obj, dict):
        return obj
    return obj.summary()


def history_series(runs: Sequence, max_per_bench: int = MAX_SPARKS_PER_BENCH,
                   ) -> dict:
    """Group :class:`~repro.obs.watch.history.BenchRun` rows into
    sparkline series: bench -> metric -> [{t, commit, v}] (time-sorted,
    metrics with <2 points dropped — a sparkline needs a trajectory)."""
    benches: dict = {}
    for run in sorted(runs, key=lambda r: r.timestamp):
        b = benches.setdefault(run.bench, {})
        for metric, value in run.metrics.items():
            b.setdefault(metric, []).append(
                {"t": run.timestamp, "commit": run.commit[:9],
                 "v": float(value)})
    out = {}
    for bench, metrics in sorted(benches.items()):
        keep = {m: pts for m, pts in sorted(metrics.items())
                if len(pts) >= 2}
        dropped = len(keep) - max_per_bench
        out[bench] = {
            "metrics": dict(list(keep.items())[:max_per_bench]),
            "dropped_metrics": max(0, dropped),
        }
    return out


def collect_data(summary: Optional[dict] = None,
                 accuracy: Optional[dict] = None,
                 watch=None, slo=None,
                 history: Optional[Sequence] = None,
                 title: str = "repro observatory") -> dict:
    """Assemble the dashboard data blob (§11 data contract).

    Every argument is optional: ``summary`` defaults to a live
    ``obs.summary()`` call; ``watch``/``slo`` accept watcher objects or
    their ``summary()`` dicts; ``history`` is a sequence of
    :class:`BenchRun` (or an already-grouped dict)."""
    if summary is None:
        from ..summary import summary as obs_summary
        summary = obs_summary()
    if history is None:
        hist = None
    elif isinstance(history, dict):
        hist = history
    else:
        hist = history_series(history)
    return {
        "title": title,
        "generated_unix": time.time(),
        "obs": summary,
        "accuracy": accuracy,
        "watch": _maybe_summary(watch),
        "slo": _maybe_summary(slo),
        "history": hist,
    }


def render_dashboard(data: dict) -> str:
    """The data blob -> a single HTML document (string)."""
    blob = json.dumps(data, sort_keys=True).replace("</", "<\\/")
    return _TEMPLATE.replace("__DATA__", blob)


def save_dashboard(path: Optional[str] = None,
                   data: Optional[dict] = None, **collect_kwargs) -> str:
    """Render and write the dashboard; returns the path."""
    if data is None:
        data = collect_data(**collect_kwargs)
    if path is None:
        path = DEFAULT_PATH
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(render_dashboard(data))
    return path


# The template keeps to the repo's chart conventions: text never wears a
# series color, marks are thin (2px lines, slim rounded-top bars), grids
# recessive, light/dark from one set of CSS custom properties, and every
# mark carries a native <title> tooltip so the numbers are hoverable
# without any dependency.
_TEMPLATE = """<!doctype html>
<html lang="en">
<head>
<meta charset="utf-8">
<meta name="viewport" content="width=device-width, initial-scale=1">
<title>repro observatory</title>
<style>
:root {
  --surface: #fcfcfb; --ink: #0b0b0b; --ink2: #52514e; --ink3: #898781;
  --grid: #e1e0d9; --card: #ffffff; --edge: #e1e0d9;
  --s1: #2a78d6; --s2: #898781;
  --good: #0ca30c; --warn: #fab219; --serious: #ec835a; --crit: #d03b3b;
}
@media (prefers-color-scheme: dark) {
  :root {
    --surface: #1a1a19; --ink: #ffffff; --ink2: #c3c2b7; --ink3: #898781;
    --grid: #2c2c2a; --card: #222221; --edge: #2c2c2a;
    --s1: #3987e5;
  }
}
* { box-sizing: border-box; }
body {
  margin: 0; padding: 24px; background: var(--surface); color: var(--ink);
  font: 14px/1.45 system-ui, -apple-system, "Segoe UI", sans-serif;
}
h1 { font-size: 18px; font-weight: 650; margin: 0 0 2px; }
h2 { font-size: 13px; font-weight: 600; color: var(--ink2);
     text-transform: uppercase; letter-spacing: .04em; margin: 28px 0 10px; }
.sub { color: var(--ink3); font-size: 12px; margin-bottom: 18px; }
.tiles { display: flex; flex-wrap: wrap; gap: 12px; }
.tile { background: var(--card); border: 1px solid var(--edge);
        border-radius: 8px; padding: 12px 16px; min-width: 150px; }
.tile .k { font-size: 12px; color: var(--ink2); }
.tile .v { font-size: 24px; font-weight: 650;
           font-variant-numeric: tabular-nums; }
.tile .d { font-size: 11px; color: var(--ink3); }
.cards { display: flex; flex-wrap: wrap; gap: 12px; }
.card { background: var(--card); border: 1px solid var(--edge);
        border-radius: 8px; padding: 12px 16px; }
.card .t { font-size: 12px; font-weight: 600; color: var(--ink2);
           margin-bottom: 6px; }
table { border-collapse: collapse; background: var(--card);
        border: 1px solid var(--edge); border-radius: 8px; }
th, td { padding: 6px 14px; text-align: right; font-size: 13px; }
th { color: var(--ink2); font-weight: 600; border-bottom: 1px solid var(--edge); }
td { font-variant-numeric: tabular-nums; border-bottom: 1px solid var(--grid); }
tr:last-child td { border-bottom: none; }
th:first-child, td:first-child { text-align: left;
                                 font-variant-numeric: normal; }
.dot { display: inline-block; width: 8px; height: 8px; border-radius: 50%;
       margin-right: 6px; vertical-align: baseline; }
.alerts { list-style: none; margin: 0; padding: 0; }
.alerts li { background: var(--card); border: 1px solid var(--edge);
             border-radius: 8px; padding: 8px 12px; margin-bottom: 6px;
             font-size: 13px; }
.alerts .when { color: var(--ink3); font-size: 12px; margin-left: 8px;
                font-variant-numeric: tabular-nums; }
.badge { font-weight: 650; margin-right: 8px; }
.empty { color: var(--ink3); font-size: 13px; }
.legend { font-size: 12px; color: var(--ink2); margin-top: 4px; }
.legend .sw { display: inline-block; width: 14px; height: 3px;
              border-radius: 2px; margin: 0 5px 2px 12px;
              vertical-align: middle; }
svg text { fill: var(--ink3); font: 10px system-ui, sans-serif; }
svg .axis { stroke: var(--grid); stroke-width: 1; }
</style>
</head>
<body>
<h1 id="title"></h1>
<div class="sub" id="sub"></div>
<div class="tiles" id="tiles"></div>
<div id="sections"></div>
<script>
window.DATA = __DATA__;
(function () {
"use strict";
var D = window.DATA, css = getComputedStyle(document.documentElement);
function v(name) { return css.getPropertyValue(name).trim(); }
var C = { s1: v('--s1'), s2: v('--s2'), grid: v('--grid'),
          good: v('--good'), warn: v('--warn'), serious: v('--serious'),
          crit: v('--crit'), ink3: v('--ink3') };
function esc(s) { return String(s).replace(/&/g, '&amp;')
  .replace(/</g, '&lt;').replace(/>/g, '&gt;').replace(/"/g, '&quot;'); }
function fmt(x, d) {
  if (x === null || x === undefined || Number.isNaN(x)) return '–';
  if (typeof x !== 'number') return esc(x);
  var a = Math.abs(x);
  if (d === undefined) d = a >= 100 ? 0 : a >= 1 ? 2 : 4;
  if (a >= 1e6 || (a > 0 && a < 1e-3)) return x.toExponential(2);
  return x.toFixed(d);
}
function pct(x) {
  return (x === null || x === undefined || Number.isNaN(x))
    ? '–' : (100 * x).toFixed(1) + '%';
}
function el(html) {
  var t = document.createElement('template');
  t.innerHTML = html.trim(); return t.content.firstChild;
}
function section(title) {
  var root = document.getElementById('sections');
  root.appendChild(el('<h2>' + esc(title) + '</h2>'));
  var box = el('<div class="cards"></div>');
  root.appendChild(box); return box;
}
function tile(k, val, detail) {
  document.getElementById('tiles').appendChild(el(
    '<div class="tile"><div class="k">' + esc(k) + '</div>' +
    '<div class="v">' + val + '</div>' +
    (detail ? '<div class="d">' + esc(detail) + '</div>' : '') + '</div>'));
}
function errColor(e) {
  if (e === null || e === undefined) return C.ink3;
  return e < 0.25 ? C.good : e < 0.5 ? C.warn : e < 1 ? C.serious : C.crit;
}

// ---- header + stat tiles ----
document.getElementById('title').textContent = D.title || 'repro observatory';
document.getElementById('sub').textContent = 'generated ' +
  new Date(1000 * (D.generated_unix || 0)).toISOString() +
  ' · spans: ' + ((D.obs && D.obs.n_spans) || 0);
var obs = D.obs || {}, tiers = obs.tiers || {};
var paired = 0, nerr = 0;
Object.keys(tiers).forEach(function (t) {
  paired += tiers[t].n_paired || 0; nerr += tiers[t].n_errors || 0;
});
var overall = D.accuracy && D.accuracy.overall;
var alertTotal = 0, ak = obs.alerts || {};
Object.keys(ak).forEach(function (k) { alertTotal += ak[k]; });
tile('paired spans', String(paired), nerr + ' span errors');
tile('mean rel err', overall ? pct(overall.mean_rel_err) : '–',
     overall ? ('max ' + pct(overall.max_rel_err)) : 'no accuracy report');
tile('alerts', String(alertTotal),
     Object.keys(ak).sort().map(function (k) {
       return k + ':' + ak[k]; }).join(' ') || 'none');
var firingRules = [];
if (D.slo && D.slo.rules) Object.keys(D.slo.rules).forEach(function (r) {
  if (D.slo.rules[r].firing) firingRules.push(r); });
tile('SLO burn', firingRules.length ? 'FIRING' : 'ok',
     firingRules.join(', ') || 'no rule firing');

// ---- per-algorithm accuracy table ----
if (D.accuracy && D.accuracy.ops && Object.keys(D.accuracy.ops).length) {
  var box = section('model accuracy by algorithm');
  var rows = Object.keys(D.accuracy.ops).sort().map(function (op) {
    var r = D.accuracy.ops[op];
    return '<tr><td><span class="dot" style="background:' +
      errColor(r.mean_rel_err) + '"></span>' + esc(op) + '</td><td>' +
      r.n_rows + '</td><td>' + pct(r.mean_rel_err) + '</td><td>' +
      pct(r.max_rel_err) + '</td><td>' +
      fmt(r.mean_abs_log_ratio, 3) + '</td></tr>';
  }).join('');
  var ov = D.accuracy.overall || {};
  box.appendChild(el('<table><thead><tr><th>algorithm</th><th>rows</th>' +
    '<th>mean rel err</th><th>max rel err</th><th>mean |log ratio|</th>' +
    '</tr></thead><tbody>' + rows +
    '<tr><td><b>overall</b></td><td>' + (ov.n_rows || 0) + '</td><td>' +
    pct(ov.mean_rel_err) + '</td><td>' + pct(ov.max_rel_err) +
    '</td><td>' + fmt(ov.mean_abs_log_ratio, 3) + '</td></tr>' +
    '</tbody></table>'));
}

// ---- per-tier residual histograms ----
function histSVG(bounds, counts) {
  var W = 260, H = 90, pad = 16, n = counts.length;
  var bw = Math.min(24, Math.floor((W - 2 * pad) / Math.max(n, 1)) - 2);
  var max = Math.max.apply(null, counts.concat([1]));
  var bars = '';
  for (var i = 0; i < n; i++) {
    var h = Math.round((H - 28) * counts[i] / max);
    var x = pad + i * (bw + 2), y = H - 14 - h;
    var lab = (i ? '[' + bounds[i - 1] + ', ' : '[0, ') + bounds[i] + ')';
    bars += '<rect x="' + x + '" y="' + y + '" width="' + bw +
      '" height="' + Math.max(h, counts[i] ? 2 : 0) + '" rx="4" fill="' +
      C.s1 + '"><title>rel err ' + esc(lab) + ': ' + counts[i] +
      '</title></rect>';
    bars += '<text x="' + (x + bw / 2) + '" y="' + (H - 3) +
      '" text-anchor="middle">' + esc(String(bounds[i])) + '</text>';
  }
  return '<svg width="' + W + '" height="' + H + '" role="img">' +
    '<line class="axis" x1="' + pad + '" y1="' + (H - 14) + '" x2="' +
    (W - pad) + '" y2="' + (H - 14) + '"/>' + bars + '</svg>';
}
var tierNames = Object.keys(tiers).sort();
if (tierNames.length) {
  var hb = section('rel-err residual histograms (per tier)');
  tierNames.forEach(function (t) {
    var ti = tiers[t], h = ti.rel_err_hist || {};
    var counts = h.counts || [], total = counts.reduce(
      function (a, b) { return a + b; }, 0);
    var body = total
      ? histSVG(h.bounds || [], counts)
      : '<div class="empty">no paired spans</div>';
    hb.appendChild(el('<div class="card"><div class="t">' + esc(t) +
      ' · mean ' + pct(ti.mean_rel_err) + ' · max ' + pct(ti.max_rel_err) +
      '</div>' + body + '</div>'));
  });
}

// ---- SLO burn-rate timelines ----
function burnSVG(pts, rule) {
  var W = 420, H = 120, padL = 34, padR = 8, padT = 8, padB = 16;
  var ts = pts.map(function (p) { return p.t; });
  var t0 = Math.min.apply(null, ts), t1 = Math.max.apply(null, ts);
  if (t1 <= t0) t1 = t0 + 1;
  var ymax = Math.max(rule.fast_burn_threshold * 1.2, 1);
  pts.forEach(function (p) {
    ymax = Math.max(ymax, p.fast, p.slow); });
  function X(t) { return padL + (W - padL - padR) * (t - t0) / (t1 - t0); }
  function Y(y) { return padT + (H - padT - padB) * (1 - y / ymax); }
  function path(key) {
    return pts.map(function (p, i) {
      return (i ? 'L' : 'M') + X(p.t).toFixed(1) + ' ' +
        Y(p[key]).toFixed(1);
    }).join('');
  }
  var marks = '';
  pts.forEach(function (p) {
    if (p.firing) marks += '<circle cx="' + X(p.t).toFixed(1) + '" cy="' +
      Y(p.fast).toFixed(1) + '" r="4" fill="' + C.crit +
      '" stroke="var(--card)" stroke-width="2"><title>firing at t=' +
      fmt(p.t, 1) + 's (fast ' + fmt(p.fast, 1) + 'x, slow ' +
      fmt(p.slow, 1) + 'x)</title></circle>';
  });
  var thr = '';
  [['fast_burn_threshold', C.crit], ['slow_burn_threshold', C.warn]]
    .forEach(function (td) {
      var y = Y(rule[td[0]]);
      if (y > padT && y < H - padB)
        thr += '<line x1="' + padL + '" y1="' + y.toFixed(1) + '" x2="' +
          (W - padR) + '" y2="' + y.toFixed(1) + '" stroke="' + td[1] +
          '" stroke-width="1" stroke-dasharray="4 3" opacity="0.7"/>';
    });
  return '<svg width="' + W + '" height="' + H + '" role="img">' +
    '<line class="axis" x1="' + padL + '" y1="' + (H - padB) + '" x2="' +
    (W - padR) + '" y2="' + (H - padB) + '"/>' +
    '<text x="2" y="' + (padT + 8) + '">' + fmt(ymax, 0) + 'x</text>' +
    '<text x="2" y="' + (H - padB) + '">0</text>' + thr +
    '<path d="' + path('slow') + '" fill="none" stroke="' + C.s2 +
    '" stroke-width="2"/>' +
    '<path d="' + path('fast') + '" fill="none" stroke="' + C.s1 +
    '" stroke-width="2"/>' + marks + '</svg>';
}
if (D.slo && D.slo.timeline && D.slo.timeline.length) {
  var sb = section('SLO burn rate (x budget)');
  var byRule = {};
  D.slo.timeline.forEach(function (p) {
    (byRule[p.rule] = byRule[p.rule] || []).push(p); });
  Object.keys(byRule).sort().forEach(function (name) {
    var rule = (D.slo.rules || {})[name] || {};
    sb.appendChild(el('<div class="card"><div class="t">' + esc(name) +
      ' (objective ' + pct(rule.objective) + ')' +
      (rule.firing ? ' — FIRING' : '') + '</div>' +
      burnSVG(byRule[name], rule) +
      '<div class="legend"><span class="sw" style="background:' + C.s1 +
      '"></span>fast ' + fmt(rule.fast_window_s, 0) +
      's<span class="sw" style="background:' + C.s2 + '"></span>slow ' +
      fmt(rule.slow_window_s, 0) + 's</div></div>'));
  });
}

// ---- bench-history sparklines ----
function sparkSVG(pts) {
  var W = 150, H = 34, pad = 3;
  var vs = pts.map(function (p) { return p.v; });
  var lo = Math.min.apply(null, vs), hi = Math.max.apply(null, vs);
  if (hi <= lo) { hi = lo + 1; lo = lo - 1; }
  function X(i) { return pad + (W - 2 * pad) * i / (pts.length - 1); }
  function Y(val) { return pad + (H - 2 * pad) * (1 - (val - lo) / (hi - lo)); }
  var d = pts.map(function (p, i) {
    return (i ? 'L' : 'M') + X(i).toFixed(1) + ' ' + Y(p.v).toFixed(1);
  }).join('');
  var last = pts[pts.length - 1];
  return '<svg width="' + W + '" height="' + H + '" role="img">' +
    '<path d="' + d + '" fill="none" stroke="' + C.s1 +
    '" stroke-width="2"/>' +
    '<circle cx="' + X(pts.length - 1).toFixed(1) + '" cy="' +
    Y(last.v).toFixed(1) + '" r="3" fill="' + C.s1 +
    '"><title>' + esc(last.commit || '') + ': ' + fmt(last.v) +
    '</title></circle></svg>';
}
if (D.history && Object.keys(D.history).length) {
  var hb2 = section('bench history');
  Object.keys(D.history).sort().forEach(function (bench) {
    var metrics = D.history[bench].metrics || {};
    var names = Object.keys(metrics).sort();
    if (!names.length) return;
    var rows = names.map(function (m) {
      var pts = metrics[m], last = pts[pts.length - 1];
      return '<tr><td>' + esc(m) + '</td><td>' + sparkSVG(pts) +
        '</td><td>' + fmt(last.v) + '</td></tr>';
    }).join('');
    var note = D.history[bench].dropped_metrics
      ? '<div class="legend">+' + D.history[bench].dropped_metrics +
        ' more metrics tracked</div>' : '';
    hb2.appendChild(el('<div class="card"><div class="t">' + esc(bench) +
      '</div><table><thead><tr><th>metric</th><th>trend</th>' +
      '<th>latest</th></tr></thead><tbody>' + rows + '</tbody></table>' +
      note + '</div>'));
  });
}

// ---- alert feed ----
var feed = [];
if (D.watch && D.watch.firings) D.watch.firings.forEach(function (f) {
  feed.push({ kind: 'watch/' + f.detector, what: f.series + ' value ' +
    fmt(f.value) + ' vs ' + f.stat + ' ' + fmt(f.threshold),
    sev: 'serious', at: f.n_obs + ' obs' });
});
if (D.slo && D.slo.alerts) D.slo.alerts.forEach(function (a) {
  feed.push({ kind: 'slo_burn/' + a.rule, what: 'fast ' +
    fmt(a.fast_burn, 1) + 'x / slow ' + fmt(a.slow_burn, 1) +
    'x budget', sev: 'critical', at: 't=' + fmt(a.clock, 1) + 's' });
});
var root = document.getElementById('sections');
root.appendChild(el('<h2>alert feed</h2>'));
if (feed.length) {
  var ul = el('<ul class="alerts"></ul>');
  feed.slice(-40).reverse().forEach(function (f) {
    var col = f.sev === 'critical' ? C.crit : C.serious;
    ul.appendChild(el('<li><span class="badge" style="color:' + col +
      '">&#9650; ' + esc(f.kind) + '</span>' + esc(f.what) +
      '<span class="when">' + esc(f.at) + '</span></li>'));
  });
  root.appendChild(ul);
} else {
  root.appendChild(el('<div class="empty">no alerts recorded</div>'));
}
})();
</script>
</body>
</html>
"""
