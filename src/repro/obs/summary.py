"""CI-consumable roll-up of the observability stream.

:func:`summary` folds the tracer's buffered spans into per-tier
residual statistics — kernel launches, cost-IR op dispatches, serving
steps — using the same relative-error bucket bounds the metrics layer
uses, plus an alert roll-up and the registry snapshot.  The output is
plain JSON: a CI step can gate on ``tiers["op"]["mean_rel_err"]``
without parsing a trace viewer file.
"""

from __future__ import annotations

import json
import os
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional

from .metrics import REL_ERR_BUCKETS
from .spans import Span

#: span category -> residual tier.
_TIERS = {"kernel": "kernel", "dispatch": "op", "manual": "op",
          "serve": "serve", "serve_step": "serve"}


def tier_of(cat: str) -> Optional[str]:
    return _TIERS.get(cat)


def _tier_stats(spans: List[Span]) -> dict:
    paired = [sp for sp in spans if sp.rel_err is not None]
    errs = [sp.rel_err for sp in paired]
    resid = [sp.residual_s for sp in paired]
    counts = [0] * (len(REL_ERR_BUCKETS) + 1)
    for e in errs:
        counts[bisect_left(REL_ERR_BUCKETS, e)] += 1
    return {
        "n_spans": len(spans),
        "n_errors": sum(1 for sp in spans if sp.error),
        "n_paired": len(paired),
        "mean_rel_err": sum(errs) / len(errs) if errs else None,
        "max_rel_err": max(errs) if errs else None,
        "mean_residual_s": sum(resid) / len(resid) if resid else None,
        "rel_err_hist": {"bounds": list(REL_ERR_BUCKETS) + ["+Inf"],
                         "counts": counts},
    }


def summary(tracer=None, registry=None,
            spans: Optional[Iterable[Span]] = None) -> dict:
    """Per-tier residual roll-up + alerts + metrics snapshot."""
    from . import default_registry, tracer as _tracer

    if spans is None:
        tr = tracer if tracer is not None else _tracer()
        spans = tr.spans()
        dropped = tr.dropped
    else:
        spans = list(spans)
        dropped = 0
    reg = registry if registry is not None else default_registry()

    by_tier: Dict[str, List[Span]] = {}
    alerts: Dict[str, int] = {}
    for sp in spans:
        if sp.kind == "instant":
            if sp.cat == "alert":
                alerts[sp.name] = alerts.get(sp.name, 0) + 1
            continue
        t = tier_of(sp.cat)
        if t is not None:
            by_tier.setdefault(t, []).append(sp)

    return {
        "n_spans": len(spans),
        "n_dropped": dropped,
        "tiers": {t: _tier_stats(sps) for t, sps in sorted(by_tier.items())},
        "alerts": alerts,
        **reg.snapshot(),
    }


def save_summary(path: Optional[str] = None, **kwargs) -> str:
    """Write :func:`summary` JSON under ``artifacts/obs/`` (or ``path``)."""
    if path is None:
        from ..core.calibration import ARTIFACTS_DIR
        path = os.path.join(os.path.abspath(ARTIFACTS_DIR), "obs",
                            "summary.json")
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(summary(**kwargs), f, indent=2, sort_keys=True)
    return path
