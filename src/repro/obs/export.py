"""Unified Chrome/Perfetto trace exporter — measured *and* predicted.

Every trace this repo produces (tracer spans from a live dispatch,
scheduler step reports from a serving replay, per-rank simulator
results) goes through one :class:`TraceBuilder`, so they share one
format: trace-event JSON loadable in ``chrome://tracing`` / Perfetto
with

* pid 0 = the **measured** timeline (what the machine did),
* pid 1 = the **predicted** timeline (what the model promised),

and, for every measured region whose emitter knew the model's
prediction, a *paired* predicted slice starting at the same timestamp
with the predicted duration, a flow arrow linking the pair, and the
signed residual (``measured - predicted`` seconds, plus relative
error) annotated on both sides — open the trace and the places where
model and machine disagree are literally the places the arrows
stretch.

Pairing rule: a span pairs iff ``predicted_s`` is set and positive and
the span closed with a positive duration; the predicted twin copies
the measured span's name/category/track so the two timelines line up
row-for-row.  Instant events (drift alerts) ride on the measured
timeline unpaired.
"""

from __future__ import annotations

import itertools
import json
import logging
import os
from typing import Dict, Iterable, List, Optional, Sequence

from .spans import Span

log = logging.getLogger("repro.obs")

MEASURED_PID = 0
PREDICTED_PID = 1

_SCALE = 1e6  # seconds -> trace-event microseconds


def traces_dir() -> str:
    # deferred: core.calibration owns the artifacts-root resolution (and
    # pulls jax-adjacent modules we don't want at obs import time)
    from ..core.calibration import ARTIFACTS_DIR
    return os.path.join(os.path.abspath(ARTIFACTS_DIR), "traces")


class TraceBuilder:
    """Incremental trace-event assembly (one flat ``traceEvents`` list)."""

    def __init__(self):
        self.events: List[dict] = []
        self._flow_ids = itertools.count(1)

    # -- metadata -------------------------------------------------------------
    def process(self, pid: int, name: str,
                sort_index: Optional[int] = None) -> None:
        self.events.append({"name": "process_name", "ph": "M", "pid": pid,
                            "args": {"name": name}})
        if sort_index is not None:
            self.events.append({"name": "process_sort_index", "ph": "M",
                                "pid": pid,
                                "args": {"sort_index": sort_index}})

    def thread(self, pid: int, tid: int, name: str) -> None:
        self.events.append({"name": "thread_name", "ph": "M", "pid": pid,
                            "tid": tid, "args": {"name": name}})

    # -- events ---------------------------------------------------------------
    def complete(self, name: str, ts_s: float, dur_s: float, *,
                 pid: int = MEASURED_PID, tid: int = 0, cat: str = "",
                 args: Optional[dict] = None) -> dict:
        ev = {"name": name, "ph": "X", "pid": pid, "tid": tid,
              "ts": ts_s * _SCALE, "dur": max(dur_s, 0.0) * _SCALE,
              "cat": cat or "phase"}
        if args:
            ev["args"] = args
        self.events.append(ev)
        return ev

    def instant(self, name: str, ts_s: float, *, pid: int = MEASURED_PID,
                tid: int = 0, cat: str = "", args: Optional[dict] = None
                ) -> dict:
        ev = {"name": name, "ph": "i", "s": "p", "pid": pid, "tid": tid,
              "ts": ts_s * _SCALE, "cat": cat or "alert"}
        if args:
            ev["args"] = args
        self.events.append(ev)
        return ev

    def counter(self, name: str, ts_s: float, values: Dict[str, float], *,
                pid: int = MEASURED_PID) -> dict:
        ev = {"name": name, "ph": "C", "pid": pid, "tid": 0,
              "ts": ts_s * _SCALE, "args": dict(values)}
        self.events.append(ev)
        return ev

    def flow(self, name: str, *, from_ts_s: float, from_pid: int,
             from_tid: int, to_ts_s: float, to_pid: int, to_tid: int,
             cat: str = "pair") -> int:
        """A flow arrow (trace-event ``s``/``f`` pair); returns its id."""
        fid = next(self._flow_ids)
        self.events.append({"name": name, "ph": "s", "id": fid, "cat": cat,
                            "pid": from_pid, "tid": from_tid,
                            "ts": from_ts_s * _SCALE})
        self.events.append({"name": name, "ph": "f", "bp": "e", "id": fid,
                            "cat": cat, "pid": to_pid, "tid": to_tid,
                            "ts": to_ts_s * _SCALE})
        return fid

    # -- pairing --------------------------------------------------------------
    def paired(self, name: str, ts_s: float, measured_s: float,
               predicted_s: Optional[float], *, tid: int = 0, cat: str = "",
               args: Optional[dict] = None) -> dict:
        """One measured slice, plus — when a prediction exists — its
        predicted twin, the flow link, and residual annotations."""
        margs = dict(args or {})
        if predicted_s is not None and predicted_s > 0 and measured_s > 0:
            resid = measured_s - predicted_s
            annot = {"predicted_s": predicted_s, "measured_s": measured_s,
                     "residual_s": resid, "rel_err": abs(resid) / measured_s}
            margs.update(annot)
            ev = self.complete(name, ts_s, measured_s, pid=MEASURED_PID,
                               tid=tid, cat=cat, args=margs)
            pargs = dict(annot)
            if "span_id" in margs:
                pargs["pair_of"] = margs["span_id"]
            self.complete(name, ts_s, predicted_s, pid=PREDICTED_PID,
                          tid=tid, cat=cat, args=pargs)
            self.flow(f"pair:{name}", from_ts_s=ts_s, from_pid=PREDICTED_PID,
                      from_tid=tid, to_ts_s=ts_s, to_pid=MEASURED_PID,
                      to_tid=tid)
            return ev
        return self.complete(name, ts_s, measured_s, pid=MEASURED_PID,
                             tid=tid, cat=cat, args=margs or None)

    # -- output ---------------------------------------------------------------
    def to_dict(self, other_data: Optional[dict] = None) -> dict:
        return {"traceEvents": self.events, "displayTimeUnit": "ms",
                "otherData": dict(other_data or {})}

    def save(self, path: str, other_data: Optional[dict] = None) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(other_data), f)
        return path


# ---------------------------------------------------------------------------
# tracer spans -> paired trace
# ---------------------------------------------------------------------------

def export_spans(spans: Sequence[Span],
                 other_data: Optional[dict] = None) -> dict:
    """The live-session exporter: every tracer span on the measured
    timeline (one track per OS thread), predicted twins + flows +
    residuals wherever the emitting layer attached ``predicted_s``."""
    tb = TraceBuilder()
    tb.process(MEASURED_PID, "measured", sort_index=0)
    tb.process(PREDICTED_PID, "predicted", sort_index=1)
    t0 = min((sp.start_s for sp in spans), default=0.0)
    tids: Dict[int, int] = {}
    for sp in spans:
        tid = tids.setdefault(sp.thread, len(tids))
    for thread, tid in tids.items():
        tb.thread(MEASURED_PID, tid, f"thread-{tid}")
        tb.thread(PREDICTED_PID, tid, f"thread-{tid}")
    n_paired = 0
    for sp in sorted(spans, key=lambda s: s.start_s):
        tid = tids[sp.thread]
        ts = sp.start_s - t0
        args = dict(sp.args)
        args["span_id"] = sp.span_id
        if sp.parent_id is not None:
            args["parent_id"] = sp.parent_id
        if sp.error:
            args["error"] = True
        if sp.kind == "instant":
            tb.instant(sp.name, ts, tid=tid, cat=sp.cat, args=args)
            continue
        if sp.predicted_s is not None and sp.predicted_s > 0 \
                and sp.dur_s > 0:
            n_paired += 1
        tb.paired(sp.name, ts, sp.dur_s, sp.predicted_s, tid=tid,
                  cat=sp.cat, args=args)
    info = {"n_spans": len(spans), "n_paired": n_paired}
    info.update(other_data or {})
    return tb.to_dict(info)


def save_trace(doc: dict, path: Optional[str] = None,
               name: str = "obs_trace.json") -> str:
    """Write an exporter document under ``artifacts/traces/`` (or
    ``path``) and return the file path."""
    if path is None:
        path = os.path.join(traces_dir(), name)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


# ---------------------------------------------------------------------------
# simulator results -> (optionally paired) trace
# ---------------------------------------------------------------------------

def sim_trace(sim, max_ranks: int = 64, eval_result=None) -> dict:
    """The per-rank simulator timeline through the unified builder (one
    measured track per rank, same layout `SimResult.chrome_trace` always
    had).  When the cap truncates, the dropped count is *annotated* in
    ``otherData`` and logged — never silent.  With ``eval_result`` (a
    ``perf.evaluate`` ``EvalResult`` for the same scenario) the model's
    per-phase predictions appear on the paired predicted track, flow-
    linked to the critical rank's measured phases with residuals."""
    import numpy as np

    tb = TraceBuilder()
    tb.process(MEASURED_PID,
               f"{sim.algo}/{sim.variant} on {sim.topology}"
               f" (n={sim.n:g}, p={sim.p})", sort_index=0)
    shown = min(sim.p, int(max_ranks))
    dropped = sim.p - shown
    if dropped > 0:
        log.warning("sim trace for %s/%s truncated to %d of %d ranks "
                    "(pass max_ranks to widen)", sim.algo, sim.variant,
                    shown, sim.p)
    cr = sim.critical_rank
    for rk in range(shown):
        tb.thread(MEASURED_PID, rk,
                  f"rank {rk}" + (" [critical]" if rk == cr else ""))
    for name, ph in sim.phases.items():
        for rk in range(shown):
            dur = float(ph.exposed[rk])
            if dur <= 0:
                continue
            tb.complete(name, float(ph.start[rk]), dur, tid=rk, cat="phase")

    other = sim.summary()
    other["ranks_shown"] = shown
    other["ranks_dropped"] = dropped
    if eval_result is not None:
        tb.process(PREDICTED_PID, "predicted (cost model)", sort_index=1)
        tb.thread(PREDICTED_PID, 0, "model phases")
        t = 0.0
        residuals = {}
        for name, ph in eval_result.phases.items():
            pred = float(np.asarray(ph.exposed).reshape(-1)[0])
            if pred <= 0:
                t += max(pred, 0.0)
                continue
            sim_ph = sim.phases.get(name)
            args = {"predicted_s": pred}
            if sim_ph is not None:
                meas = float(sim_ph.exposed[cr])
                if meas > 0:
                    args.update(measured_s=meas, residual_s=meas - pred,
                                rel_err=abs(meas - pred) / meas)
                    residuals[name] = meas - pred
                    if cr < shown:
                        tb.flow(f"pair:{name}", from_ts_s=t,
                                from_pid=PREDICTED_PID, from_tid=0,
                                to_ts_s=float(sim_ph.start[cr]),
                                to_pid=MEASURED_PID, to_tid=cr)
            tb.complete(name, t, pred, pid=PREDICTED_PID, tid=0,
                        cat="phase", args=args)
            t += pred
        other["predicted_total_s"] = t
        other["phase_residual_s"] = residuals
    return tb.to_dict(other)


# ---------------------------------------------------------------------------
# scheduler step reports -> paired serving trace
# ---------------------------------------------------------------------------

def serving_trace(reports: Iterable,
                  other_data: Optional[dict] = None) -> dict:
    """Paired serving timeline from scheduler :class:`StepReport`s (a
    live run or a ``trace.replay``): per-step measured slices (clock
    deltas) against the cost model's predicted step composition, with
    per-phase prefill/decode sub-tracks, flow links, residual
    annotations, and counter tracks for queue depth, KV-block occupancy
    and batch composition."""
    tb = TraceBuilder()
    tb.process(MEASURED_PID, "measured (scheduler)", sort_index=0)
    tb.process(PREDICTED_PID, "predicted (ServeCostModel)", sort_index=1)
    for pid in (MEASURED_PID, PREDICTED_PID):
        tb.thread(pid, 0, "step")
        tb.thread(pid, 1, "prefill")
        tb.thread(pid, 2, "decode")

    n_steps = 0
    total_resid = 0.0
    for rep in reports:
        n_steps += 1
        pred = rep.predicted
        meas_pf = float(rep.measured_prefill_s)
        meas_dc = float(rep.measured_decode_s)
        measured = meas_pf + meas_dc
        if measured <= 0:                  # simulated clock: the schedule
            meas_pf, meas_dc = pred.prefill_s, pred.decode_s
            measured = pred.total_s
        ts = float(rep.clock) - measured
        args = {"step": rep.step, "admitted": list(rep.admitted),
                "finished": list(rep.finished),
                "prefill_tokens": sum(n for _, n in rep.plan.prefill),
                "decode_batch": len(rep.plan.decode)}
        tb.paired(f"step {rep.step}", ts, measured, pred.total_s,
                  tid=0, cat="serve_step", args=args)
        if meas_pf > 0 or pred.prefill_s > 0:
            tb.paired("prefill", ts, meas_pf, pred.prefill_s, tid=1,
                      cat="serve_step")
        if meas_dc > 0 or pred.decode_s > 0:
            tb.paired("decode", ts + meas_pf, meas_dc, pred.decode_s,
                      tid=2, cat="serve_step")
        total_resid += measured - pred.total_s
        tb.counter("queue", ts, {"waiting": rep.queue_depth,
                                 "active": rep.active})
        tb.counter("kv_blocks", ts, {"used": rep.kv_blocks_used,
                                     "total": rep.kv_blocks_total})
        tb.counter("batch", ts,
                   {"prefill_tokens": args["prefill_tokens"],
                    "decode_batch": args["decode_batch"]})
    info = {"n_steps": n_steps, "total_residual_s": total_resid}
    info.update(other_data or {})
    return tb.to_dict(info)
