"""AdamW with ZeRO-1 optimizer-state sharding and mixed-precision policy.

ZeRO-1 is the LM-training incarnation of the paper's 2.5D trade — spend
communication (an extra all-gather of updated params) to cut per-chip
memory by the data-axis degree.  It is expressed purely through sharding
constraints: optimizer moments get the param's sharding *plus* the 'data'
axis on the first divisible replicated dimension, and GSPMD inserts the
reduce-scatter / all-gather pair around the update.

State dtype is configurable (fp32 default; bf16 for the 480B-MoE cells to
fit 16 GB/chip — recorded per-cell in EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..distributed import sharding as shd


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    zero_sharding: bool = True
    # "adamw" | "adafactor" — factored second moment (Shazeer & Stern),
    # no first moment: state is O(rows+cols) instead of 2x params.  The
    # production choice for ~0.5T-param models on tight HBM (cf. PaLM).
    kind: str = "adamw"


def lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _zero_constrain(tree, params):
    """Apply ZeRO-1 sharding: param spec + 'data' on the first replicated,
    divisible dimension of each state leaf."""
    ctx = shd.active()
    if ctx is None:
        return tree
    mesh, rules = ctx
    zero_axes = rules.get("zero") or ("data",)
    if isinstance(zero_axes, str):
        zero_axes = (zero_axes,)
    zero_axes = tuple(a for a in zero_axes if a in mesh.shape)
    if not zero_axes:
        return tree
    specs = shd.tree_param_specs(params)

    def constrain_leaf(x, spec):
        if x.ndim == 0:
            return x
        from jax.sharding import NamedSharding
        zs = shd.zero_spec(spec, x.shape, mesh, data_axes=zero_axes)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, zs))

    return jax.tree.map(constrain_leaf, tree, specs)


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] >= 8 and p.shape[-2] >= 8


def init_adamw(cfg: AdamWConfig, params) -> AdamState:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]
    if cfg.kind == "adafactor":
        # factored second moment: row/col accumulators in f32 (tiny)
        def fstate(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        nu = jax.tree.map(fstate, params,
                          is_leaf=lambda x: hasattr(x, "shape"))
        return AdamState(jnp.zeros((), jnp.int32), None, nu)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    mu, nu = zeros, jax.tree.map(jnp.copy, zeros)
    if cfg.zero_sharding:
        mu = _zero_constrain(mu, params)
        nu = _zero_constrain(nu, params)
    return AdamState(jnp.zeros((), jnp.int32), mu, nu)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adafactor_update(cfg: AdamWConfig, grads, state: AdamState, params):
    """Adafactor (factored 2nd moment, no 1st moment, RMS update clipping).
    The elementwise math runs in the param dtype (the factored accumulators
    stay f32 — they are tiny); f32 elementwise temporaries over ~0.5T-param
    stacks are a measured multi-GB memory line item (§Perf)."""
    step = state.step + 1
    lr = lr_at(cfg, step)
    b2 = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8   # Shazeer-Stern decay
    eps = 1e-30

    def upd(p, g, v):
        # full-size temporaries in the param dtype; f32 only inside fused
        # reductions and the (tiny) factored accumulators
        mdt = p.dtype if p.dtype == jnp.bfloat16 else jnp.float32
        if "vr" in v:
            vr = v["vr"] * b2 + (1 - b2) * (jnp.mean(
                jnp.square(g.astype(jnp.float32)), axis=-1) + eps)
            vc = v["vc"] * b2 + (1 - b2) * (jnp.mean(
                jnp.square(g.astype(jnp.float32)), axis=-2) + eps)
            # u = g * rsqrt(vr_i / mean(vr)) * rsqrt(vc_j)
            r = jax.lax.rsqrt(jnp.clip(
                vr / jnp.clip(vr.mean(axis=-1, keepdims=True), eps), eps))
            c = jax.lax.rsqrt(jnp.clip(vc, eps))
            u = (g.astype(mdt) * r[..., :, None].astype(mdt)
                 * c[..., None, :].astype(mdt))
            new_v = {"vr": vr, "vc": vc}
        else:
            vfull = v["v"] * b2 + (1 - b2) * jnp.square(g.astype(jnp.float32))
            u = g.astype(mdt) * jax.lax.rsqrt(jnp.clip(vfull, eps)).astype(mdt)
            new_v = {"v": vfull}
        # update clipping at RMS 1.0 (Adafactor's d parameter)
        rms = jnp.sqrt(jnp.mean(jnp.square(u.astype(jnp.float32))) + eps)
        u = u / jnp.maximum(1.0, rms).astype(mdt)
        if cfg.weight_decay and p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(mdt)
        newp = (p.astype(mdt) - lr.astype(mdt) * u).astype(p.dtype)
        return newp, new_v

    is_state_leaf = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_v = jax.tree.flatten(state.nu, is_leaf=is_state_leaf)[0]
    outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in outs])
    new_nu = jax.tree.unflatten(tdef, [o[1] for o in outs])
    gnorm = global_norm(grads)
    return new_params, AdamState(step, None, new_nu), {
        "grad_norm": gnorm, "lr": lr}


def adamw_update(cfg: AdamWConfig, grads, state: AdamState, params):
    """Returns (new_params, new_state, metrics)."""
    if cfg.kind == "adafactor":
        return adafactor_update(cfg, grads, state, params)
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    # Precision policy: when the moments are kept in bf16 (>=100B models),
    # the update math runs in bf16 too — f32 math over bf16 stores would
    # materialize model-sized f32 temporaries (measured: 6 x 2.44 GB/dev on
    # arctic-480b; see EXPERIMENTS.md §Perf).  Smaller models keep f32.
    mdt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    scale_t = jnp.asarray(scale, mdt)
    lr_t = lr.astype(mdt)
    bc1_t = bc1.astype(mdt)
    bc2_t = bc2.astype(mdt)

    def upd_math(p, g, mu, nu):
        g = g.astype(mdt) * scale_t
        mu_n = mu.astype(mdt) * b1 + (1.0 - b1) * g      # python floats are
        nu_n = nu.astype(mdt) * b2 + (1.0 - b2) * g * g  # weak-typed -> mdt
        mhat = mu_n / bc1_t
        vhat = nu_n / bc2_t
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(mdt)
        newp = (p.astype(mdt) - lr_t * delta).astype(p.dtype)
        return newp, mu_n.astype(mu.dtype), nu_n.astype(nu.dtype)

    out = jax.tree.map(upd_math, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3)
    if cfg.zero_sharding:
        new_mu = _zero_constrain(new_mu, params)
        new_nu = _zero_constrain(new_nu, params)
    return new_params, AdamState(step, new_mu, new_nu), {
        "grad_norm": gnorm, "lr": lr}
