"""The training driver: jit'd train step (loss + AdamW + optional cross-pod
gradient compression) wired to the data pipeline, checkpointing, straggler
monitoring and the restartable loop."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed import sharding as shd
from ..models import build_model
from . import checkpoint as ckpt
from .data import DataConfig, DataPipeline
from .fault import FaultInjector, RestartableLoop, RestartPolicy, StragglerMonitor
from .optimizer import AdamState, AdamWConfig, adamw_update, init_adamw


@dataclasses.dataclass
class TrainConfig:
    model: ModelConfig
    opt: AdamWConfig
    data: DataConfig
    n_steps: int = 100
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10


def make_train_step(model, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    accum_dtype=None):
    """One optimizer step.  ``microbatches`` > 1 splits the global batch on
    the leading axis and accumulates gradients sequentially (the activation
    stash shrinks by the same factor; on multi-pod meshes the per-microbatch
    gradients are also the natural unit to overlap cross-pod reduction with
    the next microbatch's backward).  ``accum_dtype`` defaults to f32; pass
    the param dtype (bf16) for >=100B models where the accumulator itself
    is a memory line item."""

    def train_step(params, opt_state: AdamState, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc_step(carry, microbatch):
                g_acc, loss_acc = carry
                (l, m), g = jax.value_and_grad(model.loss, has_aux=True)(
                    params, microbatch)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     g_acc, g)
                return (g_acc, loss_acc + l), m

            def acc_init(p):
                dt = accum_dtype or (jnp.float32 if p.dtype == jnp.bfloat16
                                     else p.dtype)
                return jnp.zeros(p.shape, dt)

            g0 = jax.tree.map(acc_init, params)
            (grads, loss_sum), ms = jax.lax.scan(acc_step, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        params, opt_state, opt_metrics = adamw_update(
            opt_cfg, grads, opt_state, params)
        return params, opt_state, {"loss": loss, **metrics, **opt_metrics}

    return train_step


class Trainer:
    def __init__(self, cfg: TrainConfig, *, mesh=None, seed: int = 0):
        self.cfg = cfg
        self.model = build_model(cfg.model)
        self.data = DataPipeline(cfg.data)
        self.mesh = mesh
        key = jax.random.PRNGKey(seed)
        self.params = self.model.init(key)
        self.opt_state = init_adamw(cfg.opt, self.params)
        self._step_fn = jax.jit(make_train_step(self.model, cfg.opt),
                                donate_argnums=(0, 1))
        self.step = 0
        self.monitor = StragglerMonitor()

    # -- checkpoint glue ------------------------------------------------------
    def save(self, step: int):
        if not self.cfg.checkpoint_dir:
            return
        ckpt.save(self.cfg.checkpoint_dir, step,
                  {"params": self.params, "opt": self.opt_state},
                  cursor=self.data.cursor(step),
                  extra_meta={"model": self.cfg.model.name})

    def restore(self) -> int:
        if not self.cfg.checkpoint_dir:
            return self.step
        step = ckpt.latest_step(self.cfg.checkpoint_dir)
        if step is None:
            return 0
        trees, manifest = ckpt.restore(
            self.cfg.checkpoint_dir,
            {"params": self.params, "opt": self.opt_state})
        self.params = trees["params"]
        self.opt_state = trees["opt"]
        self.step = manifest["cursor"].get("step", step)
        return self.step

    # -- loop ----------------------------------------------------------------
    def run(self, fault_injector: Optional[FaultInjector] = None) -> dict:
        history = []

        def step_fn(step: int) -> Dict[str, Any]:
            if fault_injector is not None:
                fault_injector.maybe_fail(step)
            batch = self.data.batch_at(step)
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, batch)
            out = {k: float(v) for k, v in metrics.items()}
            if step % self.cfg.log_every == 0:
                history.append({"step": step, **out})
            return out

        loop = RestartableLoop(RestartPolicy(max_restarts=5),
                               monitor=self.monitor,
                               checkpoint_every=self.cfg.checkpoint_every)
        report = loop.run(n_steps=self.cfg.n_steps, step_fn=step_fn,
                          save_fn=self.save, restore_fn=self.restore)
        report["logged"] = history
        return report
