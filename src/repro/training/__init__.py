"""Training substrate: AdamW (+ZeRO-1), int8 cross-pod grad compression,
deterministic data pipeline, atomic sharded checkpoints, straggler
monitoring and restartable loops."""

from .checkpoint import latest_step, restore, save
from .data import DataConfig, DataPipeline
from .fault import (FaultInjector, RecoveryDecision, RecoveryPlanner,
                    RescheduleRequested, RestartableLoop, RestartPolicy,
                    StragglerConfig, StragglerMonitor)
from .optimizer import AdamState, AdamWConfig, adamw_update, init_adamw
from .trainer import TrainConfig, Trainer, make_train_step
