"""Sharded checkpointing with two-phase atomic commit + elastic restore.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json     step, paths, shapes, dtypes, mesh, cursor, rng
        arrays.npz        flat {tree-path: host array}
    <dir>/LATEST          text file naming the newest committed step dir

Commit protocol: write into ``step_X.tmp``, fsync, rename to ``step_X``,
then update LATEST — a crash at any point leaves a consistent store
(rename is atomic on POSIX).  ``restore`` takes a *template* pytree
(structure + shapes from ``jax.eval_shape``) and materializes leaves with
the *current* mesh's shardings — loading a checkpoint written on a
different mesh shape is therefore automatic (elastic reshard on host).

Multi-host note: with jax.distributed each host writes
``arrays.<proc>.npz`` for its addressable shards; this container is
single-process so there is exactly one shard file, but the manifest format
carries the process count so the restore path is already multi-host-shaped.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..distributed.sharding import tree_paths


def _flatten(tree) -> Dict[str, Any]:
    return dict(tree_paths(tree))


def save(directory: str, step: int, trees: Dict[str, Any], *,
         cursor: Optional[dict] = None, keep: int = 3,
         extra_meta: Optional[dict] = None) -> str:
    """trees: {"params": ..., "opt": ..., ...} pytrees of jax/np arrays."""
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(final):          # already committed: idempotent
        return final
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat: Dict[str, np.ndarray] = {}
    meta_arrays = {}
    for tree_name, tree in trees.items():
        for path, leaf in tree_paths(tree):
            key = f"{tree_name}::{path}"
            arr = np.asarray(jax.device_get(leaf))
            flat[key] = arr
            meta_arrays[key] = {"shape": list(arr.shape),
                                "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "arrays": meta_arrays,
        "cursor": cursor or {},
        "process_count": jax.process_count(),
        "extra": extra_meta or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(name)
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    latest = os.path.join(directory, "LATEST")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(directory: str, templates: Dict[str, Any], *,
            step: Optional[int] = None,
            shardings: Optional[Dict[str, Any]] = None):
    """templates: {"params": pytree of arrays or ShapeDtypeStruct, ...}.
    Returns (trees, manifest).  Elastic: leaves are device_put with the
    template's sharding if given (current mesh), else default placement."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))

    out = {}
    for tree_name, template in templates.items():
        flat = dict(tree_paths(template))
        shard_flat = dict(tree_paths(shardings[tree_name])) \
            if shardings and tree_name in shardings else {}
        loaded = {}
        for path, leaf in flat.items():
            key = f"{tree_name}::{path}"
            if key not in data:
                raise KeyError(f"checkpoint missing {key}")
            arr = data[key]
            want_shape = tuple(leaf.shape)
            if tuple(arr.shape) != want_shape:
                raise ValueError(f"{key}: shape {arr.shape} != {want_shape}")
            dtype = leaf.dtype
            arr = arr.astype(dtype) if str(arr.dtype) != str(dtype) else arr
            sh = shard_flat.get(path)
            loaded[path] = jax.device_put(arr, sh) if sh is not None \
                else jax.device_put(arr)
        out[tree_name] = _rebuild_like(template, loaded)
    return out, manifest


def _rebuild_like(template, flat: Dict[str, Any], prefix=""):
    if isinstance(template, dict):
        return {k: _rebuild_like(v, flat, f"{prefix}/{k}" if prefix else str(k))
                for k, v in template.items()}
    if hasattr(template, "_fields"):
        return type(template)(*(
            _rebuild_like(getattr(template, k), flat,
                          f"{prefix}/{k}" if prefix else str(k))
            for k in template._fields))
    if isinstance(template, (list, tuple)):
        return type(template)(
            _rebuild_like(v, flat, f"{prefix}/{i}" if prefix else str(i))
            for i, v in enumerate(template))
    return flat[prefix]
