"""Deterministic, stateless, elastically-resumable data pipeline.

The batch at step ``t`` is a pure function of (seed, t) — ``fold_in`` keyed
synthesis — so the "data cursor" in a checkpoint is just the step integer:
resume at any scale re-produces the identical global batch regardless of
how many hosts shard it (the elastic-scaling requirement).

Two sources:
* ``synthetic``: structured pseudo-text (Zipf unigrams + a deterministic
  k-gram rule) so that a model *can learn* something — loss visibly drops
  in the e2e example while needing no files;
* ``bytes``: byte-level tokens from a repeated corpus buffer (quickstart).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"          # synthetic | bytes
    corpus: Optional[bytes] = None


def _zipf_logits(vocab: int) -> jnp.ndarray:
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    return -jnp.log(ranks)


def synthetic_batch(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    """With prob 1/2, tokens[t+1] = (31*tokens[t] + 7) mod V (a learnable
    bigram rule on the *observable* history); otherwise a fresh Zipf draw —
    enough structure for a LM to reduce loss well below unigram entropy."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    b, s, v = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    fresh = jax.random.categorical(k1, _zipf_logits(v), shape=(b, s + 1))
    mix = jax.random.bernoulli(k2, 0.5, (b, s + 1))

    def step_fn(tok, inp):
        f, m = inp
        nxt = jnp.where(m, (tok * 31 + 7) % v, f)
        return nxt, nxt

    first = fresh[:, 0]
    _, seq = jax.lax.scan(step_fn, first,
                          (fresh[:, 1:].T, mix[:, 1:].T))
    tokens = jnp.concatenate([first[:, None], seq.T], axis=1)
    return {"tokens": tokens[:, :-1].astype(jnp.int32),
            "labels": tokens[:, 1:].astype(jnp.int32)}


def bytes_batch(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    corpus = np.frombuffer(cfg.corpus, dtype=np.uint8)
    b, s = cfg.global_batch, cfg.seq_len
    n = corpus.size
    rng = np.random.default_rng(cfg.seed + step)
    starts = rng.integers(0, max(n - s - 1, 1), size=b)
    idx = starts[:, None] + np.arange(s + 1)[None]
    chunk = corpus[idx % n].astype(np.int32)
    return {"tokens": jnp.asarray(chunk[:, :-1]),
            "labels": jnp.asarray(chunk[:, 1:])}


class DataPipeline:
    """step-indexed batch source with checkpointable cursor."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._fn = {"synthetic": synthetic_batch, "bytes": bytes_batch}[cfg.kind]
        if cfg.kind == "synthetic":
            self._fn = jax.jit(synthetic_batch, static_argnums=0)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        return self._fn(self.cfg, step)

    def cursor(self, step: int) -> dict:
        return {"step": int(step), "seed": self.cfg.seed, "kind": self.cfg.kind}
