"""Fault tolerance: straggler detection and a restartable training loop.

Straggler detection reuses the paper's central statistic: the ratio of the
slowest observation to the typical one.  On Hopper the paper measured
C_max/C_avg offline per communication pattern; here we estimate it *online*
from step wall-times — ``ratio = max(window) / median(window)`` — and treat
a sustained blow-up as a sick node / congested link signal.  Actions are
pluggable: warn, checkpoint-now, or raise for reschedule (the cluster
scheduler restarts the job; the loop resumes from the last checkpoint).

``RestartableLoop`` wraps a step function with crash recovery: on an
injected/real fault it restores the latest checkpoint and replays — the
test suite kills steps deterministically to exercise the path.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    window: int = 20
    ratio_threshold: float = 2.5      # max/median over the window
    sustained: int = 3                # consecutive anomalous windows
    min_steps: int = 10


class StragglerMonitor:
    """Online C_max/C_avg-style step-time statistic (paper §IV adapted)."""

    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times = collections.deque(maxlen=cfg.window)
        self._anomalous = 0
        self.events: list[dict] = []

    def record(self, seconds: float) -> Optional[dict]:
        self.times.append(seconds)
        if len(self.times) < max(self.cfg.min_steps, 4):
            return None
        arr = np.asarray(self.times)
        ratio = float(arr.max() / max(np.median(arr), 1e-9))
        if ratio > self.cfg.ratio_threshold:
            self._anomalous += 1
        else:
            self._anomalous = 0
        if self._anomalous >= self.cfg.sustained:
            event = {"type": "straggler", "ratio": ratio,
                     "median_s": float(np.median(arr)),
                     "max_s": float(arr.max())}
            self.events.append(event)
            self._anomalous = 0
            return event
        return None

    @property
    def online_cmax_over_cavg(self) -> float:
        if not self.times:
            return 1.0
        arr = np.asarray(self.times)
        return float(arr.max() / max(np.median(arr), 1e-9))


class FaultInjector:
    """Deterministic fault injection for tests/examples."""

    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected fault at step {step}")


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.0


class RestartableLoop:
    """run(step_fn, save_fn, restore_fn, n_steps): executes step_fn(step)
    for steps [start, n); on exception restores and continues from the
    last checkpointed step.  Returns a report dict."""

    def __init__(self, policy: RestartPolicy = RestartPolicy(),
                 monitor: Optional[StragglerMonitor] = None,
                 checkpoint_every: int = 50):
        self.policy = policy
        self.monitor = monitor or StragglerMonitor()
        self.checkpoint_every = checkpoint_every

    def run(self, *, n_steps: int, step_fn: Callable[[int], dict],
            save_fn: Callable[[int], None],
            restore_fn: Callable[[], int]) -> dict:
        restarts = 0
        step = restore_fn()
        history = []
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                metrics = step_fn(step)
                dt = time.perf_counter() - t0
                event = self.monitor.record(dt)
                history.append({"step": step, "dt": dt, **(metrics or {})})
                step += 1
                if event is not None:
                    save_fn(step)          # checkpoint-now on anomaly
                    # (post-increment: the state is *after* step-1)
                elif step % self.checkpoint_every == 0:
                    save_fn(step)
            except Exception as e:  # noqa: BLE001 — restart path
                restarts += 1
                if restarts > self.policy.max_restarts:
                    raise
                time.sleep(self.policy.backoff_s)
                step = restore_fn()
        save_fn(step)
        return {"steps": step, "restarts": restarts,
                "straggler_events": self.monitor.events,
                "history": history}
