"""Fault tolerance: straggler detection and a model-guided restartable loop.

Straggler detection reuses the paper's central statistic: the ratio of the
slowest observation to the typical one.  On Hopper the paper measured
C_max/C_avg offline per communication pattern; here we estimate it *online*
from step wall-times — ``ratio = latest / median(window)`` — and treat a
sustained blow-up as a sick node / congested link signal.  (The latest
observation, not ``max(window)``: one historical spike must not keep the
statistic pinned high for a whole window after the machine recovers.)

``RestartableLoop`` wraps a step function with crash recovery: on an
injected/real fault it restores the latest checkpoint and replays.  With a
:class:`RecoveryPlanner` attached, straggler events are answered by the
*model* rather than a fixed rule: the planner compares the predicted cost
of finishing the remaining steps on the degraded machine against paying
the restart overhead to finish on a healthy one, and decides
``continue`` / ``checkpoint_now`` / ``reschedule`` — the training analog
of the tuner re-planning under a degraded profile.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerConfig:
    window: int = 20
    ratio_threshold: float = 2.5      # latest/median over the window
    sustained: int = 3                # consecutive anomalous steps
    min_steps: int = 10


class StragglerMonitor:
    """Online C_max/C_avg-style step-time statistic (paper §IV adapted)."""

    def __init__(self, cfg: Optional[StragglerConfig] = None):
        self.cfg = cfg if cfg is not None else StragglerConfig()
        self.times = collections.deque(maxlen=self.cfg.window)
        self._anomalous = 0
        self.events: list[dict] = []

    def record(self, seconds: float) -> Optional[dict]:
        self.times.append(seconds)
        if len(self.times) < max(self.cfg.min_steps, 4):
            return None
        arr = np.asarray(self.times)
        # the *latest* step against the window's typical step: a single
        # past spike ages out of the statistic the moment times recover,
        # instead of dominating max(window) until it leaves the deque
        ratio = float(arr[-1]) / max(float(np.median(arr)), 1e-9)
        if ratio > self.cfg.ratio_threshold:
            self._anomalous += 1
        else:
            self._anomalous = 0
        if self._anomalous >= self.cfg.sustained:
            event = {"type": "straggler", "ratio": ratio,
                     "median_s": float(np.median(arr)),
                     "latest_s": float(arr[-1]),
                     "max_s": float(arr.max())}
            self.events.append(event)
            self._anomalous = 0
            return event
        return None

    @property
    def online_cmax_over_cavg(self) -> float:
        if not self.times:
            return 1.0
        arr = np.asarray(self.times)
        return float(arr.max() / max(np.median(arr), 1e-9))


class FaultInjector:
    """Deterministic fault injection for tests/examples."""

    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected fault at step {step}")


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_s: float = 0.0


class RescheduleRequested(RuntimeError):
    """The recovery planner decided migrating beats continuing degraded.

    Raised by :class:`RestartableLoop` *after* checkpointing, so the
    cluster scheduler can kill and relaunch the job with zero lost work;
    carries the decision that justified it."""

    def __init__(self, decision: "RecoveryDecision"):
        super().__init__(
            f"reschedule requested at step {decision.step}: degraded "
            f"continue {decision.continue_s:.3g}s vs reschedule "
            f"{decision.reschedule_s:.3g}s")
        self.decision = decision


@dataclasses.dataclass
class RecoveryDecision:
    """One planner verdict on a straggler event."""

    action: str                  # "continue" | "checkpoint_now" | "reschedule"
    step: int
    observed_ratio: float        # degraded-step time over healthy
    continue_s: float            # predicted cost of finishing degraded
    reschedule_s: float          # checkpoint + restart + finish healthy
    remaining_steps: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class RecoveryPlanner:
    """Model-guided recovery: continue degraded, checkpoint, or migrate?

    The same comparison the tuner makes between candidate grids, applied
    to the job itself.  Continuing costs
    ``remaining * healthy_step_s * max(ratio, 1)`` — the remaining work
    at the degraded rate the monitor observed.  Rescheduling costs
    ``checkpoint_s + restart_overhead_s + remaining * healthy_step_s`` —
    pay the migration once, then run at the healthy rate.  When
    rescheduling wins by ``margin`` (predictions are noisy; don't migrate
    on a coin flip) the verdict is ``reschedule``; a degradation too mild
    to justify migrating but above ``degraded_threshold`` earns a
    ``checkpoint_now`` (bound the work at risk while the machine is
    sick); otherwise ``continue``.
    """

    def __init__(self, healthy_step_s: float, *, restart_overhead_s: float,
                 checkpoint_s: float = 0.0, margin: float = 1.25,
                 degraded_threshold: float = 1.5):
        if healthy_step_s <= 0:
            raise ValueError("healthy_step_s must be > 0")
        if margin < 1.0:
            raise ValueError("margin must be >= 1")
        self.healthy_step_s = float(healthy_step_s)
        self.restart_overhead_s = float(restart_overhead_s)
        self.checkpoint_s = float(checkpoint_s)
        self.margin = float(margin)
        self.degraded_threshold = float(degraded_threshold)

    def decide(self, observed_ratio: float, remaining_steps: int, *,
               step: int = -1) -> RecoveryDecision:
        ratio = max(float(observed_ratio), 1.0)
        remaining = max(int(remaining_steps), 0)
        cont = remaining * self.healthy_step_s * ratio
        resch = (self.checkpoint_s + self.restart_overhead_s
                 + remaining * self.healthy_step_s)
        if resch * self.margin < cont:
            action = "reschedule"
        elif ratio > self.degraded_threshold:
            action = "checkpoint_now"
        else:
            action = "continue"
        return RecoveryDecision(action=action, step=step,
                                observed_ratio=ratio, continue_s=cont,
                                reschedule_s=resch,
                                remaining_steps=remaining)


class RestartableLoop:
    """run(step_fn, save_fn, restore_fn, n_steps): executes step_fn(step)
    for steps [start, n); on exception restores and continues from the
    last checkpointed step.  Returns a report dict.

    With ``planner`` set, a straggler event is routed through
    :meth:`RecoveryPlanner.decide`: ``continue`` does nothing,
    ``checkpoint_now`` bounds the at-risk work, and ``reschedule``
    checkpoints then raises :class:`RescheduleRequested` for the cluster
    scheduler.  Without a planner, every straggler event checkpoints
    (the legacy conservative rule)."""

    def __init__(self, policy: Optional[RestartPolicy] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 checkpoint_every: int = 50,
                 planner: Optional[RecoveryPlanner] = None):
        self.policy = policy if policy is not None else RestartPolicy()
        self.monitor = monitor if monitor is not None else StragglerMonitor()
        self.checkpoint_every = checkpoint_every
        self.planner = planner

    def run(self, *, n_steps: int, step_fn: Callable[[int], dict],
            save_fn: Callable[[int], None],
            restore_fn: Callable[[], int]) -> dict:
        restarts = 0
        step = restore_fn()
        history: List[dict] = []
        decisions: List[RecoveryDecision] = []
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                metrics = step_fn(step)
                dt = time.perf_counter() - t0
                event = self.monitor.record(dt)
                history.append({"step": step, "dt": dt, **(metrics or {})})
                step += 1
                if event is not None:
                    if self.planner is None:
                        save_fn(step)      # checkpoint-now on anomaly
                        # (post-increment: the state is *after* step-1)
                    else:
                        d = self.planner.decide(event["ratio"],
                                                n_steps - step,
                                                step=step)
                        decisions.append(d)
                        if d.action in ("checkpoint_now", "reschedule"):
                            save_fn(step)
                        if d.action == "reschedule":
                            raise RescheduleRequested(d)
                elif step % self.checkpoint_every == 0:
                    save_fn(step)
            except RescheduleRequested:
                raise                      # planner verdict, not a fault
            except Exception:  # noqa: BLE001 — restart path
                restarts += 1
                if restarts > self.policy.max_restarts:
                    raise
                time.sleep(self.policy.backoff_s)
                step = restore_fn()
                # the replayed steps are the checkpoint's future, not this
                # run's past: drop history at/after the resume point so a
                # step never appears twice
                history = [h for h in history if h["step"] < step]
        save_fn(step)
        return {"steps": step, "restarts": restarts,
                "straggler_events": self.monitor.events,
                "recovery_decisions": [d.to_dict() for d in decisions],
                "history": history}
