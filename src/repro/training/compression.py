"""Error-feedback int8 gradient compression for the cross-pod (DCN) axis.

Within a pod, gradients reduce over ICI at full precision (cheap).  Across
pods the DCN is the scarce resource — the paper's bandwidth-degradation
lesson — so the pod-axis mean is computed on int8-quantized gradients with
per-tensor scales and an error-feedback buffer that re-injects the
quantization residual next step (Seide et al. 2014 / Karimireddy et al.
2019 — guarantees convergence matching uncompressed SGD asymptotically).

Implementation: shard_map over the 'pod' axis; each pod quantizes its
local mean gradient, all-gathers the int8 payload (pods x bytes instead of
2 x bytes x fp32 for a ring all-reduce), dequantizes and averages locally.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import compat


def _quantize(x, *, dtype=jnp.int8):
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(dtype)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_pod_mean(grads: Any, err: Any, mesh) -> Tuple[Any, Any]:
    """Mean over the 'pod' mesh axis with int8 + error feedback.

    grads: pytree of *pod-local* gradient arrays (already reduced over the
    in-pod data axis, replicated within the pod).  err: matching residual
    buffers.  Returns (mean_grads, new_err)."""
    if "pod" not in mesh.shape or mesh.shape["pod"] == 1:
        return grads, err
    npods = mesh.shape["pod"]

    def one(g, e):
        def body(gl, el):
            x = gl.astype(jnp.float32) + el
            q, scale = _quantize(x)
            new_e = x - _dequantize(q, scale)
            qs = jax.lax.all_gather(q, "pod")                 # (npods, ...)
            ss = jax.lax.all_gather(scale, "pod")             # (npods,)
            deq = qs.astype(jnp.float32) * ss.reshape(
                (npods,) + (1,) * gl.ndim)
            return jnp.mean(deq, axis=0).astype(gl.dtype), new_e

        spec = P()  # replicated over pod inside each pod's shards
        return compat.shard_map(body, mesh=mesh,
                                in_specs=(spec, spec), out_specs=(spec, spec),
                                check_vma=False)(g, e)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        mg, ne = one(g, e)
        out_g.append(mg)
        out_e.append(ne)
    return jax.tree.unflatten(treedef, out_g), jax.tree.unflatten(treedef, out_e)


def init_error_buffers(grads_shape_tree):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                        grads_shape_tree)
