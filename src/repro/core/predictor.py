"""Variant selection and prediction tables (paper §VI).

The paper's headline application: given a machine, an algorithm, a problem
size and a core count, evaluate the models for every variant (2D / 2.5D,
with/without overlapping, over the legal replication factors ``c`` and
block-cyclic factors ``r``) and pick the fastest — including the memory
constraint that 2.5D replication must fit ("our models ... can take into
account runtime constraints (e.g., available memory)").

``prediction_table`` reproduces the structure of paper Tables II-V
(percentage-of-peak for each variant over a grid of core counts and sizes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Sequence

from .algorithms import ALGOS, VARIANTS, AlgoContext, ModelResult, evaluate, pct_of_peak

#: matrices resident per algorithm (A,B,C for matmul; X/B + U for trsm; A for chol)
_MATRICES = {"cannon": 3.0, "summa": 3.0, "trsm": 2.0, "cholesky": 1.0}


def _fits_memory(ctx: AlgoContext, algo: str, n: int, p: int, c: int) -> bool:
    words = _MATRICES[algo] * float(n) * n * c / p
    return words * ctx.comm.machine.word_bytes <= ctx.comp.machine.mem_per_unit


def legal_c_values(p: int, *, max_c: Optional[int] = None) -> list[int]:
    """Replication factors: powers of two with c <= p^(1/3) (Solomonik's
    bound: beyond that, the reduction cost dominates) and p/c a perfect
    square (grid constraint)."""
    out = []
    cap = max_c or int(round(p ** (1.0 / 3.0)))
    c = 2
    while c <= cap:
        g = math.sqrt(p / c)
        if abs(g - round(g)) < 1e-9:
            out.append(c)
        c *= 2
    return out or [2]


@dataclasses.dataclass
class VariantChoice:
    result: ModelResult
    pct_peak: float


def best_variant(ctx: AlgoContext, algo: str, n: int, p: int,
                 variants: Sequence[str] = VARIANTS,
                 r_values: Sequence[int] = (1, 2, 4),
                 max_c: Optional[int] = None) -> Dict[str, VariantChoice]:
    """Evaluate every variant, tuning (c, r); returns {variant: best choice}."""
    out: Dict[str, VariantChoice] = {}
    needs_r = algo in ("trsm", "cholesky")
    for variant in variants:
        candidates = []
        cs = [1] if variant.startswith("2d") else legal_c_values(p, max_c=max_c)
        rs = r_values if needs_r else (1,)
        for c in cs:
            if variant.startswith("2.5d") and not _fits_memory(ctx, algo, n, p, c):
                continue
            for r in rs:
                res = evaluate(ctx, algo, variant, n, p, c=c, r=r)
                candidates.append(res)
        if not candidates:  # no c fits: fall back to smallest c (paper notes OOM limits)
            candidates = [evaluate(ctx, algo, variant, n, p, c=2, r=rs[0])]
        best = min(candidates, key=lambda res: res.total)
        out[variant] = VariantChoice(best, pct_of_peak(ctx, best))
    return out


def select(ctx: AlgoContext, algo: str, n: int, p: int, **kw) -> VariantChoice:
    """The tuner entry point: the single fastest variant for the scenario."""
    choices = best_variant(ctx, algo, n, p, **kw)
    return max(choices.values(), key=lambda ch: ch.pct_peak)


def prediction_table(ctx: AlgoContext, algo: str,
                     sizes: Iterable[int], core_counts: Iterable[int],
                     threads_per_process: Optional[int] = None,
                     **kw) -> Dict[int, Dict[int, Dict[str, float]]]:
    """Paper Tables II-V: {n: {cores: {variant: pct_of_peak}}}.

    ``core_counts`` are physical cores; processes p = cores / threads_per_unit
    (Hopper runs one process per NUMA domain).
    """
    tpp = threads_per_process or ctx.comp.machine.threads_per_unit
    table: Dict[int, Dict[int, Dict[str, float]]] = {}
    for n in sizes:
        table[n] = {}
        for cores in core_counts:
            p = max(1, cores // tpp)
            choices = best_variant(ctx, algo, n, p, **kw)
            # %-peak is vs *total cores* peak, as the paper reports.
            row = {}
            for variant, ch in choices.items():
                from .algorithms import USEFUL_FLOPS
                flops = USEFUL_FLOPS[algo](n)
                peak = cores * ctx.comp.machine.peak_flops_per_thread
                row[variant] = 100.0 * flops / (ch.result.total * peak)
            table[n][cores] = row
    return table


def format_table(table, algo: str) -> str:
    lines = [f"# predicted %-of-peak — {algo}"]
    for n, by_cores in table.items():
        lines.append(f"  size n={n}")
        lines.append("    cores     " + "  ".join(f"{v:>11}" for v in VARIANTS))
        for cores, row in by_cores.items():
            best = max(row.values())
            cells = []
            for v in VARIANTS:
                mark = "*" if abs(row[v] - best) < 1e-12 else " "
                cells.append(f"{row[v]:>10.2f}{mark}")
            lines.append(f"    {cores:>8}  " + "  ".join(cells))
    return "\n".join(lines)


def crossover_core_count(ctx: AlgoContext, algo: str, n: int,
                         core_counts: Sequence[int],
                         threads_per_process: Optional[int] = None) -> Optional[int]:
    """Smallest core count where 2.5D+overlap beats 2D+overlap — the paper's
    'sweet spot' (§VI-B).  None if no crossover in the range."""
    tpp = threads_per_process or ctx.comp.machine.threads_per_unit
    for cores in sorted(core_counts):
        p = max(1, cores // tpp)
        ch = best_variant(ctx, algo, n, p)
        if ch["2.5d_ovlp"].result.total < ch["2d_ovlp"].result.total:
            return cores
    return None
