"""Variant selection and prediction tables (paper §VI).

The paper's headline application: given a machine, an algorithm, a problem
size and a core count, evaluate the models for every variant (2D / 2.5D,
with/without overlapping, over the legal replication factors ``c`` and
block-cyclic factors ``r``) and pick the fastest — including the memory
constraint that 2.5D replication must fit ("our models ... can take into
account runtime constraints (e.g., available memory)").

The model surface itself lives in ``repro.tuner.registry``
(``PerfModelRegistry``): this module no longer hard-codes the
ALGOS/VARIANTS tuples but enumerates whatever the registry holds, so
registering a new algorithm model makes it selectable here (and by the
end-to-end autotuner in ``repro.tuner``) with no further changes.

``prediction_table`` reproduces the structure of paper Tables II-V
(percentage-of-peak for each variant over a grid of core counts and sizes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, Optional, Sequence

from .algorithms import (USEFUL_FLOPS, AlgoContext, ModelResult, pct_of_peak)

#: matrices resident per algorithm (A,B,C for matmul; X/B + U for trsm; A for chol)
_MATRICES = {"cannon": 3.0, "summa": 3.0, "trsm": 2.0, "cholesky": 1.0}

#: algorithms whose layouts are block-cyclic (the r factor matters)
_NEEDS_R = ("trsm", "cholesky")


def _registry():
    """The unified model registry (lazy import: core must stay importable
    without the tuner package, and tuner imports core)."""
    from ..tuner.registry import DEFAULT_REGISTRY
    return DEFAULT_REGISTRY


def _fits_memory(ctx: AlgoContext, algo: str, n: int, p: int, c: int) -> bool:
    words = _MATRICES.get(algo, 3.0) * float(n) * n * c / p
    return words * ctx.comm.machine.word_bytes <= ctx.comp.machine.mem_per_unit


def legal_c_values(p: int, *, max_c: Optional[int] = None) -> list[int]:
    """Replication factors: powers of two with c <= p^(1/3) (Solomonik's
    bound: beyond that, the reduction cost dominates) and p/c a perfect
    square (grid constraint).  Returns ``[]`` when no legal factor exists —
    callers decide their own fallback (an illegal c silently returned here
    used to poison downstream grid construction)."""
    out = []
    cap = max_c or int(round(p ** (1.0 / 3.0)))
    c = 2
    while c <= cap:
        g = math.sqrt(p / c)
        if abs(g - round(g)) < 1e-9:
            out.append(c)
        c *= 2
    return out


@dataclasses.dataclass
class VariantChoice:
    result: ModelResult
    pct_peak: float


def best_variant(ctx: AlgoContext, algo: str, n: int, p: int,
                 variants: Optional[Sequence[str]] = None,
                 r_values: Sequence[int] = (1, 2, 4),
                 max_c: Optional[int] = None,
                 c_values: Optional[Sequence[int]] = None,
                 registry=None) -> Dict[str, VariantChoice]:
    """Evaluate every variant, tuning (c, r); returns {variant: best choice}.

    ``c_values`` overrides the legal-c enumeration for 2.5D variants (the
    end-to-end tuner passes the replication factors its device pool can
    actually realize); ``registry`` overrides the default model registry.
    """
    reg = registry or _registry()
    out: Dict[str, VariantChoice] = {}
    needs_r = algo in _NEEDS_R
    for variant in (variants if variants is not None else reg.variants(algo)):
        candidates = []
        if variant.startswith("2d"):
            cs = [1]
        elif c_values is not None:
            cs = list(c_values)
        else:
            cs = legal_c_values(p, max_c=max_c)
            if not cs:
                # No legal replication factor: fall back to the smallest
                # power of two (the model tolerates non-square grids).
                cs = [2]
        rs = r_values if needs_r else (1,)
        for c in cs:
            if variant.startswith("2.5d") and not _fits_memory(ctx, algo, n, p, c):
                continue
            for r in rs:
                res = reg.evaluate(ctx, algo, variant, n, p, c=c, r=r)
                candidates.append(res)
        if not candidates:
            if c_values is not None:
                # the caller pinned the replication factors (the end-to-end
                # tuner does): an over-memory config must *lose*, not be
                # re-scored as if it fit — drop the variant instead
                continue
            # auto-enumeration: fall back to the smallest c so the table
            # still has an entry (the paper notes these cells as OOM-limited)
            candidates = [reg.evaluate(ctx, algo, variant, n, p, c=cs[0], r=rs[0])]
        best = min(candidates, key=lambda res: res.total)
        out[variant] = VariantChoice(best, pct_of_peak(ctx, best))
    return out


def select(ctx: AlgoContext, algo: str, n: int, p: int, **kw) -> VariantChoice:
    """The tuner entry point: the single fastest variant for the scenario.

    Raises ValueError when every requested variant is memory-infeasible
    (only possible with pinned ``c_values``)."""
    choices = best_variant(ctx, algo, n, p, **kw)
    if not choices:
        raise ValueError(f"no feasible variant for {algo} n={n} p={p} "
                         f"under the given constraints")
    return max(choices.values(), key=lambda ch: ch.pct_peak)


def prediction_table(ctx: AlgoContext, algo: str,
                     sizes: Iterable[int], core_counts: Iterable[int],
                     threads_per_process: Optional[int] = None,
                     **kw) -> Dict[int, Dict[int, Dict[str, float]]]:
    """Paper Tables II-V: {n: {cores: {variant: pct_of_peak}}}.

    ``core_counts`` are physical cores; processes p = cores / threads_per_unit
    (Hopper runs one process per NUMA domain).
    """
    tpp = threads_per_process or ctx.comp.machine.threads_per_unit
    flops_of = USEFUL_FLOPS[algo]
    table: Dict[int, Dict[int, Dict[str, float]]] = {}
    for n in sizes:
        table[n] = {}
        flops = flops_of(n)
        for cores in core_counts:
            p = max(1, cores // tpp)
            choices = best_variant(ctx, algo, n, p, **kw)
            # %-peak is vs *total cores* peak, as the paper reports.
            peak = cores * ctx.comp.machine.peak_flops_per_thread
            table[n][cores] = {
                variant: 100.0 * flops / (ch.result.total * peak)
                for variant, ch in choices.items()}
    return table


def format_table(table, algo: str, registry=None) -> str:
    variants = (registry or _registry()).variants(algo)
    lines = [f"# predicted %-of-peak — {algo}"]
    for n, by_cores in table.items():
        lines.append(f"  size n={n}")
        lines.append("    cores     " + "  ".join(f"{v:>11}" for v in variants))
        for cores, row in by_cores.items():
            best = max(row.values())
            cells = []
            for v in variants:
                mark = "*" if abs(row[v] - best) < 1e-12 else " "
                cells.append(f"{row[v]:>10.2f}{mark}")
            lines.append(f"    {cores:>8}  " + "  ".join(cells))
    return "\n".join(lines)


def crossover_core_count(ctx: AlgoContext, algo: str, n: int,
                         core_counts: Sequence[int],
                         threads_per_process: Optional[int] = None) -> Optional[int]:
    """Smallest core count where 2.5D+overlap beats 2D+overlap — the paper's
    'sweet spot' (§VI-B).  None if no crossover in the range."""
    tpp = threads_per_process or ctx.comp.machine.threads_per_unit
    for cores in sorted(core_counts):
        p = max(1, cores // tpp)
        ch = best_variant(ctx, algo, n, p)
        if ch["2.5d_ovlp"].result.total < ch["2d_ovlp"].result.total:
            return cores
    return None
