"""Variant selection and prediction tables (paper §VI).

The paper's headline application: given a machine, an algorithm, a problem
size and a core count, evaluate the models for every variant (2D / 2.5D,
with/without overlapping, over the legal replication factors ``c`` and
block-cyclic factors ``r``) and pick the fastest — including the memory
constraint that 2.5D replication must fit ("our models ... can take into
account runtime constraints (e.g., available memory)").

The model surface itself lives in ``repro.tuner.registry``
(``PerfModelRegistry``): this module no longer hard-codes the
ALGOS/VARIANTS tuples but enumerates whatever the registry holds, so
registering a new algorithm model makes it selectable here (and by the
end-to-end autotuner in ``repro.tuner``) with no further changes.

Selection is *batched*: every public entry point collects its whole
candidate set — (scenario, variant, c, r) tuples across all table cells —
and makes one vectorized cost-IR evaluation per variant
(``PerfModelRegistry.evaluate_grid``) instead of one scalar model call per
candidate.  ``prediction_table`` reproduces the structure of paper
Tables II-V (percentage-of-peak for each variant over a grid of core
counts and sizes).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..perf import EvalOptions
from .algorithms import (USEFUL_FLOPS, AlgoContext, ModelResult, pct_of_peak,
                         result_from_eval)

#: matrices resident per algorithm (A,B,C for matmul; X/B + U for trsm;
#: A for chol; A in-place for LU)
_MATRICES = {"cannon": 3.0, "summa": 3.0, "trsm": 2.0, "cholesky": 1.0,
             "lu": 1.0}

#: algorithms whose layouts are block-cyclic (the r factor matters)
_NEEDS_R = ("trsm", "cholesky", "lu")


def _registry():
    """The unified model registry (lazy import: core must stay importable
    without the tuner package, and tuner imports core)."""
    from ..tuner.registry import DEFAULT_REGISTRY
    return DEFAULT_REGISTRY


def fits_memory(ctx: AlgoContext, algo: str, n: int, p: int, c: int) -> bool:
    """Does c-way replication of the algorithm's resident matrices fit the
    per-process memory?  The single feasibility predicate shared by the
    predictor and the end-to-end tuner."""
    words = _MATRICES.get(algo, 3.0) * float(n) * n * c / p
    return words * ctx.comm.machine.word_bytes <= ctx.comp.machine.mem_per_unit


def legal_c_values(p: int, *, max_c: Optional[int] = None) -> list[int]:
    """Replication factors: powers of two with c <= p^(1/3) (Solomonik's
    bound: beyond that, the reduction cost dominates) and p/c a perfect
    square (grid constraint).  Returns ``[]`` when no legal factor exists —
    callers decide their own fallback (an illegal c silently returned here
    used to poison downstream grid construction)."""
    out = []
    cap = max_c or int(round(p ** (1.0 / 3.0)))
    c = 2
    while c <= cap:
        g = math.sqrt(p / c)
        if abs(g - round(g)) < 1e-9:
            out.append(c)
        c *= 2
    return out


@dataclasses.dataclass
class VariantChoice:
    result: ModelResult
    pct_peak: float


def _cell_candidates(ctx: AlgoContext, algo: str, variant: str, n: int,
                     p: int, r_values: Sequence[int], max_c: Optional[int],
                     c_values: Optional[Sequence[int]],
                     needs_r: bool) -> Optional[List[Tuple[int, int]]]:
    """(c, r) candidates for one (cell, variant), with the memory filter
    and fallback policy of the scalar-era ``best_variant``; ``None`` means
    the variant is infeasible under pinned ``c_values`` and must be
    dropped (an over-memory config must *lose*, not be re-scored)."""
    if variant.startswith("2d"):
        cs = [1]
    elif c_values is not None:
        cs = list(c_values)
    else:
        cs = legal_c_values(p, max_c=max_c)
        if not cs:
            # No legal replication factor: fall back to the smallest power
            # of two (the model tolerates non-square grids).
            cs = [2]
    rs = tuple(r_values) if needs_r else (1,)
    cands = [(c, r) for c in cs
             if not (variant.startswith("2.5d")
                     and not fits_memory(ctx, algo, n, p, c))
             for r in rs]
    if not cands:
        if c_values is not None:
            return None
        # auto-enumeration: fall back to the smallest c so the table still
        # has an entry (the paper notes these cells as OOM-limited)
        cands = [(cs[0], rs[0])]
    return cands


def best_variant_batch(ctx: AlgoContext, algo: str,
                       cells: Sequence[Tuple[int, int]], *,
                       variants: Optional[Sequence[str]] = None,
                       r_values: Sequence[int] = (1, 2, 4),
                       max_c: Optional[int] = None,
                       c_values: Optional[Sequence[int]] = None,
                       registry=None,
                       options: Optional[EvalOptions] = None,
                       ) -> List[Dict[str, VariantChoice]]:
    """Tune every ``(n, p)`` cell at once: one vectorized model evaluation
    per variant over the union of all cells' (c, r) candidates.

    Returns one ``{variant: best choice}`` dict per cell, in cell order;
    a variant infeasible for a cell (memory, under pinned ``c_values``) is
    absent from that cell's dict.
    """
    reg = registry or _registry()
    needs_r = algo in _NEEDS_R
    variant_list = (tuple(variants) if variants is not None
                    else reg.variants(algo))
    out: List[Dict[str, VariantChoice]] = [dict() for _ in cells]
    for variant in variant_list:
        idx: List[int] = []
        cand: List[Tuple[int, int, int, int]] = []   # (n, p, c, r)
        for ci, (n, p) in enumerate(cells):
            cs = _cell_candidates(ctx, algo, variant, n, p, r_values, max_c,
                                  c_values, needs_r)
            if cs is None:
                continue
            for c, r in cs:
                idx.append(ci)
                cand.append((n, p, c, r))
        if not idx:
            continue
        program = reg.program(algo, variant) \
            if reg.has_program(algo, variant) else None
        scalars: List[ModelResult] = []
        if program is not None:
            arr = np.array(cand, dtype=float)
            res = reg.evaluate_grid(ctx, algo, variant, arr[:, 0], arr[:, 1],
                                    arr[:, 2], arr[:, 3], options=options)
            totals = res.total
        else:
            # legacy ModelFn registered without a program: scalar fallback
            # (options are forwarded so estimator flavors stay consistent
            # across variants; a legacy fn that cannot accept them fails
            # loudly rather than silently mixing est_Cal with est_NoCal)
            scalars = [reg.evaluate(ctx, algo, variant, n, p, c=c, r=r,
                                    options=options)
                       for (n, p, c, r) in cand]
            totals = np.array([m.total for m in scalars])
        best_j: Dict[int, int] = {}
        for j, ci in enumerate(idx):
            b = best_j.get(ci)
            if b is None or totals[j] < totals[b]:
                best_j[ci] = j
        for ci, j in best_j.items():
            n, p, c, r = cand[j]
            mr = (scalars[j] if program is None
                  else result_from_eval(program, res, n, p, c, r, idx=j))
            out[ci][variant] = VariantChoice(mr, pct_of_peak(ctx, mr))
    return out


def best_variant(ctx: AlgoContext, algo: str, n: int, p: int,
                 variants: Optional[Sequence[str]] = None,
                 r_values: Sequence[int] = (1, 2, 4),
                 max_c: Optional[int] = None,
                 c_values: Optional[Sequence[int]] = None,
                 registry=None) -> Dict[str, VariantChoice]:
    """Evaluate every variant, tuning (c, r); returns {variant: best choice}.

    ``c_values`` overrides the legal-c enumeration for 2.5D variants (the
    end-to-end tuner passes the replication factors its device pool can
    actually realize); ``registry`` overrides the default model registry.
    """
    return best_variant_batch(ctx, algo, [(n, p)], variants=variants,
                              r_values=r_values, max_c=max_c,
                              c_values=c_values, registry=registry)[0]


def select(ctx: AlgoContext, algo: str, n: int, p: int, **kw) -> VariantChoice:
    """The tuner entry point: the single fastest variant for the scenario.

    Raises ValueError when every requested variant is memory-infeasible
    (only possible with pinned ``c_values``)."""
    choices = best_variant(ctx, algo, n, p, **kw)
    if not choices:
        raise ValueError(f"no feasible variant for {algo} n={n} p={p} "
                         f"under the given constraints")
    return max(choices.values(), key=lambda ch: ch.pct_peak)


def prediction_table(ctx: AlgoContext, algo: str,
                     sizes: Iterable[int], core_counts: Iterable[int],
                     threads_per_process: Optional[int] = None,
                     **kw) -> Dict[int, Dict[int, Dict[str, float]]]:
    """Paper Tables II-V: {n: {cores: {variant: pct_of_peak}}}.

    ``core_counts`` are physical cores; processes p = cores / threads_per_unit
    (Hopper runs one process per NUMA domain).  All cells are tuned in one
    batched model evaluation per variant.
    """
    tpp = threads_per_process or ctx.comp.machine.threads_per_unit
    sizes = list(sizes)
    core_counts = list(core_counts)
    flops_of = USEFUL_FLOPS[algo]
    cells = [(n, max(1, cores // tpp)) for n in sizes for cores in core_counts]
    tuned = best_variant_batch(ctx, algo, cells, **kw)
    table: Dict[int, Dict[int, Dict[str, float]]] = {}
    i = 0
    for n in sizes:
        table[n] = {}
        flops = flops_of(n)
        for cores in core_counts:
            choices = tuned[i]
            i += 1
            # %-peak is vs *total cores* peak, as the paper reports.
            peak = cores * ctx.comp.machine.peak_flops_per_thread
            table[n][cores] = {
                variant: 100.0 * flops / (ch.result.total * peak)
                for variant, ch in choices.items()}
    return table


def format_table(table, algo: str, registry=None) -> str:
    variants = (registry or _registry()).variants(algo)
    lines = [f"# predicted %-of-peak — {algo}"]
    for n, by_cores in table.items():
        lines.append(f"  size n={n}")
        lines.append("    cores     " + "  ".join(f"{v:>11}" for v in variants))
        for cores, row in by_cores.items():
            best = max(row.values()) if row else 0.0
            cells = []
            for v in variants:
                val = row.get(v)
                if val is None:     # dropped as infeasible for this cell
                    cells.append(f"{'—':>10} ")
                    continue
                mark = "*" if abs(val - best) < 1e-12 else " "
                cells.append(f"{val:>10.2f}{mark}")
            lines.append(f"    {cores:>8}  " + "  ".join(cells))
    return "\n".join(lines)


def crossover_core_count(ctx: AlgoContext, algo: str, n: int,
                         core_counts: Sequence[int],
                         threads_per_process: Optional[int] = None,
                         registry=None) -> Optional[int]:
    """Smallest core count where 2.5D+overlap beats 2D+overlap — the paper's
    'sweet spot' (§VI-B).  None if no crossover in the range, or when the
    algorithm lacks either overlapped variant (e.g. a freshly registered
    model with only 2d/2.5d); cells where a variant is memory-infeasible
    are skipped rather than KeyError'd.  One batched model evaluation per
    variant covers the whole core-count range.
    """
    reg = registry or _registry()
    wanted = ("2d_ovlp", "2.5d_ovlp")
    have = reg.variants(algo)
    if any(v not in have for v in wanted):
        return None
    tpp = threads_per_process or ctx.comp.machine.threads_per_unit
    cores_sorted = sorted(core_counts)
    cells = [(n, max(1, cores // tpp)) for cores in cores_sorted]
    tuned = best_variant_batch(ctx, algo, cells, variants=wanted,
                               registry=reg)
    for cores, ch in zip(cores_sorted, tuned):
        flat, ovlp = ch.get("2d_ovlp"), ch.get("2.5d_ovlp")
        if flat is None or ovlp is None:
            continue
        if ovlp.result.total < flat.result.total:
            return cores
    return None
