"""The paper's performance-model primitives.

Three ingredients (paper §IV):

* ``ComputeModel`` — ``T_rout(d, t)``: time of a local numerical routine at
  block size ``d`` with ``t`` threads, from measured/parametric efficiency
  curves (paper Fig. 1).
* ``CommModel`` — the alpha-beta ideal time ``T_comm_ideal(w) = L + beta*w``
  (paper Fig. 2) scaled by the contention **calibration factors**:

      T_comm(w, d)          = C_avg(d)      * (L + beta*w)
      T_comm_sync(p, w, d)  = C_max(p, d)   * (L + beta*w)

  ``C_max`` is used when a synchronization makes every process wait for the
  slowest one; ``C_avg`` otherwise.  ``d`` is the "communication distance"
  (rank difference; hops on the torus, roughly).
* ``CalibrationTable`` / ``ParametricCalibration`` — the C surfaces, either
  tabulated from the contention micro-benchmark (paper Figs. 3-4) with
  interpolation + the paper's polynomial-regression extrapolation in ``p``,
  or as a fitted closed form.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Mapping, Optional, Sequence

import numpy as np

from .fitting import polyfit, polyval
from .machine import Machine


# ---------------------------------------------------------------------------
# Calibration surfaces
# ---------------------------------------------------------------------------


class Calibration:
    """Interface: C_avg(d) and C_max(p, d), both >= 1.

    The ``_vec`` variants evaluate elementwise over numpy arrays (the
    cost-IR evaluator in ``repro.perf`` calls them on whole scenario
    grids); the default implementations fall back to the scalar methods,
    subclasses override with closed-form numpy where possible.
    """

    def c_avg(self, d: float) -> float:
        raise NotImplementedError

    def c_max(self, p: float, d: float) -> float:
        raise NotImplementedError

    def c_avg_vec(self, d):
        return np.vectorize(self.c_avg, otypes=[float])(d)

    def c_max_vec(self, p, d):
        return np.vectorize(self.c_max, otypes=[float])(p, d)


class IdentityCalibration(Calibration):
    """No contention — the paper's ``est_NoCal`` baseline."""

    def c_avg(self, d: float) -> float:
        return 1.0

    def c_max(self, p: float, d: float) -> float:
        return 1.0

    def c_avg_vec(self, d):
        return 1.0

    def c_max_vec(self, p, d):
        return 1.0


@dataclasses.dataclass
class ParametricCalibration(Calibration):
    """Closed-form surfaces, fit either to micro-benchmarks or to published
    tables.  Shape choices follow the paper's empirical findings (§IV):

    * ``C_avg`` depends only on distance, is >= 1 and grows with ``d``;
    * ``C_max`` additionally grows with the total process count ``p``.

    C_avg(d)    = 1 + a1 * log2(1 + d)^a2
    C_max(p, d) = C_avg(d) * (1 + b1 * log2(max(p, 2))^b2 * log2(1 + d)^b3)
    """

    a1: float = 0.15
    a2: float = 1.3
    b1: float = 0.02
    b2: float = 1.6
    b3: float = 0.9

    def c_avg(self, d: float) -> float:
        d = max(float(d), 0.0)
        return 1.0 + abs(self.a1) * math.log2(1.0 + d) ** abs(self.a2)

    def c_max(self, p: float, d: float) -> float:
        p = max(float(p), 2.0)
        d = max(float(d), 0.0)
        growth = abs(self.b1) * math.log2(p) ** abs(self.b2) * math.log2(1.0 + d) ** abs(self.b3)
        return self.c_avg(d) * (1.0 + growth)

    def c_avg_vec(self, d):
        d = np.maximum(np.asarray(d, dtype=float), 0.0)
        return 1.0 + abs(self.a1) * np.log2(1.0 + d) ** abs(self.a2)

    def c_max_vec(self, p, d):
        p = np.maximum(np.asarray(p, dtype=float), 2.0)
        d = np.maximum(np.asarray(d, dtype=float), 0.0)
        growth = (abs(self.b1) * np.log2(p) ** abs(self.b2)
                  * np.log2(1.0 + d) ** abs(self.b3))
        return self.c_avg_vec(d) * (1.0 + growth)

    def params(self) -> np.ndarray:
        return np.array([self.a1, self.a2, self.b1, self.b2, self.b3])

    @classmethod
    def from_params(cls, v: Sequence[float]) -> "ParametricCalibration":
        return cls(*[float(x) for x in v])


@dataclasses.dataclass
class CalibrationTable(Calibration):
    """Tabulated calibration surfaces from the contention micro-benchmark.

    ``avg``: distance -> C_avg.   ``mx``: (p, distance) -> C_max.
    Interpolation is linear in log2(distance); extrapolation of C_max beyond
    the largest measured ``p`` uses the paper's polynomial regression (in
    log2 p, per distance, degree ``extrapolation_degree``).
    """

    avg: Mapping[float, float]
    mx: Mapping[tuple[float, float], float]
    extrapolation_degree: int = 2

    def __post_init__(self):
        self._avg_d = np.array(sorted(self.avg.keys()), dtype=float)
        self._avg_v = np.array([self.avg[d] for d in self._avg_d], dtype=float)
        self._ps = np.array(sorted({p for p, _ in self.mx.keys()}), dtype=float)
        self._ds = np.array(sorted({d for _, d in self.mx.keys()}), dtype=float)
        # Dense (p, d) grid; missing cells filled by nearest measured p.
        grid = np.empty((self._ps.size, self._ds.size))
        for i, p in enumerate(self._ps):
            for j, d in enumerate(self._ds):
                if (p, d) in self.mx:
                    grid[i, j] = self.mx[(p, d)]
                else:
                    cands = [self.mx[(pp, dd)] for (pp, dd) in self.mx if dd == d]
                    grid[i, j] = float(np.mean(cands)) if cands else 1.0
        self._grid = grid
        # Per-distance polynomial regression of C_max in log2(p) — used for
        # extrapolation to core counts beyond the benchmark (paper §VI-B).
        self._poly = []
        deg = min(self.extrapolation_degree, max(1, self._ps.size - 1))
        for j in range(self._ds.size):
            self._poly.append(polyfit(np.log2(self._ps), grid[:, j], deg))

    @staticmethod
    def _interp_logd(ds: np.ndarray, vs: np.ndarray, d: float) -> float:
        d = max(float(d), float(ds[0]))
        x = math.log2(1.0 + d)
        xs = np.log2(1.0 + ds)
        return float(np.interp(x, xs, vs))

    def c_avg(self, d: float) -> float:
        return max(1.0, self._interp_logd(self._avg_d, self._avg_v, d))

    def c_max(self, p: float, d: float) -> float:
        p = max(float(p), float(self._ps[0]))
        if p <= self._ps[-1]:
            # bilinear: interp in log2 p between bracketing measured rows
            lo = int(np.searchsorted(self._ps, p, side="right") - 1)
            lo = min(max(lo, 0), self._ps.size - 1)
            hi = min(lo + 1, self._ps.size - 1)
            vlo = self._interp_logd(self._ds, self._grid[lo], d)
            vhi = self._interp_logd(self._ds, self._grid[hi], d)
            if hi == lo:
                return max(1.0, vlo)
            t = (math.log2(p) - math.log2(self._ps[lo])) / (
                math.log2(self._ps[hi]) - math.log2(self._ps[lo]))
            return max(1.0, vlo + t * (vhi - vlo))
        # Polynomial-regression extrapolation beyond the measured range.
        vals = np.array([polyval(c, math.log2(p)) for c in self._poly])
        return max(1.0, self._interp_logd(self._ds, vals, d))

    # -- vectorized surfaces (same math as the scalar methods, elementwise
    # over numpy arrays — the cost-IR evaluator calls these on whole
    # scenario grids) -------------------------------------------------------
    def c_avg_vec(self, d):
        d = np.maximum(np.asarray(d, dtype=float), float(self._avg_d[0]))
        x = np.log2(1.0 + d)
        xs = np.log2(1.0 + self._avg_d)
        return np.maximum(1.0, np.interp(x, xs, self._avg_v))

    def c_max_vec(self, p, d):
        p = np.maximum(np.asarray(p, dtype=float), float(self._ps[0]))
        d = np.asarray(d, dtype=float)
        p, d = np.broadcast_arrays(p, d)
        shape = p.shape
        pf = p.ravel()
        xs = np.log2(1.0 + self._ds)
        x = np.log2(1.0 + np.maximum(d.ravel(), float(self._ds[0])))
        ix = np.arange(pf.size)
        # in-range: distance-interpolate every measured p row, then lerp in
        # log2 p between the bracketing rows (as the scalar bilinear path)
        rows = np.stack([np.interp(x, xs, row) for row in self._grid]) \
            if pf.size else np.empty((self._ps.size, 0))
        lo = np.clip(np.searchsorted(self._ps, pf, side="right") - 1,
                     0, self._ps.size - 1)
        hi = np.minimum(lo + 1, self._ps.size - 1)
        vlo, vhi = rows[lo, ix], rows[hi, ix]
        lp, lps = np.log2(pf), np.log2(self._ps)
        denom = lps[hi] - lps[lo]
        t = np.where(denom > 0, (lp - lps[lo]) / np.where(denom > 0, denom, 1.0),
                     0.0)
        val = np.where(hi == lo, vlo, vlo + t * (vhi - vlo))
        # beyond the measured range: per-distance polynomial regression in
        # log2 p, then the same log-distance interpolation per element
        beyond = pf > self._ps[-1]
        if np.any(beyond):
            vals = np.stack([polyval(c, lp) for c in self._poly])
            k = np.clip(np.searchsorted(xs, x, side="right") - 1,
                        0, xs.size - 1)
            k1 = np.minimum(k + 1, xs.size - 1)
            y0, y1 = vals[k, ix], vals[k1, ix]
            dx = xs[k1] - xs[k]
            tt = np.clip(np.where(dx > 0, (x - xs[k])
                                  / np.where(dx > 0, dx, 1.0), 0.0), 0.0, 1.0)
            val = np.where(beyond, y0 + tt * (y1 - y0), val)
        return np.maximum(1.0, val).reshape(shape)

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "avg": [[float(d), float(v)] for d, v in self.avg.items()],
            "max": [[float(p), float(d), float(v)] for (p, d), v in self.mx.items()],
            "deg": self.extrapolation_degree,
        })

    @classmethod
    def from_json(cls, s: str) -> "CalibrationTable":
        obj = json.loads(s)
        return cls(
            avg={d: v for d, v in obj["avg"]},
            mx={(p, d): v for p, d, v in obj["max"]},
            extrapolation_degree=obj.get("deg", 2),
        )


# ---------------------------------------------------------------------------
# Communication model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CommModel:
    """alpha-beta model + calibration factors (paper §IV).

    ``w`` is in *words* (``machine.word_bytes`` bytes each), matching the
    paper's seconds/word ``beta``.
    """

    machine: Machine
    calibration: Calibration

    def t_ideal(self, w: float) -> float:
        return self.machine.latency + self.machine.inv_bandwidth * float(w)

    def t_comm(self, w: float, d: float) -> float:
        return self.calibration.c_avg(d) * self.t_ideal(w)

    def t_comm_sync(self, p: float, w: float, d: float) -> float:
        return self.calibration.c_max(p, d) * self.t_ideal(w)

    def without_calibration(self) -> "CommModel":
        return CommModel(self.machine, IdentityCalibration())


# ---------------------------------------------------------------------------
# Computation model
# ---------------------------------------------------------------------------

#: flops of each square-block routine at block size n (numpy-compatible:
#: the cost-IR evaluator calls these on whole scenario grids)
ROUTINE_FLOPS = {
    "dgemm": lambda n: 2.0 * n ** 3,
    "dtrsm": lambda n: 1.0 * n ** 3,
    "dsyrk": lambda n: 1.0 * n ** 3,
    "dpotrf": lambda n: n ** 3 / 3.0,
    "dgetrf": lambda n: 2.0 * n ** 3 / 3.0,
}


@dataclasses.dataclass
class EfficiencyCurve:
    """Fraction-of-peak of a local routine vs. block size (paper Fig. 1).

    eff(n) = eff_max * (1 - exp(-n / n0)), floored at ``eff_min``.
    Parameters are measured (``calibration.bench_routines``) or digitized
    from the paper's Fig. 1 for Hopper.
    """

    eff_max: float
    n0: float
    eff_min: float = 0.05

    def __call__(self, n: float) -> float:
        return max(self.eff_min, self.eff_max * (1.0 - math.exp(-float(n) / self.n0)))

    def ev(self, n):
        """Elementwise over numpy arrays (same curve as ``__call__``)."""
        n = np.asarray(n, dtype=float)
        return np.maximum(self.eff_min,
                          self.eff_max * (1.0 - np.exp(-n / self.n0)))


# Digitized from paper Fig. 1 (LibSci on Hopper, 6 threads / NUMA domain).
# dgetrf is not in Fig. 1; its curve follows dpotrf's shape with the higher
# plateau of a dgemm-rich panel factorization.
HOPPER_EFFICIENCY = {
    "dgemm": EfficiencyCurve(0.92, 350.0),
    "dtrsm": EfficiencyCurve(0.85, 500.0),
    "dsyrk": EfficiencyCurve(0.88, 420.0),
    "dpotrf": EfficiencyCurve(0.70, 600.0),
    "dgetrf": EfficiencyCurve(0.75, 550.0),
}

# TPU v5e MXU: efficiency driven by tile alignment (128x128 MXU); a block
# below ~512 leaves the MXU starved.  These are planning curves; on-hardware
# they would be re-measured by the same benchmark.
TPU_EFFICIENCY = {
    "dgemm": EfficiencyCurve(0.95, 640.0),
    "dtrsm": EfficiencyCurve(0.60, 1024.0),   # tri-solve maps poorly to MXU
    "dsyrk": EfficiencyCurve(0.90, 640.0),
    "dpotrf": EfficiencyCurve(0.45, 1024.0),
    "dgetrf": EfficiencyCurve(0.50, 1024.0),  # pivot/solve-heavy, like dpotrf
}


@dataclasses.dataclass
class ComputeModel:
    """``T_rout(d, t)`` (paper §IV).

    Thread scaling is linear in ``t`` up to ``machine.threads_per_unit`` —
    this matches the paper's use of ``T_rout(bs, t-1)`` when one thread is
    dedicated to communication in the overlapped variants.
    Rectangular operations are modeled as several consecutive square
    operations (paper §IV) via ``t_rect``.
    """

    machine: Machine
    efficiency: Mapping[str, EfficiencyCurve]

    def t_rout(self, rout: str, n: float, t: Optional[int] = None) -> float:
        if n <= 0:
            return 0.0
        t = self.machine.threads_per_unit if t is None else t
        t = max(1, min(t, self.machine.threads_per_unit))
        flops = ROUTINE_FLOPS[rout](float(n))
        eff = self.efficiency[rout](n)
        return flops / (self.machine.peak_flops_per_thread * t * eff)

    def t_rect(self, rout: str, m: float, n: float, t: Optional[int] = None) -> float:
        """(m, n) rectangular op as ceil(max/min) consecutive square ops of
        the smaller dimension (paper §IV)."""
        if m <= 0 or n <= 0:
            return 0.0
        small, big = (m, n) if m <= n else (n, m)
        return math.ceil(big / small) * self.t_rout(rout, small, t)


def hopper_compute_model() -> ComputeModel:
    from .machine import HOPPER
    return ComputeModel(HOPPER, HOPPER_EFFICIENCY)


def tpu_compute_model() -> ComputeModel:
    from .machine import TPU_V5E
    return ComputeModel(TPU_V5E, TPU_EFFICIENCY)
