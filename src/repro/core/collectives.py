"""Analytic models of collective operations (paper §V).

The paper models MPI collectives by their published internal algorithms
(Thakur/Rabenseifner/Gropp [23], Rabenseifner [24]):

* ``reduce``  = recursive-halving reduce-scatter + binomial gather, with a
  synchronization between the two phases (Rabenseifner's algorithm);
* ``bcast``   = scatter + recursive-doubling all-gather (+ sync variants);

Every step ``i`` of a recursive schedule doubles the partner distance
(``2^i * d``), so each step gets its own calibration factor.  A step that
closes a synchronization uses ``C_max``; all others use ``C_avg``.

Transcription note: the printed equations in §V carry OCR-damaged word
counts (e.g. ``beta*w*q/2^i`` in ``T_redSca_sync`` against ``beta*(w/q)*2^i``
in the very next ``T_gather`` equation, and a stray ``t`` in the last term).
We use the standard volumes of the cited algorithms, which are consistent
with ``T_gather`` as printed and conserve total traffic:

* recursive halving on a ``w``-word vector: step ``i`` exchanges ``w/2^(i+1)``;
* binomial gather / recursive-doubling all-gather: step ``i`` moves
  ``(w/q) * 2^i``.

``q`` is the number of processes in the collective, ``p`` the total number
of processes in the job (C_max depends on ``p``), ``w`` the vector length in
words, ``d`` the base communication distance between group neighbours.

We also provide ring-schedule models for TPU ICI (what GSPMD emits on a
torus axis), with the same calibration hooks — used by the LM-step models
and the roofline cross-checks.

These closed forms are the scalar reference implementation.  The cost-IR
(``repro.perf``) ports the same schedules to ``Collective`` nodes with
vectorized step-masked evaluation; ``tests/test_collectives_properties.py``
pins the two implementations to each other step-for-step and checks the
traffic-conservation/monotonicity invariants of both.
"""

from __future__ import annotations

import math

from .perfmodel import CommModel


def _steps(q: float) -> int:
    q = max(2.0, float(q))
    return max(1, int(round(math.log2(q))))


# ---------------------------------------------------------------------------
# Paper collectives (recursive schedules on the rank space)
# ---------------------------------------------------------------------------


def t_redsca_sync(cm: CommModel, p: float, q: float, w: float, d: float) -> float:
    """Recursive-halving reduce-scatter; last step closes a sync (C_max)."""
    if q <= 1:
        return 0.0
    s = _steps(q)
    total = 0.0
    for i in range(s - 1):
        total += cm.t_comm(w / 2 ** (i + 1), (2 ** i) * d)
    total += cm.t_comm_sync(p, w / 2 ** s, (2 ** (s - 1)) * d)
    return total


def t_scatter_sync(cm: CommModel, p: float, q: float, w: float, d: float) -> float:
    """Binomial scatter (same volumes as recursive halving); sync at end."""
    return t_redsca_sync(cm, p, q, w, d)


def t_gather(cm: CommModel, q: float, w: float, d: float) -> float:
    """Binomial-tree gather; no closing sync => C_avg everywhere."""
    if q <= 1:
        return 0.0
    s = _steps(q)
    total = 0.0
    for i in range(s):
        total += cm.t_comm((w / q) * 2 ** i, (2 ** i) * d)
    return total


def t_allgather(cm: CommModel, q: float, w: float, d: float) -> float:
    """Recursive-doubling all-gather (same per-step volumes as gather)."""
    return t_gather(cm, q, w, d)


def t_allgather_sync(cm: CommModel, p: float, q: float, w: float, d: float) -> float:
    """All-gather whose last step closes a synchronization (C_max)."""
    if q <= 1:
        return 0.0
    s = _steps(q)
    total = 0.0
    for i in range(s - 1):
        total += cm.t_comm((w / q) * 2 ** i, (2 ** i) * d)
    total += cm.t_comm_sync(p, (w / q) * 2 ** (s - 1), (2 ** (s - 1)) * d)
    return total


def t_reduce(cm: CommModel, p: float, q: float, w: float, d: float) -> float:
    """Rabenseifner reduce = reduce-scatter (sync) + binomial gather."""
    return t_redsca_sync(cm, p, q, w, d) + t_gather(cm, q, w, d)


def t_bcast(cm: CommModel, p: float, q: float, w: float, d: float) -> float:
    """MPI bcast = scatter + all-gather (sync between phases)."""
    return t_scatter_sync(cm, p, q, w, d) + t_allgather(cm, q, w, d)


def t_bcast_sync(cm: CommModel, p: float, q: float, w: float, d: float) -> float:
    """bcast that itself closes a synchronization: C_max on the last
    all-gather step (paper §V-B)."""
    return t_scatter_sync(cm, p, q, w, d) + t_allgather_sync(cm, p, q, w, d)


def t_inirepl(cm: CommModel, p: float, w: float, c: float) -> float:
    """2.5D initial replication of A and B from layer 0 to c-1 layers
    (paper §V-A): worst-case distance (c-1)*p/c, synchronized, two matrices.
    """
    if c <= 1:
        return 0.0
    return 2.0 * cm.t_comm_sync(p, w, (c - 1.0) * p / c)


# ---------------------------------------------------------------------------
# TPU ICI ring schedules (GSPMD on a torus mesh axis).
# k shards on the axis; w words per shard of the *global* result.
# Bidirectional ring: effective per-step volume halves.
# ---------------------------------------------------------------------------


def t_ring_allgather(cm: CommModel, k: float, w_global: float, *, d: float = 1.0,
                     bidir: bool = True) -> float:
    """All-gather of a w_global-word array sharded k ways, ring schedule:
    (k-1) steps of w_global/k words each (halved if bidirectional)."""
    if k <= 1:
        return 0.0
    per_step = (w_global / k) / (2.0 if bidir else 1.0)
    total = 0.0
    for _ in range(int(k) - 1):
        total += cm.t_comm(per_step, d)
    return total


def t_ring_reducescatter(cm: CommModel, k: float, w_global: float, *, d: float = 1.0,
                         bidir: bool = True) -> float:
    return t_ring_allgather(cm, k, w_global, d=d, bidir=bidir)


def t_ring_allreduce(cm: CommModel, k: float, w_global: float, *, d: float = 1.0,
                     bidir: bool = True) -> float:
    """reduce-scatter + all-gather."""
    return 2.0 * t_ring_allgather(cm, k, w_global, d=d, bidir=bidir)


def t_all_to_all(cm: CommModel, k: float, w_global: float, *, d: float = 1.0) -> float:
    """All-to-all of w_global words total: each shard keeps 1/k, sends
    (k-1)/k of its w_global/k share; on a ring the bisection limits it to
    ~w_global/4 crossing each direction — model as (k-1) steps of
    w_global/k^2 with growing distance."""
    if k <= 1:
        return 0.0
    total = 0.0
    for i in range(1, int(k)):
        total += cm.t_comm(w_global / (k * k), min(i, int(k) - i) * d)
    return total


PAPER_COLLECTIVES = {
    "redsca_sync": t_redsca_sync,
    "scatter_sync": t_scatter_sync,
    "gather": t_gather,
    "allgather": t_allgather,
    "reduce": t_reduce,
    "bcast": t_bcast,
    "bcast_sync": t_bcast_sync,
}
