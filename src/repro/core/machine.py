"""Machine descriptions for the performance-model engine.

The paper's methodology (González-Domínguez et al., 2014) parameterizes a
machine by: per-process peak flops (one process per NUMA domain with ``t``
BLAS threads on Hopper), network latency ``L``, contention-free inverse
bandwidth ``beta`` (seconds/word), and the contention-calibration surfaces
``C_avg(d)`` / ``C_max(p, d)``.  We keep the same parameterization and add
the TPU-side constants (HBM bandwidth/capacity, ICI link bandwidth) needed
by the roofline analysis and by the TPU adaptation of the models.

Units: seconds, flop/s, bytes, and "words" (``word_bytes`` per element —
8 for the paper's doubles, 2 for bf16 on TPU).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Optional


@dataclasses.dataclass(frozen=True)
class KernelConstants:
    """Intra-kernel phase-model constants (the WSE-2 SUMMA exemplar's
    parameterization, adapted to Pallas grids): per-kernel time decomposes
    into H2D streaming, issue/execute cycles inflated by a measured
    overhead factor plus per-grid-step loop cost, and D2H write-back —
    with H2D/D2H bandwidths kept separate because write-back gather
    patterns are consistently slower than operand broadcast.

    Seed values come from ``benchmarks/bench_kernels.py`` sweeps;
    ``telemetry.refit_kernels`` recalibrates them from recorded per-kernel
    phase times (revision-bumped, never in place).
    """

    fma_rate: float          # flop/s for MXU-shaped (dgemm) inner loops
    vpu_rate: float          # flop/s for column-recurrence (VPU) work
    bw_h2d: float            # B/s operand streaming into on-chip memory
    bw_d2h: float            # B/s result write-back (gather side; slower)
    c_h2d: float             # s fixed input-side setup per kernel launch
    c_d2h: float             # s fixed output-side setup per kernel launch
    overhead_factor: float   # >= 1 multiplier on pure issue/execute time
    loop_overhead: float     # s per grid step (index math, task switch)
    vmem_bytes: float        # usable on-chip bytes for one step's blocks


@dataclasses.dataclass(frozen=True)
class Machine:
    name: str
    # -- compute ------------------------------------------------------------
    peak_flops_per_unit: float      # one "process unit": NUMA domain / TPU chip
    threads_per_unit: int           # BLAS threads per process (Hopper: 6; TPU: 1)
    units_per_node: int             # NUMA domains per node / chips per host
    mem_per_unit: float             # bytes of memory available to one unit
    # -- network ------------------------------------------------------------
    word_bytes: int                 # bytes per "word" in the alpha-beta model
    latency: float                  # L  [s]
    inv_bandwidth: float            # beta  [s/word], contention-free
    link_bandwidth: float           # per-direction per-link  [B/s]
    torus_dims: int                 # 3 for Gemini 3D torus, 2 for v5e ICI
    # -- memory system (None when not modeled, e.g. the paper's Hopper) -----
    hbm_bandwidth: Optional[float] = None   # [B/s] per unit
    # -- cross-pod (multi-pod meshes only) -----------------------------------
    dcn_bandwidth: Optional[float] = None   # per-host DCN [B/s]
    notes: str = ""
    # -- intra-kernel tier (None: no Pallas profile -> heuristic tiles) ------
    kernel_constants: Optional[KernelConstants] = None
    # -- profile revision ----------------------------------------------------
    # Bumped (never mutated in place) when measured-run feedback refits the
    # profile or drift detection declares the current one stale.  The
    # fingerprint hashes it, so every revision owns distinct plan-cache and
    # telemetry keys.
    revision: int = 0

    @property
    def peak_flops_per_thread(self) -> float:
        return self.peak_flops_per_unit / self.threads_per_unit

    def fingerprint(self) -> str:
        """Short stable hash of every dataclass field.  Any profile change —
        re-measured peak, new beta, a drift-bumped ``revision`` — yields a
        new fingerprint, which is what keys the tuner plan cache and the
        telemetry run store."""
        blob = json.dumps(dataclasses.asdict(self), sort_keys=True,
                          default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:12]

    def peak_flops(self, units: int) -> float:
        return units * self.peak_flops_per_unit

    def contention_free_bandwidth(self) -> float:
        """Bytes/s implied by beta (large-message plateau)."""
        return self.word_bytes / self.inv_bandwidth


# ---------------------------------------------------------------------------
# Hopper — Cray XE6 (paper Table I).  The latency and plateau bandwidth are
# digitized from paper Fig. 2 (UPC one-sided ping): L ~= 1.5 us and a large-
# message plateau of ~5.9 GB/s (per-direction peak is 7 GB/s).
# One process unit = one NUMA domain = 6 cores * 8.4 Gflop/s.
# ---------------------------------------------------------------------------
HOPPER = Machine(
    name="hopper-cray-xe6",
    peak_flops_per_unit=6 * 8.4e9,
    threads_per_unit=6,
    units_per_node=4,
    mem_per_unit=32e9 / 4,
    word_bytes=8,
    latency=1.5e-6,
    inv_bandwidth=8.0 / 5.9e9,      # s/word (doubles) at the Fig. 2 plateau
    link_bandwidth=7.0e9,
    torus_dims=3,
    hbm_bandwidth=25.6e9,
    notes="Paper target platform (Table I / Fig. 2).",
)

# ---------------------------------------------------------------------------
# TPU v5e — the adaptation target of this framework (one unit = one chip).
# Constants fixed by the assignment: 197 TFLOP/s bf16, 16 GB HBM @ 819 GB/s,
# ~50 GB/s per ICI link, 2D ICI torus within a 16x16 pod, DCN between pods.
# latency: ~1 us per ICI hop is a standard planning number.
# ---------------------------------------------------------------------------
TPU_V5E = Machine(
    name="tpu-v5e",
    peak_flops_per_unit=197e12,
    threads_per_unit=1,
    units_per_node=4,                # chips per host
    mem_per_unit=16e9,
    word_bytes=2,                    # bf16
    latency=1.0e-6,
    inv_bandwidth=2.0 / 50e9,        # s/word over one ICI link
    link_bandwidth=50e9,
    torus_dims=2,
    hbm_bandwidth=819e9,
    dcn_bandwidth=25e9,
    notes="Adaptation target (assignment constants).",
    # Kernel-tier seeds (planning numbers, refit from telemetry): MXU at
    # the bf16 peak, VPU two orders down; H2D streams at HBM rate while
    # D2H write-back pays the gather-side penalty (the WSE-2 exemplar
    # measures ~3x — we seed 2x for the TPU's memory system).
    kernel_constants=KernelConstants(
        fma_rate=197e12, vpu_rate=4e12,
        bw_h2d=819e9, bw_d2h=410e9,
        c_h2d=2e-6, c_d2h=5e-6,
        overhead_factor=1.35, loop_overhead=1.5e-6,
        vmem_bytes=96 * 1024 * 1024),
)

# ---------------------------------------------------------------------------
# The machine this container actually has: one CPU socket exposed to JAX as
# N host devices.  Its alpha/beta/C tables are *measured* by
# repro.core.calibration.bench_* — the values here are only fallbacks so the
# model engine stays usable before calibration has run.
# ---------------------------------------------------------------------------
CPU_HOST = Machine(
    name="cpu-host",
    peak_flops_per_unit=5.0e9,       # conservative 1-core f64 dgemm; re-measured
    threads_per_unit=1,
    units_per_node=8,
    mem_per_unit=4e9,
    word_bytes=8,
    latency=5.0e-6,
    inv_bandwidth=8.0 / 8e9,
    link_bandwidth=8e9,
    torus_dims=1,
    hbm_bandwidth=20e9,
    notes="Host CPU 'machine' used for live validation of the methodology.",
    # Interpret-path seeds: the Pallas interpreter charges heavy per-grid-
    # step overhead, which is exactly what bench_kernels measures and
    # refit_kernels recalibrates; these fallbacks only need the right
    # ordering (steps expensive, bandwidth cheap-ish) to rank tiles sanely.
    kernel_constants=KernelConstants(
        fma_rate=5e9, vpu_rate=5e8,
        bw_h2d=8e9, bw_d2h=4e9,
        c_h2d=2e-4, c_d2h=2e-4,
        overhead_factor=2.0, loop_overhead=5e-4,
        vmem_bytes=96 * 1024 * 1024),
)

MACHINES = {m.name: m for m in (HOPPER, TPU_V5E, CPU_HOST)}
