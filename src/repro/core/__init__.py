"""repro.core — the paper's contribution: contention-calibrated performance
models for distributed dense linear algebra (and, beyond the paper, for LM
train/serve steps on TPU meshes).

Layout:
  machine.py      machine constants (Hopper Cray XE6, TPU v5e, CPU host)
  perfmodel.py    alpha-beta + calibration-factor primitives (paper §IV)
  collectives.py  analytic collective models (paper §V, scalar closed forms)
  algorithms.py   scalar shims over the cost-IR algorithm models (§V);
                  the models themselves are authored in repro.perf.models
  calibration.py  portable benchmarks + fitting (paper §IV, Figs. 1-4)
  predictor.py    variant selection + prediction tables (paper §VI),
                  batched through the vectorized cost-IR evaluator
  roofline.py     3-term TPU roofline from compiled HLO (§Roofline)
  hlo.py          structural HLO parsing (trip-count-corrected costs)
  lm_model.py     the methodology applied to LM steps (beyond-paper)

The cost-IR itself (nodes, symbolic scenario parameters, the vectorized
evaluator) lives in the sibling package ``repro.perf``.
"""

from .machine import (CPU_HOST, HOPPER, KernelConstants, MACHINES, TPU_V5E,
                      Machine)
from .perfmodel import (CalibrationTable, CommModel, ComputeModel,
                        EfficiencyCurve, IdentityCalibration,
                        ParametricCalibration)
from .algorithms import (ALGOS, VARIANTS, AlgoContext, ModelResult, evaluate,
                         pct_of_peak)
from .predictor import best_variant, prediction_table, select

__all__ = [
    "CPU_HOST", "HOPPER", "KernelConstants", "MACHINES", "TPU_V5E", "Machine",
    "CalibrationTable", "CommModel", "ComputeModel", "EfficiencyCurve",
    "IdentityCalibration", "ParametricCalibration",
    "ALGOS", "VARIANTS", "AlgoContext", "ModelResult", "evaluate",
    "pct_of_peak", "best_variant", "prediction_table", "select",
]
