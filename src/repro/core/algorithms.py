"""Scalar shims over the cost-IR algorithm models (paper §V).

.. deprecated::
   The 16 closed-form model functions that used to live here
   (``cannon_2d`` ... ``cholesky_25d_ovlp``) are now *authored* as
   declarative cost-IR programs in ``repro.perf.models`` and *evaluated*
   by ``repro.perf.evaluate`` — vectorized over scenario grids for batch
   consumers, scalar here.  The module-level functions, ``MODELS`` and
   ``evaluate`` remain as thin shims for one release so existing call
   sites keep working; new code should use
   ``repro.tuner.PerfModelRegistry.evaluate_grid`` or
   ``repro.perf.evaluate_program`` directly.

The transcription deviations from the printed paper (2.5D step count,
TRSM update multiplicity, collective volumes, overlap thread accounting)
are documented in DESIGN.md §1 and pinned by the golden fixtures in
``tests/golden/model_values.json``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

from ..perf import EvalOptions, PROGRAMS, evaluate_program
from ..perf.models import USEFUL_FLOPS  # noqa: F401  (re-export, back-compat)
from .perfmodel import CommModel, ComputeModel


@dataclasses.dataclass
class AlgoContext:
    comm: CommModel
    comp: ComputeModel

    @property
    def threads(self) -> int:
        return self.comp.machine.threads_per_unit


@dataclasses.dataclass
class ModelResult:
    """Estimated seconds, with a comm/comp decomposition.

    ``comm``/``comp`` are the *serialized* sums of each class of term;
    ``total`` accounts for overlap (max-composition), so
    ``total <= comm + comp`` always holds.  ``terms`` is the scalar
    back-compat view of the structured per-phase breakdown
    (``repro.perf.EvalResult.phases``).
    """

    total: float
    comm: float
    comp: float
    terms: Dict[str, float]
    algo: str = ""
    variant: str = ""
    n: int = 0
    p: int = 0
    c: int = 1
    r: int = 1


def pct_of_peak(ctx: AlgoContext, res: ModelResult) -> float:
    """Percentage of machine peak achieved (the paper's reporting metric)."""
    flops = USEFUL_FLOPS[res.algo](res.n)
    peak = res.p * ctx.comp.machine.peak_flops_per_unit
    return 100.0 * flops / (res.total * peak)


def result_from_eval(program, res, n, p, c, r, idx=None) -> ModelResult:
    """Convert one perf.EvalResult cell to the legacy ModelResult, echoing
    only the tuning parameters the model reads.  ``idx`` selects one cell
    of a vectorized result; ``None`` reads a 0-d (scalar) result."""
    pick = float if idx is None else (lambda a: float(a[idx]))
    return ModelResult(
        pick(res.total), pick(res.comm), pick(res.comp),
        {name: pick(ph.exposed) for name, ph in res.phases.items()},
        algo=program.algo, variant=program.variant, n=n, p=p,
        c=c if program.uses_c else 1, r=r if program.uses_r else 1)


def scalar_shim(program) -> "ModelFn":
    def fn(ctx: AlgoContext, n: int, p: int,
           c: int = program.default_c, r: int = program.default_r,
           options: Optional[EvalOptions] = None) -> ModelResult:
        res = evaluate_program(program, ctx, n, p, c, r, options=options)
        return result_from_eval(program, res, n, p, c, r)

    fn.__name__ = f"{program.algo}_{program.variant}".replace(".", "")
    fn.__doc__ = (f"Deprecated shim: scalar evaluation of the "
                  f"({program.algo}, {program.variant}) cost-IR program.")
    fn.program = program
    return fn


ModelFn = Callable[..., ModelResult]

#: (algo, variant) -> scalar shim over the registered cost-IR program
MODELS: Dict[tuple[str, str], ModelFn] = {
    key: scalar_shim(prog) for key, prog in PROGRAMS.items()
}

# Deprecated module-level names, kept for one release.
cannon_2d = MODELS[("cannon", "2d")]
cannon_2d_ovlp = MODELS[("cannon", "2d_ovlp")]
cannon_25d = MODELS[("cannon", "2.5d")]
cannon_25d_ovlp = MODELS[("cannon", "2.5d_ovlp")]
summa_2d = MODELS[("summa", "2d")]
summa_2d_ovlp = MODELS[("summa", "2d_ovlp")]
summa_25d = MODELS[("summa", "2.5d")]
summa_25d_ovlp = MODELS[("summa", "2.5d_ovlp")]
trsm_2d = MODELS[("trsm", "2d")]
trsm_2d_ovlp = MODELS[("trsm", "2d_ovlp")]
trsm_25d = MODELS[("trsm", "2.5d")]
trsm_25d_ovlp = MODELS[("trsm", "2.5d_ovlp")]
cholesky_2d = MODELS[("cholesky", "2d")]
cholesky_2d_ovlp = MODELS[("cholesky", "2d_ovlp")]
cholesky_25d = MODELS[("cholesky", "2.5d")]
cholesky_25d_ovlp = MODELS[("cholesky", "2.5d_ovlp")]
lu_2d = MODELS[("lu", "2d")]
lu_25d = MODELS[("lu", "2.5d")]

#: the paper's algorithm/variant matrix (LU is a beyond-paper addition and
#: is deliberately not listed here; enumerate the registry for everything)
ALGOS = ("cannon", "summa", "trsm", "cholesky")
VARIANTS = ("2d", "2d_ovlp", "2.5d", "2.5d_ovlp")


def evaluate(ctx: AlgoContext, algo: str, variant: str, n: int, p: int,
             c: int = 1, r: int = 1,
             options: Optional[EvalOptions] = None) -> ModelResult:
    """Scalar evaluation of one registered model.  ``options`` selects the
    estimator flavor (est_Cal / est_NoCal / est_ideal) without rebuilding
    the context — see :class:`repro.perf.EvalOptions`."""
    return MODELS[(algo, variant)](ctx, n, p, c=c, r=r, options=options)
