"""The paper's algorithm-level performance models (§V), all 16 variants:

    {cannon, summa, trsm, cholesky} x {2d, 2.5d} x {+-overlap}

Each model walks the algorithm's execution flow (divide-and-conquer over the
loop structure), charging ``T_rout`` for local computation, ``T_comm`` /
``T_comm_sync`` for point-to-point transfers and the collective models of
``core.collectives`` for MPI-style collectives.  Overlapped segments are
charged ``max(comm, comp)`` (paper §IV: "the models predict the execution
time as the maximum expected completion time of each individual operation").

Transcription notes (deviations from the printed equations, all documented
in DESIGN.md):

* **Cannon/SUMMA 2.5D step count** — the printed loop bound ``sqrt(p/c)-1``
  contradicts the paper's own text ("there are only sqrt(p)/c shifts") and
  the 2.5D lower bound O(n^2/sqrt(c p)) it cites: a ``sqrt(p/c)``-step loop
  with blocks of ``n/sqrt(p/c)`` would move *more* words than 2D, not fewer.
  We use ``s = sqrt(p/c)/c`` steps per layer (Solomonik & Demmel), which
  reproduces the cited volume and degenerates exactly to 2D at ``c=1``.
* **TRSM trailing-update multiplicity** — we multiply the per-iteration
  dgemm term by the ``r`` row-blocks a process owns (the printed equation's
  parenthesization is ambiguous); this choice conserves total flops
  (sums to n^3/p per process).
* ``t-1`` threads during overlap (one thread dedicated to communication)
  follows the paper; ``ComputeModel`` clamps at 1 thread, so on TPU
  (1 "thread" = the chip, comms via async DMA) overlap carries no compute
  penalty.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

from . import collectives as coll
from .perfmodel import CommModel, ComputeModel


@dataclasses.dataclass
class AlgoContext:
    comm: CommModel
    comp: ComputeModel

    @property
    def threads(self) -> int:
        return self.comp.machine.threads_per_unit


@dataclasses.dataclass
class ModelResult:
    """Estimated seconds, with a comm/comp decomposition.

    ``comm``/``comp`` are the *serialized* sums of each class of term;
    ``total`` accounts for overlap (max-composition), so
    ``total <= comm + comp`` always holds.
    """

    total: float
    comm: float
    comp: float
    terms: Dict[str, float]
    algo: str = ""
    variant: str = ""
    n: int = 0
    p: int = 0
    c: int = 1
    r: int = 1


USEFUL_FLOPS = {
    "cannon": lambda n: 2.0 * n ** 3,
    "summa": lambda n: 2.0 * n ** 3,
    "trsm": lambda n: 1.0 * n ** 3,
    "cholesky": lambda n: n ** 3 / 3.0,
}


def pct_of_peak(ctx: AlgoContext, res: ModelResult) -> float:
    """Percentage of machine peak achieved (the paper's reporting metric)."""
    flops = USEFUL_FLOPS[res.algo](res.n)
    peak = res.p * ctx.comp.machine.peak_flops_per_unit
    return 100.0 * flops / (res.total * peak)


class _Acc:
    """Accumulates model terms, tracking comm/comp classes and overlap."""

    def __init__(self):
        self.total = 0.0
        self.comm = 0.0
        self.comp = 0.0
        self.terms: Dict[str, float] = {}

    def add(self, name: str, seconds: float, kind: str, repeat: float = 1.0):
        s = seconds * repeat
        self.total += s
        if kind == "comm":
            self.comm += s
        else:
            self.comp += s
        self.terms[name] = self.terms.get(name, 0.0) + s

    def add_overlapped(self, name: str, comm_s: float, comp_s: float,
                       repeat: float = 1.0):
        """max(comm, comp), tracked into both serialized ledgers."""
        self.total += max(comm_s, comp_s) * repeat
        self.comm += comm_s * repeat
        self.comp += comp_s * repeat
        self.terms[name] = self.terms.get(name, 0.0) + max(comm_s, comp_s) * repeat

    def result(self, **meta) -> ModelResult:
        return ModelResult(self.total, self.comm, self.comp, dict(self.terms), **meta)


def _grid(p: float, c: float) -> float:
    g = math.sqrt(p / c)
    if abs(g - round(g)) > 1e-9:
        g = math.sqrt(p / c)  # non-square grids are allowed in the model
    return g


# ---------------------------------------------------------------------------
# Cannon's algorithm (paper §V-A)
# ---------------------------------------------------------------------------


def cannon_2d(ctx: AlgoContext, n: int, p: int, c: int = 1, r: int = 1) -> ModelResult:
    del c, r
    sp = math.sqrt(p)
    bs = n / sp
    w = bs * bs
    t = ctx.threads
    a = _Acc()
    a.add("shift_row", ctx.comm.t_comm_sync(p, w, 1), "comm", repeat=sp)
    a.add("shift_col", ctx.comm.t_comm_sync(p, w, sp), "comm", repeat=sp)
    a.add("dgemm", ctx.comp.t_rout("dgemm", bs, t), "comp", repeat=sp)
    return a.result(algo="cannon", variant="2d", n=n, p=p)


def cannon_2d_ovlp(ctx: AlgoContext, n: int, p: int, c: int = 1, r: int = 1) -> ModelResult:
    del c, r
    sp = math.sqrt(p)
    bs = n / sp
    w = bs * bs
    t = ctx.threads
    shift = ctx.comm.t_comm_sync(p, w, 1) + ctx.comm.t_comm_sync(p, w, sp)
    mult = ctx.comp.t_rout("dgemm", bs, t)
    a = _Acc()
    a.add("first_shift", shift, "comm")
    a.add("final_dgemm", mult, "comp")
    a.add_overlapped("loop", shift, mult, repeat=sp - 1)
    return a.result(algo="cannon", variant="2d_ovlp", n=n, p=p)


def _cannon_25d_steps(p: float, c: float) -> float:
    """Shift steps per layer; see transcription note in the module docstring."""
    return max(1.0, math.sqrt(p / c) / c)


def cannon_25d(ctx: AlgoContext, n: int, p: int, c: int = 4, r: int = 1) -> ModelResult:
    del r
    g = _grid(p, c)
    bs = n / g
    w = bs * bs
    t = ctx.threads
    s = _cannon_25d_steps(p, c)
    a = _Acc()
    a.add("ini_repl", coll.t_inirepl(ctx.comm, p, w, c), "comm")
    # Loop shifts use the average factor, as printed in the paper's 2.5D model.
    a.add("shift_row", ctx.comm.t_comm(w, 1), "comm", repeat=s - 1)
    a.add("shift_col", ctx.comm.t_comm(w, g), "comm", repeat=s - 1)
    a.add("dgemm", ctx.comp.t_rout("dgemm", bs, t), "comp", repeat=s)
    a.add("reduce", coll.t_reduce(ctx.comm, p, c, w, p / c), "comm")
    return a.result(algo="cannon", variant="2.5d", n=n, p=p, c=c)


def cannon_25d_ovlp(ctx: AlgoContext, n: int, p: int, c: int = 4, r: int = 1) -> ModelResult:
    del r
    g = _grid(p, c)
    bs = n / g
    w = bs * bs
    t = ctx.threads
    s = _cannon_25d_steps(p, c)
    shift = ctx.comm.t_comm(w, 1) + ctx.comm.t_comm(w, g)
    mult = ctx.comp.t_rout("dgemm", bs, t)
    a = _Acc()
    a.add("ini_repl", coll.t_inirepl(ctx.comm, p, w, c), "comm")
    a.add_overlapped("loop", shift, mult, repeat=s - 1)
    a.add("final_dgemm", mult, "comp")
    a.add("reduce", coll.t_reduce(ctx.comm, p, c, w, p / c), "comm")
    return a.result(algo="cannon", variant="2.5d_ovlp", n=n, p=p, c=c)


# ---------------------------------------------------------------------------
# SUMMA (constructed with the paper's methodology; the paper models it but
# prints only Cannon/TRSM in detail).  Panel broadcasts along grid rows
# (distance 1) and columns (distance sqrt(p)).
# ---------------------------------------------------------------------------


def summa_2d(ctx: AlgoContext, n: int, p: int, c: int = 1, r: int = 1) -> ModelResult:
    del c, r
    sp = math.sqrt(p)
    bs = n / sp
    w = bs * bs
    t = ctx.threads
    a = _Acc()
    a.add("bcast_A", coll.t_bcast_sync(ctx.comm, p, sp, w, 1), "comm", repeat=sp)
    a.add("bcast_B", coll.t_bcast_sync(ctx.comm, p, sp, w, sp), "comm", repeat=sp)
    a.add("dgemm", ctx.comp.t_rout("dgemm", bs, t), "comp", repeat=sp)
    return a.result(algo="summa", variant="2d", n=n, p=p)


def summa_2d_ovlp(ctx: AlgoContext, n: int, p: int, c: int = 1, r: int = 1) -> ModelResult:
    del c, r
    sp = math.sqrt(p)
    bs = n / sp
    w = bs * bs
    t = ctx.threads
    bcasts = (coll.t_bcast_sync(ctx.comm, p, sp, w, 1)
              + coll.t_bcast_sync(ctx.comm, p, sp, w, sp))
    mult = ctx.comp.t_rout("dgemm", bs, t)
    a = _Acc()
    a.add("first_bcasts", bcasts, "comm")
    a.add_overlapped("loop", bcasts, mult, repeat=sp - 1)
    a.add("final_dgemm", mult, "comp")
    return a.result(algo="summa", variant="2d_ovlp", n=n, p=p)


def summa_25d(ctx: AlgoContext, n: int, p: int, c: int = 4, r: int = 1) -> ModelResult:
    del r
    g = _grid(p, c)
    bs = n / g
    w = bs * bs
    t = ctx.threads
    s = _cannon_25d_steps(p, c)
    a = _Acc()
    a.add("ini_repl", coll.t_inirepl(ctx.comm, p, w, c), "comm")
    a.add("bcast_A", coll.t_bcast(ctx.comm, p, g, w, 1), "comm", repeat=s)
    a.add("bcast_B", coll.t_bcast(ctx.comm, p, g, w, g), "comm", repeat=s)
    a.add("dgemm", ctx.comp.t_rout("dgemm", bs, t), "comp", repeat=s)
    a.add("reduce", coll.t_reduce(ctx.comm, p, c, w, p / c), "comm")
    return a.result(algo="summa", variant="2.5d", n=n, p=p, c=c)


def summa_25d_ovlp(ctx: AlgoContext, n: int, p: int, c: int = 4, r: int = 1) -> ModelResult:
    del r
    g = _grid(p, c)
    bs = n / g
    w = bs * bs
    t = ctx.threads
    s = _cannon_25d_steps(p, c)
    bcasts = (coll.t_bcast(ctx.comm, p, g, w, 1)
              + coll.t_bcast(ctx.comm, p, g, w, g))
    mult = ctx.comp.t_rout("dgemm", bs, t)
    a = _Acc()
    a.add("ini_repl", coll.t_inirepl(ctx.comm, p, w, c), "comm")
    a.add("first_bcasts", bcasts, "comm")
    a.add_overlapped("loop", bcasts, mult, repeat=s - 1)
    a.add("final_dgemm", mult, "comp")
    a.add("reduce", coll.t_reduce(ctx.comm, p, c, w, p / c), "comm")
    return a.result(algo="summa", variant="2.5d_ovlp", n=n, p=p, c=c)


# ---------------------------------------------------------------------------
# Triangular solve (paper §V-B).  Block-cyclic with r blocks/process/dim.
# ---------------------------------------------------------------------------


def _sum_decreasing(nb: float, offset: float = 0.0) -> float:
    """sum_{i=0}^{nb-1} (nb - i - offset)  — closed form, keeps the models
    O(1) so the calibration fit can call them millions of times."""
    k = int(round(nb))
    return k * nb - (k - 1) * k / 2.0 - offset * k


def trsm_2d(ctx: AlgoContext, n: int, p: int, c: int = 1, r: int = 1) -> ModelResult:
    del c
    sp = math.sqrt(p)
    nb = r * sp                      # blocks per matrix dimension
    bs = n / nb
    w = bs * bs
    t = ctx.threads
    k = int(round(nb))
    a = _Acc()
    a.add("bcast_U", coll.t_bcast_sync(ctx.comm, p, sp, w, sp), "comm",
          repeat=_sum_decreasing(nb) / sp)
    a.add("dtrsm", r * ctx.comp.t_rout("dtrsm", bs, t), "comp", repeat=k)
    a.add("bcast_X", r * coll.t_bcast(ctx.comm, p, sp, w, 1), "comm", repeat=k)
    a.add("update", r * ctx.comp.t_rout("dgemm", bs, t), "comp",
          repeat=_sum_decreasing(nb, 1.0) / sp)
    a.add("last_bcast_U", coll.t_bcast_sync(ctx.comm, p, sp, w, sp), "comm")
    a.add("last_solve", r * ctx.comp.t_rout("dtrsm", bs, t), "comp")
    return a.result(algo="trsm", variant="2d", n=n, p=p, r=r)


def trsm_2d_ovlp(ctx: AlgoContext, n: int, p: int, c: int = 1, r: int = 1) -> ModelResult:
    del c
    sp = math.sqrt(p)
    nb = r * sp
    bs = n / nb
    w = bs * bs
    t = ctx.threads
    k = int(round(nb))
    a = _Acc()
    a.add("first_bcast_U", r * coll.t_bcast_sync(ctx.comm, p, sp, w, sp), "comm")
    a.add("dtrsm", r * ctx.comp.t_rout("dtrsm", bs, t - 1), "comp", repeat=k)
    a.add("bcast_X", r * coll.t_bcast(ctx.comm, p, sp, w, 1), "comm", repeat=k)
    # per-iteration: ((nb-i-1)/sp) * max(bcast_U, r*dgemm) — coefficient is
    # linear in i, so the sum collapses.
    bc = coll.t_bcast_sync(ctx.comm, p, sp, w, sp)
    up = r * ctx.comp.t_rout("dgemm", bs, t - 1)
    a.add_overlapped("bcastU_vs_update", bc, up, repeat=_sum_decreasing(nb, 1.0) / sp)
    a.add("last_solve", r * ctx.comp.t_rout("dtrsm", bs, t - 1), "comp")
    return a.result(algo="trsm", variant="2d_ovlp", n=n, p=p, r=r)


def trsm_25d(ctx: AlgoContext, n: int, p: int, c: int = 4, r: int = 2) -> ModelResult:
    g = _grid(p, c)
    nb = r * g
    bs = n / nb
    w = bs * bs
    t = ctx.threads
    k = int(round(nb))
    a = _Acc()
    # Initial distribution: U replicated along layers (3/4: upper triangle),
    # X/B rows scattered among layers (paper §V-B).
    a.add("repl_U", r * r * 0.75 * coll.t_bcast(ctx.comm, p, c, w, p / c), "comm")
    a.add("scatter_X", r * r * coll.t_scatter_sync(ctx.comm, p, c, w / c, p / c), "comm")
    a.add("bcast_U", coll.t_bcast_sync(ctx.comm, p, g, w, g), "comm",
          repeat=_sum_decreasing(nb) / g)
    a.add("dtrsm", (r / c) * ctx.comp.t_rout("dtrsm", bs, t), "comp", repeat=k)
    a.add("bcast_X", (r / c) * coll.t_bcast(ctx.comm, p, g, w, 1), "comm", repeat=k)
    a.add("update", (r / c) * ctx.comp.t_rout("dgemm", bs, t), "comp",
          repeat=_sum_decreasing(nb, 1.0) / g)
    a.add("last_bcast_U", coll.t_bcast_sync(ctx.comm, p, g, w, g), "comm")
    a.add("last_solve", (r / c) * ctx.comp.t_rout("dtrsm", bs, t), "comp")
    a.add("gather_X", r * r * coll.t_gather(ctx.comm, c, w, p / c), "comm")
    return a.result(algo="trsm", variant="2.5d", n=n, p=p, c=c, r=r)


def trsm_25d_ovlp(ctx: AlgoContext, n: int, p: int, c: int = 4, r: int = 2) -> ModelResult:
    g = _grid(p, c)
    nb = r * g
    bs = n / nb
    w = bs * bs
    t = ctx.threads
    k = int(round(nb))
    a = _Acc()
    a.add("repl_U", r * r * 0.75 * coll.t_bcast(ctx.comm, p, c, w, p / c), "comm")
    a.add("scatter_X", r * r * coll.t_scatter_sync(ctx.comm, p, c, w / c, p / c), "comm")
    a.add("first_bcast_U", r * coll.t_bcast_sync(ctx.comm, p, g, w, g), "comm")
    a.add("dtrsm", (r / c) * ctx.comp.t_rout("dtrsm", bs, t - 1), "comp", repeat=k)
    a.add("bcast_X", (r / c) * coll.t_bcast(ctx.comm, p, g, w, 1), "comm", repeat=k)
    bc = coll.t_bcast_sync(ctx.comm, p, g, w, g)
    up = (r / c) * ctx.comp.t_rout("dgemm", bs, t - 1)
    a.add_overlapped("bcastU_vs_update", bc, up, repeat=_sum_decreasing(nb, 1.0) / g)
    a.add("last_solve", (r / c) * ctx.comp.t_rout("dtrsm", bs, t - 1), "comp")
    a.add("gather_X", r * r * coll.t_gather(ctx.comm, c, w, p / c), "comm")
    return a.result(algo="trsm", variant="2.5d_ovlp", n=n, p=p, c=c, r=r)


# ---------------------------------------------------------------------------
# Cholesky factorization (constructed with the paper's methodology; blocked
# right-looking, block-cyclic layout with r blocks/process/dim).
# ---------------------------------------------------------------------------


def _cholesky_loop(ctx: AlgoContext, a: _Acc, p: float, g: float, nb: float,
                   bs: float, t: int, overlap: bool, c: float = 1.0):
    """Right-looking loop over k = nb block-columns; trailing size
    m_i = nb-i-1 makes every coefficient a polynomial in i, so the loop
    collapses to closed-form sums (the fit calls this O(1e6) times)."""
    w = bs * bs
    k = int(round(nb))
    tt = t - 1 if overlap else t
    sum_m = _sum_decreasing(nb, 1.0)                      # sum m_i
    sum_m2 = (k - 1) * k * (2 * k - 1) / 6.0              # sum m_i^2
    a.add("dpotrf", ctx.comp.t_rout("dpotrf", bs, tt), "comp", repeat=k)
    a.add("bcast_diag", coll.t_bcast_sync(ctx.comm, p, g, w, g), "comm", repeat=k)
    a.add("panel_dtrsm", ctx.comp.t_rout("dtrsm", bs, tt), "comp", repeat=sum_m / g)
    panel_unit = (coll.t_bcast(ctx.comm, p, g, w, 1)
                  + coll.t_bcast(ctx.comm, p, g, w, g)) / g     # per unit m
    upd_unit = ctx.comp.t_rout("dgemm", bs, tt) / (2.0 * p)     # per unit m^2
    if overlap:
        # per-iteration max(panel_unit*m, upd_unit*m^2): crossover at
        # m* = panel_unit/upd_unit; above it update dominates.
        mstar = panel_unit / upd_unit if upd_unit > 0 else float("inf")
        comm_tot = comp_tot = exposed = 0.0
        # m runs over 0..k-1
        m_hi = min(k - 1, int(math.floor(mstar)))
        # below/at crossover: panel dominates -> sum of m for m<=m_hi
        s1 = m_hi * (m_hi + 1) / 2.0
        s2 = sum_m2 - m_hi * (m_hi + 1) * (2 * m_hi + 1) / 6.0
        exposed = panel_unit * s1 + upd_unit * s2
        comm_tot = panel_unit * sum_m
        comp_tot = upd_unit * sum_m2
        a.total += exposed
        a.comm += comm_tot
        a.comp += comp_tot
        a.terms["panelbcast_vs_update"] = a.terms.get("panelbcast_vs_update", 0.0) + exposed
    else:
        a.add("panel_bcast", panel_unit, "comm", repeat=sum_m)
        a.add("update", upd_unit, "comp", repeat=sum_m2)
    if c > 1.0:
        # Periodic combination of partial trailing updates across layers.
        a.add("layer_reduce", coll.t_reduce(ctx.comm, p, c, w, p / c), "comm",
              repeat=sum_m / (g * c))


def cholesky_2d(ctx: AlgoContext, n: int, p: int, c: int = 1, r: int = 2) -> ModelResult:
    del c
    sp = math.sqrt(p)
    nb = r * sp
    bs = n / nb
    a = _Acc()
    _cholesky_loop(ctx, a, p, sp, nb, bs, ctx.threads, overlap=False)
    return a.result(algo="cholesky", variant="2d", n=n, p=p, r=r)


def cholesky_2d_ovlp(ctx: AlgoContext, n: int, p: int, c: int = 1, r: int = 2) -> ModelResult:
    del c
    sp = math.sqrt(p)
    nb = r * sp
    bs = n / nb
    a = _Acc()
    _cholesky_loop(ctx, a, p, sp, nb, bs, ctx.threads, overlap=True)
    return a.result(algo="cholesky", variant="2d_ovlp", n=n, p=p, r=r)


def cholesky_25d(ctx: AlgoContext, n: int, p: int, c: int = 4, r: int = 2) -> ModelResult:
    g = _grid(p, c)
    nb = r * g
    bs = n / nb
    w = bs * bs
    a = _Acc()
    a.add("repl_A", 0.5 * r * r * coll.t_bcast(ctx.comm, p, c, w, p / c), "comm")
    _cholesky_loop(ctx, a, p, g, nb, bs, ctx.threads, overlap=False, c=c)
    a.add("gather_L", 0.5 * r * r * coll.t_gather(ctx.comm, c, w, p / c), "comm")
    return a.result(algo="cholesky", variant="2.5d", n=n, p=p, c=c, r=r)


def cholesky_25d_ovlp(ctx: AlgoContext, n: int, p: int, c: int = 4, r: int = 2) -> ModelResult:
    g = _grid(p, c)
    nb = r * g
    bs = n / nb
    w = bs * bs
    a = _Acc()
    a.add("repl_A", 0.5 * r * r * coll.t_bcast(ctx.comm, p, c, w, p / c), "comm")
    _cholesky_loop(ctx, a, p, g, nb, bs, ctx.threads, overlap=True, c=c)
    a.add("gather_L", 0.5 * r * r * coll.t_gather(ctx.comm, c, w, p / c), "comm")
    return a.result(algo="cholesky", variant="2.5d_ovlp", n=n, p=p, c=c, r=r)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ModelFn = Callable[..., ModelResult]

MODELS: Dict[tuple[str, str], ModelFn] = {
    ("cannon", "2d"): cannon_2d,
    ("cannon", "2d_ovlp"): cannon_2d_ovlp,
    ("cannon", "2.5d"): cannon_25d,
    ("cannon", "2.5d_ovlp"): cannon_25d_ovlp,
    ("summa", "2d"): summa_2d,
    ("summa", "2d_ovlp"): summa_2d_ovlp,
    ("summa", "2.5d"): summa_25d,
    ("summa", "2.5d_ovlp"): summa_25d_ovlp,
    ("trsm", "2d"): trsm_2d,
    ("trsm", "2d_ovlp"): trsm_2d_ovlp,
    ("trsm", "2.5d"): trsm_25d,
    ("trsm", "2.5d_ovlp"): trsm_25d_ovlp,
    ("cholesky", "2d"): cholesky_2d,
    ("cholesky", "2d_ovlp"): cholesky_2d_ovlp,
    ("cholesky", "2.5d"): cholesky_25d,
    ("cholesky", "2.5d_ovlp"): cholesky_25d_ovlp,
}

ALGOS = ("cannon", "summa", "trsm", "cholesky")
VARIANTS = ("2d", "2d_ovlp", "2.5d", "2.5d_ovlp")


def evaluate(ctx: AlgoContext, algo: str, variant: str, n: int, p: int,
             c: int = 1, r: int = 1) -> ModelResult:
    return MODELS[(algo, variant)](ctx, n, p, c=c, r=r)
