"""Tiny dependency-free optimizers used to fit calibration surfaces.

The paper fits a polynomial regression to extrapolate ``C_max`` beyond the
largest measured core count; we additionally fit parametric calibration
surfaces to published table data (see ``calibration.fit_hopper_calibration``).
scipy is not available offline, so we carry a small Nelder--Mead and a
least-squares polynomial fit on plain numpy.
"""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np


def polyfit(x: Sequence[float], y: Sequence[float], deg: int) -> np.ndarray:
    """Least-squares polynomial fit; returns coefficients, highest power first."""
    return np.polyfit(np.asarray(x, dtype=float), np.asarray(y, dtype=float), deg)


def polyval(coeffs: np.ndarray, x) -> np.ndarray:
    return np.polyval(coeffs, x)


def ridge_lstsq(A: np.ndarray, b: np.ndarray, lam: float = 0.0) -> np.ndarray:
    """Regularized least squares: argmin ||A x - b||^2 + lam ||x||^2.

    The L2 penalty shrinks the solution toward zero, which is exactly what
    online recalibration wants — a handful of noisy measured runs should
    nudge a model parameter, not yank it (lam = 0 recovers plain lstsq).

    Solved as lstsq on the ridge-augmented system [A; sqrt(lam) I], which
    keeps A's conditioning (no normal equations) and degrades to the
    least-norm solution for singular A at lam = 0, like np.linalg.lstsq."""
    A = np.asarray(A, dtype=float)
    b = np.asarray(b, dtype=float)
    if A.ndim == 1:
        A = A[:, None]
    n = A.shape[1]
    A_aug = np.vstack([A, float(np.sqrt(max(lam, 0.0))) * np.eye(n)])
    b_aug = np.concatenate([b, np.zeros(n)])
    x, *_ = np.linalg.lstsq(A_aug, b_aug, rcond=None)
    return x


def nelder_mead(
    f: Callable[[np.ndarray], float],
    x0: np.ndarray,
    *,
    step: float = 0.25,
    max_iter: int = 2000,
    xatol: float = 1e-8,
    fatol: float = 1e-10,
) -> tuple[np.ndarray, float]:
    """Minimal Nelder--Mead simplex minimizer (Lagarias et al. parameters)."""
    x0 = np.asarray(x0, dtype=float)
    n = x0.size
    # Initial simplex: x0 plus per-coordinate perturbations.
    simplex = [x0]
    for i in range(n):
        xi = x0.copy()
        xi[i] = xi[i] + (step * abs(xi[i]) if xi[i] != 0 else step)
        simplex.append(xi)
    simplex = np.asarray(simplex)
    fvals = np.asarray([f(x) for x in simplex], dtype=float)

    alpha, gamma, rho, sigma = 1.0, 2.0, 0.5, 0.5
    for _ in range(max_iter):
        order = np.argsort(fvals)
        simplex, fvals = simplex[order], fvals[order]
        if (np.max(np.abs(simplex[1:] - simplex[0])) < xatol
                and np.max(np.abs(fvals[1:] - fvals[0])) < fatol):
            break
        centroid = simplex[:-1].mean(axis=0)
        # Reflection
        xr = centroid + alpha * (centroid - simplex[-1])
        fr = f(xr)
        if fvals[0] <= fr < fvals[-2]:
            simplex[-1], fvals[-1] = xr, fr
            continue
        if fr < fvals[0]:
            # Expansion
            xe = centroid + gamma * (xr - centroid)
            fe = f(xe)
            if fe < fr:
                simplex[-1], fvals[-1] = xe, fe
            else:
                simplex[-1], fvals[-1] = xr, fr
            continue
        # Contraction
        xc = centroid + rho * (simplex[-1] - centroid)
        fc = f(xc)
        if fc < fvals[-1]:
            simplex[-1], fvals[-1] = xc, fc
            continue
        # Shrink
        for i in range(1, n + 1):
            simplex[i] = simplex[0] + sigma * (simplex[i] - simplex[0])
            fvals[i] = f(simplex[i])
    order = np.argsort(fvals)
    return simplex[order][0], float(fvals[order][0])


def multistart_nelder_mead(
    f: Callable[[np.ndarray], float],
    x0: np.ndarray,
    *,
    n_starts: int = 8,
    spread: float = 0.5,
    seed: int = 0,
    **kw,
) -> tuple[np.ndarray, float]:
    """Nelder--Mead from several jittered starts; returns the best optimum."""
    rng = np.random.default_rng(seed)
    best_x, best_f = nelder_mead(f, x0, **kw)
    for _ in range(n_starts - 1):
        jitter = 1.0 + spread * rng.standard_normal(np.asarray(x0).size)
        x, fx = nelder_mead(f, np.asarray(x0) * jitter, **kw)
        if fx < best_f:
            best_x, best_f = x, fx
    return best_x, best_f
