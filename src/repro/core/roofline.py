"""Three-term roofline analysis from compiled dry-run artifacts (§Roofline).

    compute term    = HLO_FLOPs      / (chips * peak_FLOP/s)
    memory term     = HLO_bytes      / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` **corrected**
for while-loop trip counts by ``core.hlo.analyze`` (a lax.scan body is
otherwise counted once); collective bytes come from the same structural
parse, since cost_analysis does not expose them.  All parsed quantities are
per-device; terms below are per-device seconds (chips cancel out), which is
what the step time would be if each resource were the only bottleneck.

This is the paper's methodology applied to the compiled artifact instead of
the source algorithm: compute term <-> T_rout, collective term <-> the
alpha-beta/calibration communication terms.  The paper-faithful refinement
``collective_term_calibrated`` multiplies each collective's time by the
contention calibration factor for its mesh axis (distance = hops between
group neighbours), which is the beyond-LogP correction the paper
contributes.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from . import hlo as hlo_mod
from .machine import TPU_V5E, Machine
from .perfmodel import Calibration, IdentityCalibration

# v5e constants fixed by the assignment
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                       # per-device, trip-count corrected
    memory_bytes: float                # per-device HBM-traffic model
    collective_bytes: float            # per-device, summed operand sizes
    collective_breakdown: Dict[str, float]
    collective_counts: Dict[str, float]
    model_flops: float                 # 6*N*D (dense) or 6*N_active*D (MoE), global
    raw_cost_analysis: Dict[str, float]
    memory_analysis: Dict[str, float]
    while_loops: list

    # -- the three terms (seconds) ------------------------------------------
    @property
    def compute_term(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_term(self) -> float:
        return self.memory_bytes / HBM_BW

    @property
    def collective_term(self) -> float:
        return self.collective_bytes / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_term, "memory": self.memory_term,
                 "collective": self.collective_term}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        """Lower-bound step time if terms overlap perfectly."""
        return max(self.compute_term, self.memory_term, self.collective_term)

    @property
    def serial_time(self) -> float:
        return self.compute_term + self.memory_term + self.collective_term

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / total HLO flops — catches remat/redundancy waste."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the step achieves if it runs at the
        bound: MODEL_FLOPS / (bound_time * chips * peak)."""
        denom = self.bound_time * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_term=self.compute_term, memory_term=self.memory_term,
                 collective_term=self.collective_term, dominant=self.dominant,
                 bound_time=self.bound_time,
                 useful_flops_fraction=self.useful_flops_fraction,
                 roofline_fraction=self.roofline_fraction)
        return d


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float) -> RooflineTerms:
    """Build RooflineTerms from a jax.stages.Compiled."""
    text = compiled.as_text()
    parsed = hlo_mod.analyze(text)
    try:
        ca = compiled.cost_analysis() or {}
        raw = {k: float(v) for k, v in ca.items()
               if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")}
    except Exception:
        raw = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": float(ma.argument_size_in_bytes),
            "output_bytes": float(ma.output_size_in_bytes),
            "temp_bytes": float(ma.temp_size_in_bytes),
            "alias_bytes": float(ma.alias_size_in_bytes),
        }
        mem["total_bytes"] = (mem["argument_bytes"] + mem["output_bytes"]
                              + mem["temp_bytes"] - mem["alias_bytes"])
    except Exception:
        mem = {}
    # Prefer the structural parse; fall back to raw cost_analysis if the
    # parse found nothing (e.g. no dots — pure memory workloads).
    flops = parsed.flops if parsed.flops > 0 else raw.get("flops", 0.0)
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops=flops,
        memory_bytes=parsed.memory_bytes or raw.get("bytes accessed", 0.0),
        collective_bytes=parsed.total_collective_bytes,
        collective_breakdown=dict(parsed.collective_bytes),
        collective_counts=dict(parsed.collective_counts),
        model_flops=model_flops,
        raw_cost_analysis=raw,
        memory_analysis=mem,
        while_loops=list(parsed.while_loops),
    )


def collective_term_calibrated(terms: RooflineTerms,
                               calibration: Optional[Calibration] = None,
                               p: Optional[int] = None,
                               synchronized: bool = True) -> float:
    """Paper-faithful collective term: scale the ideal time by the
    contention calibration factor at ICI-neighbour distance (ring schedules
    talk to distance-1 neighbours; the factor captures link sharing when
    every chip does so at once).  ``synchronized=True`` uses C_max — a
    collective *is* a synchronization — per the paper's rule."""
    calibration = calibration or IdentityCalibration()
    p = p or terms.chips
    factor = (calibration.c_max(p, 1.0) if synchronized
              else calibration.c_avg(1.0))
    return terms.collective_term * factor


def format_table(rows: list) -> str:
    """Markdown table for EXPERIMENTS.md §Roofline."""
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL_FLOPS | useful frac | roofline frac |")
    sep = "|" + "---|" * 10
    lines = [hdr, sep]
    for t in rows:
        lines.append(
            f"| {t.arch} | {t.shape} | {t.mesh} | {t.compute_term:.4g} | "
            f"{t.memory_term:.4g} | {t.collective_term:.4g} | {t.dominant} | "
            f"{t.model_flops:.3g} | {t.useful_flops_fraction:.3f} | "
            f"{t.roofline_fraction:.3f} |")
    return "\n".join(lines)


def save_terms(terms: RooflineTerms, path: str):
    with open(path, "w") as f:
        json.dump(terms.to_dict(), f, indent=1)


def load_terms(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
