"""Structural parsing of post-optimization HLO text.

Why this exists: ``compiled.cost_analysis()`` counts the body of a
``while`` loop (what ``lax.scan`` lowers to) **once**, and collective bytes
are not in cost_analysis at all.  For a scanned-over-layers LM, that
undercounts flops/bytes by ~L x.  This module parses ``compiled.as_text()``
into a computation graph, extracts per-instruction costs, and multiplies
through ``known_trip_count`` of each while loop, yielding:

* ``flops``            — dot/convolution flops (execution-weighted)
* ``collective_bytes`` — per collective kind, summed operand bytes
                         (execution-weighted), as required by §Roofline
* ``memory_bytes``     — an HBM-traffic model: for every materializing
                         top-level instruction, output + operand bytes
                         (fusions count their operands/output once, which is
                         exactly XLA's materialization behavior);
                         dynamic-(update-)slice counts slice-sized traffic.

All numbers are per-device (SPMD modules are per-device programs).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                    "collective-permute")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape string (handles tuples by summing elements)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []


@dataclasses.dataclass
class Instruction:
    name: str
    shape: str          # result shape string
    op: str             # opcode
    operands: List[str]
    raw: str            # full line

    def attr(self, key: str) -> Optional[str]:
        m = re.search(re.escape(key) + r"=(\{[^}]*\}|[^,\s]+)", self.raw)
        return m.group(1) if m else None


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    shapes: Dict[str, str]  # instr name -> result shape


# instruction line:  %name = shape opcode(...operands...), attrs
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\((?:[^()]|\([^()]*\))*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([\w\-]+)\((.*)$")
_COMP_NAME_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    """Parse HLO text -> ({computation name: Computation}, entry name).

    Computation headers start at column 0 and end with '{' (bodies are
    indented); this avoids regexing the (nested-paren) parameter lists."""
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if (line and not line[0].isspace() and stripped.endswith("{")
                    and not stripped.startswith("HloModule")):
                m = _COMP_NAME_RE.match(stripped)
                if m:
                    cur = Computation(m.group(2), [], {})
                    if m.group(1):
                        entry = m.group(2)
            continue
        if stripped == "}" or stripped.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        _, name, shape, op, rest = m.groups()
        # operand list: up to the matching close paren of the op's '('
        depth, end = 1, len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnds = [o.strip().lstrip("%") for o in _split_top(rest[:end]) if o.strip()]
        instr = Instruction(name, shape, op, opnds, line)
        cur.instructions.append(instr)
        cur.shapes[name] = shape
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _split_top(s: str) -> List[str]:
    """Split on commas not inside (), {}, []."""
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "({[":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    return out


_TRIP_RE = re.compile(r'known_trip_count[":{\s]+n["\s:]+["\']?(\d+)')
_INDUCTION_LT_RE = re.compile(r"constant\((\d+)\)")


def while_trip_count(instr: Instruction, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(instr.raw)
    if m:
        return int(m.group(1))
    # Fallback: look for `compare(..., constant(N)), direction=LT` in condition
    cond = instr.attr("condition")
    if cond:
        comp = comps.get(cond.lstrip("%"))
        if comp:
            consts = _INDUCTION_LT_RE.findall("\n".join(i.raw for i in comp.instructions))
            if consts:
                return int(consts[-1])
    return 1


def _called_computations(instr: Instruction) -> List[Tuple[str, float]]:
    """(computation, weight) pairs invoked by this instruction."""
    out: List[Tuple[str, float]] = []
    for key in ("calls", "to_apply", "body"):
        v = instr.attr(key)
        if v:
            out.append((v.lstrip("%"), 1.0))
    cond = instr.attr("condition")
    if cond:
        out.append((cond.lstrip("%"), 1.0))
    bc = instr.attr("branch_computations")
    if bc:
        for name in re.findall(r"%?([\w.\-]+)", bc):
            out.append((name, 1.0))
    return out


_ZERO_COST_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-get-and-update-state",
}


def _operand_shape(comp: Computation, operand: str) -> str:
    """Shape of an operand reference.  Depending on the XLA version the
    operand text either embeds the shape ("f32[64,64]{1,0} %name") or is a
    bare name resolved through the computation's shape table."""
    if _SHAPE_RE.search(operand):
        return operand
    return comp.shapes.get(operand.split("%")[-1].strip(), "")


def _dot_flops(instr: Instruction, comp: Computation) -> float:
    out_elems = _shape_elems(instr.shape)
    out_n = math.prod(out_elems) if out_elems else 1
    lhs = instr.operands[0] if instr.operands else None
    lhs_shape = _operand_shape(comp, lhs) if lhs else ""
    lhs_elems = _shape_elems(lhs_shape)
    contract = instr.attr("lhs_contracting_dims")
    k = 1
    if contract and lhs_elems:
        for idx in re.findall(r"\d+", contract):
            i = int(idx)
            if i < len(lhs_elems):
                k *= lhs_elems[i]
    return 2.0 * out_n * k


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    while_loops: List[Tuple[str, int]] = dataclasses.field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HloCost:
    comps, entry = parse_module(text)
    cost = HloCost()
    if entry is None:
        return cost
    _walk(comps, comps[entry], 1.0, cost, set())
    return cost


def _instr_memory_bytes(instr: Instruction, comp: Computation) -> float:
    if instr.op in _ZERO_COST_OPS:
        return 0.0
    out_b = shape_bytes(instr.shape)
    if instr.op == "dynamic-update-slice":
        upd = instr.operands[1] if len(instr.operands) > 1 else None
        ub = shape_bytes(_operand_shape(comp, upd)) if upd else 0
        return 2.0 * ub
    if instr.op == "dynamic-slice":
        return 2.0 * out_b
    in_b = 0
    for o in instr.operands:
        in_b += shape_bytes(_operand_shape(comp, o))
    return float(out_b + in_b)


def _walk(comps: Dict[str, Computation], comp: Computation, mult: float,
          cost: HloCost, fused_stack: set):
    for instr in comp.instructions:
        if instr.op == "while":
            trips = while_trip_count(instr, comps)
            cost.while_loops.append((instr.name, trips))
            body = instr.attr("body")
            condition = instr.attr("condition")
            if body and body.lstrip("%") in comps:
                _walk(comps, comps[body.lstrip("%")], mult * trips, cost, fused_stack)
            if condition and condition.lstrip("%") in comps:
                _walk(comps, comps[condition.lstrip("%")], mult * trips, cost, fused_stack)
            continue
        if instr.op in COLLECTIVE_KINDS or (
                instr.op.endswith("-start") and instr.op[:-6] in COLLECTIVE_KINDS):
            kind = instr.op[:-6] if instr.op.endswith("-start") else instr.op
            b = sum(shape_bytes(_operand_shape(comp, o)) for o in instr.operands)
            if b == 0:  # operands may be parameters of shape unknown: use result
                b = shape_bytes(instr.shape)
            cost.collective_bytes[kind] = cost.collective_bytes.get(kind, 0.0) + b * mult
            cost.collective_counts[kind] = cost.collective_counts.get(kind, 0.0) + mult
            cost.memory_bytes += _instr_memory_bytes(instr, comp) * mult
            continue
        if instr.op == "dot":
            cost.flops += _dot_flops(instr, comp) * mult
        if instr.op == "convolution":
            # rough: 2 * out_elems * (in_channels * kernel_spatial)
            out_elems = math.prod(_shape_elems(instr.shape) or [1])
            cost.flops += 2.0 * out_elems * 128.0 * mult  # documented coarse fallback
        # Memory traffic for materializing top-level ops.  A fusion counts
        # its operands + output once (instructions inside the fused body are
        # never materialized) — matching XLA's buffer behavior.
        cost.memory_bytes += _instr_memory_bytes(instr, comp) * mult
        for callee, w in _called_computations(instr):
            if callee in comps and instr.op != "while":
                if instr.op == "fusion":
                    # fusion bodies: count flops (dots) but not memory
                    _walk_fused(comps, comps[callee], mult * w, cost)
                else:
                    _walk(comps, comps[callee], mult * w, cost, fused_stack)


def _walk_fused(comps: Dict[str, Computation], comp: Computation, mult: float,
                cost: HloCost):
    for instr in comp.instructions:
        if instr.op == "dot":
            cost.flops += _dot_flops(instr, comp) * mult
        elif instr.op in COLLECTIVE_KINDS:
            b = sum(shape_bytes(_operand_shape(comp, o)) for o in instr.operands)
            if b == 0:
                b = shape_bytes(instr.shape)
            cost.collective_bytes[instr.op] = cost.collective_bytes.get(instr.op, 0.0) + b * mult
            cost.collective_counts[instr.op] = cost.collective_counts.get(instr.op, 0.0) + mult
        for callee, w in _called_computations(instr):
            if callee in comps:
                _walk_fused(comps, comps[callee], mult * w, cost)


def remat_duplication(text: str) -> Dict[str, int]:
    """Count duplicate op_name metadata occurrences — a proxy for
    remat-inserted recompute (perf-loop §Pallas hints)."""
    names = re.findall(r'op_name="([^"]+)"', text)
    counts: Dict[str, int] = defaultdict(int)
    for n in names:
        counts[n] += 1
    return {n: c for n, c in counts.items() if c > 1}
