"""Published data from the paper, used for fitting and validation.

Tables II-V: predicted percentage-of-peak on Hopper for each algorithm x
variant over core counts {1536, 6144, 24576, 98304, 393216} and two matrix
sizes.  (The paper's own model outputs — our reproduction target.)
"""

from __future__ import annotations

CORE_COUNTS = (1536, 6144, 24576, 98304, 393216)
VARIANTS = ("2d", "2d_ovlp", "2.5d", "2.5d_ovlp")

# {algo: {size: {variant: (pct at each of CORE_COUNTS)}}}
PAPER_TABLES = {
    "cannon": {  # Table II
        32768: {
            "2d": (67.95, 35.42, 12.87, 4.57, 1.30),
            "2d_ovlp": (83.69, 59.88, 15.33, 4.93, 1.35),
            "2.5d": (53.63, 35.95, 21.56, 9.37, 3.94),
            "2.5d_ovlp": (55.56, 37.96, 27.80, 10.55, 4.19),
        },
        65536: {
            "2d": (72.36, 50.20, 22.59, 8.71, 2.78),
            "2d_ovlp": (80.40, 73.20, 30.73, 9.78, 2.91),
            "2.5d": (64.52, 48.22, 34.51, 17.04, 7.55),
            "2.5d_ovlp": (65.91, 50.95, 45.78, 21.04, 8.32),
        },
    },
    "summa": {  # Table III
        32768: {
            "2d": (52.29, 24.98, 10.46, 4.01, 1.27),
            "2d_ovlp": (68.59, 27.85, 12.02, 4.29, 1.33),
            "2.5d": (49.18, 30.28, 16.44, 7.93, 3.56),
            "2.5d_ovlp": (46.65, 34.74, 19.71, 8.75, 3.77),
        },
        65536: {
            "2d": (62.43, 38.82, 18.92, 8.75, 3.62),
            "2d_ovlp": (66.47, 58.69, 24.28, 9.83, 3.84),
            "2.5d": (61.19, 43.54, 27.67, 14.68, 7.75),
            "2.5d_ovlp": (55.19, 43.37, 38.51, 17.51, 8.56),
        },
    },
    "trsm": {  # Table IV
        65536: {
            "2d": (43.40, 21.04, 8.70, 3.33, 1.24),
            "2d_ovlp": (39.85, 21.50, 9.84, 3.60, 1.29),
            "2.5d": (41.37, 24.20, 10.94, 4.42, 1.38),
            "2.5d_ovlp": (44.16, 28.00, 13.16, 4.79, 1.43),
        },
        131072: {
            "2d": (56.10, 33.49, 15.87, 6.85, 2.87),
            "2d_ovlp": (49.62, 32.39, 17.10, 7.88, 3.06),
            "2.5d": (55.58, 38.01, 20.12, 9.13, 3.11),
            "2.5d_ovlp": (57.89, 42.03, 26.06, 10.59, 3.29),
        },
    },
    "cholesky": {  # Table V
        65536: {
            "2d": (32.29, 15.02, 5.64, 1.89, 0.56),
            "2d_ovlp": (32.29, 19.71, 6.82, 2.01, 0.57),
            "2.5d": (21.02, 11.68, 4.73, 1.83, 0.59),
            "2.5d_ovlp": (21.81, 12.51, 5.01, 1.87, 0.61),
        },
        131072: {
            "2d": (46.88, 18.44, 6.36, 4.67, 1.66),
            "2d_ovlp": (58.26, 26.19, 8.79, 5.45, 1.74),
            "2.5d": (29.86, 14.78, 6.47, 4.29, 1.76),
            "2.5d_ovlp": (30.72, 15.96, 6.60, 4.29, 1.83),
        },
    },
}

# Headline qualitative claims (paper §VI-B) used as validation assertions:
# 1. Cannon/SUMMA/Cholesky: at small core counts 2D_ovlp wins; at large core
#    counts 2.5D_ovlp wins (a crossover exists within the studied range).
# 2. TRSM: 2.5D_ovlp is best at every studied core count... (Table IV shows
#    2D best at 1536 for 65536? No: 44.16 (2.5d_ovlp) > 43.40 (2d) — best
#    everywhere indeed, matching the text.)
# 3. est_Cal ranks variants correctly; est_NoCal does not (Figs. 5-8).
CLAIMED_CROSSOVER = {"cannon": True, "summa": True, "cholesky": True, "trsm": False}


def table_best_variant(algo: str, size: int, cores: int) -> str:
    idx = CORE_COUNTS.index(cores)
    row = PAPER_TABLES[algo][size]
    return max(row, key=lambda v: row[v][idx])
