"""Beyond-paper: the paper's methodology applied to LM train/serve steps.

Exactly as §IV prescribes for linear algebra, we walk the step's execution
flow, charging ``T_rout`` for each local matmul (MXU efficiency curve at
the operand's blocking) and the calibrated alpha-beta collective models for
every mesh collective the sharding implies:

  per layer (Megatron TP over 'model', DP over 'data'/'pod'):
    fwd: 2 ring all-reduces of the (B_local, S, D) activations over TP
    bwd: 2 more + weight-gradient compute
    (FSDP: + per-layer all-gather of the layer's params over 'data')
  per step:
    DP gradient reduce-scatter + all-gather (ring) over 'data'
    cross-pod gradient all-reduce over 'pod' (DCN beta), optionally int8
  MoE: all-to-all dispatch/return over the expert axis, top_k-scaled FFN

The result is the same three-term structure as §Roofline but derived from
the *model*, not the compiled HLO — EXPERIMENTS.md cross-checks the two
(model collective bytes vs HLO-parsed collective bytes), which is this
framework's analog of the paper's Fig. 5-8 est-vs-measured validation.

C_avg/C_max enter exactly as in the paper: every collective is a
synchronization, so sync-closing steps take C_max(p, d); the torus
link-load simulator supplies the surfaces for hardware we cannot measure.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from ..configs.base import ModelConfig, ShapeConfig
from ..sim import derive_calibration, v5e_pod_topology
from .collectives import t_all_to_all, t_ring_allgather, t_ring_allreduce, \
    t_ring_reducescatter
from .machine import TPU_V5E, Machine
from .perfmodel import (Calibration, CommModel, ComputeModel,
                        IdentityCalibration, TPU_EFFICIENCY)


@dataclasses.dataclass
class LMStepEstimate:
    compute_s: float
    tp_collective_s: float
    dp_collective_s: float
    pod_collective_s: float
    moe_alltoall_s: float
    flops_per_chip: float
    collective_bytes_per_chip: float

    @property
    def collective_s(self) -> float:
        return (self.tp_collective_s + self.dp_collective_s
                + self.pod_collective_s + self.moe_alltoall_s)

    @property
    def total_overlapped(self) -> float:
        """Paper overlap composition: collectives hidden behind compute."""
        return max(self.compute_s, self.collective_s)

    @property
    def total_serial(self) -> float:
        return self.compute_s + self.collective_s

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(collective_s=self.collective_s,
                 total_overlapped=self.total_overlapped,
                 total_serial=self.total_serial)
        return d


def predict_train_step(cfg: ModelConfig, shape: ShapeConfig,
                       mesh_shape: Dict[str, int],
                       machine: Machine = TPU_V5E,
                       calibration: Optional[Calibration] = None,
                       *, fsdp: bool = False,
                       int8_pod_reduce: bool = False) -> LMStepEstimate:
    cal = calibration or derive_calibration(
        v5e_pod_topology(), ps=[16, 64, 256], distances=[1, 2, 4, 8])
    cm = CommModel(machine, cal)
    comp = ComputeModel(machine, TPU_EFFICIENCY)

    tp = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1)
    pods = mesh_shape.get("pod", 1)
    chips = tp * dp * pods
    B, S, D, L = shape.global_batch, shape.seq_len, cfg.d_model, cfg.n_layers
    tokens = B * S
    words = lambda n_bytes: n_bytes / machine.word_bytes

    # ---- compute term: 6 * active-params * tokens, at matmul efficiency of
    # the per-chip blocking (the MXU sees [tokens/dp x D/tp]-ish tiles)
    flops = 6.0 * cfg.active_param_count() * tokens
    block = min(tokens / (dp * pods), max(cfg.d_ff, cfg.d_model) / tp)
    eff = comp.efficiency["dgemm"](block)
    compute_s = flops / (chips * machine.peak_flops_per_unit * eff)
    # remat forward recompute: +fwd pass (1/3 of 6ND)
    if cfg.remat:
        compute_s *= 4.0 / 3.0

    # ---- TP collectives: 4 all-reduces of local activations per layer
    # (2 fwd + 2 bwd), ring over the model axis, every one a sync (C_max)
    act_bytes = (tokens / (dp * pods)) * D * 2
    tp_one = t_ring_allreduce(cm, tp, words(act_bytes), d=1)
    tp_s = 4 * L * tp_one * (cal.c_max(chips, 1) / max(cal.c_avg(1), 1e-9))

    # ---- FSDP per-layer param all-gather (fwd + bwd) over 'data'
    fsdp_s = 0.0
    param_bytes = cfg.param_count() * 2
    if fsdp:
        per_layer = param_bytes / max(L, 1) / tp
        fsdp_s = 2 * L * t_ring_allgather(cm, dp, words(per_layer), d=1)

    # ---- DP gradient reduce-scatter + all-gather over 'data'
    grad_bytes = param_bytes / tp
    dp_s = (t_ring_reducescatter(cm, dp, words(grad_bytes), d=1)
            + t_ring_allgather(cm, dp, words(grad_bytes), d=1)) if dp > 1 else 0.0
    dp_s += fsdp_s

    # ---- cross-pod gradient all-reduce over DCN
    pod_s = 0.0
    if pods > 1:
        dcn = machine.dcn_bandwidth or machine.link_bandwidth
        shard = grad_bytes / dp
        factor = 1.0 if int8_pod_reduce else 2.0   # int8 AG vs bf16 ring AR
        pod_s = factor * shard * (pods - 1) / pods / dcn
        pod_s *= cal.c_max(chips, 1) / max(cal.c_avg(1), 1e-9)

    # ---- MoE all-to-all (dispatch + return, fwd + bwd)
    moe_s = 0.0
    routed_bytes = 0.0
    if cfg.moe:
        routed_bytes = tokens / (dp * pods) * D * 2 * cfg.moe.top_k
        moe_s = 4 * t_all_to_all(cm, tp, words(routed_bytes), d=1)

    coll_bytes = (4 * L * act_bytes * 2 * (tp - 1) / tp
                  + 2 * grad_bytes
                  + (routed_bytes * 4 if cfg.moe else 0.0))
    return LMStepEstimate(
        compute_s=compute_s, tp_collective_s=tp_s, dp_collective_s=dp_s,
        pod_collective_s=pod_s, moe_alltoall_s=moe_s,
        flops_per_chip=flops / chips,
        collective_bytes_per_chip=coll_bytes / chips)


def sharding_tradeoff_table(cfg: ModelConfig, shape: ShapeConfig,
                            chips: int = 256,
                            machine: Machine = TPU_V5E) -> Dict[str, dict]:
    """The paper's Tables II-V analog for LM training: sweep the (dp, tp)
    factorization (and FSDP on/off — the 2.5D-style memory-for-comm trade)
    and report predicted step time per configuration."""
    out = {}
    tp = 1
    while tp <= chips:
        dp = chips // tp
        if dp * tp == chips and dp >= 1:
            for fsdp in (False, True):
                est = predict_train_step(cfg, shape,
                                         {"data": dp, "model": tp},
                                         machine, fsdp=fsdp)
                mem_gb = (cfg.param_count() * 2 *
                          (1.0 / tp if not fsdp else 1.0 / (tp * dp))) / 1e9
                out[f"dp{dp}xtp{tp}{'+fsdp' if fsdp else ''}"] = {
                    "step_s": est.total_overlapped,
                    "compute_s": est.compute_s,
                    "collective_s": est.collective_s,
                    "param_gb_per_chip": mem_gb,
                }
        tp *= 2
    return out
