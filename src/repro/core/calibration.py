"""Portable benchmarks + fitting for the model parameters (paper §IV).

Three benchmark families, exactly mirroring the paper:

1. ``bench_routines`` — local-routine efficiency (paper Fig. 1): times each
   BLAS-like routine over block sizes on one device and fits an
   ``EfficiencyCurve`` per routine.
2. ``bench_ping`` — the LogP latency/bandwidth benchmark (paper Fig. 2):
   two devices exchange messages of increasing size; (L, beta) by least
   squares.
3. ``bench_contention`` — the paper's new calibration micro-benchmark
   (Figs. 3-4): all p processes transfer simultaneously at communication
   distance d; the calibration factor is real/ideal time.

All three run on whatever devices JAX exposes (here: host CPU devices; on a
real pod: TPU chips) — the benchmarks are the portable part of the
methodology, the numbers are machine-specific.

Because a single-process CPU run cannot observe *per-rank* completion times
(everything is jitted SPMD), the repo derives deterministic
``C_avg``/``C_max`` surfaces from a dimension-ordered-routing link-load
model of a torus.  That model lives in ``repro.sim`` (topologies, the
link-contention network engine, and the full per-rank program simulator);
see ``repro.sim.derive_calibration``.

``fit_hopper_calibration`` recovers the paper's (unpublished) calibration
surface by fitting ``ParametricCalibration`` to the paper's *published*
Cannon predictions (Table II) — then §Paper-validation checks the fit
transfers to SUMMA/TRSM/Cholesky (Tables III-V), which is the paper's own
claim that one set of benchmarked parameters predicts all algorithms.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Dict, Optional, Sequence

import numpy as np

from . import algorithms as alg
from .. import compat
from .fitting import multistart_nelder_mead
from .machine import CPU_HOST, HOPPER, Machine
from .paper_data import CORE_COUNTS, PAPER_TABLES
from .perfmodel import (CommModel, ComputeModel, EfficiencyCurve,
                        HOPPER_EFFICIENCY, ParametricCalibration,
                        ROUTINE_FLOPS)

ARTIFACTS_DIR = os.environ.get(
    "REPRO_ARTIFACTS", os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts"))


# ---------------------------------------------------------------------------
# 1. Local routine efficiency (paper Fig. 1)
# ---------------------------------------------------------------------------


def _time_call(fn, *args, reps: int = 3) -> float:
    fn(*args)  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        _block(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _block(x):
    try:
        import jax
        jax.block_until_ready(x)
    except Exception:
        pass


def bench_routines(sizes: Sequence[int] = (128, 256, 512, 1024, 2048),
                   dtype=None) -> Dict[str, Dict[int, float]]:
    """Measured GFLOP/s of each routine per block size (Fig. 1 analog)."""
    import jax
    import jax.numpy as jnp
    dtype = dtype or jnp.float64
    results: Dict[str, Dict[int, float]] = {r: {} for r in ROUTINE_FLOPS}
    key = jax.random.PRNGKey(0)
    for n in sizes:
        a = jax.random.normal(key, (n, n), dtype=jnp.float32).astype(dtype)
        spd = (a @ a.T + n * jnp.eye(n, dtype=dtype))
        tri = jnp.triu(a) + n * jnp.eye(n, dtype=dtype)
        fns = {
            "dgemm": jax.jit(lambda x, y: x @ y),
            "dtrsm": jax.jit(lambda u, b: jax.scipy.linalg.solve_triangular(u, b, lower=False)),
            "dsyrk": jax.jit(lambda x, y: x @ y.T),
            "dpotrf": jax.jit(jnp.linalg.cholesky),
            "dgetrf": jax.jit(jax.scipy.linalg.lu),
        }
        args = {"dgemm": (a, a), "dtrsm": (tri, a), "dsyrk": (a, a),
                "dpotrf": (spd,), "dgetrf": (spd,)}
        for rout in ROUTINE_FLOPS:
            secs = _time_call(fns[rout], *args[rout])
            results[rout][n] = ROUTINE_FLOPS[rout](n) / secs
    return results


def fit_efficiency(gflops_by_size: Dict[int, float], peak: float) -> EfficiencyCurve:
    sizes = np.array(sorted(gflops_by_size))
    effs = np.array([gflops_by_size[int(n)] / peak for n in sizes])
    effs = np.clip(effs, 1e-4, 1.0)

    def loss(theta):
        emax, n0 = abs(theta[0]), abs(theta[1]) + 1.0
        pred = np.clip(emax * (1 - np.exp(-sizes / n0)), 1e-4, None)
        return float(np.mean((np.log(pred) - np.log(effs)) ** 2))

    theta, _ = multistart_nelder_mead(loss, np.array([effs.max(), 300.0]), n_starts=4)
    return EfficiencyCurve(float(abs(theta[0])), float(abs(theta[1]) + 1.0))


def measured_compute_model(machine: Machine = CPU_HOST,
                           sizes: Sequence[int] = (128, 256, 512, 1024)) -> ComputeModel:
    """Benchmark this host and return a fitted ComputeModel.  Also updates
    the machine's peak to the best observed dgemm rate (the paper uses the
    vendor peak; on an unknown host, measured peak is the honest analog)."""
    bench = bench_routines(sizes)
    peak = max(bench["dgemm"].values())
    machine = dataclasses.replace(machine, peak_flops_per_unit=peak)
    curves = {r: fit_efficiency(v, peak) for r, v in bench.items()}
    return ComputeModel(machine, curves)


# ---------------------------------------------------------------------------
# 2. LogP ping (paper Fig. 2): fit L and beta
# ---------------------------------------------------------------------------


def bench_ping(sizes_words: Sequence[int] = (256, 1024, 4096, 16384, 65536, 262144),
               word_bytes: int = 8, reps: int = 5) -> Dict[int, float]:
    """Round-trip/2 time between two JAX devices per message size (words)."""
    import jax
    import jax.numpy as jnp
    devs = jax.devices()
    if len(devs) < 2:
        raise RuntimeError("bench_ping needs >= 2 devices "
                           "(set --xla_force_host_platform_device_count)")
    dtype = jnp.float64 if word_bytes == 8 else jnp.float32
    out: Dict[int, float] = {}
    for w in sizes_words:
        x = jnp.ones((w,), dtype)
        xa = jax.device_put(x, devs[0])
        def ping(y):
            return jax.device_put(y, devs[1])
        ping(xa)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(ping(xa))
            best = min(best, time.perf_counter() - t0)
        out[w] = best
    return out


def fit_alpha_beta(ping: Dict[int, float]) -> tuple[float, float]:
    """Least-squares (L, beta) from T(w) = L + beta*w."""
    ws = np.array(sorted(ping))
    ts = np.array([ping[int(w)] for w in ws])
    A = np.stack([np.ones_like(ws, dtype=float), ws.astype(float)], axis=1)
    (L, beta), *_ = np.linalg.lstsq(A, ts, rcond=None)
    return float(max(L, 1e-9)), float(max(beta, 1e-15))


# ---------------------------------------------------------------------------
# 3. Contention calibration benchmark (paper Figs. 3-4)
# ---------------------------------------------------------------------------


def bench_contention(n_procs: int, distance: int, words: int = 1 << 20,
                     word_bytes: int = 8, reps: int = 5) -> float:
    """All n_procs devices ppermute by ``distance`` simultaneously; returns
    wall seconds of the slowest (i.e., the C_max-style observation — in an
    SPMD jit there is a single completion time)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    devs = jax.devices()[:n_procs]
    if len(devs) < n_procs:
        raise RuntimeError(f"need {n_procs} devices, have {len(devs)}")
    mesh = compat.make_mesh((n_procs,), ("x",), devices=devs)
    dtype = jnp.float64 if word_bytes == 8 else jnp.float32

    def shift(x):
        perm = [(i, (i + distance) % n_procs) for i in range(n_procs)]
        return jax.lax.ppermute(x, "x", perm)

    run = jax.jit(compat.shard_map(shift, mesh=mesh, in_specs=P("x"),
                                   out_specs=P("x")))

    x = jnp.ones((n_procs * words,), dtype)
    xs = jax.device_put(x, NamedSharding(mesh, P("x")))
    run(xs)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(run(xs))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Fit the Hopper calibration surface to the paper's published Table II
# ---------------------------------------------------------------------------


def _hopper_ctx(calib: ParametricCalibration) -> alg.AlgoContext:
    return alg.AlgoContext(
        comm=CommModel(HOPPER, calib),
        comp=ComputeModel(HOPPER, HOPPER_EFFICIENCY),
    )


def _table_residuals(calib: ParametricCalibration, algos: Sequence[str]) -> np.ndarray:
    """log-space residuals of our models vs the paper's published tables."""
    from .predictor import best_variant
    ctx = _hopper_ctx(calib)
    res = []
    for algo in algos:
        for size, rows in PAPER_TABLES[algo].items():
            for ci, cores in enumerate(CORE_COUNTS):
                p = cores // HOPPER.threads_per_unit
                choices = best_variant(ctx, algo, size, p)
                for variant, published in rows.items():
                    pred = choices[variant]
                    pred_pct = (100.0 * alg.USEFUL_FLOPS[algo](size)
                                / (pred.result.total * cores * HOPPER.peak_flops_per_thread))
                    res.append(math.log(max(pred_pct, 1e-6)) - math.log(published[ci]))
    return np.array(res)


def fit_hopper_calibration(fit_algos: Sequence[str] = ("cannon",),
                           n_starts: int = 6, seed: int = 0) -> ParametricCalibration:
    def loss(theta):
        calib = ParametricCalibration.from_params(theta)
        r = _table_residuals(calib, fit_algos)
        return float(np.mean(r ** 2))

    x0 = ParametricCalibration().params()
    theta, _ = multistart_nelder_mead(loss, x0, n_starts=n_starts, seed=seed,
                                      max_iter=400)
    return ParametricCalibration.from_params(np.abs(theta))


def hopper_fitted_calibration(refit: bool = False) -> ParametricCalibration:
    """Cached fitted surface (artifacts/hopper_calibration.json)."""
    os.makedirs(ARTIFACTS_DIR, exist_ok=True)
    path = os.path.join(ARTIFACTS_DIR, "hopper_calibration.json")
    if not refit and os.path.exists(path):
        with open(path) as f:
            return ParametricCalibration.from_params(json.load(f)["params"])
    calib = fit_hopper_calibration()
    with open(path, "w") as f:
        json.dump({"params": [float(x) for x in calib.params()]}, f)
    return calib


def _ctx_from_theta(theta: np.ndarray) -> alg.AlgoContext:
    """theta = 5 calibration params + (eff_max, n0) for dgemm/dtrsm/dpotrf.
    dsyrk tracks dgemm (same MXU/BLAS3 path).  Efficiency parameters are
    box-constrained to the visually-plausible range of paper Fig. 1
    (eff_max in [0.5, 0.98], n0 in [80, 1200]) so the fit can't push
    compute curves into absurd regions to absorb model-structure error."""

    def _eff(em, n0):
        return EfficiencyCurve(float(np.clip(abs(em), 0.5, 0.98)),
                               float(np.clip(abs(n0), 80.0, 1200.0)))

    calib = ParametricCalibration.from_params(np.abs(theta[:5]))
    eff = {
        "dgemm": _eff(theta[5], theta[6]),
        "dtrsm": _eff(theta[7], theta[8]),
        "dpotrf": _eff(theta[9], theta[10]),
        "dsyrk": _eff(theta[5], theta[6]),
    }
    return alg.AlgoContext(comm=CommModel(HOPPER, calib),
                           comp=ComputeModel(HOPPER, eff))


def _residuals_ctx(ctx: alg.AlgoContext, algos: Sequence[str],
                   core_idx: Optional[Sequence[int]] = None) -> np.ndarray:
    from .predictor import best_variant
    res = []
    for algo in algos:
        for size, rows in PAPER_TABLES[algo].items():
            for ci, cores in enumerate(CORE_COUNTS):
                if core_idx is not None and ci not in core_idx:
                    continue
                p = cores // HOPPER.threads_per_unit
                choices = best_variant(ctx, algo, size, p)
                for variant, published in rows.items():
                    pred = choices[variant]
                    pred_pct = (100.0 * alg.USEFUL_FLOPS[algo](size)
                                / (pred.result.total * cores * HOPPER.peak_flops_per_thread))
                    res.append(math.log(max(pred_pct, 1e-6)) - math.log(published[ci]))
    return np.array(res)


def fit_hopper_joint(train_core_idx: Sequence[int] = (0, 2, 4),
                     n_starts: int = 4, seed: int = 0) -> tuple[alg.AlgoContext, np.ndarray]:
    """Jointly fit calibration + routine-efficiency curves on a train split
    (alternate core counts, all four tables); returns (ctx, theta).
    Held-out columns {1, 3} are the validation set."""

    def loss(theta):
        ctx = _ctx_from_theta(theta)
        r = _residuals_ctx(ctx, list(PAPER_TABLES), core_idx=train_core_idx)
        return float(np.mean(r ** 2))

    x0 = np.concatenate([ParametricCalibration().params(),
                         [0.92, 350.0, 0.85, 500.0, 0.70, 600.0]])
    theta, _ = multistart_nelder_mead(loss, x0, n_starts=n_starts, seed=seed,
                                      max_iter=600)
    return _ctx_from_theta(theta), theta


def fit_hopper_two_stage(train_core_idx: Sequence[int] = (0, 2, 4),
                         n_starts: int = 6, seed: int = 0) -> tuple[alg.AlgoContext, np.ndarray]:
    """Two-stage fit mirroring the paper's measurement independence:

    stage 1 — calibration surface + dgemm curve from the pure-dgemm
              algorithms (Cannon + SUMMA);
    stage 2 — dtrsm / dpotrf curves from TRSM + Cholesky with stage-1
              parameters frozen (they only add routine terms).
    """

    def loss1(sub):
        theta = np.concatenate([sub, [0.85, 500.0, 0.70, 600.0]])
        ctx = _ctx_from_theta(theta)
        r = _residuals_ctx(ctx, ["cannon", "summa"], core_idx=train_core_idx)
        return float(np.mean(r ** 2))

    x0 = np.concatenate([ParametricCalibration().params(), [0.92, 350.0]])
    sub1, _ = multistart_nelder_mead(loss1, x0, n_starts=n_starts, seed=seed,
                                     max_iter=800)

    def loss2(sub):
        theta = np.concatenate([sub1, sub])
        ctx = _ctx_from_theta(theta)
        r = _residuals_ctx(ctx, ["trsm", "cholesky"], core_idx=train_core_idx)
        return float(np.mean(r ** 2))

    sub2, _ = multistart_nelder_mead(loss2, np.array([0.85, 500.0, 0.70, 600.0]),
                                     n_starts=n_starts, seed=seed, max_iter=800)
    theta = np.concatenate([sub1, sub2])
    return _ctx_from_theta(theta), theta


def hopper_fitted_ctx(refit: bool = False) -> alg.AlgoContext:
    """Cached jointly-fitted Hopper context (artifacts/hopper_joint.json)."""
    os.makedirs(ARTIFACTS_DIR, exist_ok=True)
    path = os.path.join(ARTIFACTS_DIR, "hopper_joint.json")
    if not refit and os.path.exists(path):
        with open(path) as f:
            theta = np.array(json.load(f)["theta"])
        return _ctx_from_theta(theta)
    ctx, theta = fit_hopper_two_stage()
    with open(path, "w") as f:
        json.dump({"theta": [float(x) for x in theta]}, f)
    return ctx


def joint_validation_report(ctx: alg.AlgoContext,
                            held_out_idx: Sequence[int] = (1, 3)) -> Dict[str, Dict[str, float]]:
    """Per-table geo-mean relative error and max absolute %-of-peak error
    (the paper's own accuracy metric) on the held-out core counts."""
    from .predictor import best_variant
    out: Dict[str, Dict[str, float]] = {}
    for algo in PAPER_TABLES:
        rel = _residuals_ctx(ctx, [algo], core_idx=held_out_idx)
        abs_err = []
        for size, rows in PAPER_TABLES[algo].items():
            for ci, cores in enumerate(CORE_COUNTS):
                if ci not in held_out_idx:
                    continue
                p = cores // HOPPER.threads_per_unit
                choices = best_variant(ctx, algo, size, p)
                for variant, published in rows.items():
                    pred_pct = (100.0 * alg.USEFUL_FLOPS[algo](size)
                                / (choices[variant].result.total * cores
                                   * HOPPER.peak_flops_per_thread))
                    abs_err.append(abs(pred_pct - published[ci]))
        out[algo] = {
            "geo_mean_rel_err": float(np.exp(np.sqrt(np.mean(rel ** 2))) - 1.0),
            "max_abs_pct_points": float(np.max(abs_err)),
            "mean_abs_pct_points": float(np.mean(abs_err)),
        }
    return out


def validation_report(calib: ParametricCalibration) -> Dict[str, float]:
    """Geometric-mean relative error of our fitted models vs each published
    table (fit quality on cannon; *transfer* quality on the rest)."""
    out = {}
    for algo in PAPER_TABLES:
        r = _table_residuals(calib, [algo])
        out[algo] = float(np.exp(np.sqrt(np.mean(r ** 2))) - 1.0)
    return out
