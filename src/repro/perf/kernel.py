"""The intra-kernel tier of the performance-model stack.

The algorithm tier (``perf.models``) stops at whole-op granularity: a
``Compute`` leaf charges ``flops / (peak * efficiency)``.  This module
models what happens *inside* one Pallas kernel launch as a function of the
tile (block) shape, in the phase style of the WSE-2 SUMMA exemplar
(``cycles = FMACS * (1 + Mt) * overhead``, H2D/D2H asymmetry, tile-size
amortization against the on-chip memory limit):

    T_kernel(tile) = T_h2d + T_compute + T_d2h

    T_h2d     = c_h2d * launches + bytes_in(tile)  / bw_h2d
    T_compute = (flops_mxu / fma_rate + flops_vpu / vpu_rate)
                  * overhead_factor + steps(tile) * loop_overhead
    T_d2h     = c_d2h * launches + bytes_out(tile) / bw_d2h

subject to the feasibility gate ``vmem_bytes(tile) <= machine VMEM``.
``bytes_in`` counts *per-grid-step* operand traffic — for matmul it is
``M*K*N * (1/bn + 1/bm) * itemsize``, the classic tile-size/traffic
tradeoff (larger tiles move less data but need more on-chip memory; the
data-movement lower bounds of Ballard et al., arXiv:0902.2537, bound what
any tile plan can save).  Padded dimensions are used throughout, so the
padding waste of an oversized tile and the amortization win of a larger
one trade off inside one formula.

Everything evaluates vectorized over numpy arrays of candidate tiles —
``KernelModel.choose`` scores the whole candidate grid in one pass, like
the scenario engine in ``perf.evaluate`` — and the constants live in
``Machine.kernel_constants`` (seeded by ``benchmarks/bench_kernels.py``,
recalibrated by ``telemetry.refit_kernels``).  When a machine profile has
no kernel-constants block, ``heuristic_plan`` reproduces today's
hard-coded wrapper blocks exactly, so the tuner can always stand down.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

#: block-dimension names per kernel family, in wrapper argument order.
KERNEL_DIMS: Dict[str, Tuple[str, ...]] = {
    "matmul": ("bm", "bn", "bk"),
    "trsm": ("block",),
    "cholesky": ("block",),
    "flash_attention": ("bq", "bkv"),
    "ssm_scan": ("bs",),
}

#: local kernels each dispatchable algorithm executes, in resolution order
#: (matmul first: trsm/cholesky charge their dgemm-shaped work at the
#: already-chosen matmul tile).
ALGO_KERNELS: Dict[str, Tuple[str, ...]] = {
    "cannon": ("matmul",),
    "summa": ("matmul",),
    "trsm": ("matmul", "trsm"),
    "cholesky": ("matmul", "trsm", "cholesky"),
}

#: the MXU/VPU lane tile — no block dimension below this is ever emitted.
MIN_TILE = 128

#: candidate block sizes per dimension (powers of two from the lane tile).
CANDIDATE_SIZES = (128, 256, 512, 1024)

#: default VMEM budget for the heuristic path — headroom out of ~128 MB,
#: shared with ``kernels.common`` (defined here so the model layer stays
#: importable without jax).
VMEM_BUDGET = 96 * 1024 * 1024

_ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


def itemsize_of(dtype) -> int:
    """Bytes per element for a dtype or dtype-name (bf16-aware)."""
    name = getattr(dtype, "name", None) or str(dtype)
    size = _ITEMSIZE.get(name)
    if size is not None:
        return size
    return int(np.dtype(name).itemsize)


def _round_up(x, m):
    """Elementwise round-up to a multiple (numpy-broadcasting)."""
    x = np.asarray(x, dtype=float)
    m = np.asarray(m, dtype=float)
    return np.ceil(x / m) * m


# ---------------------------------------------------------------------------
# TilePlan — the resolved block-shape decision for one kernel family
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Block sizes for one kernel launch.  Frozen and tuple-backed so it is
    hashable — the kernel wrappers take it as a jit-static argument and the
    dispatch executor memoizes on it."""

    kernel: str
    blocks: Tuple[Tuple[str, int], ...]   # ((dim, size), ...) wrapper order
    source: str = "heuristic"             # "heuristic" | "model" | "explicit"

    @classmethod
    def make(cls, kernel: str, source: str = "explicit",
             **dims: int) -> "TilePlan":
        names = KERNEL_DIMS[kernel]
        missing = [d for d in names if d not in dims]
        extra = [d for d in dims if d not in names]
        if missing or extra:
            raise ValueError(f"{kernel} tile needs dims {names}; "
                             f"missing {missing}, extra {extra}")
        return cls(kernel, tuple((d, int(dims[d])) for d in names), source)

    @classmethod
    def from_blocks(cls, kernel: str, blocks: Mapping[str, int],
                    source: str = "explicit") -> "TilePlan":
        return cls.make(kernel, source=source, **dict(blocks))

    def __getitem__(self, dim: str) -> int:
        for d, v in self.blocks:
            if d == dim:
                return v
        raise KeyError(dim)

    def get(self, dim: str, default: Optional[int] = None) -> Optional[int]:
        for d, v in self.blocks:
            if d == dim:
                return v
        return default

    def block_dict(self) -> Dict[str, int]:
        return dict(self.blocks)

    def sizes(self) -> Tuple[int, ...]:
        return tuple(v for _d, v in self.blocks)

    def to_dict(self) -> dict:
        return {"kernel": self.kernel, "blocks": dict(self.blocks),
                "source": self.source}

    @classmethod
    def from_dict(cls, d: Mapping) -> "TilePlan":
        return cls.from_blocks(d["kernel"], d["blocks"],
                               source=d.get("source", "explicit"))


# ---------------------------------------------------------------------------
# Work decomposition per kernel family (vectorized over tile arrays)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KernelWork:
    """What one kernel invocation does, as numpy arrays broadcast over the
    candidate-tile axes: the raw material of both the time model and the
    refit design matrix."""

    flops_mxu: np.ndarray    # dgemm-shaped flops (padded dims)
    flops_vpu: np.ndarray    # column-recurrence / elementwise flops
    bytes_in: np.ndarray     # operand bytes streamed on-chip (per-step sum)
    bytes_out: np.ndarray    # result bytes written back
    steps: np.ndarray        # total grid steps across all launches
    launches: np.ndarray     # pallas_call launches (fixed setup each)
    vmem_bytes: np.ndarray   # peak on-chip bytes of one step's blocks


def _matmul_work(shape, tiles, itemsize):
    # shape entries may themselves be arrays (best_time broadcasts a whole
    # problem-edge axis against the candidate-tile axis)
    m, k, n = (np.asarray(x, dtype=float) for x in shape)
    bm = np.asarray(tiles["bm"], dtype=float)
    bn = np.asarray(tiles["bn"], dtype=float)
    bk = np.asarray(tiles["bk"], dtype=float)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    gm, gn, gk = mp / bm, np_ / bn, kp / bk
    steps = gm * gn * gk
    return KernelWork(
        flops_mxu=2.0 * mp * np_ * kp,
        flops_vpu=np.zeros_like(steps),
        # A-block refetched per N-tile, B-block per M-tile: the 1/bn + 1/bm
        # traffic law that makes tile choice a memory/bandwidth tradeoff.
        bytes_in=steps * (bm * bk + bk * bn) * itemsize,
        bytes_out=gm * gn * bm * bn * itemsize,
        steps=steps,
        launches=np.ones_like(steps),
        vmem_bytes=((bm * bk + bk * bn + bm * bn) * itemsize
                    + bm * bn * 4.0),
    )


def _mm_tile_sizes(mm_tile: Optional[TilePlan]) -> Tuple[float, float, float]:
    if mm_tile is None:
        return 256.0, 256.0, 512.0        # the historical default blocks
    return (float(mm_tile["bm"]), float(mm_tile["bn"]), float(mm_tile["bk"]))


def _trsm_work(shape, tiles, itemsize, mm_tile=None):
    """X U = B with U (n, n), B (m, n), blocked at ``block``: n/b diagonal
    back-substitutions on the VPU + n/b - 1 trailing dgemm updates whose
    aggregate flops are tile-independent but whose launch/step overheads
    amortize with larger blocks."""
    m, n = (float(x) for x in shape)
    b = np.asarray(tiles["block"], dtype=float)
    mp = _round_up(m, MIN_TILE)
    np_ = _round_up(n, b)
    nb = np_ / b
    bm_mm, bn_mm, bk_mm = _mm_tile_sizes(mm_tile)
    # trailing updates: sum_j 2 * mp * b * (np_ - (j+1) b) = mp*np_*(np_-b)
    mxu = mp * np_ * (np_ - b)
    mm_steps = mxu / (2.0 * bm_mm * bn_mm * bk_mm)
    # diagonal solves: one matvec per column -> 2*mp*b flops, b columns/blk
    vpu = 2.0 * mp * b * np_
    diag_steps = nb * np.maximum(mp / 256.0, 1.0)   # trsm_diag row blocks
    # traffic: U blocks once (nb * b*b + upper panels ~ np_^2/2), B panels
    # in and X panels out once per diagonal block, update tails in+out.
    tri = np_ * np_ / 2.0 + np_ * b / 2.0
    bytes_in = (tri + 2.0 * nb * mp * b + mp * (np_ - b)) * itemsize
    bytes_out = (nb * mp * b + mp * (np_ - b)) * itemsize
    return KernelWork(
        flops_mxu=mxu,
        flops_vpu=vpu,
        bytes_in=bytes_in,
        bytes_out=bytes_out,
        steps=diag_steps + mm_steps,
        launches=2.0 * nb - 1.0,          # nb diag solves + nb-1 dgemms
        vmem_bytes=(b * b + 256.0 * b) * itemsize + 256.0 * b * 4.0,
    )


def _cholesky_work(shape, tiles, itemsize, mm_tile=None):
    """Right-looking blocked Cholesky at block ``b``: nb VPU diagonal
    factors, nb-1 panel solves (VPU diag + dgemm tails) and nb-1 trailing
    syrk updates on the MXU."""
    (n,) = (float(x) for x in shape)
    b = np.asarray(tiles["block"], dtype=float)
    np_ = _round_up(n, b)
    nb = np_ / b
    bm_mm, bn_mm, bk_mm = _mm_tile_sizes(mm_tile)
    # rows_j = np_ - (j+1) b for j = 0..nb-2
    sum_rows = np_ * (nb - 1.0) - b * nb * (nb - 1.0) / 2.0
    sum_rows2 = b * b * (nb - 1.0) * nb * (2.0 * nb - 1.0) / 6.0
    # syrk trailing updates + trsm-tail dgemms
    mxu = 2.0 * b * sum_rows2 + b * b * sum_rows
    mm_steps = mxu / (2.0 * bm_mm * bn_mm * bk_mm)
    # diagonal factors (~2/3 b^3 each) + panel back-substitutions
    vpu = nb * (2.0 / 3.0) * b ** 3 + 2.0 * b * b * sum_rows
    diag_steps = nb + (nb - 1.0) * np.maximum(sum_rows
                                              / np.maximum(nb - 1.0, 1.0)
                                              / 256.0, 1.0)
    bytes_in = (nb * b * b + 2.0 * b * sum_rows + 2.0 * sum_rows2) * itemsize
    bytes_out = (nb * b * b + b * sum_rows + sum_rows2) * itemsize
    return KernelWork(
        flops_mxu=mxu,
        flops_vpu=vpu,
        bytes_in=bytes_in,
        bytes_out=bytes_out,
        steps=diag_steps + mm_steps,
        launches=3.0 * nb - 2.0,
        vmem_bytes=(b * b * 2.0) * itemsize + b * b * 4.0,
    )


def _flash_work(shape, tiles, itemsize, causal=False):
    bh, sq, skv, d = (float(x) for x in shape)
    bq = np.asarray(tiles["bq"], dtype=float)
    bkv = np.asarray(tiles["bkv"], dtype=float)
    sqp, skvp = _round_up(sq, bq), _round_up(skv, bkv)
    dp = _round_up(d, MIN_TILE)
    gq, gk = sqp / bq, skvp / bkv
    # causal skips blocks above the diagonal: ~ (1 + 1/gk)/2 of the work
    frac = (1.0 + 1.0 / gk) / 2.0 if causal else 1.0
    steps = bh * gq * gk * frac
    return KernelWork(
        flops_mxu=4.0 * bh * sqp * skvp * dp * frac,   # QK^T and PV
        flops_vpu=6.0 * bh * sqp * skvp * frac,        # exp/max/rescale
        bytes_in=(bh * (sqp * dp * gk + 2.0 * skvp * dp * gq)
                  * frac * itemsize),
        bytes_out=bh * sqp * dp * itemsize,
        steps=steps,
        launches=np.ones_like(steps),
        vmem_bytes=((bq * dp + 2.0 * bkv * dp) * itemsize
                    + (bq * dp + 2.0 * bq * 128.0) * 4.0),
    )


def _ssm_work(shape, tiles, itemsize):
    bh, s, dk, dv = (float(x) for x in shape)
    bs = np.asarray(tiles["bs"], dtype=float)
    sp = _round_up(s, bs)
    gc = sp / bs
    steps = bh * gc
    return KernelWork(
        # intra-chunk scores + intra y + inter y + state update
        flops_mxu=bh * gc * (2.0 * bs * bs * (dk + dv)
                             + 4.0 * bs * dk * dv),
        flops_vpu=6.0 * bh * sp * bs,                  # cumsum/exp/mask
        bytes_in=bh * sp * (2.0 * dk + dv + 1.0) * itemsize,
        bytes_out=bh * sp * dv * itemsize,
        steps=steps,
        launches=np.ones_like(steps),
        vmem_bytes=(bs * (2.0 * dk + 2.0 * dv + 1.0) * itemsize
                    + dk * dv * 4.0),
    )


def kernel_work(kernel: str, shape: Sequence[float],
                tiles: Mapping[str, np.ndarray], itemsize: int, *,
                mm_tile: Optional[TilePlan] = None,
                causal: bool = False) -> KernelWork:
    """The work decomposition of one ``kernel`` invocation on ``shape`` at
    the given tile sizes (arrays broadcast over candidate axes).

    Shapes: matmul ``(m, k, n)``; trsm ``(m, n)``; cholesky ``(n,)``;
    flash_attention ``(bh, sq, skv, d)``; ssm_scan ``(bh, s, dk, dv)``.
    ``mm_tile`` is the already-resolved matmul tile that trsm/cholesky
    charge their dgemm-shaped trailing updates at.
    """
    if kernel == "matmul":
        return _matmul_work(shape, tiles, itemsize)
    if kernel == "trsm":
        return _trsm_work(shape, tiles, itemsize, mm_tile=mm_tile)
    if kernel == "cholesky":
        return _cholesky_work(shape, tiles, itemsize, mm_tile=mm_tile)
    if kernel == "flash_attention":
        return _flash_work(shape, tiles, itemsize, causal=causal)
    if kernel == "ssm_scan":
        return _ssm_work(shape, tiles, itemsize)
    raise ValueError(f"unknown kernel family {kernel!r}; "
                     f"known: {sorted(KERNEL_DIMS)}")


# ---------------------------------------------------------------------------
# Heuristic plans — today's hard-coded wrapper blocks, verbatim
# ---------------------------------------------------------------------------


def heuristic_matmul_blocks(m: int, n: int, k: int, bytes_per_el: int,
                            vmem_budget: Optional[int] = None
                            ) -> Tuple[int, int, int]:
    """The wrapper's historical block choice: start at (256, 256, 512),
    shrink K first, then M/N together, until the blocks fit the budget.

    Unlike the original loop this terminates unconditionally: once every
    dimension has bottomed out at the 128 floor we bail with the floor
    blocks even if they still exceed a tiny budget (the kernel then runs
    VMEM-oversubscribed rather than the picker spinning forever), and the
    budget is a parameter instead of a module constant.
    """
    budget = VMEM_BUDGET if vmem_budget is None else int(vmem_budget)
    bm, bn, bk = 256, 256, 512

    def over(bm, bn, bk):
        # the historical cost formula (f32 accumulator; out block ignored)
        return (bm * bk + bk * bn) * bytes_per_el + bm * bn * 4 > budget

    while over(bm, bn, bk):
        if bk > MIN_TILE:
            bk //= 2
        elif bm > MIN_TILE or bn > MIN_TILE:
            bm, bn = max(MIN_TILE, bm // 2), max(MIN_TILE, bn // 2)
        else:
            break                         # floor-and-bail: nothing to shrink
    return bm, bn, bk


def _divide_down(total: int, start: int) -> int:
    """Largest block <= start that divides ``total`` by repeated halving,
    flooring at MIN_TILE — the wrappers' divisibility loop."""
    b = min(start, total) if total >= MIN_TILE else start
    while total % b != 0 and b > MIN_TILE:
        b //= 2
    return b


def heuristic_plan(kernel: str, shape: Sequence[int], itemsize: int,
                   vmem_budget: Optional[int] = None) -> TilePlan:
    """The tile plan today's wrappers implicitly use — the stand-down path
    when a machine has no kernel-constants profile, and the golden baseline
    the bit-identity tests pin."""
    if kernel == "matmul":
        m, k, n = shape
        bm, bn, bk = heuristic_matmul_blocks(int(m), int(n), int(k),
                                             itemsize, vmem_budget)
        return TilePlan.make("matmul", source="heuristic",
                             bm=bm, bn=bn, bk=bk)
    if kernel == "trsm":
        return TilePlan.make("trsm", source="heuristic", block=256)
    if kernel == "cholesky":
        return TilePlan.make("cholesky", source="heuristic", block=256)
    if kernel == "flash_attention":
        _bh, sq, skv, _d = shape
        sqp = int(_round_up(sq, MIN_TILE))
        skvp = int(_round_up(skv, MIN_TILE))
        return TilePlan.make("flash_attention", source="heuristic",
                             bq=_divide_down(sqp, 256),
                             bkv=_divide_down(skvp, 256))
    if kernel == "ssm_scan":
        _bh, s, _dk, _dv = shape
        sp = int(_round_up(s, MIN_TILE))
        return TilePlan.make("ssm_scan", source="heuristic",
                             bs=_divide_down(sp, 256))
    raise ValueError(f"unknown kernel family {kernel!r}")


# ---------------------------------------------------------------------------
# Candidate grids
# ---------------------------------------------------------------------------


def candidate_tiles(kernel: str, shape: Sequence[int]
                    ) -> Dict[str, np.ndarray]:
    """The flattened candidate-tile grid for one kernel/shape: per block
    dimension every power-of-two size from the 128 lane tile up to (one
    step past) the relevant padded extent, meshed and flattened so the
    model scores all combinations in one vectorized pass.

    trsm/cholesky candidates are restricted to blocks that divide the
    problem edge — their wrappers fall back to the oracle otherwise.
    """
    dims = KERNEL_DIMS[kernel]
    extent = _dim_extents(kernel, shape)
    per_dim = []
    for d in dims:
        cap = int(_round_up(min(extent[d], CANDIDATE_SIZES[-1]), MIN_TILE))
        sizes = [s for s in CANDIDATE_SIZES if s <= cap] or [MIN_TILE]
        if cap not in sizes and cap <= CANDIDATE_SIZES[-1]:
            sizes.append(cap)             # the exact padded edge (no waste)
        if kernel in ("trsm", "cholesky"):
            n = int(extent[d])
            sizes = [s for s in sizes if n % s == 0] or [MIN_TILE]
        per_dim.append(sorted(set(sizes)))
    grids = np.meshgrid(*[np.asarray(s, dtype=float) for s in per_dim],
                        indexing="ij")
    return {d: g.reshape(-1) for d, g in zip(dims, grids)}


def _dim_extents(kernel: str, shape: Sequence[int]) -> Dict[str, int]:
    if kernel == "matmul":
        m, k, n = shape
        return {"bm": int(m), "bn": int(n), "bk": int(k)}
    if kernel == "trsm":
        _m, n = shape
        return {"block": int(n)}
    if kernel == "cholesky":
        (n,) = shape
        return {"block": int(n)}
    if kernel == "flash_attention":
        _bh, sq, skv, _d = shape
        return {"bq": int(sq), "bkv": int(skv)}
    if kernel == "ssm_scan":
        _bh, s, _dk, _dv = shape
        return {"bs": int(s)}
    raise ValueError(kernel)


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KernelPhases:
    """Per-phase predicted seconds, arrays over the candidate axes."""

    h2d: np.ndarray
    compute: np.ndarray
    d2h: np.ndarray

    @property
    def total(self) -> np.ndarray:
        return self.h2d + self.compute + self.d2h


class KernelModel:
    """Tile-parameterized kernel-time prediction for one machine profile."""

    def __init__(self, machine):
        kc = getattr(machine, "kernel_constants", None)
        if kc is None:
            raise ValueError(
                f"machine {getattr(machine, 'name', machine)!r} has no "
                "kernel_constants profile; use heuristic_plan instead")
        self.machine = machine
        self.kc = kc

    # -- evaluation -----------------------------------------------------------
    def phase_times(self, kernel: str, shape: Sequence[float],
                    tiles: Mapping[str, np.ndarray], itemsize: int, *,
                    mm_tile: Optional[TilePlan] = None,
                    causal: bool = False) -> KernelPhases:
        work = kernel_work(kernel, shape, tiles, itemsize,
                           mm_tile=mm_tile, causal=causal)
        return self.phases_of(work)

    def phases_of(self, work: KernelWork) -> KernelPhases:
        kc = self.kc
        pure = work.flops_mxu / kc.fma_rate + work.flops_vpu / kc.vpu_rate
        return KernelPhases(
            h2d=kc.c_h2d * work.launches + work.bytes_in / kc.bw_h2d,
            compute=pure * kc.overhead_factor
            + work.steps * kc.loop_overhead,
            d2h=kc.c_d2h * work.launches + work.bytes_out / kc.bw_d2h,
        )

    def feasible(self, kernel: str, shape: Sequence[float],
                 tiles: Mapping[str, np.ndarray], itemsize: int
                 ) -> np.ndarray:
        work = kernel_work(kernel, shape, tiles, itemsize)
        return work.vmem_bytes <= self.kc.vmem_bytes

    def time(self, kernel: str, shape: Sequence[float], plan: TilePlan,
             itemsize: int, *, mm_tile: Optional[TilePlan] = None,
             causal: bool = False) -> float:
        tiles = {d: np.asarray(float(v)) for d, v in plan.blocks}
        return float(self.phase_times(kernel, shape, tiles, itemsize,
                                      mm_tile=mm_tile, causal=causal).total)

    # -- selection ------------------------------------------------------------
    def choose(self, kernel: str, shape: Sequence[int], itemsize: int, *,
               mm_tile: Optional[TilePlan] = None,
               causal: bool = False) -> TilePlan:
        """The model-chosen tile: vectorized argmin of predicted total time
        over the VMEM-feasible candidate grid.  Falls back to the heuristic
        plan when no candidate fits (tiny budgets) — never raises."""
        cands = candidate_tiles(kernel, shape)
        work = kernel_work(kernel, shape, cands, itemsize,
                           mm_tile=mm_tile, causal=causal)
        ok = work.vmem_bytes <= self.kc.vmem_bytes
        if not bool(np.any(ok)):
            return heuristic_plan(kernel, shape, itemsize)
        total = self.phases_of(work).total
        j = int(np.argmin(np.where(ok, total, np.inf)))
        return TilePlan.make(kernel, source="model",
                             **{d: int(cands[d][j]) for d in cands})

    def best_time(self, kernel: str, shapes, itemsize: int) -> np.ndarray:
        """Model-optimal kernel seconds over an array of problem edges —
        the evaluate-hook entry point.  ``shapes`` is a dict of per-dim
        arrays broadcast against each other (e.g. square dgemm blocks:
        ``{"m": b, "k": b, "n": b}``)."""
        if kernel != "matmul":
            raise NotImplementedError(
                "best_time currently serves the dgemm evaluate hook only")
        m = np.asarray(shapes["m"], dtype=float).reshape(-1)
        k = np.asarray(shapes["k"], dtype=float).reshape(-1)
        n = np.asarray(shapes["n"], dtype=float).reshape(-1)
        edge = int(max(1.0, float(np.max([m.max(initial=1.0),
                                          k.max(initial=1.0),
                                          n.max(initial=1.0)]))))
        cands = candidate_tiles("matmul", (edge, edge, edge))
        tiles = {d: v[:, None] for d, v in cands.items()}   # (T, 1)
        work = kernel_work("matmul", (m[None, :], k[None, :], n[None, :]),
                           tiles, itemsize)
        ok = work.vmem_bytes <= self.kc.vmem_bytes
        total = np.where(ok, self.phases_of(work).total, np.inf)
        return np.min(total, axis=0)


# ---------------------------------------------------------------------------
# Tuner integration
# ---------------------------------------------------------------------------


def tiles_for_plan(machine, algo: str, n: int, g: int,
                   dtype: str) -> Dict[str, Dict[str, int]]:
    """Resolved tile plans for every local kernel an execution plan needs:
    the model's choice when the machine profile carries kernel constants,
    today's heuristic blocks otherwise.  Keys are kernel family names,
    values plain block dicts (JSON-shaped for the plan cache)."""
    kernels = ALGO_KERNELS.get(algo)
    if not kernels:
        return {}
    itemsize = itemsize_of(dtype)
    # dispatch pads the global problem to a multiple of g, then each rank
    # owns an (nb x nb) local block
    nb = int(math.ceil(float(n) / float(g))) if g else int(n)
    shapes = {"matmul": (nb, nb, nb), "trsm": (nb, nb), "cholesky": (nb,)}
    model = None
    if getattr(machine, "kernel_constants", None) is not None:
        model = KernelModel(machine)
    out: Dict[str, Dict[str, int]] = {}
    mm_tile: Optional[TilePlan] = None
    for kern in kernels:
        if model is None:
            tp = heuristic_plan(kern, shapes[kern], itemsize)
        else:
            tp = model.choose(kern, shapes[kern], itemsize, mm_tile=mm_tile)
        if kern == "matmul":
            mm_tile = tp
        out[kern] = tp.block_dict()
    return out
