"""Evaluation of cost-IR programs: scalar or vectorized, one calibration site.

``evaluate_program`` walks a :class:`repro.perf.ir.Program` once and returns
arrays — evaluate a single scenario by passing scalars, or a whole
``(n, p, c, r)`` grid by passing numpy arrays (everything broadcasts).

Contention calibration is applied in exactly one place — the ``_t_comm`` /
``_t_comm_sync`` helpers below — and the paper's three estimator flavors
are evaluation *options*, not rebuilt contexts:

* ``est_Cal``   (``mode="cal"``, default): the context's C_avg/C_max surfaces;
* ``est_NoCal`` (``mode="nocal"``): C = 1 everywhere;
* ``est_ideal`` (``mode="ideal"``): C = 1 and zero latency — the pure
  bandwidth bound.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import numpy as np

from .ir import (Collective, Compute, Loop, Node, Overlap, P2P, Program, Seq,
                 SyncP2P)

#: bump when model semantics change incompatibly — consumers (the plan
#: cache) embed this so predictions from older equations are invalidated.
MODEL_VERSION = "ir-1"

EVAL_MODES = ("cal", "nocal", "ideal")


@dataclasses.dataclass(frozen=True)
class EvalOptions:
    """How to evaluate: which estimator flavor (see module docstring).

    ``kernel_tier=True`` routes dgemm-shaped Compute leaves through the
    intra-kernel model (``perf.kernel.KernelModel.best_time``: model-optimal
    tiled time including H2D/D2H transfer and launch overheads) instead of
    the efficiency-curve surface — only on machines whose profile carries a
    ``kernel_constants`` block; others keep the curve path.  Off by
    default, so existing predictions are bit-identical.
    """

    mode: str = "cal"
    kernel_tier: bool = False

    def __post_init__(self):
        if self.mode not in EVAL_MODES:
            raise ValueError(f"mode must be one of {EVAL_MODES}, "
                             f"got {self.mode!r}")


@dataclasses.dataclass
class PhaseCost:
    """One labeled phase of a program: exposed (overlap-aware) seconds plus
    the serialized comm/comp ledgers.  Arrays when the env is a grid."""

    exposed: np.ndarray
    comm: np.ndarray
    comp: np.ndarray


@dataclasses.dataclass
class EvalResult:
    """Structured evaluation output (supersedes the ad-hoc ``terms`` dict
    of the pre-IR ``ModelResult``): totals plus a per-phase breakdown, all
    broadcast to the scenario grid's shape."""

    total: np.ndarray
    comm: np.ndarray
    comp: np.ndarray
    phases: Dict[str, PhaseCost]

    def terms(self) -> Dict[str, np.ndarray]:
        """Back-compat view: phase label -> exposed seconds."""
        return {name: ph.exposed for name, ph in self.phases.items()}


class _Evaluator:
    """One walk of a program against (machine surface, env, options)."""

    def __init__(self, ctx, env: Dict[str, np.ndarray], options: EvalOptions):
        # Imported here, not at module top: repro.core.algorithms imports
        # repro.perf for its shims, so a top-level core import would cycle.
        from ..core.perfmodel import ROUTINE_FLOPS
        self.routine_flops = ROUTINE_FLOPS
        self.env = env
        self.options = options
        comm = ctx.comm
        self.machine = comm.machine
        self.latency = 0.0 if options.mode == "ideal" else comm.machine.latency
        self.beta = comm.machine.inv_bandwidth
        self.calibrated = options.mode == "cal"
        self.calibration = comm.calibration
        self.comp_machine = ctx.comp.machine
        self.efficiency = ctx.comp.efficiency
        self.kernel_model = None
        if options.kernel_tier and \
                getattr(self.comp_machine, "kernel_constants", None) \
                is not None:
            from .kernel import KernelModel
            self.kernel_model = KernelModel(self.comp_machine)
        self.phases: Dict[str, PhaseCost] = {}

    # -- the single calibration site ----------------------------------------
    def _t_ideal(self, w):
        return self.latency + self.beta * w

    def _c_avg(self, d):
        if not self.calibrated:
            return 1.0
        return self.calibration.c_avg_vec(d)

    def _c_max(self, d):
        if not self.calibrated:
            return 1.0
        return self.calibration.c_max_vec(self.env["p"], d)

    def _t_comm(self, w, d):
        return self._c_avg(d) * self._t_ideal(w)

    def _t_comm_sync(self, w, d):
        return self._c_max(d) * self._t_ideal(w)

    # -- leaf costs ----------------------------------------------------------
    def _t_rout(self, routine: str, block, threads):
        m = self.comp_machine
        block = np.asarray(block, dtype=float)
        if self.kernel_model is not None and routine == "dgemm":
            # intra-kernel tier: model-optimal tiled dgemm time (incl.
            # transfer and launch terms) for the local (b, b, b) block
            edges = np.maximum(block.reshape(-1), 1.0)
            t_k = self.kernel_model.best_time(
                "matmul", {"m": edges, "k": edges, "n": edges},
                int(m.word_bytes)).reshape(block.shape)
            return np.where(block > 0, t_k, 0.0)
        t = m.threads_per_unit if threads is None else threads
        t = np.clip(t, 1, m.threads_per_unit)
        flops = self.routine_flops[routine](block)
        eff = self.efficiency[routine].ev(block)
        out = flops / (m.peak_flops_per_thread * t * eff)
        return np.where(block > 0, out, 0.0)

    def _collective(self, kind: str, q, w, d):
        return _collective_time(kind, self.env["p"], q, w, d,
                                self._t_ideal, self._c_avg, self._c_max)

    # -- walk ----------------------------------------------------------------
    def run(self, root: Node):
        """Evaluate a program root, recording its top-level phases.

        Only the root Seq's direct children become named phases — a label
        on a Seq nested inside e.g. an Overlap branch is structural, not a
        phase (its cost is already accounted to the enclosing phase).
        """
        if not isinstance(root, Seq):
            e, cm, cp = self.visit(root)
            self._record("total", e, cm, cp)
            return e, cm, cp
        tot_e = tot_cm = tot_cp = 0.0
        for i, (label, child) in enumerate(root.children):
            e, cm, cp = self.visit(child)
            tot_e = tot_e + e
            tot_cm = tot_cm + cm
            tot_cp = tot_cp + cp
            self._record(label if label is not None else f"phase{i}",
                         e, cm, cp)
        return tot_e, tot_cm, tot_cp

    def visit(self, node: Node):
        """Returns the (exposed, comm, comp) second triple of ``node``."""
        if isinstance(node, Compute):
            t = None if node.threads is None else node.threads.ev(self.env)
            s = self._t_rout(node.routine, node.block.ev(self.env), t)
            return s, 0.0, s
        if isinstance(node, P2P):
            s = self._t_comm(node.words.ev(self.env), node.dist.ev(self.env))
            return s, s, 0.0
        if isinstance(node, SyncP2P):
            s = self._t_comm_sync(node.words.ev(self.env),
                                  node.dist.ev(self.env))
            return s, s, 0.0
        if isinstance(node, Collective):
            s = self._collective(node.kind, node.q.ev(self.env),
                                 node.words.ev(self.env),
                                 node.dist.ev(self.env))
            return s, s, 0.0
        if isinstance(node, Loop):
            e, cm, cp = self.visit(node.body)
            k = node.count.ev(self.env)
            return e * k, cm * k, cp * k
        if isinstance(node, Overlap):
            return self._overlap(node)
        if isinstance(node, Seq):
            tot_e = tot_cm = tot_cp = 0.0
            for _label, child in node.children:
                e, cm, cp = self.visit(child)
                tot_e = tot_e + e
                tot_cm = tot_cm + cm
                tot_cp = tot_cp + cp
            return tot_e, tot_cm, tot_cp
        raise TypeError(f"unknown IR node {type(node).__name__}")

    def _record(self, label: str, e, cm, cp):
        ph = self.phases.get(label)
        if ph is None:
            self.phases[label] = PhaseCost(np.asarray(e, dtype=float),
                                           np.asarray(cm, dtype=float),
                                           np.asarray(cp, dtype=float))
        else:
            ph.exposed = ph.exposed + e
            ph.comm = ph.comm + cm
            ph.comp = ph.comp + cp

    def _overlap(self, node: Overlap):
        ea, ca, pa = self.visit(node.comm)
        eb, cb, pb = self.visit(node.comp)
        if node.ramp is None:
            k = node.count.ev(self.env)
            return (np.maximum(ea, eb) * k, (ca + cb) * k, (pa + pb) * k)
        # Ramp form: iteration m=0..k-1 overlaps comm*m with comp*m^2.
        nb = np.asarray(node.ramp.ev(self.env), dtype=float)
        k = np.rint(nb)
        sum_m = k * nb - (k - 1.0) * k / 2.0 - k     # sum_decreasing(nb, 1)
        sum_m2 = (k - 1.0) * k * (2.0 * k - 1.0) / 6.0
        with np.errstate(divide="ignore", invalid="ignore"):
            mstar = np.where(eb > 0, ea / np.where(eb > 0, eb, 1.0), np.inf)
        m_hi = np.minimum(k - 1.0, np.floor(mstar))
        s1 = m_hi * (m_hi + 1.0) / 2.0
        s2 = sum_m2 - m_hi * (m_hi + 1.0) * (2.0 * m_hi + 1.0) / 6.0
        exposed = ea * s1 + eb * s2
        return (exposed,
                ca * sum_m + cb * sum_m2,
                pa * sum_m + pb * sum_m2)


def _build_env(n, p, c, r, machine) -> Dict[str, np.ndarray]:
    env = {"n": np.asarray(n, dtype=float),
           "p": np.asarray(p, dtype=float),
           "c": np.asarray(c, dtype=float),
           "r": np.asarray(r, dtype=float),
           "t": float(machine.threads_per_unit)}
    return env


def evaluate_program(program: Program, ctx, n, p, c=1, r=1,
                     options: Optional[EvalOptions] = None) -> EvalResult:
    """Evaluate ``program`` for scalar or array scenarios.

    ``n``/``p``/``c``/``r`` broadcast against each other; the result arrays
    have the broadcast shape (0-d for all-scalar input).
    """
    options = options or EvalOptions()
    env = _build_env(n, p, c, r, ctx.comp.machine)
    ev = _Evaluator(ctx, env, options)
    exposed, comm, comp = ev.run(program.root)
    shape = np.broadcast_shapes(*(np.shape(env[k]) for k in ("n", "p", "c", "r")))
    bc = lambda x: np.broadcast_to(np.asarray(x, dtype=float), shape)
    phases = {name: PhaseCost(bc(ph.exposed), bc(ph.comm), bc(ph.comp))
              for name, ph in ev.phases.items()}
    return EvalResult(bc(exposed), bc(comm), bc(comp), phases)


# ---------------------------------------------------------------------------
# Collective schedules (paper §V) — vectorized with per-step masking
# ---------------------------------------------------------------------------


def _steps_of(q):
    """``max(1, round(log2(max(2, q))))`` — per-scenario step count."""
    q = np.maximum(2.0, np.asarray(q, dtype=float))
    return np.maximum(1.0, np.rint(np.log2(q)))


def _collective_time(kind, p, q, w, d, t_ideal, c_avg, c_max):
    """Time of one collective schedule, elementwise over scenario arrays.

    Scenario step counts differ across a grid, so the recursive schedules
    are expanded to the grid's maximum step count with inactive steps
    masked to zero — per-step values match the scalar schedule exactly.
    """
    if kind == "reduce":
        return (_collective_time("redsca_sync", p, q, w, d, t_ideal, c_avg, c_max)
                + _collective_time("gather", p, q, w, d, t_ideal, c_avg, c_max))
    if kind == "bcast":
        return (_collective_time("scatter_sync", p, q, w, d, t_ideal, c_avg, c_max)
                + _collective_time("allgather", p, q, w, d, t_ideal, c_avg, c_max))
    if kind == "bcast_sync":
        return (_collective_time("scatter_sync", p, q, w, d, t_ideal, c_avg, c_max)
                + _collective_time("allgather_sync", p, q, w, d, t_ideal, c_avg, c_max))
    if kind == "inirepl":
        # c (the replication factor) arrives as q; distance (c-1)*p/c.
        c = np.asarray(q, dtype=float)
        with np.errstate(divide="ignore", invalid="ignore"):
            dist = (c - 1.0) * np.asarray(p, dtype=float) / c
        t = 2.0 * c_max(dist) * t_ideal(np.asarray(w, dtype=float))
        return np.where(c > 1, t, 0.0)

    q = np.asarray(q, dtype=float)
    w = np.asarray(w, dtype=float)
    d = np.asarray(d, dtype=float)
    active = q > 1.0
    s = _steps_of(q)
    smax = int(np.max(s)) if np.size(s) else 1
    total = np.zeros(np.broadcast_shapes(np.shape(q), np.shape(w), np.shape(d),
                                         np.shape(p)))
    if kind in ("redsca_sync", "scatter_sync"):
        for i in range(smax - 1):
            mask = active & (i < s - 1)
            step = c_avg((2 ** i) * d) * t_ideal(w / 2 ** (i + 1))
            total = total + np.where(mask, step, 0.0)
        last = c_max(2.0 ** (s - 1.0) * d) * t_ideal(w / 2.0 ** s)
        return total + np.where(active, last, 0.0)
    if kind == "allgather_sync":
        for i in range(smax - 1):
            mask = active & (i < s - 1)
            step = c_avg((2 ** i) * d) * t_ideal((w / q) * 2 ** i)
            total = total + np.where(mask, step, 0.0)
        last = c_max(2.0 ** (s - 1.0) * d) * t_ideal((w / q) * 2.0 ** (s - 1.0))
        return total + np.where(active, last, 0.0)
    if kind in ("gather", "allgather"):
        for i in range(smax):
            mask = active & (i < s)
            step = c_avg((2 ** i) * d) * t_ideal((w / q) * 2 ** i)
            total = total + np.where(mask, step, 0.0)
        return total
    raise ValueError(f"unknown collective kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class CollectiveStep:
    """One step of an expanded collective schedule (scalar scenario)."""

    phase: str      # "reduce_scatter" | "scatter" | "gather" | "allgather" | "repl"
    words: float    # words each participating process sends in this step
    dist: float     # communication distance of the step's partner
    sync: bool      # True when the step closes a synchronization (C_max)


def collective_schedule(kind: str, q: float, w: float,
                        d: float = 1.0) -> Tuple[CollectiveStep, ...]:
    """Expand a collective's schedule for one scalar scenario — the
    step-level view used by the traffic-conservation property tests and
    the per-rank simulator.

    The per-step (words, dist, sync) match ``_collective_time`` exactly.
    Expansions are memoized on ``(kind, q, w, d)`` (hence the immutable
    tuple): the same collective step recurs across every iteration of a
    ``Loop`` body and across every shortlist candidate the tuner
    simulates.
    """
    return _collective_schedule(kind, float(q), float(w), float(d))


@functools.lru_cache(maxsize=4096)
def _collective_schedule(kind: str, q: float, w: float,
                         d: float) -> Tuple[CollectiveStep, ...]:
    if kind == "reduce":
        return (_collective_schedule("redsca_sync", q, w, d)
                + _collective_schedule("gather", q, w, d))
    if kind == "bcast":
        return (tuple(dataclasses.replace(st, phase="scatter")
                      for st in _collective_schedule("scatter_sync", q, w, d))
                + tuple(dataclasses.replace(st, phase="allgather")
                        for st in _collective_schedule("allgather", q, w, d)))
    if kind == "bcast_sync":
        return (tuple(dataclasses.replace(st, phase="scatter")
                      for st in _collective_schedule("scatter_sync", q, w, d))
                + _collective_schedule("allgather_sync", q, w, d))
    if q <= 1:
        return ()
    s = int(_steps_of(q))
    out: List[CollectiveStep] = []
    if kind in ("redsca_sync", "scatter_sync"):
        phase = "reduce_scatter" if kind == "redsca_sync" else "scatter"
        for i in range(s - 1):
            out.append(CollectiveStep(phase, w / 2 ** (i + 1), (2 ** i) * d,
                                      False))
        out.append(CollectiveStep(phase, w / 2 ** s, (2 ** (s - 1)) * d, True))
        return tuple(out)
    if kind in ("gather", "allgather", "allgather_sync"):
        phase = "gather" if kind == "gather" else "allgather"
        for i in range(s):
            sync = kind == "allgather_sync" and i == s - 1
            out.append(CollectiveStep(phase, (w / q) * 2 ** i, (2 ** i) * d,
                                      sync))
        return tuple(out)
    raise ValueError(f"unknown collective kind {kind!r}")
