"""Symbolic scenario expressions for the cost IR.

An :class:`Expr` is a tiny numpy-evaluated expression tree over named
scenario parameters (``n``, ``p``, ``c``, ``r``, ``q``, ``d``, plus the
machine thread count ``t`` injected by the evaluator).  Model authors write
ordinary arithmetic (``n / sqrt(p / c)``) and the same tree evaluates for a
single scalar scenario or — the point of the IR — broadcast over numpy
grids of scenarios in one pass.

Only the operations the closed-form paper models need are provided:
arithmetic, ``sqrt``/``floor``/``rint``, ``fmax``/``fmin``, ``where``, and
the closed-form decreasing sum ``sum_decreasing`` that collapses the
triangular loops of TRSM/Cholesky/LU (paper §V-B).
"""

from __future__ import annotations

from typing import Any, Dict, Union

import numpy as np

#: the scenario parameters a model program may reference
SCENARIO_PARAMS = ("n", "p", "c", "r", "q", "d", "t")

ExprLike = Union["Expr", float, int]


class Expr:
    """Base node: evaluate with :meth:`ev` against an env of numpy arrays."""

    def ev(self, env: Dict[str, Any]):
        raise NotImplementedError

    # -- operator sugar ------------------------------------------------------
    def __add__(self, other): return _Bin(np.add, self, as_expr(other))
    def __radd__(self, other): return _Bin(np.add, as_expr(other), self)
    def __sub__(self, other): return _Bin(np.subtract, self, as_expr(other))
    def __rsub__(self, other): return _Bin(np.subtract, as_expr(other), self)
    def __mul__(self, other): return _Bin(np.multiply, self, as_expr(other))
    def __rmul__(self, other): return _Bin(np.multiply, as_expr(other), self)
    def __truediv__(self, other): return _Bin(np.divide, self, as_expr(other))
    def __rtruediv__(self, other): return _Bin(np.divide, as_expr(other), self)
    def __pow__(self, other): return _Bin(np.power, self, as_expr(other))
    def __neg__(self): return _Bin(np.multiply, Const(-1.0), self)


class Param(Expr):
    """A named scenario parameter, looked up in the evaluation env."""

    def __init__(self, name: str):
        if name not in SCENARIO_PARAMS:
            raise ValueError(f"unknown scenario parameter {name!r}; "
                             f"have {SCENARIO_PARAMS}")
        self.name = name

    def ev(self, env):
        try:
            return env[self.name]
        except KeyError:
            raise KeyError(f"scenario parameter {self.name!r} missing from "
                           f"env (have {sorted(env)})") from None

    def __repr__(self):
        return self.name


class Const(Expr):
    def __init__(self, value: float):
        self.value = float(value)

    def ev(self, env):
        return self.value

    def __repr__(self):
        return repr(self.value)


class _Bin(Expr):
    def __init__(self, fn, a: Expr, b: Expr):
        self.fn, self.a, self.b = fn, a, b

    def ev(self, env):
        with np.errstate(divide="ignore", invalid="ignore"):
            return self.fn(self.a.ev(env), self.b.ev(env))

    def __repr__(self):
        return f"{self.fn.__name__}({self.a!r}, {self.b!r})"


class _Fn(Expr):
    def __init__(self, fn, *args: Expr):
        self.fn, self.args = fn, args

    def ev(self, env):
        with np.errstate(divide="ignore", invalid="ignore"):
            return self.fn(*[a.ev(env) for a in self.args])

    def __repr__(self):
        names = ", ".join(repr(a) for a in self.args)
        return f"{self.fn.__name__}({names})"


def as_expr(x: ExprLike) -> Expr:
    if isinstance(x, Expr):
        return x
    if isinstance(x, (int, float)):
        return Const(x)
    raise TypeError(f"cannot convert {type(x).__name__} to Expr")


def sqrt(x: ExprLike) -> Expr: return _Fn(np.sqrt, as_expr(x))
def floor(x: ExprLike) -> Expr: return _Fn(np.floor, as_expr(x))
def rint(x: ExprLike) -> Expr:
    """Round half to even — matches ``int(round(x))`` on CPython floats."""
    return _Fn(np.rint, as_expr(x))


def fmax(a: ExprLike, b: ExprLike) -> Expr:
    return _Fn(np.maximum, as_expr(a), as_expr(b))


def fmin(a: ExprLike, b: ExprLike) -> Expr:
    return _Fn(np.minimum, as_expr(a), as_expr(b))


def where(cond_gt_zero: ExprLike, a: ExprLike, b: ExprLike) -> Expr:
    """``a`` where ``cond_gt_zero > 0``, else ``b``."""
    return _Fn(lambda c, x, y: np.where(np.asarray(c) > 0, x, y),
               as_expr(cond_gt_zero), as_expr(a), as_expr(b))


def sum_decreasing(nb: ExprLike, offset: float = 0.0) -> Expr:
    """``sum_{i=0}^{k-1} (nb - i - offset)`` with ``k = rint(nb)`` — the
    closed form that keeps triangular loops O(1) (transcribed verbatim from
    the pre-IR ``algorithms._sum_decreasing``)."""
    nb = as_expr(nb)
    k = rint(nb)
    return k * nb - (k - 1.0) * k * 0.5 - offset * k


def sum_squares(nb: ExprLike) -> Expr:
    """``sum_{m=1}^{k-1} m^2 = (k-1) k (2k-1) / 6`` with ``k = rint(nb)``."""
    k = rint(as_expr(nb))
    return (k - 1.0) * k * (2.0 * k - 1.0) / 6.0


#: the canonical scenario parameters, ready to import in model programs
N, P, C, R, Q, D, T = (Param(x) for x in SCENARIO_PARAMS)
