"""The cost IR: composable nodes for the paper's modeling methodology.

Every algorithm model in the paper (§V) is a composition of three leaf
costs — local routines (``T_rout``), calibrated point-to-point transfers
(``T_comm`` / ``T_comm_sync``) and analytic collective schedules — combined
by sequencing, loops, and max-overlap.  The IR makes those combinators
first-class:

=============  ============================================================
``Compute``    ``T_rout(routine, block, threads)`` local computation
``P2P``        ``C_avg(d) * (L + beta*w)`` point-to-point transfer
``SyncP2P``    ``C_max(p, d) * (L + beta*w)`` transfer closing a sync
``Collective`` a named recursive collective schedule (``bcast``,
               ``reduce``, ...) expanded step-by-step by the evaluator
``Seq``        sequential composition; children may carry phase labels
``Loop``       ``count`` repetitions of an iteration-independent body
               (``count`` may be any closed-form Expr, e.g. the collapsed
               triangular sums of TRSM/Cholesky)
``Overlap``    max-composition of a comm branch and a comp branch
               (paper §IV); the ``ramp`` form charges
               ``sum_m max(comm*m, comp*m^2)`` analytically for the
               right-looking factorization loops
=============  ============================================================

Nodes hold :class:`repro.perf.expr.Expr` parameters, so one program
evaluates either for a scalar scenario or vectorized over numpy grids of
``(n, p, c, r)`` — see ``repro.perf.evaluate``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

from .expr import Expr, ExprLike, as_expr

#: collective schedule kinds the evaluator knows how to expand
COLLECTIVE_KINDS = ("redsca_sync", "scatter_sync", "gather", "allgather",
                    "allgather_sync", "reduce", "bcast", "bcast_sync",
                    "inirepl")


class Node:
    """Base class of all IR nodes."""


@dataclasses.dataclass
class Compute(Node):
    """Local routine time ``T_rout(routine, block, threads)``.

    ``threads=None`` uses the machine's full thread count; the overlapped
    variants pass ``T - 1`` (one thread dedicated to communication).
    """

    routine: str
    block: Expr
    threads: Optional[Expr] = None

    def __post_init__(self):
        self.block = as_expr(self.block)
        if self.threads is not None:
            self.threads = as_expr(self.threads)


@dataclasses.dataclass
class P2P(Node):
    """Point-to-point transfer of ``words`` at distance ``dist``: charged
    ``C_avg(dist) * (L + beta * words)``."""

    words: Expr
    dist: Expr

    def __post_init__(self):
        self.words = as_expr(self.words)
        self.dist = as_expr(self.dist)


@dataclasses.dataclass
class SyncP2P(Node):
    """Transfer that closes a synchronization: ``C_max(p, dist)`` applies
    (every process waits for the slowest; paper §IV)."""

    words: Expr
    dist: Expr

    def __post_init__(self):
        self.words = as_expr(self.words)
        self.dist = as_expr(self.dist)


@dataclasses.dataclass
class Collective(Node):
    """A named analytic collective schedule over ``q`` processes moving a
    ``words``-word vector between neighbours at base distance ``dist``.

    The evaluator expands the schedule (recursive halving / doubling steps,
    each with its own calibration factor; the closing step of a
    synchronized schedule uses ``C_max``) — see
    ``repro.perf.evaluate.collective_schedule`` for the step-level view.
    """

    kind: str
    words: Expr
    q: Expr
    dist: Expr = None  # type: ignore[assignment]

    def __post_init__(self):
        if self.kind not in COLLECTIVE_KINDS:
            raise ValueError(f"unknown collective kind {self.kind!r}; "
                             f"have {COLLECTIVE_KINDS}")
        self.words = as_expr(self.words)
        self.q = as_expr(self.q)
        self.dist = as_expr(1.0 if self.dist is None else self.dist)


Child = Union[Node, Tuple[str, Node]]


@dataclasses.dataclass
class Seq(Node):
    """Sequential composition.  Children may be ``(label, node)`` pairs;
    labeled children become named phases in the evaluation breakdown."""

    children: Sequence[Child]

    def __init__(self, *children: Child):
        norm = []
        for ch in children:
            if isinstance(ch, tuple):
                label, node = ch
                norm.append((str(label), node))
            else:
                norm.append((None, ch))
        self.children = tuple(norm)


@dataclasses.dataclass
class Loop(Node):
    """``count`` repetitions of an iteration-independent ``body``.

    ``count`` is any Expr — including fractional closed-form sums such as
    ``sum_decreasing(nb)/g``, exactly as the paper's collapsed loop bounds.
    """

    body: Node
    count: Expr

    def __post_init__(self):
        self.count = as_expr(self.count)


@dataclasses.dataclass
class Overlap(Node):
    """Max-composition of a communication and a computation branch
    (paper §IV: charged ``max(comm, comp)``; both serialized ledgers still
    accumulate their branch in full).

    Plain form — ``count`` iterations, each ``max(T_comm, T_comp)``.

    Ramp form (``ramp=nb``) — the right-looking factorization loops, where
    iteration ``i`` overlaps a panel broadcast linear in the trailing size
    ``m`` with an update quadratic in ``m`` (``m = k-1-i``, ``k =
    rint(nb)``).  The exposed time ``sum_m max(comm*m, comp*m^2)`` is
    charged via the analytic crossover ``m* = comm/comp`` so evaluation
    stays O(1) per scenario.
    """

    comm: Node
    comp: Node
    count: Expr = None  # type: ignore[assignment]
    ramp: Optional[Expr] = None

    def __post_init__(self):
        self.count = as_expr(1.0 if self.count is None else self.count)
        if self.ramp is not None:
            self.ramp = as_expr(self.ramp)


@dataclasses.dataclass
class Program:
    """A complete cost model: an IR tree plus its registry identity.

    ``uses_c`` / ``uses_r`` mark which tuning knobs the model actually
    reads (2D variants ignore ``c``; the matmuls ignore ``r``) so result
    metadata can echo only meaningful parameters, matching the pre-IR
    closed forms.
    """

    algo: str
    variant: str
    root: Node
    uses_c: bool = False
    uses_r: bool = False
    default_c: int = 1
    default_r: int = 1
    doc: str = ""

    @property
    def key(self) -> Tuple[str, str]:
        return (self.algo, self.variant)
