"""repro.perf — the composable cost-IR behind every performance model.

The paper's methodology (§IV-V) builds every algorithm model from three
ingredients — local-routine times ``T_rout``, calibrated transfers
``T_comm`` / ``T_comm_sync``, and analytic collective schedules — combined
by sequencing, loops, and max-overlap.  This package makes that composition
first-class:

  expr.py      symbolic scenario parameters (n, p, c, r, q, d) and the
               closed-form sums that collapse triangular loops
  ir.py        the node set: Compute, P2P, SyncP2P, Collective, Seq,
               Loop, Overlap — and Program, a registered model
  evaluate.py  the evaluator: calibration applied in exactly one place,
               est_Cal / est_NoCal / est_ideal chosen by EvalOptions,
               vectorized over numpy grids of scenarios
  models.py    the paper's 16 variants + LU 2D/2.5D as IR programs

Scalar call sites keep working through ``repro.core.algorithms`` shims;
batch consumers (``core.predictor``, ``repro.tuner``) evaluate whole
scenario grids in one pass via ``evaluate_program`` /
``PerfModelRegistry.evaluate_grid``.
"""

from .expr import (C, D, Expr, N, P, Param, Q, R, T, as_expr, floor, fmax,
                   fmin, rint, sqrt, sum_decreasing, sum_squares, where)
from .ir import (COLLECTIVE_KINDS, Collective, Compute, Loop, Node, Overlap,
                 P2P, Program, Seq, SyncP2P)
from .evaluate import (EVAL_MODES, CollectiveStep, EvalOptions, EvalResult,
                       MODEL_VERSION, PhaseCost, collective_schedule,
                       evaluate_program)
from .models import PROGRAMS, USEFUL_FLOPS, build_programs, lu_2d, lu_25d
# kernel imports repro.core.machine; keep it LAST so the attributes above
# exist if core's import of this package re-enters mid-initialization.
from .kernel import (ALGO_KERNELS, CANDIDATE_SIZES, KERNEL_DIMS, KernelModel,
                     KernelPhases, KernelWork, MIN_TILE, TilePlan,
                     VMEM_BUDGET, candidate_tiles, heuristic_matmul_blocks,
                     heuristic_plan, itemsize_of, kernel_work, tiles_for_plan)

__all__ = [
    "C", "D", "Expr", "N", "P", "Param", "Q", "R", "T", "as_expr", "floor",
    "fmax", "fmin", "rint", "sqrt", "sum_decreasing", "sum_squares", "where",
    "COLLECTIVE_KINDS", "Collective", "Compute", "Loop", "Node", "Overlap",
    "P2P", "Program", "Seq", "SyncP2P",
    "EVAL_MODES", "CollectiveStep", "EvalOptions", "EvalResult",
    "MODEL_VERSION", "PhaseCost", "collective_schedule", "evaluate_program",
    "PROGRAMS", "USEFUL_FLOPS", "build_programs", "lu_2d", "lu_25d",
    "ALGO_KERNELS", "CANDIDATE_SIZES", "KERNEL_DIMS", "KernelModel",
    "KernelPhases", "KernelWork", "MIN_TILE", "TilePlan", "VMEM_BUDGET",
    "candidate_tiles", "heuristic_matmul_blocks", "heuristic_plan",
    "itemsize_of", "kernel_work", "tiles_for_plan",
]
