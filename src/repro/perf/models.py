"""The paper's 16 algorithm-variant models (§V) — and LU — as IR programs.

Each program is a declarative transcription of the closed-form model it
replaces (``core.algorithms`` pre-IR; golden-pinned by
``tests/golden/model_values.json``): the same terms in the same order, with
the transcription deviations documented in DESIGN.md §1 carried over
verbatim.  Loop bounds are the paper's collapsed closed-form sums
(``sum_decreasing`` etc.), so evaluation stays O(1) per scenario and
vectorizes over ``(n, p, c, r)`` grids.

Authoring a new model is a ~20-40 line function returning a
:class:`~repro.perf.ir.Program` — see ``lu_2d`` / ``lu_25d`` at the bottom,
which extend the methodology to LU factorization (right-looking, block
cyclic; 2.5D layout per Solomonik & Demmel, arXiv:1306.4161 applies the
same recipe hierarchically).
"""

from __future__ import annotations

from typing import Dict, Tuple

from .expr import (C, N, P, R, T, fmax, rint, sqrt, sum_decreasing,
                   sum_squares)
from .ir import Collective, Compute, Loop, Overlap, P2P, Program, Seq, SyncP2P

#: useful flops of each algorithm at global size n (the paper's %-of-peak
#: numerator); numpy-compatible like ROUTINE_FLOPS.
USEFUL_FLOPS = {
    "cannon": lambda n: 2.0 * n ** 3,
    "summa": lambda n: 2.0 * n ** 3,
    "trsm": lambda n: 1.0 * n ** 3,
    "cholesky": lambda n: n ** 3 / 3.0,
    "lu": lambda n: 2.0 * n ** 3 / 3.0,
}

# Shared sub-expressions: the 2D grid edge, the 2.5D grid edge, and the
# 2.5D shift count s = sqrt(p/c)/c (DESIGN.md §1.1).
_SP = sqrt(P)
_G = sqrt(P / C)
_S25 = fmax(1.0, sqrt(P / C) / C)


# ---------------------------------------------------------------------------
# Cannon (paper §V-A) and SUMMA (same structure, broadcasts for shifts)
# ---------------------------------------------------------------------------


def _matmul_2d(algo: str, *, overlap: bool, summa: bool) -> Program:
    bs = N / _SP
    w = bs * bs
    if summa:
        move = Seq(("bcast_A", Collective("bcast_sync", w, q=_SP, dist=1)),
                   ("bcast_B", Collective("bcast_sync", w, q=_SP, dist=_SP)))
        first = "first_bcasts"
    else:
        move = Seq(("shift_row", SyncP2P(w, 1)), ("shift_col", SyncP2P(w, _SP)))
        first = "first_shift"
    mult = Compute("dgemm", bs, T)
    if not overlap:
        root = Seq(*[(lbl, Loop(node, _SP)) for lbl, node in move.children],
                   ("dgemm", Loop(mult, _SP)))
        return Program(algo, "2d", root)
    root = Seq((first, move),
               ("final_dgemm", mult),
               ("loop", Overlap(move, mult, count=_SP - 1)))
    return Program(algo, "2d_ovlp", root)


def _matmul_25d(algo: str, *, overlap: bool, summa: bool) -> Program:
    bs = N / _G
    w = bs * bs
    ini = Collective("inirepl", w, q=C)
    red = Collective("reduce", w, q=C, dist=P / C)
    if summa:
        move = Seq(("bcast_A", Collective("bcast", w, q=_G, dist=1)),
                   ("bcast_B", Collective("bcast", w, q=_G, dist=_G)))
    else:
        move = Seq(("shift_row", P2P(w, 1)), ("shift_col", P2P(w, _G)))
    mult = Compute("dgemm", bs, T)
    if not overlap:
        # SUMMA broadcasts all s panels; Cannon shifts s-1 times (the first
        # block is already in place) — exactly as the closed forms.
        reps = _S25 if summa else _S25 - 1
        root = Seq(("ini_repl", ini),
                   *[(lbl, Loop(node, reps)) for lbl, node in move.children],
                   ("dgemm", Loop(mult, _S25)),
                   ("reduce", red))
        return Program(algo, "2.5d", root, uses_c=True, default_c=4)
    pre = (("first_bcasts", move),) if summa else ()
    root = Seq(("ini_repl", ini), *pre,
               ("loop", Overlap(move, mult, count=_S25 - 1)),
               ("final_dgemm", mult),
               ("reduce", red))
    return Program(algo, "2.5d_ovlp", root, uses_c=True, default_c=4)


# ---------------------------------------------------------------------------
# TRSM (paper §V-B): block-cyclic, r row/column blocks per process per dim
# ---------------------------------------------------------------------------


def _trsm_2d(*, overlap: bool) -> Program:
    nb = R * _SP
    bs = N / nb
    w = bs * bs
    k = rint(nb)
    tt = T - 1 if overlap else T
    bcast_u = Collective("bcast_sync", w, q=_SP, dist=_SP)
    solve = Loop(Compute("dtrsm", bs, tt), R)
    bcast_x = Loop(Collective("bcast", w, q=_SP, dist=1), R)
    update = Loop(Compute("dgemm", bs, tt), R)
    if not overlap:
        root = Seq(
            ("bcast_U", Loop(bcast_u, sum_decreasing(nb) / _SP)),
            ("dtrsm", Loop(solve, k)),
            ("bcast_X", Loop(bcast_x, k)),
            ("update", Loop(update, sum_decreasing(nb, 1.0) / _SP)),
            ("last_bcast_U", bcast_u),
            ("last_solve", solve),
        )
        return Program("trsm", "2d", root, uses_r=True)
    root = Seq(
        ("first_bcast_U", Loop(bcast_u, R)),
        ("dtrsm", Loop(solve, k)),
        ("bcast_X", Loop(bcast_x, k)),
        ("bcastU_vs_update",
         Overlap(bcast_u, update, count=sum_decreasing(nb, 1.0) / _SP)),
        ("last_solve", solve),
    )
    return Program("trsm", "2d_ovlp", root, uses_r=True)


def _trsm_25d(*, overlap: bool) -> Program:
    nb = R * _G
    bs = N / nb
    w = bs * bs
    k = rint(nb)
    tt = T - 1 if overlap else T
    repl_u = Loop(Collective("bcast", w, q=C, dist=P / C), R * R * 0.75)
    scatter = Loop(Collective("scatter_sync", w / C, q=C, dist=P / C), R * R)
    gather = Loop(Collective("gather", w, q=C, dist=P / C), R * R)
    bcast_u = Collective("bcast_sync", w, q=_G, dist=_G)
    solve = Loop(Compute("dtrsm", bs, tt), R / C)
    bcast_x = Loop(Collective("bcast", w, q=_G, dist=1), R / C)
    update = Loop(Compute("dgemm", bs, tt), R / C)
    if not overlap:
        root = Seq(
            ("repl_U", repl_u), ("scatter_X", scatter),
            ("bcast_U", Loop(bcast_u, sum_decreasing(nb) / _G)),
            ("dtrsm", Loop(solve, k)),
            ("bcast_X", Loop(bcast_x, k)),
            ("update", Loop(update, sum_decreasing(nb, 1.0) / _G)),
            ("last_bcast_U", bcast_u),
            ("last_solve", solve),
            ("gather_X", gather),
        )
        return Program("trsm", "2.5d", root, uses_c=True, uses_r=True,
                       default_c=4, default_r=2)
    root = Seq(
        ("repl_U", repl_u), ("scatter_X", scatter),
        ("first_bcast_U", Loop(bcast_u, R)),
        ("dtrsm", Loop(solve, k)),
        ("bcast_X", Loop(bcast_x, k)),
        ("bcastU_vs_update",
         Overlap(bcast_u, update, count=sum_decreasing(nb, 1.0) / _G)),
        ("last_solve", solve),
        ("gather_X", gather),
    )
    return Program("trsm", "2.5d_ovlp", root, uses_c=True, uses_r=True,
                   default_c=4, default_r=2)


# ---------------------------------------------------------------------------
# Right-looking factorizations: Cholesky (paper methodology) and LU (new)
# ---------------------------------------------------------------------------


def _factorization_loop(diag_routine: str, g, nb, bs, *, overlap: bool,
                        panel_count, update_scale):
    """The shared right-looking loop: per block-column — factor the
    diagonal block, broadcast it, solve `panel_count` panels, broadcast the
    panels, rank-update the trailing matrix (``update_scale`` dgemm per
    unit m^2).  Returns the labeled Seq children."""
    w = bs * bs
    k = rint(nb)
    tt = T - 1 if overlap else T
    sum_m = sum_decreasing(nb, 1.0)
    panel_unit = Loop(Seq(Collective("bcast", w, q=g, dist=1),
                          Collective("bcast", w, q=g, dist=g)), 1.0 / g)
    upd_unit = Loop(Compute("dgemm", bs, tt), update_scale)
    children = [
        (diag_routine, Loop(Compute(diag_routine, bs, tt), k)),
        ("bcast_diag", Loop(Collective("bcast_sync", w, q=g, dist=g), k)),
        ("panel_dtrsm", Loop(Compute("dtrsm", bs, tt),
                             panel_count * sum_m / g)),
    ]
    if overlap:
        children.append(("panelbcast_vs_update",
                         Overlap(panel_unit, upd_unit, ramp=nb)))
    else:
        children.append(("panel_bcast", Loop(panel_unit, sum_m)))
        children.append(("update", Loop(upd_unit, sum_squares(nb))))
    # Periodic combination of partial trailing updates across layers
    # (zero at c=1: a q=1 reduce schedule is empty).
    children.append(("layer_reduce",
                     Loop(Collective("reduce", w, q=C, dist=P / C),
                          sum_m / (g * C))))
    return children


def _cholesky(variant: str) -> Program:
    overlap = variant.endswith("_ovlp")
    two_five = variant.startswith("2.5d")
    g = _G if two_five else _SP
    nb = R * g
    bs = N / nb
    w = bs * bs
    loop = _factorization_loop("dpotrf", g, nb, bs, overlap=overlap,
                               panel_count=1.0, update_scale=1.0 / (2.0 * P))
    if not two_five:
        # 2D: drop the (identically zero) layer_reduce term to match the
        # pre-IR closed form's term set exactly.
        root = Seq(*loop[:-1])
        return Program("cholesky", variant, root, uses_r=True, default_r=2)
    root = Seq(("repl_A", Loop(Collective("bcast", w, q=C, dist=P / C),
                               0.5 * R * R)),
               *loop,
               ("gather_L", Loop(Collective("gather", w, q=C, dist=P / C),
                                 0.5 * R * R)))
    return Program("cholesky", variant, root, uses_c=True, uses_r=True,
                   default_c=4, default_r=2)


def lu_2d() -> Program:
    """LU, 2D block-cyclic right-looking (paper methodology, new algo):
    per block-column — dgetrf the diagonal block, broadcast it down the
    column (synchronized), dtrsm both the row and the column panel,
    broadcast the panels along both grid dimensions, dgemm-update the full
    trailing matrix (2x the symmetric Cholesky volume)."""
    nb = R * _SP
    bs = N / nb
    loop = _factorization_loop("dgetrf", _SP, nb, bs, overlap=False,
                               panel_count=2.0, update_scale=1.0 / P)
    return Program("lu", "2d", Seq(*loop[:-1]), uses_r=True, default_r=2,
                   doc="right-looking LU, block-cyclic 2D grid")


def lu_25d() -> Program:
    """LU, 2.5D: replicate A across c layers, run the 2D loop on each
    layer's share (r/c of the panels), periodically reduce partial trailing
    updates across layers, gather L/U at the end (Solomonik & Demmel's
    2.5D schedule applied with the paper's collective models)."""
    nb = R * _G
    bs = N / nb
    w = bs * bs
    loop = _factorization_loop("dgetrf", _G, nb, bs, overlap=False,
                               panel_count=2.0, update_scale=1.0 / P)
    root = Seq(("repl_A", Loop(Collective("bcast", w, q=C, dist=P / C),
                               R * R)),
               *loop,
               ("gather_LU", Loop(Collective("gather", w, q=C, dist=P / C),
                                  R * R)))
    return Program("lu", "2.5d", root, uses_c=True, uses_r=True,
                   default_c=4, default_r=2,
                   doc="right-looking LU on a replicated 2.5D layout")


# ---------------------------------------------------------------------------
# Registry of all programs
# ---------------------------------------------------------------------------


def build_programs() -> Dict[Tuple[str, str], Program]:
    progs = [
        _matmul_2d("cannon", overlap=False, summa=False),
        _matmul_2d("cannon", overlap=True, summa=False),
        _matmul_25d("cannon", overlap=False, summa=False),
        _matmul_25d("cannon", overlap=True, summa=False),
        _matmul_2d("summa", overlap=False, summa=True),
        _matmul_2d("summa", overlap=True, summa=True),
        _matmul_25d("summa", overlap=False, summa=True),
        _matmul_25d("summa", overlap=True, summa=True),
        _trsm_2d(overlap=False),
        _trsm_2d(overlap=True),
        _trsm_25d(overlap=False),
        _trsm_25d(overlap=True),
        _cholesky("2d"),
        _cholesky("2d_ovlp"),
        _cholesky("2.5d"),
        _cholesky("2.5d_ovlp"),
        lu_2d(),
        lu_25d(),
    ]
    return {p.key: p for p in progs}


PROGRAMS: Dict[Tuple[str, str], Program] = build_programs()
