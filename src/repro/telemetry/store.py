"""Append-only JSONL run store under ``artifacts/telemetry/``.

Measured runs are small self-describing records; the store groups them by
machine fingerprint (one ``runs-<fingerprint>.jsonl`` file each, like the
tuner's plan cache keys plans) so profiles from different hardware — or
different drift-bumped *revisions* of the same hardware — never mix.

The format is versioned (``TELEMETRY_SCHEMA``): readers skip lines whose
schema they do not understand instead of misreading them, and
``compact()`` rewrites a file dropping unreadable lines and capping the
per-scenario history, mirroring how the plan cache treats corrupt or
schema-mismatched entries as misses.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import threading
import time
from typing import Dict, Iterable, List, Optional

#: bump when the record field set changes incompatibly — old lines are
#: skipped on read and dropped on compaction, never misread.
TELEMETRY_SCHEMA = 1


def telemetry_dir() -> str:
    env = os.environ.get("REPRO_TELEMETRY_DIR")
    if env:
        return env
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(repo, "artifacts", "telemetry")


@dataclasses.dataclass
class RunRecord:
    """One measured execution, tagged with everything the residual join
    needs to look up the model's prediction for the same scenario."""

    fingerprint: str            # machine fingerprint (keys the store file)
    machine: str                # machine-model name ("cpu-host", ...)
    op: str                     # algo/model key: "summa", "cannon", "serve"...
    variant: str                # "2d", "2.5d_ovlp", ... ("" when N/A)
    n: int                      # problem size (seq len for serving)
    p: int                      # processes used
    c: int                      # replication factor
    dtype: str = "float32"
    kind: str = "dispatch"      # "dispatch" | "serve" | "plan" | "manual"
    phases: Dict[str, float] = dataclasses.field(default_factory=dict)
    predicted: Dict[str, float] = dataclasses.field(default_factory=dict)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)
    timestamp: float = 0.0

    def __post_init__(self):
        if not self.timestamp:
            self.timestamp = time.time()

    @property
    def total(self) -> float:
        """Measured wall seconds: the explicit "total" phase when present,
        else the sum of the recorded phases."""
        if "total" in self.phases:
            return float(self.phases["total"])
        return float(sum(self.phases.values()))

    def scenario_key(self) -> str:
        return f"{self.kind}-{self.op}-{self.variant}-n{self.n}-p{self.p}-c{self.c}-{self.dtype}"

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = TELEMETRY_SCHEMA
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RunRecord":
        d = dict(d)
        if d.pop("schema", None) != TELEMETRY_SCHEMA:
            raise ValueError("telemetry schema mismatch")
        return cls(**d)


class RunStore:
    """Append-only JSONL store of :class:`RunRecord`, one file per machine
    fingerprint.  Appends are line-atomic (single ``write`` of one
    ``\\n``-terminated line under a lock); reads tolerate torn or foreign
    lines by skipping them."""

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory or telemetry_dir()
        self._lock = threading.Lock()
        self.appended = 0
        self.skipped_lines = 0

    def path_for(self, fingerprint: str) -> str:
        safe = re.sub(r"[^A-Za-z0-9._-]", "_", fingerprint or "unknown")
        return os.path.join(self.directory, f"runs-{safe}.jsonl")

    def fingerprints(self) -> List[str]:
        try:
            names = os.listdir(self.directory)
        except OSError:
            return []
        out = []
        for name in sorted(names):
            m = re.fullmatch(r"runs-(.+)\.jsonl", name)
            if m:
                out.append(m.group(1))
        return out

    def append(self, record: RunRecord) -> None:
        os.makedirs(self.directory, exist_ok=True)
        line = json.dumps(record.to_dict(), sort_keys=True) + "\n"
        path = self.path_for(record.fingerprint)
        with self._lock:
            with open(path, "a") as f:
                f.write(line)
            self.appended += 1

    def extend(self, records: Iterable[RunRecord]) -> None:
        for r in records:
            self.append(r)

    def load(self, fingerprint: Optional[str] = None) -> List[RunRecord]:
        """All readable records (for one fingerprint, or every file),
        oldest first.  Unparseable / wrong-schema lines are counted in
        ``skipped_lines`` and otherwise ignored."""
        fps = [fingerprint] if fingerprint is not None else self.fingerprints()
        out: List[RunRecord] = []
        for fp in fps:
            try:
                with open(self.path_for(fp)) as f:
                    lines = f.readlines()
            except OSError:
                continue
            for line in lines:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(RunRecord.from_dict(json.loads(line)))
                except (ValueError, TypeError):
                    with self._lock:
                        self.skipped_lines += 1
        out.sort(key=lambda r: r.timestamp)
        return out

    def count(self, fingerprint: Optional[str] = None) -> int:
        return len(self.load(fingerprint))

    def compact(self, fingerprint: Optional[str] = None,
                keep_last: int = 256) -> int:
        """Rewrite the store file(s): drop unreadable and old-schema lines,
        keep at most ``keep_last`` most-recent records per scenario key.
        Returns the number of lines dropped.  The rewrite goes through a
        temp file + ``os.replace`` so concurrent readers never see a
        partial file."""
        fps = [fingerprint] if fingerprint is not None else self.fingerprints()
        dropped = 0
        for fp in fps:
            path = self.path_for(fp)
            # read-filter-rewrite under the lock: an append racing an
            # unlocked read would be erased by the replace below
            with self._lock:
                try:
                    with open(path) as f:
                        lines = [ln for ln in f.read().splitlines()
                                 if ln.strip()]
                except OSError:
                    continue
                records: List[RunRecord] = []
                for line in lines:
                    try:
                        records.append(RunRecord.from_dict(json.loads(line)))
                    except (ValueError, TypeError):
                        dropped += 1
                by_scenario: Dict[str, List[RunRecord]] = {}
                for r in records:
                    by_scenario.setdefault(r.scenario_key(), []).append(r)
                keep: List[RunRecord] = []
                for scen in by_scenario.values():
                    scen.sort(key=lambda r: r.timestamp)
                    dropped += max(0, len(scen) - keep_last)
                    keep.extend(scen[-keep_last:])
                keep.sort(key=lambda r: r.timestamp)
                tmp = f"{path}.tmp.{os.getpid()}"
                with open(tmp, "w") as f:
                    for r in keep:
                        f.write(json.dumps(r.to_dict(), sort_keys=True) + "\n")
                os.replace(tmp, path)
        return dropped
