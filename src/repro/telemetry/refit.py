"""Online recalibration: residuals -> a new machine-profile revision.

The paper parameterizes its models once from portable benchmarks; this
module closes the loop by *re*-parameterizing them from production
residuals (Bienz et al.'s measurement-driven refinement of alpha-beta
models, applied to the calibrated surfaces here):

* compute side — a Nelder--Mead fit (``core.fitting``) of a speed scale
  and a block-size shape factor against the compute-dominated residual
  rows updates every :class:`EfficiencyCurve`; speed beyond the physical
  ``eff_max`` ceiling is attributed to the machine's measured peak
  (exactly what ``measured_compute_model`` does offline);
* comm side — a ridge-regularized least-squares scale
  (``core.fitting.ridge_lstsq``) on the comm-dominated rows rescales the
  ``C_avg`` / ``C_max`` surfaces into a fresh :class:`CalibrationTable`.

Nothing is mutated in place: ``refit`` returns a :class:`RefitResult`
holding a revision-bumped :class:`Machine` plus new surfaces, and
``apply`` registers that revision, which changes the machine fingerprint
and thereby retires every stale plan-cache entry and telemetry file.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..core.fitting import multistart_nelder_mead, ridge_lstsq
from ..core.machine import Machine
from ..core.perfmodel import Calibration, CalibrationTable, EfficiencyCurve
from .residuals import Residual, split_comm_comp

#: fitted scales are clamped to this symmetric range — a refit may move a
#: profile a lot (the CPU fallback constants are conservative on purpose)
#: but never to absurdity.
MAX_SCALE = 64.0


@dataclasses.dataclass
class RefitResult:
    """A candidate machine-profile revision, not yet registered."""

    machine: Machine                            # revision bumped, peak updated
    efficiency: Dict[str, EfficiencyCurve]
    calibration: Calibration
    speed_scale: float          # fitted compute speed multiplier (>1: faster)
    shape_scale: float          # fitted multiplier on every curve's n0
    comm_scale: float           # fitted multiplier on C_avg / C_max
    n_comp_rows: int
    n_comm_rows: int

    @property
    def fingerprint(self) -> str:
        return self.machine.fingerprint()

    def apply(self, registry) -> Machine:
        """Register the revision (same name, bumped ``revision`` field) so
        subsequent planning and recording use it."""
        registry.register_machine(self.machine, self.efficiency,
                                  self.calibration, overwrite=True)
        return self.machine


def refit(rows: Sequence[Residual], registry=None,
          machine_name: Optional[str] = None, *,
          ridge_lam: float = 2.0, n_starts: int = 3) -> RefitResult:
    """Fit a profile revision to residual rows (``source == "model"``).

    ``ridge_lam`` regularizes both fits toward "no change": a handful of
    noisy runs nudges the profile, a consistent bias moves it.
    """
    if registry is None:
        from ..tuner.registry import DEFAULT_REGISTRY
        registry = DEFAULT_REGISTRY
    rows = [r for r in rows if r.source == "model"]
    if not rows:
        raise ValueError("refit needs at least one model-source residual row")
    machine_name = machine_name or rows[0].machine
    rows = [r for r in rows if r.machine == machine_name]
    if not rows:
        raise ValueError(f"no residual rows for machine {machine_name!r} — "
                         "refusing to emit an evidence-free revision")
    surface = registry.machine(machine_name)
    # One median-ratio representative per scenario: wall-clock spikes (GC,
    # noisy neighbors) must not drag the squared-loss fits.
    comm_rows, comp_rows = split_comm_comp(_robust_rows(rows))

    comm_scale = _fit_comm_scale(comm_rows, ridge_lam)
    speed, shape = _fit_compute(comp_rows, surface, comm_scale, ridge_lam,
                                n_starts)

    # Decompose the speed scale: what eff_max can absorb stays in the
    # curves; the remainder is a re-measured peak (curves cannot exceed 1).
    efficiency = {}
    max_eff = max(c.eff_max for c in surface.efficiency.values())
    eff_part = min(speed, 0.98 / max_eff) if max_eff > 0 else 1.0
    eff_part = max(eff_part, 0.02 / max_eff) if max_eff > 0 else 1.0
    peak_part = speed / eff_part
    for rout, curve in surface.efficiency.items():
        new_max = float(np.clip(curve.eff_max * eff_part, 1e-3, 0.98))
        new_n0 = float(np.clip(curve.n0 * shape, 1.0, 1e7))
        efficiency[rout] = EfficiencyCurve(
            new_max, new_n0, eff_min=min(curve.eff_min, new_max / 2.0))

    calibration = _scaled_calibration(surface.calibration, comm_scale,
                                      [r.p for r in rows])
    machine = dataclasses.replace(
        surface.machine,
        peak_flops_per_unit=surface.machine.peak_flops_per_unit * peak_part,
        revision=surface.machine.revision + 1)
    return RefitResult(machine=machine, efficiency=efficiency,
                       calibration=calibration, speed_scale=speed,
                       shape_scale=shape, comm_scale=comm_scale,
                       n_comp_rows=len(comp_rows), n_comm_rows=len(comm_rows))


def _robust_rows(rows: Sequence[Residual]) -> List[Residual]:
    """The median-log-ratio row of every (op, variant, n, p, c, phase)
    group — the fit's view of the data, outlier-proof by construction."""
    groups: Dict[tuple, List[Residual]] = {}
    for r in rows:
        key = (r.op, r.variant, r.n, r.p, r.c, r.phase)
        groups.setdefault(key, []).append(r)
    out: List[Residual] = []
    for group in groups.values():
        group.sort(key=lambda r: r.log_ratio)
        out.append(group[(len(group) - 1) // 2])
    return out


def _fit_comm_scale(comm_rows: Sequence[Residual], lam: float) -> float:
    """Ridge scalar in log space: measured ~= comm_scale * predicted."""
    if not comm_rows:
        return 1.0
    y = np.array([r.log_ratio for r in comm_rows])
    theta = ridge_lstsq(np.ones((y.size, 1)), y, lam=lam)[0]
    return float(np.clip(math.exp(theta), 1.0 / MAX_SCALE, MAX_SCALE))


def _block_of(r: Residual) -> float:
    """Nominal local block size of a residual's scenario — ``n / g`` on the
    (c, g, g) grid — used to re-evaluate the efficiency curve shape without
    re-walking the program."""
    g = math.sqrt(max(float(r.p) / max(float(r.c), 1.0), 1.0))
    return max(float(r.n) / g, 1.0)


def _fit_compute(comp_rows: Sequence[Residual], surface, comm_scale: float,
                 lam: float, n_starts: int):
    """Nelder--Mead over (log speed, log shape).

    A row's adjusted prediction divides its compute seconds by
    ``speed * eff_shape(block)/eff_old(block)`` and scales its comm
    seconds by the already-fitted ``comm_scale`` — so the fit targets
    exactly the part of the residual the compute model owns.
    """
    if not comp_rows:
        return 1.0, 1.0
    eff = surface.efficiency.get("dgemm") or next(iter(
        surface.efficiency.values()))
    blocks = np.array([_block_of(r) for r in comp_rows])
    meas = np.array([r.measured for r in comp_rows])
    pcomp = np.array([max(r.pred_comp, 0.0) for r in comp_rows])
    pcomm = np.array([max(r.pred_comm, 0.0) for r in comp_rows])
    # exposed may be < comm + comp under overlap: scale both ledgers and
    # keep the row's exposed/serialized ratio fixed
    exposed = np.array([r.predicted for r in comp_rows])
    serial = np.maximum(pcomp + pcomm, 1e-300)
    overlap_keep = exposed / serial
    eff_old = eff.ev(blocks)

    def loss(theta):
        la, lb = float(theta[0]), float(theta[1])
        a = math.exp(np.clip(la, -math.log(MAX_SCALE), math.log(MAX_SCALE)))
        b = math.exp(np.clip(lb, -2.0, 2.0))
        # same floor as EfficiencyCurve.ev, so the loss matches what the
        # rebuilt curve will actually predict after apply()
        eff_new = eff.eff_max * (1.0 - np.exp(-blocks / (b * eff.n0)))
        eff_new = np.maximum(eff_new, eff.eff_min)
        pred = (pcomp * eff_old / (a * eff_new)
                + pcomm * comm_scale) * overlap_keep
        resid = np.log(meas) - np.log(np.maximum(pred, 1e-300))
        return float(np.mean(resid ** 2)
                     + 0.01 * lam * (la ** 2 + lb ** 2) / max(meas.size, 1))

    theta, _ = multistart_nelder_mead(loss, np.array([0.0, 0.0]),
                                      n_starts=n_starts, max_iter=300)
    speed = float(np.clip(math.exp(theta[0]), 1.0 / MAX_SCALE, MAX_SCALE))
    shape = float(np.clip(math.exp(theta[1]), math.exp(-2.0), math.exp(2.0)))
    return speed, shape


def _scaled_calibration(old: Calibration, comm_scale: float,
                        ps: Sequence[int]) -> Calibration:
    """A fresh CalibrationTable sampling the old surfaces scaled by the
    fitted factor (floored at the C >= 1 contract)."""
    if abs(comm_scale - 1.0) < 1e-12:
        return old
    grid_p = sorted({2.0, 4.0, 16.0, 64.0, 256.0}
                    | {float(max(p, 2)) for p in ps})
    grid_d = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
    avg = {d: max(1.0, float(old.c_avg(d)) * comm_scale) for d in grid_d}
    mx = {(p, d): max(1.0, float(old.c_max(p, d)) * comm_scale)
          for p in grid_p for d in grid_d}
    return CalibrationTable(avg=avg, mx=mx)
