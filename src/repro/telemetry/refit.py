"""Online recalibration: residuals -> a new machine-profile revision.

The paper parameterizes its models once from portable benchmarks; this
module closes the loop by *re*-parameterizing them from production
residuals (Bienz et al.'s measurement-driven refinement of alpha-beta
models, applied to the calibrated surfaces here):

* compute side — a Nelder--Mead fit (``core.fitting``) of a speed scale
  and a block-size shape factor against the compute-dominated residual
  rows updates every :class:`EfficiencyCurve`; speed beyond the physical
  ``eff_max`` ceiling is attributed to the machine's measured peak
  (exactly what ``measured_compute_model`` does offline);
* comm side — a ridge-regularized least-squares scale
  (``core.fitting.ridge_lstsq``) on the comm-dominated rows rescales the
  ``C_avg`` / ``C_max`` surfaces into a fresh :class:`CalibrationTable`.

Nothing is mutated in place: ``refit`` returns a :class:`RefitResult`
holding a revision-bumped :class:`Machine` plus new surfaces, and
``apply`` registers that revision, which changes the machine fingerprint
and thereby retires every stale plan-cache entry and telemetry file.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Mapping, Optional, Sequence

import numpy as np

from ..core.fitting import multistart_nelder_mead, ridge_lstsq
from ..core.machine import KernelConstants, Machine
from ..core.perfmodel import Calibration, CalibrationTable, EfficiencyCurve
from ..perf.kernel import KernelModel, TilePlan, itemsize_of, kernel_work
from .residuals import Residual, split_comm_comp

#: fitted scales are clamped to this symmetric range — a refit may move a
#: profile a lot (the CPU fallback constants are conservative on purpose)
#: but never to absurdity.
MAX_SCALE = 64.0


@dataclasses.dataclass
class RefitResult:
    """A candidate machine-profile revision, not yet registered."""

    machine: Machine                            # revision bumped, peak updated
    efficiency: Dict[str, EfficiencyCurve]
    calibration: Calibration
    speed_scale: float          # fitted compute speed multiplier (>1: faster)
    shape_scale: float          # fitted multiplier on every curve's n0
    comm_scale: float           # fitted multiplier on C_avg / C_max
    n_comp_rows: int
    n_comm_rows: int

    @property
    def fingerprint(self) -> str:
        return self.machine.fingerprint()

    def apply(self, registry) -> Machine:
        """Register the revision (same name, bumped ``revision`` field) so
        subsequent planning and recording use it."""
        registry.register_machine(self.machine, self.efficiency,
                                  self.calibration, overwrite=True)
        return self.machine


def refit(rows: Sequence[Residual], registry=None,
          machine_name: Optional[str] = None, *,
          ridge_lam: float = 2.0, n_starts: int = 3) -> RefitResult:
    """Fit a profile revision to residual rows (``source == "model"``).

    ``ridge_lam`` regularizes both fits toward "no change": a handful of
    noisy runs nudges the profile, a consistent bias moves it.
    """
    if registry is None:
        from ..tuner.registry import DEFAULT_REGISTRY
        registry = DEFAULT_REGISTRY
    rows = [r for r in rows if r.source == "model"]
    if not rows:
        raise ValueError("refit needs at least one model-source residual row")
    machine_name = machine_name or rows[0].machine
    rows = [r for r in rows if r.machine == machine_name]
    if not rows:
        raise ValueError(f"no residual rows for machine {machine_name!r} — "
                         "refusing to emit an evidence-free revision")
    surface = registry.machine(machine_name)
    # One median-ratio representative per scenario: wall-clock spikes (GC,
    # noisy neighbors) must not drag the squared-loss fits.
    comm_rows, comp_rows = split_comm_comp(_robust_rows(rows))

    comm_scale = _fit_comm_scale(comm_rows, ridge_lam)
    speed, shape = _fit_compute(comp_rows, surface, comm_scale, ridge_lam,
                                n_starts)

    # Decompose the speed scale: what eff_max can absorb stays in the
    # curves; the remainder is a re-measured peak (curves cannot exceed 1).
    efficiency = {}
    max_eff = max(c.eff_max for c in surface.efficiency.values())
    eff_part = min(speed, 0.98 / max_eff) if max_eff > 0 else 1.0
    eff_part = max(eff_part, 0.02 / max_eff) if max_eff > 0 else 1.0
    peak_part = speed / eff_part
    for rout, curve in surface.efficiency.items():
        new_max = float(np.clip(curve.eff_max * eff_part, 1e-3, 0.98))
        new_n0 = float(np.clip(curve.n0 * shape, 1.0, 1e7))
        efficiency[rout] = EfficiencyCurve(
            new_max, new_n0, eff_min=min(curve.eff_min, new_max / 2.0))

    calibration = _scaled_calibration(surface.calibration, comm_scale,
                                      [r.p for r in rows])
    machine = dataclasses.replace(
        surface.machine,
        peak_flops_per_unit=surface.machine.peak_flops_per_unit * peak_part,
        revision=surface.machine.revision + 1)
    return RefitResult(machine=machine, efficiency=efficiency,
                       calibration=calibration, speed_scale=speed,
                       shape_scale=shape, comm_scale=comm_scale,
                       n_comp_rows=len(comp_rows), n_comm_rows=len(comm_rows))


def _robust_rows(rows: Sequence[Residual]) -> List[Residual]:
    """The median-log-ratio row of every (op, variant, n, p, c, phase)
    group — the fit's view of the data, outlier-proof by construction."""
    groups: Dict[tuple, List[Residual]] = {}
    for r in rows:
        key = (r.op, r.variant, r.n, r.p, r.c, r.phase)
        groups.setdefault(key, []).append(r)
    out: List[Residual] = []
    for group in groups.values():
        group.sort(key=lambda r: r.log_ratio)
        out.append(group[(len(group) - 1) // 2])
    return out


def _fit_comm_scale(comm_rows: Sequence[Residual], lam: float) -> float:
    """Ridge scalar in log space: measured ~= comm_scale * predicted."""
    if not comm_rows:
        return 1.0
    y = np.array([r.log_ratio for r in comm_rows])
    theta = ridge_lstsq(np.ones((y.size, 1)), y, lam=lam)[0]
    return float(np.clip(math.exp(theta), 1.0 / MAX_SCALE, MAX_SCALE))


def _block_of(r: Residual) -> float:
    """Nominal local block size of a residual's scenario — ``n / g`` on the
    (c, g, g) grid — used to re-evaluate the efficiency curve shape without
    re-walking the program."""
    g = math.sqrt(max(float(r.p) / max(float(r.c), 1.0), 1.0))
    return max(float(r.n) / g, 1.0)


def _fit_compute(comp_rows: Sequence[Residual], surface, comm_scale: float,
                 lam: float, n_starts: int):
    """Nelder--Mead over (log speed, log shape).

    A row's adjusted prediction divides its compute seconds by
    ``speed * eff_shape(block)/eff_old(block)`` and scales its comm
    seconds by the already-fitted ``comm_scale`` — so the fit targets
    exactly the part of the residual the compute model owns.
    """
    if not comp_rows:
        return 1.0, 1.0
    eff = surface.efficiency.get("dgemm") or next(iter(
        surface.efficiency.values()))
    blocks = np.array([_block_of(r) for r in comp_rows])
    meas = np.array([r.measured for r in comp_rows])
    pcomp = np.array([max(r.pred_comp, 0.0) for r in comp_rows])
    pcomm = np.array([max(r.pred_comm, 0.0) for r in comp_rows])
    # exposed may be < comm + comp under overlap: scale both ledgers and
    # keep the row's exposed/serialized ratio fixed
    exposed = np.array([r.predicted for r in comp_rows])
    serial = np.maximum(pcomp + pcomm, 1e-300)
    overlap_keep = exposed / serial
    eff_old = eff.ev(blocks)

    def loss(theta):
        la, lb = float(theta[0]), float(theta[1])
        a = math.exp(np.clip(la, -math.log(MAX_SCALE), math.log(MAX_SCALE)))
        b = math.exp(np.clip(lb, -2.0, 2.0))
        # same floor as EfficiencyCurve.ev, so the loss matches what the
        # rebuilt curve will actually predict after apply()
        eff_new = eff.eff_max * (1.0 - np.exp(-blocks / (b * eff.n0)))
        eff_new = np.maximum(eff_new, eff.eff_min)
        pred = (pcomp * eff_old / (a * eff_new)
                + pcomm * comm_scale) * overlap_keep
        resid = np.log(meas) - np.log(np.maximum(pred, 1e-300))
        return float(np.mean(resid ** 2)
                     + 0.01 * lam * (la ** 2 + lb ** 2) / max(meas.size, 1))

    theta, _ = multistart_nelder_mead(loss, np.array([0.0, 0.0]),
                                      n_starts=n_starts, max_iter=300)
    speed = float(np.clip(math.exp(theta[0]), 1.0 / MAX_SCALE, MAX_SCALE))
    shape = float(np.clip(math.exp(theta[1]), math.exp(-2.0), math.exp(2.0)))
    return speed, shape


# ---------------------------------------------------------------------------
# Kernel-tier recalibration: recorded per-kernel phase times -> new constants
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KernelRefitResult:
    """A candidate kernel-constants revision, not yet registered."""

    machine: Machine                # revision bumped, kernel_constants swapped
    constants: KernelConstants
    compute_scale: float    # fitted multiplier on the issue/execute term
    loop_scale: float       # fitted multiplier on the per-grid-step term
    h2d_scale: float        # fitted time multiplier on the H2D phase
    d2h_scale: float        # fitted time multiplier on the D2H phase
    n_rows: int

    @property
    def fingerprint(self) -> str:
        return self.machine.fingerprint()

    def apply(self, registry) -> Machine:
        """Register the revision (efficiency/calibration surfaces carried
        over unchanged — this refit only owns the kernel constants)."""
        surface = registry.machine(self.machine.name)
        registry.register_machine(self.machine, surface.efficiency,
                                  surface.calibration, overwrite=True)
        return self.machine


def _kernel_rows(records, machine_name: str):
    """(record, KernelWork, measured-phase dict) for every usable
    ``kernel:<family>`` run record on this machine."""
    rows = []
    for rec in records:
        op = getattr(rec, "op", "")
        if not op.startswith("kernel:") or rec.machine != machine_name:
            continue
        meta = getattr(rec, "meta", None) or {}
        shape = meta.get("shape")
        tile = meta.get("tile")
        if not shape or not tile:
            continue
        kernel = op.split(":", 1)[1]
        itemsize = int(meta.get("itemsize") or itemsize_of(rec.dtype))
        tiles = {d: np.asarray(float(v)) for d, v in dict(tile).items()}
        mm_tile = meta.get("mm_tile")
        mm = TilePlan.from_blocks("matmul", mm_tile) if mm_tile else None
        try:
            work = kernel_work(kernel, [float(x) for x in shape], tiles,
                               itemsize, mm_tile=mm)
        except (ValueError, KeyError):
            continue
        rows.append((rec, work))
    return rows


def _phase_time_scale(meas: np.ndarray, pred: np.ndarray,
                      lam: float) -> float:
    """Ridge log-ratio scalar (regularized toward 1): how much longer the
    phase really takes than the model says."""
    keep = (meas > 0) & (pred > 0)
    if not np.any(keep):
        return 1.0
    y = np.log(meas[keep] / pred[keep])
    theta = ridge_lstsq(np.ones((y.size, 1)), y, lam=lam)[0]
    return float(np.clip(math.exp(theta), 1.0 / MAX_SCALE, MAX_SCALE))


def refit_kernels(records, registry=None,
                  machine_name: Optional[str] = None, *,
                  ridge_lam: float = 2.0) -> KernelRefitResult:
    """Fit a kernel-constants revision to recorded per-kernel phase times
    (``op == "kernel:<family>"`` run records, as ``benchmarks/bench_kernels``
    emits: ``meta`` carries shape/tile/itemsize, phases carry measured
    seconds for ``execute`` — or ``h2d``/``compute``/``d2h`` when the
    harness can split them).

    The compute side is a two-feature linear ridge fit: measured compute
    seconds against the model's issue/execute term and its per-grid-step
    term, regularized toward "no change", so consistent evidence moves
    ``overhead_factor`` and ``loop_overhead`` *independently* — that ratio
    is exactly what tile selection trades off.  Transfer phases (when
    present) refit as log-ratio scalars on ``bw_h2d`` / ``bw_d2h``.
    """
    if registry is None:
        from ..tuner.registry import DEFAULT_REGISTRY
        registry = DEFAULT_REGISTRY
    records = list(records)
    if machine_name is None:
        for rec in records:
            if getattr(rec, "op", "").startswith("kernel:"):
                machine_name = rec.machine
                break
    if machine_name is None:
        raise ValueError("refit_kernels needs at least one kernel:* record")
    surface = registry.machine(machine_name)
    kc = surface.machine.kernel_constants
    if kc is None:
        raise ValueError(f"machine {machine_name!r} has no kernel_constants "
                         "block to refit")
    rows = _kernel_rows(records, machine_name)
    if not rows:
        raise ValueError(f"no usable kernel:* records for {machine_name!r}")
    model = KernelModel(surface.machine)

    pure = np.array([float(w.flops_mxu / kc.fma_rate
                           + w.flops_vpu / kc.vpu_rate) for _r, w in rows])
    steps = np.array([float(w.steps) for _r, w in rows])
    phases = [model.phases_of(w) for _r, w in rows]
    pred_h2d = np.array([float(ph.h2d) for ph in phases])
    pred_d2h = np.array([float(ph.d2h) for ph in phases])

    def meas(name):
        return np.array([float(r.phases.get(name, 0.0)) for r, _w in rows])

    m_h2d, m_cmp, m_d2h, m_exec = (meas(k) for k in
                                   ("h2d", "compute", "d2h", "execute"))
    # un-split records: charge everything past the predicted transfer
    # phases to the compute fit (on the interpret path compute dominates)
    whole = (m_cmp == 0.0) & (m_exec > 0.0)
    m_cmp = np.where(whole,
                     np.maximum(m_exec - pred_h2d - pred_d2h, 0.0), m_cmp)

    # measured_compute ~= s_exec * (pure * overhead) + s_loop * (steps * loop)
    x1 = pure * kc.overhead_factor
    x2 = steps * kc.loop_overhead
    keep = (m_cmp > 0) & (x1 + x2 > 0)
    if np.any(keep):
        X = np.stack([x1[keep], x2[keep]], axis=1)
        y = m_cmp[keep] - X.sum(axis=1)
        # regularize the *deltas*: theta = 1 + ridge(X, y - X.1) pulls
        # toward "constants already right", mirroring the log-space fits
        scale = float(np.mean(X.sum(axis=1))) or 1.0
        theta = 1.0 + ridge_lstsq(X / scale, y / scale, lam=ridge_lam)
        s_exec, s_loop = (float(np.clip(t, 1.0 / MAX_SCALE, MAX_SCALE))
                          for t in theta)
    else:
        s_exec = s_loop = 1.0
    s_h2d = _phase_time_scale(m_h2d, pred_h2d, ridge_lam)
    s_d2h = _phase_time_scale(m_d2h, pred_d2h, ridge_lam)

    constants = dataclasses.replace(
        kc,
        overhead_factor=max(1.0, kc.overhead_factor * s_exec),
        loop_overhead=kc.loop_overhead * s_loop,
        bw_h2d=kc.bw_h2d / s_h2d,
        bw_d2h=kc.bw_d2h / s_d2h)
    machine = dataclasses.replace(surface.machine,
                                  kernel_constants=constants,
                                  revision=surface.machine.revision + 1)
    return KernelRefitResult(machine=machine, constants=constants,
                             compute_scale=s_exec, loop_scale=s_loop,
                             h2d_scale=s_h2d, d2h_scale=s_d2h,
                             n_rows=len(rows))


def _scaled_calibration(old: Calibration, comm_scale: float,
                        ps: Sequence[int]) -> Calibration:
    """A fresh CalibrationTable sampling the old surfaces scaled by the
    fitted factor (floored at the C >= 1 contract)."""
    if abs(comm_scale - 1.0) < 1e-12:
        return old
    grid_p = sorted({2.0, 4.0, 16.0, 64.0, 256.0}
                    | {float(max(p, 2)) for p in ps})
    grid_d = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0]
    avg = {d: max(1.0, float(old.c_avg(d)) * comm_scale) for d in grid_d}
    mx = {(p, d): max(1.0, float(old.c_max(p, d)) * comm_scale)
          for p in grid_p for d in grid_d}
    return CalibrationTable(avg=avg, mx=mx)
