"""The instrumentation layer: per-phase wall-clock capture.

A :class:`PhaseTimer` accumulates named phase durations (context-manager
or decorator form) and emits one :class:`~repro.telemetry.store.RunRecord`
tagged with (machine fingerprint, op, variant, n, p, c) — the exact key
the residual join needs to look up the model's prediction for the same
scenario.

Recording is off by default: the dispatch and serving hot paths pay one
``enabled()`` check and nothing else.  Turn it on either with
``REPRO_TELEMETRY=1`` in the environment (records land in the default
:class:`RunStore` under ``artifacts/telemetry/``) or programmatically with
``enable(store)``; explicit per-call opt-ins (``observe=True`` on the
dispatch entry points and ``Tuner.plan``) record regardless of the global
switch.
"""

from __future__ import annotations

import functools
import os
import threading
import time
from contextlib import contextmanager, nullcontext
from typing import Dict, Optional

from .store import RunRecord, RunStore
from .. import obs

_STATE_LOCK = threading.Lock()
_ENABLED: Optional[bool] = None          # None: fall back to the env var
_STORE: Optional[RunStore] = None

#: one reusable no-op context for every disabled phase_scope call — the
#: disabled hot path must not allocate (bench_telemetry asserts < 1 µs).
_NULL = nullcontext()


def enabled() -> bool:
    """True when measured runs should be recorded globally.  Lock-free:
    a single global read (atomic in CPython) — this sits on the dispatch
    hot path and must cost nanoseconds when recording is off."""
    e = _ENABLED
    if e is not None:
        return e
    return os.environ.get("REPRO_TELEMETRY", "") not in ("", "0", "false")


def enable(store: Optional[RunStore] = None) -> RunStore:
    """Turn recording on (optionally into a specific store); returns the
    store every subsequent emission will append to."""
    global _ENABLED, _STORE
    with _STATE_LOCK:
        _ENABLED = True
        if store is not None:
            _STORE = store
        elif _STORE is None:
            _STORE = RunStore()
        return _STORE


def disable() -> None:
    global _ENABLED
    with _STATE_LOCK:
        _ENABLED = False


def reset() -> None:
    """Back to env-var-controlled recording with the default store (tests)."""
    global _ENABLED, _STORE
    with _STATE_LOCK:
        _ENABLED = None
        _STORE = None
    from .drift import reset_latch
    reset_latch()


def default_store() -> RunStore:
    global _STORE
    with _STATE_LOCK:
        if _STORE is None:
            _STORE = RunStore()
        return _STORE


class PhaseTimer:
    """Accumulates per-phase wall seconds for one logical run.

    >>> pt = PhaseTimer("summa", variant="2d", n=4096, p=16)
    >>> with pt.phase("execute"):
    ...     do_work()
    >>> pt.emit()            # -> RunRecord appended to the active store

    Re-entering a phase accumulates (the serving engine enters ``decode``
    once per generated token).  ``wrap`` is the decorator form.
    """

    def __init__(self, op: str, *, variant: str = "", n: int = 0, p: int = 1,
                 c: int = 1, dtype: str = "float32", machine: str = "",
                 fingerprint: str = "", kind: str = "manual",
                 predicted: Optional[Dict[str, float]] = None,
                 meta: Optional[Dict[str, object]] = None):
        self.op = op
        self.variant = variant
        self.n = int(n)
        self.p = int(p)
        self.c = int(c)
        self.dtype = dtype
        self.machine = machine
        self.fingerprint = fingerprint
        self.kind = kind
        self.predicted = dict(predicted or {})
        self.meta = dict(meta or {})
        self.phases: Dict[str, float] = {}

    #: phase names whose prediction falls back to ``predicted["total"]``
    #: when no same-named entry exists (mirrors residuals.TOTAL_PHASES).
    _TOTALISH = ("execute", "total", "step")

    def _predicted_for(self, name: str) -> Optional[float]:
        p = self.predicted.get(name)
        if p is None and name in self._TOTALISH:
            p = self.predicted.get("total")
        return p

    @contextmanager
    def phase(self, name: str):
        sp = tr = None
        if obs.enabled():
            tr = obs.tracer()
            sp = tr.begin(name, cat=self.kind,
                          args={"op": self.op, "variant": self.variant,
                                "n": self.n, "p": self.p})
        t0 = time.perf_counter()
        err = False
        try:
            yield self
        except BaseException:
            err = True
            raise
        finally:
            dt = time.perf_counter() - t0
            self.phases[name] = self.phases.get(name, 0.0) + dt
            if sp is not None:
                # span duration = exactly what the phase accounting saw,
                # paired with the plan's prediction for the same phase
                sp.predicted_s = self._predicted_for(name)
                tr.end(sp, error=err, dur_s=dt)

    def wrap(self, name: str):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                with self.phase(name):
                    return fn(*args, **kwargs)
            return wrapper
        return deco

    def add(self, name: str, seconds: float) -> None:
        """Account externally-measured seconds to a phase."""
        self.phases[name] = self.phases.get(name, 0.0) + float(seconds)

    def record(self) -> RunRecord:
        return RunRecord(
            fingerprint=self.fingerprint, machine=self.machine, op=self.op,
            variant=self.variant, n=self.n, p=self.p, c=self.c,
            dtype=self.dtype, kind=self.kind, phases=dict(self.phases),
            predicted=dict(self.predicted), meta=dict(self.meta))

    def emit(self, store: Optional[RunStore] = None,
             force: bool = False) -> Optional[RunRecord]:
        """Append the accumulated record.  Returns it, or None when
        recording is off (and not forced) or no phase was timed."""
        if not (force or enabled()) or not self.phases:
            return None
        rec = self.record()
        (store or default_store()).append(rec)
        return rec


def phase_scope(pt: Optional["PhaseTimer"], name: str):
    """``pt.phase(name)`` when a timer is active, else a shared no-op
    context — the guard every instrumented hot path needs, written once,
    allocation-free when recording is off."""
    return pt.phase(name) if pt is not None else _NULL


def timer_for_plan(plan, kind: str = "dispatch",
                   meta: Optional[Dict[str, object]] = None) -> PhaseTimer:
    """A PhaseTimer pre-tagged from an ExecutionPlan — the dispatch layer's
    one-liner.  ``plan.algo`` (not the public op name) keys the record so
    the residual join can look the cost-IR program straight up."""
    return PhaseTimer(plan.algo, variant=plan.variant, n=plan.n, p=plan.p,
                      c=plan.c, dtype=plan.dtype, machine=plan.machine,
                      fingerprint=plan.fingerprint, kind=kind,
                      predicted=dict(plan.predicted), meta=meta)


def kernel_timer(kernel: str, shape, tiles, *, dtype: str = "float32",
                 machine: str = "", fingerprint: str = "",
                 itemsize: Optional[int] = None,
                 mm_tile: Optional[Dict[str, int]] = None,
                 predicted: Optional[Dict[str, float]] = None) -> PhaseTimer:
    """A PhaseTimer for one Pallas kernel run, tagged the way
    ``telemetry.refit_kernels`` consumes it: ``op = "kernel:<family>"``,
    ``meta`` carrying the problem shape, the tile block dict (a
    :class:`~repro.perf.kernel.TilePlan` or plain dict) and the itemsize.
    Time the launch under ``phase("execute")`` (or split h2d/compute/d2h
    when the harness can) and ``emit(force=True)``.
    """
    blocks = tiles.block_dict() if hasattr(tiles, "block_dict") else dict(tiles)
    meta: Dict[str, object] = {
        "kernel": kernel,
        "shape": [int(x) for x in shape],
        "tile": {d: int(v) for d, v in blocks.items()},
        "itemsize": int(itemsize) if itemsize is not None else None,
    }
    if mm_tile:
        meta["mm_tile"] = {d: int(v) for d, v in dict(mm_tile).items()}
    return PhaseTimer(f"kernel:{kernel}", variant="pallas",
                      n=int(max(shape)), dtype=dtype, machine=machine,
                      fingerprint=fingerprint, kind="kernel",
                      predicted=predicted, meta=meta)


def observe_plan(plan, store: Optional[RunStore] = None) -> RunRecord:
    """Record a planning decision itself (``Tuner.plan(..., observe=True)``):
    a zero-phase record carrying the prediction, so the store holds what
    the model *promised* even for scenarios never executed here."""
    rec = RunRecord(
        fingerprint=plan.fingerprint, machine=plan.machine, op=plan.algo,
        variant=plan.variant, n=plan.n, p=plan.p, c=plan.c, dtype=plan.dtype,
        kind="plan", phases={}, predicted=dict(plan.predicted))
    (store or default_store()).append(rec)
    return rec
