"""Drift detection: when measurements stop matching the model, retire it.

A refit profile is only valid while the hardware keeps behaving the way
the residuals said it did — thermal throttling, a noisy neighbor, a BLAS
or XLA upgrade all shift the ground truth under a frozen model.  The
detector keeps a rolling mean of the per-op relative error (newest
``window`` residual rows per op); when it crosses ``threshold`` the
machine profile's ``revision`` is bumped and re-registered, which changes
``Machine.fingerprint()`` and therefore every tuner plan-cache key — the
stale plans are not deleted, they simply can never be recalled again.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.machine import Machine
from .. import obs
from .residuals import Residual

#: default rolling window (rows per op) and mean-relative-error threshold.
DEFAULT_WINDOW = 10
DEFAULT_THRESHOLD = 0.75
#: fewer rows than this in an op's window is not evidence, just noise.
MIN_ROWS = 3


class DriftLatch:
    """Fired-at-revision / fired-on-evidence latch for the drift path.

    Without it the detector double-fires: every ``check`` over a
    still-drifted window re-emits the same ``obs.alert("drift")``, and
    every ``detect_and_invalidate`` re-bumps the machine revision —
    which silently re-keys the plan cache and telemetry stores once per
    call instead of once per drift episode.  The latch records

    * per ``(machine, op)``: the newest residual timestamp that has
      already alerted — the same window re-checked is silent, a window
      containing *new* evidence fires again;
    * per machine: the revision our own bump produced — while the
      registry still holds that revision, further bumps are swallowed.
      A healthy check (nothing drifted) re-arms the machine, as does any
      outside revision change (e.g. the streaming watch responder).

    ``DriftStatus.drifted`` itself stays truthful either way — the latch
    gates side effects (alerts, bumps), never the diagnosis.
    """

    def __init__(self):
        self._alerted: Dict[tuple, float] = {}
        self._bumped: Dict[str, int] = {}

    def arm_alert(self, machine: str, op: str, newest_ts: float) -> bool:
        key = (machine, op)
        last = self._alerted.get(key)
        if last is not None and newest_ts <= last:
            return False
        self._alerted[key] = newest_ts
        return True

    def should_bump(self, machine_name: str, current_revision: int) -> bool:
        return self._bumped.get(machine_name) != current_revision

    def record_bump(self, machine_name: str, new_revision: int) -> None:
        self._bumped[machine_name] = new_revision

    def clear_bump(self, machine_name: str) -> None:
        self._bumped.pop(machine_name, None)

    def clear(self) -> None:
        self._alerted.clear()
        self._bumped.clear()


#: process-global latch (``telemetry.reset()`` clears it); pass your own
#: :class:`DriftLatch` for isolated pipelines.
_LATCH = DriftLatch()


def reset_latch() -> None:
    _LATCH.clear()


@dataclasses.dataclass
class DriftStatus:
    """Rolling accuracy of one op against the current profile."""

    op: str
    rolling_mean_rel_err: float
    n_rows: int
    window: int
    threshold: float

    @property
    def drifted(self) -> bool:
        return (self.n_rows >= MIN_ROWS
                and self.rolling_mean_rel_err > self.threshold)


def check(rows: Sequence[Residual], *, threshold: float = DEFAULT_THRESHOLD,
          window: int = DEFAULT_WINDOW,
          sources: Sequence[str] = ("model",),
          latch: Optional[DriftLatch] = None) -> Dict[str, DriftStatus]:
    """Per-op rolling mean relative error over the newest ``window`` rows
    (model-source rows by default; the sim flavor has its own error
    profile).  Pass ``sources=("model", "serve")`` to let scheduler
    serve-step residuals trigger invalidation too — a revision bump
    re-keys the serving cost tables exactly like the tuner plan cache,
    since both are keyed by ``Machine.fingerprint()``."""
    if latch is None:
        latch = _LATCH
    by_op: Dict[str, List[Residual]] = {}
    for r in rows:
        if r.source not in sources:
            continue
        by_op.setdefault(r.op, []).append(r)
    out: Dict[str, DriftStatus] = {}
    for op, op_rows in by_op.items():
        op_rows.sort(key=lambda r: r.timestamp)
        tail = op_rows[-window:]
        err = float(np.mean([r.rel_err for r in tail]))
        st = DriftStatus(op=op, rolling_mean_rel_err=err,
                         n_rows=len(tail), window=window,
                         threshold=threshold)
        out[op] = st
        if st.drifted and latch.arm_alert(tail[-1].machine, op,
                                          tail[-1].timestamp):
            # structured alert into the obs stream (instant event +
            # obs_alerts_total counter); no-op when tracing is off.
            # The latch keeps a re-check of the same window silent —
            # one alert per piece of evidence, not per call.
            obs.alert("drift", op=op, rolling_mean_rel_err=err,
                      threshold=threshold, window=window,
                      n_rows=st.n_rows)
    return out


def bump_revision(registry, machine_name: str) -> Machine:
    """Re-register ``machine_name`` with ``revision + 1`` (surfaces kept).

    The new fingerprint retires every plan-cache entry and telemetry file
    keyed by the old one; returns the new :class:`Machine`."""
    surface = registry.machine(machine_name)
    machine = dataclasses.replace(surface.machine,
                                  revision=surface.machine.revision + 1)
    registry.register_machine(machine, surface.efficiency,
                              surface.calibration, overwrite=True,
                              faults=getattr(surface, "faults", None))
    return machine


def detect_and_invalidate(rows: Sequence[Residual], registry,
                          machine_name: str, *,
                          threshold: float = DEFAULT_THRESHOLD,
                          window: int = DEFAULT_WINDOW,
                          sources: Sequence[str] = ("model",),
                          latch: Optional[DriftLatch] = None
                          ) -> Optional[Machine]:
    """The full drift step: check the rolling error; on any drifted op,
    bump the machine revision.  Returns the new Machine (None when the
    profile is still healthy, or when the latch shows this drift episode
    already bumped the revision the registry still holds)."""
    if latch is None:
        latch = _LATCH
    statuses = check(rows, threshold=threshold, window=window,
                     sources=sources, latch=latch)
    if not any(s.drifted for s in statuses.values()):
        latch.clear_bump(machine_name)      # healthy -> re-arm
        return None
    current = registry.machine(machine_name).machine.revision
    if not latch.should_bump(machine_name, current):
        return None                         # this episode already bumped
    machine = bump_revision(registry, machine_name)
    latch.record_bump(machine_name, machine.revision)
    return machine
