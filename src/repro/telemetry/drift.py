"""Drift detection: when measurements stop matching the model, retire it.

A refit profile is only valid while the hardware keeps behaving the way
the residuals said it did — thermal throttling, a noisy neighbor, a BLAS
or XLA upgrade all shift the ground truth under a frozen model.  The
detector keeps a rolling mean of the per-op relative error (newest
``window`` residual rows per op); when it crosses ``threshold`` the
machine profile's ``revision`` is bumped and re-registered, which changes
``Machine.fingerprint()`` and therefore every tuner plan-cache key — the
stale plans are not deleted, they simply can never be recalled again.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.machine import Machine
from .. import obs
from .residuals import Residual

#: default rolling window (rows per op) and mean-relative-error threshold.
DEFAULT_WINDOW = 10
DEFAULT_THRESHOLD = 0.75
#: fewer rows than this in an op's window is not evidence, just noise.
MIN_ROWS = 3


@dataclasses.dataclass
class DriftStatus:
    """Rolling accuracy of one op against the current profile."""

    op: str
    rolling_mean_rel_err: float
    n_rows: int
    window: int
    threshold: float

    @property
    def drifted(self) -> bool:
        return (self.n_rows >= MIN_ROWS
                and self.rolling_mean_rel_err > self.threshold)


def check(rows: Sequence[Residual], *, threshold: float = DEFAULT_THRESHOLD,
          window: int = DEFAULT_WINDOW,
          sources: Sequence[str] = ("model",)) -> Dict[str, DriftStatus]:
    """Per-op rolling mean relative error over the newest ``window`` rows
    (model-source rows by default; the sim flavor has its own error
    profile).  Pass ``sources=("model", "serve")`` to let scheduler
    serve-step residuals trigger invalidation too — a revision bump
    re-keys the serving cost tables exactly like the tuner plan cache,
    since both are keyed by ``Machine.fingerprint()``."""
    by_op: Dict[str, List[Residual]] = {}
    for r in rows:
        if r.source not in sources:
            continue
        by_op.setdefault(r.op, []).append(r)
    out: Dict[str, DriftStatus] = {}
    for op, op_rows in by_op.items():
        op_rows.sort(key=lambda r: r.timestamp)
        tail = op_rows[-window:]
        err = float(np.mean([r.rel_err for r in tail]))
        st = DriftStatus(op=op, rolling_mean_rel_err=err,
                         n_rows=len(tail), window=window,
                         threshold=threshold)
        out[op] = st
        if st.drifted:
            # structured alert into the obs stream (instant event +
            # obs_alerts_total counter); no-op when tracing is off
            obs.alert("drift", op=op, rolling_mean_rel_err=err,
                      threshold=threshold, window=window,
                      n_rows=st.n_rows)
    return out


def bump_revision(registry, machine_name: str) -> Machine:
    """Re-register ``machine_name`` with ``revision + 1`` (surfaces kept).

    The new fingerprint retires every plan-cache entry and telemetry file
    keyed by the old one; returns the new :class:`Machine`."""
    surface = registry.machine(machine_name)
    machine = dataclasses.replace(surface.machine,
                                  revision=surface.machine.revision + 1)
    registry.register_machine(machine, surface.efficiency,
                              surface.calibration, overwrite=True)
    return machine


def detect_and_invalidate(rows: Sequence[Residual], registry,
                          machine_name: str, *,
                          threshold: float = DEFAULT_THRESHOLD,
                          window: int = DEFAULT_WINDOW,
                          sources: Sequence[str] = ("model",)
                          ) -> Optional[Machine]:
    """The full drift step: check the rolling error; on any drifted op,
    bump the machine revision.  Returns the new Machine (None when the
    profile is still healthy)."""
    statuses = check(rows, threshold=threshold, window=window,
                     sources=sources)
    if not any(s.drifted for s in statuses.values()):
        return None
    return bump_revision(registry, machine_name)
