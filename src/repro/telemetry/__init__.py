"""repro.telemetry — the measured-run feedback loop.

Every layer below this one *predicts*: the cost IR estimates, the
simulator replays, the tuner plans.  This package closes the paper's
methodology loop by feeding what the hardware actually did back into
those predictions:

  record.py     PhaseTimer instrumentation (context manager + decorator)
                wired into linalg dispatch and the serving engine;
                recording is off unless REPRO_TELEMETRY=1 / enable()
  store.py      append-only JSONL run store under artifacts/telemetry/,
                keyed by machine fingerprint, schema-versioned, compactable
  residuals.py  join measured runs against perf.evaluate per-phase
                predictions (and optionally repro.sim) -> ratio rows
  refit.py      online recalibration: Nelder-Mead efficiency-curve fit +
                ridge-scaled calibration tables, emitted as a new
                Machine-profile *revision* (never mutated in place)
  drift.py      rolling per-op relative error; crossing the threshold
                bumps Machine.revision, changing the fingerprint and so
                retiring every stale tuner plan-cache entry
  diagnose.py   fault localization: shift-pattern probes score links by
                the lateness of ranks routed over them; the winning
                hypothesis is emitted as a *degraded* machine revision
                whose surface carries an injectable FaultSpec
  report.py     the paper's accuracy tables (mean/max relative error per
                algorithm) as a living report, JSON-saved for CI gates

Closed loop: dispatch records -> residuals join -> refit shrinks the
error -> drift detection retires the profile when reality moves again.
"""

from .store import RunRecord, RunStore, TELEMETRY_SCHEMA, telemetry_dir
from .record import (PhaseTimer, default_store, disable, enable, enabled,
                     kernel_timer, observe_plan, phase_scope, reset,
                     timer_for_plan)
from .residuals import (Residual, TOTAL_PHASES, join, mean_abs_log_ratio,
                        split_comm_comp)
from .refit import KernelRefitResult, RefitResult, refit, refit_kernels
from .drift import (DEFAULT_THRESHOLD, DEFAULT_WINDOW, DriftLatch,
                    DriftStatus, bump_revision, check,
                    detect_and_invalidate, reset_latch)
from .diagnose import (Diagnosis, DiagnosisResponder,
                       default_probe_distances, emit_degraded_profile,
                       localize_link, localize_rank, probe_links,
                       probe_shift_durations)
from .report import accuracy_report, format_report, save_report

__all__ = [
    "RunRecord", "RunStore", "TELEMETRY_SCHEMA", "telemetry_dir",
    "PhaseTimer", "default_store", "disable", "enable", "enabled",
    "kernel_timer", "observe_plan", "phase_scope", "reset", "timer_for_plan",
    "Residual", "TOTAL_PHASES", "join", "mean_abs_log_ratio",
    "split_comm_comp",
    "KernelRefitResult", "RefitResult", "refit", "refit_kernels",
    "DEFAULT_THRESHOLD", "DEFAULT_WINDOW", "DriftLatch", "DriftStatus",
    "bump_revision", "check", "detect_and_invalidate", "reset_latch",
    "Diagnosis", "DiagnosisResponder", "default_probe_distances",
    "emit_degraded_profile",
    "localize_link", "localize_rank", "probe_links",
    "probe_shift_durations",
    "accuracy_report", "format_report", "save_report",
]
