"""Paper-style accuracy reporting over the residual table.

The paper validates its models with per-algorithm tables of predicted vs
measured %-of-peak (Tables II-V) and relative-error plots (Figs. 5-8);
``accuracy_report`` produces the same summary — per-algorithm mean / max
relative error plus the log-ratio the refit optimizes — continuously,
from whatever the telemetry store has recorded.  ``save_report`` drops it
as JSON next to the run files so CI can gate on it.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional, Sequence

import numpy as np

from .residuals import Residual, mean_abs_log_ratio
from .store import telemetry_dir


def accuracy_report(rows: Sequence[Residual]) -> dict:
    """Per-op and overall accuracy of the current model vs measurement."""
    by_op: Dict[str, list] = {}
    for r in rows:
        if r.source == "model":
            by_op.setdefault(r.op, []).append(r)
    ops = {}
    for op, op_rows in sorted(by_op.items()):
        rel = [r.rel_err for r in op_rows]
        ops[op] = {
            "n_rows": len(op_rows),
            "mean_rel_err": float(np.mean(rel)),
            "max_rel_err": float(np.max(rel)),
            "mean_abs_log_ratio": mean_abs_log_ratio(op_rows),
            "phases": sorted({r.phase for r in op_rows}),
        }
    all_rows = [r for rs in by_op.values() for r in rs]
    overall = {
        "n_rows": len(all_rows),
        "mean_rel_err": (float(np.mean([r.rel_err for r in all_rows]))
                         if all_rows else float("nan")),
        "max_rel_err": (float(np.max([r.rel_err for r in all_rows]))
                        if all_rows else float("nan")),
        "mean_abs_log_ratio": mean_abs_log_ratio(all_rows),
    }
    return {"ops": ops, "overall": overall}


def format_report(report: dict) -> str:
    """Fixed-width text table (the Tables II-V look, rel-err flavored)."""
    lines = [f"{'op':<12} {'rows':>5} {'mean rel err':>13} "
             f"{'max rel err':>12} {'mean |log r|':>13}"]
    for op, row in report["ops"].items():
        lines.append(f"{op:<12} {row['n_rows']:>5} "
                     f"{row['mean_rel_err']:>12.1%} "
                     f"{row['max_rel_err']:>11.1%} "
                     f"{row['mean_abs_log_ratio']:>13.3f}")
    ov = report["overall"]
    lines.append(f"{'overall':<12} {ov['n_rows']:>5} "
                 f"{ov['mean_rel_err']:>12.1%} "
                 f"{ov['max_rel_err']:>11.1%} "
                 f"{ov['mean_abs_log_ratio']:>13.3f}")
    return "\n".join(lines)


def save_report(report: dict, path: Optional[str] = None) -> str:
    """Write the report JSON under ``artifacts/telemetry/`` (CI gates on
    ``overall.mean_rel_err``); returns the path."""
    if path is None:
        path = os.path.join(telemetry_dir(), "report.json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path
