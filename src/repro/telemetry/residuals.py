"""Joining measured runs against model predictions — the paper's
est_Cal-vs-measured comparison (Tables II-V, Figs. 5-8) as a living table.

Each measured :class:`~repro.telemetry.store.RunRecord` is looked up in
the :class:`~repro.tuner.registry.PerfModelRegistry` and evaluated through
``perf.evaluate`` for the same (n, p, c) scenario; matching phase names
join measured seconds to the prediction's per-phase ``EvalResult.phases``
(the whole-run ``execute`` / ``total`` phases join against the predicted
total).  ``include_sim=True`` additionally replays each scenario through
the per-rank discrete-event simulator (``repro.sim``) so the residuals
carry both estimator flavors.

The output rows — measured/predicted ratio per phase — feed ``refit``
(online recalibration) and ``drift`` (invalidation), and summarize into
the paper-style accuracy numbers in ``report``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

import numpy as np

from .store import RunRecord

#: measured phase names that stand for the whole run rather than one model
#: phase — they join against the predicted *total*.
TOTAL_PHASES = ("execute", "total", "step")


@dataclasses.dataclass
class Residual:
    """One (measured phase) x (predicted phase) joined observation."""

    op: str
    variant: str
    n: int
    p: int
    c: int
    phase: str
    measured: float         # wall seconds
    predicted: float        # model (or sim) seconds for the same scenario
    source: str = "model"   # "model" | "sim"
    machine: str = ""       # machine-model name the prediction used
    pred_comm: float = 0.0  # serialized comm seconds inside ``predicted``
    pred_comp: float = 0.0  # serialized comp seconds inside ``predicted``
    timestamp: float = 0.0

    @property
    def ratio(self) -> float:
        return self.measured / self.predicted

    @property
    def log_ratio(self) -> float:
        return math.log(self.ratio)

    @property
    def rel_err(self) -> float:
        """|predicted - measured| / measured — the paper's accuracy metric
        with the measurement as ground truth."""
        return abs(self.predicted - self.measured) / self.measured


def _default_registry():
    from ..tuner.registry import DEFAULT_REGISTRY
    return DEFAULT_REGISTRY


def join(runs: Sequence[RunRecord], registry=None, *,
         options=None, include_sim: bool = False) -> List[Residual]:
    """Residual rows for every joinable (run, phase) pair, oldest first.

    Runs whose (op, variant) has no registered cost-IR program, whose
    machine is unknown to the registry, or whose phases are all overhead
    (no model analog) contribute nothing — serving records join only
    if an LM program is registered under their op.  The exception is
    scheduler ``serve_step`` records: they carry the serving cost
    model's own per-phase prediction inline (made at scheduling time,
    under the scales then installed), so they self-join without any
    registry lookup and come back tagged ``source="serve"`` —
    ``cost.refit_serving`` consumes them, and ``accuracy_report`` (which
    aggregates only ``source="model"`` rows) stays unaffected.

    ``include_sim=True`` replays every distinct joinable scenario through
    the per-rank simulator in one ``simulate_programs`` batch per machine
    (shared route/fold caches), before the row assembly below.
    """
    registry = registry or _default_registry()
    rows: List[Residual] = []
    eval_cache: Dict[tuple, object] = {}
    if include_sim:
        _batch_sim_totals(runs, registry, eval_cache)
    for run in runs:
        if not run.phases:
            continue
        if run.kind == "serve_step" and run.predicted:
            rows.extend(_self_join(run))
            continue
        if not registry.has_program(run.op, run.variant):
            continue
        try:
            surface = registry.machine(run.machine)
        except KeyError:
            continue
        key = (run.machine, run.op, run.variant, run.n, run.p, run.c)
        res = eval_cache.get(key)
        if res is None:
            ctx = surface.context()
            res = registry.evaluate_grid(ctx, run.op, run.variant,
                                         float(run.n), float(run.p),
                                         float(run.c), 1.0, options=options)
            eval_cache[key] = res
        for phase, measured in run.phases.items():
            if phase in TOTAL_PHASES:
                predicted = float(res.total)
                pcm, pcp = float(res.comm), float(res.comp)
            elif phase in res.phases:
                ph = res.phases[phase]
                predicted = float(ph.exposed)
                pcm, pcp = float(ph.comm), float(ph.comp)
            else:
                continue  # overhead phase (plan/distribute/...): no analog
            if measured <= 0.0 or predicted <= 0.0:
                continue
            rows.append(Residual(run.op, run.variant, run.n, run.p, run.c,
                                 phase, float(measured), predicted,
                                 source="model", machine=run.machine,
                                 pred_comm=pcm, pred_comp=pcp,
                                 timestamp=run.timestamp))
        if include_sim:
            sim_t = _sim_total(registry, surface, run, eval_cache)
            if sim_t is not None and run.total > 0.0 and sim_t > 0.0:
                rows.append(Residual(run.op, run.variant, run.n, run.p,
                                     run.c, "total", run.total, sim_t,
                                     source="sim", machine=run.machine,
                                     timestamp=run.timestamp))
    rows.sort(key=lambda r: r.timestamp)
    return rows


def _self_join(run: RunRecord) -> List[Residual]:
    """Residual rows for a record that carries its own prediction
    (scheduler serve_steps): measured phase vs the same-named entry of
    ``run.predicted``, no registry round-trip."""
    rows = []
    for phase, measured in run.phases.items():
        predicted = run.predicted.get(phase)
        if not predicted or measured <= 0.0 or predicted <= 0.0:
            continue
        rows.append(Residual(run.op, run.variant, run.n, run.p, run.c,
                             phase, float(measured), float(predicted),
                             source="serve", machine=run.machine,
                             timestamp=run.timestamp))
    return rows


def _sim_key(run: RunRecord) -> tuple:
    return ("sim", run.machine, run.op, run.variant, run.n, run.p, run.c)


def _batch_sim_totals(runs: Sequence[RunRecord], registry,
                      cache: Dict[tuple, object]) -> None:
    """Pre-fill ``cache`` with simulated totals for every distinct
    joinable (machine, op, variant, n, p, c) among ``runs`` — one
    ``simulate_programs`` call per machine, failures cached as None."""
    from ..sim import simulate_programs
    by_machine: Dict[str, List[tuple]] = {}
    for run in runs:
        key = _sim_key(run)
        if key in cache or not run.phases:
            continue
        if not registry.has_program(run.op, run.variant):
            continue
        try:
            registry.machine(run.machine)
        except KeyError:
            continue
        cache[key] = None  # dedup marker; overwritten on success
        by_machine.setdefault(run.machine, []).append(key)
    for machine, keys in by_machine.items():
        surface = registry.machine(machine)
        programs = [registry.program(k[2], k[3]) for k in keys]
        scens = [{"n": float(k[4]), "p": int(k[5]), "c": int(k[6]), "r": 1}
                 for k in keys]
        sims = simulate_programs(programs, surface.context(), scens,
                                 machine=surface.machine, strict=False)
        for key, sim in zip(keys, sims):
            cache[key] = float(sim.total) if sim is not None else None


def _sim_total(registry, surface, run: RunRecord,
               cache: Dict[tuple, object]) -> Optional[float]:
    key = _sim_key(run)
    if key in cache:
        return cache[key]
    from ..sim import simulate_programs
    sims = simulate_programs(
        [registry.program(run.op, run.variant)], surface.context(),
        [{"n": float(run.n), "p": int(run.p), "c": int(run.c), "r": 1}],
        machine=surface.machine, strict=False)
    total = float(sims[0].total) if sims[0] is not None else None
    cache[key] = total
    return total


def mean_abs_log_ratio(rows: Sequence[Residual]) -> float:
    """The refit objective: 0 when the model nails every phase, symmetric
    in over- and under-prediction."""
    if not rows:
        return float("nan")
    return float(np.mean([abs(r.log_ratio) for r in rows]))


def split_comm_comp(rows: Sequence[Residual]):
    """(comm-dominated, comp-dominated) partition of the rows, by the
    model's own predicted comm fraction carried on each row.  Refit uses
    it to attribute residual error to the right model surface."""
    comm_rows: List[Residual] = []
    comp_rows: List[Residual] = []
    for r in rows:
        tot = r.pred_comm + r.pred_comp
        frac = r.pred_comm / tot if tot > 0 else 0.0
        (comm_rows if frac > 0.5 else comp_rows).append(r)
    return comm_rows, comp_rows
