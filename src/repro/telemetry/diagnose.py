"""Fault diagnosis: localize a degraded component and re-key the models.

Drift detection (``telemetry.drift``, ``obs.watch``) answers *that* the
machine moved — predictions are off by a sustained factor.  This module
answers *where*: which physical link or rank is sick, how sick, and what
the planner should assume about it.  The closed loop is

  residual firing -> :class:`DiagnosisResponder` -> probe the measured
  channel with shift patterns -> :func:`localize_link` /
  :func:`localize_rank` score components -> :func:`emit_degraded_profile`
  re-registers the machine at ``revision + 1`` with a
  :class:`~repro.sim.faults.FaultSpec` attached to its surface -> every
  plan-cache key and telemetry store file keyed by the old fingerprint is
  retired -> ``Tuner.plan`` re-plans (sim-refined, fault injected) and
  provably routes around the sick component.

Link localization is probe-based, mirroring the paper's calibration
methodology: the shift pattern ``rank -> rank + d`` at a few distances is
replayed through the *measured* channel (real hardware, or a faulted
``sim.Network`` standing in for it) and through the healthy model.  Ranks
whose measured/modeled duration ratio is high are "late"; every link on a
late rank's route is charged ``ratio - 1`` and the highest-scoring link
is the suspect — at ``d`` small most routes are single-hop, so the probe
pins the link nearly directly.  Severity is the median lateness of the
ranks crossing it, which is exactly the per-link beta multiplier a
:class:`~repro.sim.faults.DegradedLink` injects.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from ..sim.faults import DegradedLink, FaultSpec, SlowRank


@dataclasses.dataclass
class Diagnosis:
    """One localized fault hypothesis (or the healthy verdict)."""

    kind: str                    # "degraded_link" | "slow_rank" | "healthy"
    component: int = -1          # physical link id / rank (kind-dependent)
    severity: float = 1.0        # beta / compute multiplier estimate
    windows: int = 0             # observation windows until localization
    component_name: str = ""     # human-readable (topology.link_name)
    evidence: Dict[str, object] = dataclasses.field(default_factory=dict)

    @property
    def healthy(self) -> bool:
        return self.kind == "healthy"

    def to_fault_spec(self) -> FaultSpec:
        """The injectable counterpart of this hypothesis — what a degraded
        machine surface carries into every candidate simulation."""
        if self.kind == "degraded_link":
            return FaultSpec(degraded_links=(
                DegradedLink(int(self.component),
                             max(float(self.severity), 1.0)),))
        if self.kind == "slow_rank":
            return FaultSpec(slow_ranks=(
                SlowRank(int(self.component),
                         max(float(self.severity), 1.0)),))
        return FaultSpec()

    def to_dict(self) -> dict:
        return {"kind": self.kind, "component": int(self.component),
                "severity": float(self.severity), "windows": int(self.windows),
                "component_name": self.component_name}


def default_probe_distances(topology, p: int) -> Tuple[int, ...]:
    """Probe distances that exercise every routing dimension: on a torus,
    ``rank -> rank + d`` moves in the dimension whose stride divides ``d``
    (node numbering is dimension-0 fastest), so one probe per dimension
    stride — plus a two-hop confirmation where the ring allows — covers
    all links.  Non-torus topologies get small distances (every channel
    pair is distinct anyway on a crossbar)."""
    shape = getattr(topology, "shape", None)
    if not shape:
        return (1, 2, 3)
    out: List[int] = []
    stride = 1
    for k in shape:
        if 0 < stride < p:
            out.append(stride)
            if k > 2 and 0 < 2 * stride < p:
                out.append(2 * stride)
        stride *= k
    return tuple(out) or (1,)


def probe_shift_durations(network, p: int, d: int, *,
                          words: float = 4096.0,
                          start: float = 0.0) -> np.ndarray:
    """Per-rank duration of one ``rank -> rank + d`` probe pattern through
    ``network`` (all ranks inject ``words`` at ``start``)."""
    starts = np.full(int(p), float(start))
    done = network.deliver_shift(starts, float(words), int(d),
                                 network.latency)
    return done - starts


def localize_link(topology, p: int, *,
                  measure: Callable[[int], np.ndarray],
                  baseline: Callable[[int], np.ndarray],
                  distances: Sequence[int] = (1, 2, 3),
                  late_ratio: float = 1.25) -> Diagnosis:
    """Score every link by the lateness of the probe ranks routed over it.

    ``measure(d)`` / ``baseline(d)`` return per-rank durations of the
    shift-``d`` probe through the measured channel and the healthy model.
    Ranks with ``measure/baseline >= late_ratio`` are late; each link on a
    late rank's route accumulates ``ratio - 1`` and the argmax is the
    suspect.  Severity is the median ratio of the late ranks that cross
    it (the per-link beta multiplier estimate)."""
    score: Dict[int, float] = {}
    rounds: List[Tuple[int, np.ndarray, np.ndarray]] = []
    for d in distances:
        meas = np.asarray(measure(d), dtype=float)
        base = np.maximum(np.asarray(baseline(d), dtype=float), 1e-30)
        ratio = meas / base
        late = ratio >= late_ratio
        if not late.any():
            continue
        plan = topology.shift_plan(int(p), int(d))
        for r in np.flatnonzero(late):
            for l in plan.links[plan.indptr[r]:plan.indptr[r + 1]]:
                score[int(l)] = score.get(int(l), 0.0) + float(ratio[r] - 1.0)
        rounds.append((int(d), ratio, late))
    if not score:
        return Diagnosis(kind="healthy",
                         evidence={"distances": list(distances)})
    best = max(score, key=lambda l: score[l])
    sev: List[float] = []
    for d, ratio, late in rounds:
        plan = topology.shift_plan(int(p), d)
        for r in np.flatnonzero(late):
            if best in plan.links[plan.indptr[r]:plan.indptr[r + 1]]:
                sev.append(float(ratio[r]))
    severity = max(float(np.median(sev)), 1.0) if sev else 1.0
    return Diagnosis(
        kind="degraded_link", component=int(best), severity=severity,
        component_name=topology.link_name(int(best)),
        evidence={"score": {int(k): float(v) for k, v in score.items()},
                  "distances": [d for d, _, _ in rounds]})


def localize_rank(per_rank_seconds: np.ndarray, *,
                  ratio_threshold: float = 2.0) -> Diagnosis:
    """Slow-rank localization from per-rank busy seconds (e.g. the
    compute ledger of a simulated or measured run): the worst rank's
    time over the median, when it clears the threshold."""
    arr = np.asarray(per_rank_seconds, dtype=float)
    med = max(float(np.median(arr)), 1e-30)
    worst = int(np.argmax(arr))
    ratio = float(arr[worst]) / med
    if ratio < ratio_threshold:
        return Diagnosis(kind="healthy", evidence={"cmax_over_med": ratio})
    return Diagnosis(kind="slow_rank", component=worst, severity=ratio,
                     component_name=f"rank{worst}",
                     evidence={"cmax_over_med": ratio})


def probe_links(measured_network, *, p: Optional[int] = None,
                distances: Optional[Sequence[int]] = None,
                words: float = 4096.0,
                late_ratio: float = 1.25) -> Diagnosis:
    """Link localization with the healthy baseline built internally: probe
    ``measured_network`` (real hardware behind a shim, or a faulted
    ``sim.Network`` standing in for it) and compare against a pristine
    ``Network`` on the same topology/latency/beta.  Default distances
    cover every routing dimension (:func:`default_probe_distances`)."""
    from ..sim.network import Network
    topo = measured_network.topology
    p = int(p) if p is not None else topo.n_nodes
    if distances is None:
        distances = default_probe_distances(topo, p)
    healthy = Network(topo, measured_network.latency, measured_network.beta)
    return localize_link(
        topo, p,
        measure=lambda d: probe_shift_durations(measured_network, p, d,
                                                words=words),
        baseline=lambda d: probe_shift_durations(healthy, p, d, words=words),
        distances=distances, late_ratio=late_ratio)


def emit_degraded_profile(registry, machine_name: str, faults: FaultSpec,
                          *, diagnosis: Optional[Diagnosis] = None):
    """Re-register ``machine_name`` at ``revision + 1`` with ``faults``
    attached to its surface.

    The bumped revision changes ``Machine.fingerprint()`` — retiring
    every tuner plan-cache entry and telemetry store file keyed by the
    healthy profile — and the surface-carried ``FaultSpec`` makes the
    next ``Tuner.plan`` call sim-refine with the fault injected.  The
    spec deliberately lives on the surface, not inside ``Machine``, so
    emission always moves the fingerprint exactly one revision.

    Returns the new :class:`~repro.core.machine.Machine`."""
    surface = registry.machine(machine_name)
    machine = dataclasses.replace(surface.machine,
                                  revision=surface.machine.revision + 1)
    registry.register_machine(machine, surface.efficiency,
                              surface.calibration, overwrite=True,
                              faults=faults)
    obs.alert("degraded_profile", machine=machine_name,
              revision=machine.revision, faults=faults.to_dict(),
              **({"diagnosis": diagnosis.to_dict()} if diagnosis else {}))
    return machine


class DiagnosisResponder:
    """An ``obs.watch`` on-fire hook that closes detection into diagnosis.

    Where :class:`~repro.obs.watch.detect.RevisionResponder` only bumps
    the revision, this responder runs ``diagnose_fn(firing)`` — typically
    a probe sweep ending in :func:`probe_links` — and, when a real fault
    comes back, emits the degraded profile (revision bump + surface
    ``FaultSpec``) via :func:`emit_degraded_profile`.  Latched one
    response per revision, mirroring the drift latch: a burst of firings
    from one degradation episode diagnoses once."""

    def __init__(self, registry, machine_name: str,
                 diagnose_fn: Callable[[object], Optional[Diagnosis]],
                 series_filter: Optional[Callable[[object], bool]] = None):
        self.registry = registry
        self.machine_name = machine_name
        self.diagnose_fn = diagnose_fn
        self.series_filter = series_filter
        self.responses: List[dict] = []
        self._fired_at_revision: Optional[int] = None

    def __call__(self, firing):
        if self.series_filter is not None and not self.series_filter(firing):
            return None
        current = self.registry.machine(self.machine_name).machine.revision
        if self._fired_at_revision is not None \
                and current == self._fired_at_revision:
            return None                      # already responded; latched
        diagnosis = self.diagnose_fn(firing)
        if diagnosis is None or diagnosis.healthy:
            return None
        machine = emit_degraded_profile(self.registry, self.machine_name,
                                        diagnosis.to_fault_spec(),
                                        diagnosis=diagnosis)
        self._fired_at_revision = machine.revision
        self.responses.append({"series": getattr(firing, "series", None),
                               "diagnosis": diagnosis.to_dict(),
                               "revision": machine.revision})
        return diagnosis
