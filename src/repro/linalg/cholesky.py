"""Distributed Cholesky factorization  A = L L^T  (right-looking, blocked).

Executable counterpart of the §V-style models (the paper models Cholesky
with the same methodology; only Cannon/TRSM equations are printed).

2D: per block-column j on a ("row","col") grid:
  1. factor the diagonal block (owner of (j,j); select-and-reduce bcast),
  2. panel solve on column-j owners:  L_ij = A_ij L_jj^{-T},
  3. broadcast the panel along rows; broadcast the *transposed* panel along
     columns (a single joint-axis ppermute moves block (k,j) -> (j,k)),
  4. trailing update  A_ik -= L_ij L_kj^T  for i,k > j.

2.5D: A replicated over c layers; the trailing update is column-striped
across layers (layer l owns trailing columns with col % c == l) into a
layer-local accumulator; the pivot column is combined with a psum over the
layer axis right before it is factored (the model's ``layer_reduce`` term).
Panel work is replicated across layers — communication, not flops, is what
2.5D saves.

Overlap variants omit the serialization barrier between panel broadcasts
and the trailing update so XLA may overlap them (paper: Pthread comm
thread; TPU: async collectives).
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import compat
from .grid import grid_size, n_layers

MatMul = Callable[[jax.Array, jax.Array], jax.Array]
#: local diagonal factor hook: A_jj -> L_jj (lower Cholesky factor)
Chol = Callable[[jax.Array], jax.Array]
#: local panel solve hook: (A, L_jj) -> A L_jj^{-T}
PanelSolve = Callable[[jax.Array, jax.Array], jax.Array]


def _default_mm(a, b):
    return jnp.dot(a, b, precision=lax.Precision.HIGHEST)


def _default_chol(a):
    return jnp.linalg.cholesky(a)


def _default_panel_solve(a, ljj):
    """A L_jj^{-T}: solve X L_jj^T = A (L_jj^T upper-triangular)."""
    return jax.scipy.linalg.solve_triangular(ljj, a.T, lower=True).T


def _bcast_from(x, axis: str, k):
    idx = lax.axis_index(axis)
    return lax.psum(jnp.where(idx == k, x, jnp.zeros_like(x)), axis)


def _transpose_perm(g: int, layers: int = 1):
    perm = []
    for l in range(layers):
        for i in range(g):
            for j in range(g):
                perm.append(((l * g + i) * g + j, (l * g + j) * g + i))
    return perm


def _chol_body(a, *, g: int, layers: int, local_mm: MatMul, local_chol: Chol,
               local_solve: PanelSolve, overlap: bool):
    row = lax.axis_index("row")
    col = lax.axis_index("col")
    lyr = lax.axis_index("lyr") if layers > 1 else 0
    grid_axes = ("lyr", "row", "col") if layers > 1 else ("row", "col")
    tperm = _transpose_perm(g, layers)

    def step(carry, j):
        a_cur, acc, l_acc = carry
        if layers > 1:
            # combine the pivot column's partial updates across layers
            pivot_fix = lax.psum(jnp.where(col == j, acc, jnp.zeros_like(acc)), "lyr")
            a_eff = a_cur - jnp.where(col == j, pivot_fix, jnp.zeros_like(acc))
        else:
            a_eff = a_cur - acc
        # 1. diagonal factor
        ajj = _bcast_from(_bcast_from(a_eff, "row", j), "col", j)
        ljj = local_chol(ajj)
        # 2. panel solve: L_ij = A_ij L_jj^{-T}
        panel = local_solve(a_eff, ljj)
        lj = jnp.where((col == j) & (row > j), panel, jnp.zeros_like(panel))
        lj = lj + jnp.where((col == j) & (row == j), ljj, jnp.zeros_like(ljj))
        # 3. panel along rows; transposed panel along columns
        lj_row = lax.psum(lj, "col")
        ljT = lax.ppermute(lj, grid_axes, tperm)
        lkj = lax.psum(jnp.where(row == j, ljT, jnp.zeros_like(ljT)), "row")
        if not overlap:
            (a_cur, acc, lj_row, lkj) = lax.optimization_barrier(
                (a_cur, acc, lj_row, lkj))
        # 4. trailing update
        upd = local_mm(lj_row, lkj.swapaxes(-1, -2))
        trailing = (row > j) & (col > j)
        if layers > 1:
            mine = (col % layers) == lyr
            acc = acc + jnp.where(trailing & mine, upd, jnp.zeros_like(upd))
        else:
            acc = acc + jnp.where(trailing, upd, jnp.zeros_like(upd))
        l_acc = jnp.where(col == j, lj_row, l_acc)
        # keep only the lower triangle of the (j,j) block
        return (a_cur, acc, l_acc), None

    zeros = jnp.zeros_like(a)
    carry0 = (a, zeros, zeros)
    if layers > 1:
        # the body's layer-striped masks make the carry vary over 'lyr'
        carry0 = jax.tree.map(
            lambda x: compat.pcast_varying(x, ("lyr",)), carry0)
    (a, acc, l_acc), _ = lax.scan(step, carry0, jnp.arange(g))
    if layers > 1:
        # All layers computed identical panels; select layer 0's copy via a
        # reduction over the layer axis — the model's gather_L term.
        l_acc = lax.psum(
            jnp.where(lyr == 0, l_acc, jnp.zeros_like(l_acc)), "lyr")
    # mask strictly-upper blocks and the upper triangle of diagonal blocks
    bs = l_acc.shape[0]
    tri = jnp.tril(jnp.ones((bs, bs), l_acc.dtype))
    l_acc = jnp.where(row == col, l_acc * tri, l_acc)
    l_acc = jnp.where(row < col, jnp.zeros_like(l_acc), l_acc)
    return l_acc


def _make(mesh, *, overlap: bool, local_mm: Optional[MatMul] = None,
          local_chol: Optional[Chol] = None,
          local_solve: Optional[PanelSolve] = None):
    g = grid_size(mesh)
    layers = n_layers(mesh)
    fn = functools.partial(_chol_body, g=g, layers=layers,
                           local_mm=local_mm or _default_mm,
                           local_chol=local_chol or _default_chol,
                           local_solve=local_solve or _default_panel_solve,
                           overlap=overlap)
    spec = P("row", "col")  # replicated over lyr when present
    return jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=(spec,),
                                    out_specs=spec))


def make(mesh, variant: str, *, local_mm: Optional[MatMul] = None,
         local_chol: Optional[Chol] = None,
         local_solve: Optional[PanelSolve] = None):
    """Reusable compiled executor: A -> L for the given variant (the
    2d/2.5d split is carried by the mesh's layer axis)."""
    return _make(mesh, overlap=variant.endswith("ovlp"), local_mm=local_mm,
                 local_chol=local_chol, local_solve=local_solve)


def cholesky_2d(A, *, mesh, local_mm: Optional[MatMul] = None,
                local_chol: Optional[Chol] = None,
                local_solve: Optional[PanelSolve] = None):
    """L with A = L L^T; A block-distributed on ("row","col")."""
    return make(mesh, "2d", local_mm=local_mm, local_chol=local_chol,
                local_solve=local_solve)(A)


def cholesky_2d_ovlp(A, *, mesh, local_mm: Optional[MatMul] = None,
                     local_chol: Optional[Chol] = None,
                     local_solve: Optional[PanelSolve] = None):
    return make(mesh, "2d_ovlp", local_mm=local_mm, local_chol=local_chol,
                local_solve=local_solve)(A)


def cholesky_25d(A, *, mesh, local_mm: Optional[MatMul] = None,
                 local_chol: Optional[Chol] = None,
                 local_solve: Optional[PanelSolve] = None):
    """2.5D on a ("lyr","row","col") mesh; A replicated over layers."""
    return make(mesh, "2.5d", local_mm=local_mm, local_chol=local_chol,
                local_solve=local_solve)(A)


def cholesky_25d_ovlp(A, *, mesh, local_mm: Optional[MatMul] = None,
                      local_chol: Optional[Chol] = None,
                      local_solve: Optional[PanelSolve] = None):
    return make(mesh, "2.5d_ovlp", local_mm=local_mm, local_chol=local_chol,
                local_solve=local_solve)(A)
