"""SUMMA on a JAX device mesh (executable counterpart of §V models).

Per step k: the owners of A's k-th block column broadcast their block along
grid rows, the owners of B's k-th block row broadcast along grid columns,
then every process accumulates a local matmul.  The broadcast is a
select-and-reduce (mask the owner, psum over the axis) — the same
collective GSPMD emits for a one-to-many transfer on a mesh axis.

2.5D: c layers each execute the contiguous chunk of s = g/c of the g steps
(offset l*s), partial C psum-combined over the layer axis.

The overlap variants prefetch the panels for step k+1 before the local
matmul of step k (no data dependency => the scheduler may overlap); the
non-overlapped variants pin serialization with an optimization_barrier.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import compat
from .grid import grid_size, n_layers

MatMul = Callable[[jax.Array, jax.Array], jax.Array]


def _default_mm(a, b):
    return jnp.dot(a, b, precision=lax.Precision.HIGHEST)


def _panels(a, b, k):
    """Select-and-reduce broadcasts of A's block-col k / B's block-row k."""
    col = lax.axis_index("col")
    row = lax.axis_index("row")
    a_panel = lax.psum(jnp.where(col == k, a, jnp.zeros_like(a)), "col")
    b_panel = lax.psum(jnp.where(row == k, b, jnp.zeros_like(b)), "row")
    return a_panel, b_panel


def _summa_body(a, b, *, steps: int, layers: int, s: int,
                local_mm: MatMul, overlap: bool):
    base = lax.axis_index("lyr") * s if layers > 1 else 0

    if overlap:
        ap, bp = _panels(a, b, base)

        def step(carry, k):
            c, ap, bp = carry
            # prefetch panels for k+1 (wraps harmlessly on the last step)
            ap_nxt, bp_nxt = _panels(a, b, jnp.minimum(k + 1, base + steps - 1))
            c = c + local_mm(ap, bp)
            return (c, ap_nxt, bp_nxt), None

        c0 = jnp.zeros_like(local_mm(ap, bp))
        (c, ap, bp), _ = lax.scan(step, (c0, ap, bp),
                                  base + jnp.arange(steps - 1))
        c = c + local_mm(ap, bp)
    else:
        def step(carry, k):
            c = carry
            c = lax.optimization_barrier(c)
            ap, bp = _panels(a, b, k)
            return c + local_mm(ap, bp), None

        ap0, bp0 = _panels(a, b, base)
        c0 = jnp.zeros_like(local_mm(ap0, bp0))
        c, _ = lax.scan(step, c0, base + jnp.arange(steps))

    if layers > 1:
        c = lax.psum(c, "lyr")
    return c


def _make(mesh, *, overlap: bool, local_mm: Optional[MatMul] = None):
    g = grid_size(mesh)
    layers = n_layers(mesh)
    if layers > 1 and g % layers != 0:
        raise ValueError(f"layers c={layers} must divide grid g={g}")
    s = g // layers if layers > 1 else g
    fn = functools.partial(_summa_body, steps=s, layers=layers, s=s,
                           local_mm=local_mm or _default_mm, overlap=overlap)
    spec = P("row", "col")
    return jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=(spec, spec),
                                    out_specs=spec))


def make(mesh, variant: str, *, local_mm: Optional[MatMul] = None):
    """Reusable compiled executor: (A, B) -> C for the given variant (the
    2d/2.5d split is carried by the mesh's layer axis)."""
    return _make(mesh, overlap=variant.endswith("ovlp"), local_mm=local_mm)


def summa_2d(A, B, *, mesh, local_mm: Optional[MatMul] = None):
    return _make(mesh, overlap=False, local_mm=local_mm)(A, B)


def summa_2d_ovlp(A, B, *, mesh, local_mm: Optional[MatMul] = None):
    return _make(mesh, overlap=True, local_mm=local_mm)(A, B)


def summa_25d(A, B, *, mesh, local_mm: Optional[MatMul] = None):
    return _make(mesh, overlap=False, local_mm=local_mm)(A, B)


def summa_25d_ovlp(A, B, *, mesh, local_mm: Optional[MatMul] = None):
    return _make(mesh, overlap=True, local_mm=local_mm)(A, B)
