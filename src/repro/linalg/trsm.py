"""Distributed triangular solve  X * U = B  (U upper-triangular).

Executable counterpart of the paper's §V-B models.

2D (``trsm_2d``): right-looking over block columns on a ("row","col") grid.
Per block-column j:
  1. broadcast U_jj (select-and-reduce over both axes — the model's
     ``T_bcast_sync`` along columns),
  2. local dtrsm on the owners of X's column j,
  3. broadcast the solved X_:j along grid rows (``T_bcast`` distance 1),
  4. broadcast U_j,: along grid columns and update the trailing matrix.

2.5D (``trsm_25d``): the paper replicates U across c layers and *scatters
the rows of X* among them — rows of X are independent, so each layer runs
the 2D algorithm on its row slice with its own ("row","col") sub-grid; the
final gather is expressed by the output sharding over the flattened
("lyr","row") axis.  This is exactly the executable shape of the paper's
model (scatter_X + per-layer loop + gather_X).

Overlap variants prefetch the *next* U panel during the trailing update
(the paper's Pthread-dedicated-to-comm trick; here: no data dependency =>
XLA may overlap).

The executable versions use r=1 block-cyclic factor (one block per process
per dimension); the performance models support general r — see DESIGN.md.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import compat
from .grid import grid_size, n_layers

MatMul = Callable[[jax.Array, jax.Array], jax.Array]
#: local solve hook: (B, U) -> X with X U = B (U upper-triangular); the
#: Pallas trsm kernel plugs in here via the tuner dispatch layer.
SolveXU = Callable[[jax.Array, jax.Array], jax.Array]


def _default_mm(a, b):
    return jnp.dot(a, b, precision=lax.Precision.HIGHEST)


def _solve_xu(b, u):
    """Local X U = B  =>  X = B U^{-1} (U upper)."""
    # solve_triangular solves a x = b; for x u = b use transpose:
    # (u^T x^T = b^T) with u^T lower.
    return jax.scipy.linalg.solve_triangular(
        u.T, b.T, lower=True).T


def _bcast_from(x, axis: str, k):
    """Select-and-reduce broadcast of the axis-index-k owner's block."""
    idx = lax.axis_index(axis)
    return lax.psum(jnp.where(idx == k, x, jnp.zeros_like(x)), axis)


def _trsm_body(u, b, *, g: int, local_mm: MatMul, local_solve: SolveXU,
               overlap: bool):
    row = lax.axis_index("row")
    col = lax.axis_index("col")

    def diag_u(j):
        # U_jj to everyone: broadcast along rows then columns
        return _bcast_from(_bcast_from(u, "row", j), "col", j)

    def u_panel(j):
        # U_j,: (block row j) to all rows
        return _bcast_from(u, "row", j)

    def step(carry, j):
        b_cur, x_acc, ujj, upan = carry
        # 2. local solve for the owners of column j
        xj = local_solve(b_cur, ujj)
        xj = jnp.where(col == j, xj, jnp.zeros_like(xj))
        # 3. broadcast X_:j along rows
        xj_b = lax.psum(xj, "col")
        if overlap:
            # prefetch next iteration's U blocks during the update
            ujj_nxt = diag_u(jnp.minimum(j + 1, g - 1))
            upan_nxt = u_panel(jnp.minimum(j + 1, g - 1))
        else:
            (b_cur, x_acc, xj_b) = lax.optimization_barrier((b_cur, x_acc, xj_b))
            ujj_nxt, upan_nxt = ujj, upan
        # 4. trailing update: B_:k -= X_:j @ U_jk for k > j
        upd = local_mm(xj_b, upan)
        b_new = jnp.where(col > j, b_cur - upd, b_cur)
        x_acc = jnp.where(col == j, xj_b, x_acc)
        if not overlap:
            ujj_nxt = diag_u(jnp.minimum(j + 1, g - 1))
            upan_nxt = u_panel(jnp.minimum(j + 1, g - 1))
        return (b_new, x_acc, ujj_nxt, upan_nxt), None

    x0 = jnp.zeros_like(b)
    carry = (b, x0, diag_u(0), u_panel(0))
    (b, x, _, _), _ = lax.scan(step, carry, jnp.arange(g))
    return x


def _make_2d(mesh, *, overlap: bool, local_mm: Optional[MatMul] = None,
             local_solve: Optional[SolveXU] = None):
    g = grid_size(mesh)
    layers = n_layers(mesh)
    fn = functools.partial(_trsm_body, g=g, local_mm=local_mm or _default_mm,
                           local_solve=local_solve or _solve_xu,
                           overlap=overlap)
    if layers > 1:
        # 2.5D: U replicated over layers; B/X rows scattered over (lyr,row).
        u_spec = P("row", "col")
        bx_spec = P(("lyr", "row"), "col")
    else:
        u_spec = P("row", "col")
        bx_spec = P("row", "col")
    return jax.jit(compat.shard_map(fn, mesh=mesh, in_specs=(u_spec, bx_spec),
                                    out_specs=bx_spec))


def make(mesh, variant: str, *, local_mm: Optional[MatMul] = None,
         local_solve: Optional[SolveXU] = None):
    """Reusable compiled executor: (U, B) -> X for the given variant (the
    2d/2.5d split is carried by the mesh's layer axis)."""
    return _make_2d(mesh, overlap=variant.endswith("ovlp"),
                    local_mm=local_mm, local_solve=local_solve)


def trsm_2d(U, B, *, mesh, local_mm: Optional[MatMul] = None,
            local_solve: Optional[SolveXU] = None):
    """Solve X U = B; U and B block-distributed on ("row","col")."""
    return _make_2d(mesh, overlap=False, local_mm=local_mm,
                    local_solve=local_solve)(U, B)


def trsm_2d_ovlp(U, B, *, mesh, local_mm: Optional[MatMul] = None,
                 local_solve: Optional[SolveXU] = None):
    return _make_2d(mesh, overlap=True, local_mm=local_mm,
                    local_solve=local_solve)(U, B)


def trsm_25d(U, B, *, mesh, local_mm: Optional[MatMul] = None,
             local_solve: Optional[SolveXU] = None):
    """2.5D: mesh ("lyr","row","col"); U replicated per layer, B rows
    scattered across layers."""
    return _make_2d(mesh, overlap=False, local_mm=local_mm,
                    local_solve=local_solve)(U, B)


def trsm_25d_ovlp(U, B, *, mesh, local_mm: Optional[MatMul] = None,
                  local_solve: Optional[SolveXU] = None):
    return _make_2d(mesh, overlap=True, local_mm=local_mm,
                    local_solve=local_solve)(U, B)
