"""Process-grid meshes and block distributions for the linalg algorithms.

2D algorithms run on a ("row", "col") mesh; 2.5D algorithms add a leading
("lyr",) replication axis — the paper's extra-memory dimension ``c``.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import compat


def make_grid_mesh(rows: int, cols: int, layers: int = 1,
                   devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    n = rows * cols * layers
    devices = devices if devices is not None else jax.devices()[:n]
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    devices = list(devices)
    if layers > 1:
        return compat.make_mesh((layers, rows, cols), ("lyr", "row", "col"),
                                devices=devices)
    return compat.make_mesh((rows, cols), ("row", "col"), devices=devices)


def square_grid_mesh(p: int, c: int = 1,
                     devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """p devices as a (c, g, g) grid with g = sqrt(p/c)."""
    g = int(round(math.sqrt(p / c)))
    if g * g * c != p:
        raise ValueError(f"p={p} is not c*g^2 for c={c}")
    return make_grid_mesh(g, g, layers=c, devices=devices)


def block_spec(mesh: jax.sharding.Mesh, replicated_layers: bool = True) -> P:
    """PartitionSpec of an (n, n) matrix block-distributed on the grid."""
    if "lyr" in mesh.shape and replicated_layers:
        return P("row", "col")
    return P("row", "col")


def distribute(x, mesh: jax.sharding.Mesh, spec: Optional[P] = None):
    spec = spec if spec is not None else block_spec(mesh)
    return jax.device_put(x, NamedSharding(mesh, spec))


def grid_size(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape["row"]


def n_layers(mesh: jax.sharding.Mesh) -> int:
    return mesh.shape.get("lyr", 1)
