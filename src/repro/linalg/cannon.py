"""Cannon's matrix-multiplication algorithm on a JAX device mesh.

Executable counterparts of the paper's models (§V-A):

* ``cannon_2d``        — p = g*g processes, initial skew + g-step shift loop.
* ``cannon_2d_ovlp``   — same, loop restructured so the iteration-(i+1)
  shifts have no data dependency on iteration-i's matmul: XLA's latency-
  hiding scheduler may overlap them (the UPC version used async copies; on
  TPU this is the idiomatic equivalent — see DESIGN.md §3).
* ``cannon_25d``/``_ovlp`` — c replication layers; each layer executes a
  contiguous chunk of s = g/c of the g shift steps starting from its own
  skew offset, partial C combined with a psum over the layer axis (the
  model's ``T_reduce`` term).  Inputs arrive replicated over layers (the
  replication itself is the ``T_iniRepl`` term and is exercised/charged by
  the driver when it distributes operands).

The initial skew (block (i,j) -> (i, j-i)) is rank-dependent, which a
static ``ppermute`` cannot express per-axis — but it *is* a fixed
permutation of the flattened (row, col) grid, so we issue one ppermute over
the joint axes.  The non-overlapped variants place an
``optimization_barrier`` between matmul and the next shift to pin the
serialized schedule (making 2D-vs-overlap measurable on real hardware).

All local matmuls go through ``local_mm`` so the Pallas kernel
(repro.kernels.matmul) can be swapped in for the jnp default.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .. import compat
from .grid import grid_size, n_layers

MatMul = Callable[[jax.Array, jax.Array], jax.Array]


def _default_mm(a, b):
    return jnp.dot(a, b, precision=lax.Precision.HIGHEST)


def _skew_perm(g: int, axis_is_row: bool, offset_sign: int, extra: int = 0,
               layers: int = 1, s: int = 1):
    """Permutation of the flattened (lyr, row, col) grid implementing the
    Cannon skew: A block (i, j) -> (i, j - i - l*s); B block (i, j) ->
    (i - j - l*s, j).  ``offset_sign`` folds direction."""
    perm = []
    for l in range(layers):
        for i in range(g):
            for j in range(g):
                src = (l * g + i) * g + j
                off = (i if axis_is_row else j) + l * s
                if axis_is_row:
                    dst = (l * g + i) * g + ((j - off) % g)
                else:
                    dst = (l * g + ((i - off) % g)) * g + j
                perm.append((src, dst))
    return perm


def _shift_perm(g: int):
    """Uniform shift by one (ring) on one axis."""
    return [(k, (k - 1) % g) for k in range(g)]


def _cannon_body(a, b, *, g: int, steps: int, layers: int, s: int,
                 local_mm: MatMul, overlap: bool):
    grid_axes = ("lyr", "row", "col") if layers > 1 else ("row", "col")
    a = lax.ppermute(a, grid_axes, _skew_perm(g, True, 1, layers=layers, s=s))
    b = lax.ppermute(b, grid_axes, _skew_perm(g, False, 1, layers=layers, s=s))
    c = local_mm(a, b)

    shift_a = _shift_perm(g)
    shift_b = _shift_perm(g)

    def step(carry, _):
        a, b, c = carry
        if overlap:
            # comm for iteration i+1 is independent of the current matmul
            a_nxt = lax.ppermute(a, "col", shift_a)
            b_nxt = lax.ppermute(b, "row", shift_b)
            c = c + local_mm(a_nxt, b_nxt)
            return (a_nxt, b_nxt, c), None
        # serialized: shifts wait for the previous matmul
        a, b, c = lax.optimization_barrier((a, b, c))
        a = lax.ppermute(a, "col", shift_a)
        b = lax.ppermute(b, "row", shift_b)
        c = c + local_mm(a, b)
        return (a, b, c), None

    if steps > 1:
        (a, b, c), _ = lax.scan(step, (a, b, c), None, length=steps - 1)
    if layers > 1:
        c = lax.psum(c, "lyr")
    return c


def _make(mesh, *, overlap: bool, local_mm: Optional[MatMul] = None):
    g = grid_size(mesh)
    c_layers = n_layers(mesh)
    if c_layers > 1 and g % c_layers != 0:
        raise ValueError(f"layers c={c_layers} must divide grid g={g}")
    s = g // c_layers if c_layers > 1 else g
    mm = local_mm or _default_mm
    in_spec = P("row", "col")  # replicated over lyr when present

    fn = functools.partial(_cannon_body, g=g, steps=s, layers=c_layers, s=s,
                           local_mm=mm, overlap=overlap)
    return jax.jit(compat.shard_map(
        fn, mesh=mesh, in_specs=(in_spec, in_spec), out_specs=in_spec))


def make(mesh, variant: str, *, local_mm: Optional[MatMul] = None):
    """Reusable compiled executor: (A, B) -> C for the given variant (the
    2d/2.5d split is carried by the mesh's layer axis)."""
    return _make(mesh, overlap=variant.endswith("ovlp"), local_mm=local_mm)


def cannon_2d(A, B, *, mesh, local_mm: Optional[MatMul] = None):
    """C = A @ B on a ("row","col") mesh; A, B block-distributed."""
    return _make(mesh, overlap=False, local_mm=local_mm)(A, B)


def cannon_2d_ovlp(A, B, *, mesh, local_mm: Optional[MatMul] = None):
    return _make(mesh, overlap=True, local_mm=local_mm)(A, B)


def cannon_25d(A, B, *, mesh, local_mm: Optional[MatMul] = None):
    """C = A @ B on a ("lyr","row","col") mesh; operands replicated over
    layers; each layer computes s = g/c of the shift steps."""
    return _make(mesh, overlap=False, local_mm=local_mm)(A, B)


def cannon_25d_ovlp(A, B, *, mesh, local_mm: Optional[MatMul] = None):
    return _make(mesh, overlap=True, local_mm=local_mm)(A, B)
