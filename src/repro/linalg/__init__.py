"""Executable distributed dense linear algebra (shard_map) — the paper's
benchmark applications: Cannon, SUMMA, TRSM, Cholesky in 2D / 2.5D,
with and without communication overlapping.

Two API levels:

* **explicit** — the per-variant functions below (``cannon_2d`` ...) take
  pre-distributed operands and a mesh you built;
* **model-guided** — ``matmul`` / ``trsm`` / ``cholesky`` take global
  operands, consult ``repro.tuner`` for the best (variant, c, grid,
  local kernel) on the available devices, and execute it (plans are
  cached persistently under ``artifacts/plans/``).  With telemetry on
  (``REPRO_TELEMETRY=1`` or per-call ``observe=True``) each call also
  records its measured per-phase times into ``repro.telemetry`` — the
  feedback loop that validates and refits the models.
"""

from .grid import distribute, make_grid_mesh, square_grid_mesh
from .cannon import cannon_2d, cannon_2d_ovlp, cannon_25d, cannon_25d_ovlp
from .summa import summa_2d, summa_2d_ovlp, summa_25d, summa_25d_ovlp
from .trsm import trsm_2d, trsm_2d_ovlp, trsm_25d, trsm_25d_ovlp
from .cholesky import (cholesky_2d, cholesky_2d_ovlp, cholesky_25d,
                       cholesky_25d_ovlp)

ALGORITHMS = {
    ("cannon", "2d"): cannon_2d,
    ("cannon", "2d_ovlp"): cannon_2d_ovlp,
    ("cannon", "2.5d"): cannon_25d,
    ("cannon", "2.5d_ovlp"): cannon_25d_ovlp,
    ("summa", "2d"): summa_2d,
    ("summa", "2d_ovlp"): summa_2d_ovlp,
    ("summa", "2.5d"): summa_25d,
    ("summa", "2.5d_ovlp"): summa_25d_ovlp,
    ("trsm", "2d"): trsm_2d,
    ("trsm", "2d_ovlp"): trsm_2d_ovlp,
    ("trsm", "2.5d"): trsm_25d,
    ("trsm", "2.5d_ovlp"): trsm_25d_ovlp,
    ("cholesky", "2d"): cholesky_2d,
    ("cholesky", "2d_ovlp"): cholesky_2d_ovlp,
    ("cholesky", "2.5d"): cholesky_25d,
    ("cholesky", "2.5d_ovlp"): cholesky_25d_ovlp,
}


# -- model-guided entry points (lazy imports: repro.tuner imports this
# package's submodules, so binding at call time avoids the cycle) -----------

def matmul(A, B, **kwargs):
    """C = A @ B via the tuner-selected Cannon/SUMMA variant and grid.

    Keyword args: ``devices``, ``tuner``, ``local_kernel`` ("pallas"/"jnp"),
    ``observe`` (record this run's measured phases); see
    ``repro.tuner.dispatch.matmul``.
    """
    from ..tuner import dispatch
    return dispatch.matmul(A, B, **kwargs)


def trsm(U, B, **kwargs):
    """Solve X U = B (U upper-triangular) via the tuner-selected variant.

    Note: shadows the ``repro.linalg.trsm`` *module* as a package
    attribute; the per-variant functions stay importable from the module
    (``from repro.linalg.trsm import trsm_2d``) and above.
    """
    from ..tuner import dispatch
    return dispatch.trsm(U, B, **kwargs)


def cholesky(A, **kwargs):
    """L with A = L L^T (A SPD) via the tuner-selected variant."""
    from ..tuner import dispatch
    return dispatch.cholesky(A, **kwargs)
