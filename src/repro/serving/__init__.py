"""Model-guided serving: continuous batching, paged KV blocks, replay.

Layers (each importable on its own):

* :mod:`.engine` — one-call ``Engine.generate`` facade,
* :mod:`.scheduler` — continuous-batching ``Scheduler`` + backends,
* :mod:`.kvblocks` — paged KV-cache ``BlockManager``,
* :mod:`.cost` — per-step serving cost model + telemetry refit,
* :mod:`.policy` — FIFO vs model-guided batch composition,
* :mod:`.trace` — synthetic traces and policy-comparison replay.
"""

from .cost import (ServeCostModel, ServeScales, ServeStepCost, cost_model_for,
                   install_scales, predict_serve_step, refit_serving)
from .engine import Engine, ServeConfig, make_serve_step
from .kvblocks import BlockCapacityError, BlockManager, blocks_for
from .policy import (DegradationController, FIFOPolicy, ModelGuidedPolicy,
                     Policy, StepPlan, make_policy)
from .scheduler import (ModelBackend, Request, Scheduler, SchedulerConfig,
                        SimBackend, build_scheduler)
from .trace import (ReplayReport, TraceConfig, compare_policies, replay,
                    replay_for, replay_traced, synthesize_trace)
