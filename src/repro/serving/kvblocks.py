"""Paged KV-cache block manager: the accounting half of paged attention.

The physical KV pool is divided into fixed-size blocks of ``block_size``
token slots; every admitted request owns a *block table* — the ordered
list of block ids whose concatenation is its logical KV stream (exactly
vLLM's layout; see also rtp-llm's cache_store block buffers).  This class
is the authority for capacity: the scheduler admits a request only when
``can_admit`` says its worst-case token budget fits, and frees the blocks
on eviction.  It is pure Python — the physical gather that turns a block
table into the contiguous cache the attention kernel consumes lives in
``repro.models.attention.gather_block_kv`` (the documented shim a paged
Pallas kernel would replace).

Invariants maintained (and property-tested in test_serving_scheduler):
  * a block id is owned by at most one request at a time,
  * ``free_blocks + sum(len(table))`` over live requests == ``num_blocks``,
  * freeing twice, or extending an unknown request, raises,
  * ``defrag`` only relabels blocks (a permutation onto the lowest free
    ids) and returns the old->new map the physical pool must apply.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


class BlockCapacityError(RuntimeError):
    """Raised when an allocation does not fit the pool."""


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` token slots."""
    if n_tokens <= 0:
        return 0
    return -(-int(n_tokens) // int(block_size))


@dataclasses.dataclass
class _Entry:
    table: List[int]
    n_tokens: int          # token slots actually written (for utilization)


class BlockManager:
    """Fixed-pool allocator of KV blocks with per-request block tables."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks <= 0 or block_size <= 0:
            raise ValueError("num_blocks and block_size must be positive")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # free list kept sorted ascending so allocation is deterministic
        # (lowest ids first) and fragmentation is observable.
        self._free: List[int] = list(range(self.num_blocks))
        self._entries: Dict[str, _Entry] = {}

    # -- capacity ----------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.num_blocks - len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        """True when a request needing ``n_tokens`` worst-case slots fits."""
        return blocks_for(n_tokens, self.block_size) <= len(self._free)

    def utilization(self) -> float:
        """Written token slots / allocated slots (1.0 = no internal waste)."""
        alloc = self.used_blocks * self.block_size
        if alloc == 0:
            return 1.0
        written = sum(e.n_tokens for e in self._entries.values())
        return written / alloc

    # -- lifecycle ---------------------------------------------------------
    def allocate(self, rid: str, n_tokens: int) -> List[int]:
        """Reserve blocks for ``n_tokens`` slots; returns the block table.

        The scheduler reserves a request's *worst case* (prompt + max new
        tokens, clamped to the ring capacity) at admission, so no later
        step can run out mid-stream — capacity-based admission gating
        with no preemption path needed."""
        if rid in self._entries:
            raise KeyError(f"request {rid!r} already has an allocation")
        need = blocks_for(n_tokens, self.block_size)
        if need > len(self._free):
            raise BlockCapacityError(
                f"need {need} blocks, only {len(self._free)} free")
        table = self._free[:need]
        del self._free[:need]
        self._entries[rid] = _Entry(table=table, n_tokens=0)
        return list(table)

    def extend(self, rid: str, n_tokens: int) -> List[int]:
        """Grow ``rid``'s table by blocks for ``n_tokens`` more slots."""
        e = self._require(rid)
        need = blocks_for(n_tokens, self.block_size)
        if need > len(self._free):
            raise BlockCapacityError(
                f"need {need} blocks, only {len(self._free)} free")
        new = self._free[:need]
        del self._free[:need]
        e.table.extend(new)
        return list(new)

    def append_tokens(self, rid: str, n: int = 1) -> None:
        """Account ``n`` written token slots (wraps at table capacity like
        the ring buffer it mirrors)."""
        e = self._require(rid)
        cap = len(e.table) * self.block_size
        e.n_tokens = min(e.n_tokens + int(n), cap)

    def free(self, rid: str) -> int:
        """Release every block of ``rid``; returns how many were freed."""
        e = self._entries.pop(rid, None)
        if e is None:
            raise KeyError(f"request {rid!r} has no allocation (double free?)")
        self._free.extend(e.table)
        self._free.sort()
        return len(e.table)

    # -- views -------------------------------------------------------------
    def block_table(self, rid: str) -> List[int]:
        return list(self._require(rid).table)

    def n_tokens(self, rid: str) -> int:
        return self._require(rid).n_tokens

    def requests(self) -> List[str]:
        return list(self._entries)

    def owner_of(self, block_id: int) -> Optional[str]:
        for rid, e in self._entries.items():
            if block_id in e.table:
                return rid
        return None

    def fragmentation(self) -> float:
        """Mean relative spread of live tables (0 = every table contiguous).

        The spread of a table occupying id range [lo, hi] with k blocks is
        (hi - lo + 1 - k) / k: extra id-space the physical gather must
        stride over."""
        if not self._entries:
            return 0.0
        spreads = []
        for e in self._entries.values():
            if not e.table:
                continue
            k = len(e.table)
            spreads.append((max(e.table) - min(e.table) + 1 - k) / k)
        return sum(spreads) / len(spreads) if spreads else 0.0

    def defrag(self) -> Dict[int, int]:
        """Relabel live blocks onto the lowest ids, tables kept in order.

        Returns the {old_id: new_id} map the physical pool must replay
        (one gather per moved block).  Deterministic: requests are
        processed in insertion order."""
        mapping: Dict[int, int] = {}
        nxt = 0
        for e in self._entries.values():
            new_table = []
            for b in e.table:
                mapping[b] = nxt
                new_table.append(nxt)
                nxt += 1
            e.table = new_table
        self._free = list(range(nxt, self.num_blocks))
        return {o: n for o, n in mapping.items() if o != n}

    def check(self) -> None:
        """Assert the pool invariants (cheap; tests call it after every op)."""
        seen = set(self._free)
        if len(seen) != len(self._free):
            raise AssertionError("duplicate ids in free list")
        total = len(self._free)
        for rid, e in self._entries.items():
            for b in e.table:
                if b in seen:
                    raise AssertionError(f"block {b} owned twice ({rid})")
                seen.add(b)
            total += len(e.table)
        if total != self.num_blocks or seen != set(range(self.num_blocks)):
            raise AssertionError("pool accounting does not cover all blocks")

    def _require(self, rid: str) -> _Entry:
        e = self._entries.get(rid)
        if e is None:
            raise KeyError(f"unknown request {rid!r}")
        return e
