"""Per-request serving cost predictions — the scheduler's brain.

Exactly the paper's §IV recipe applied to a serve step instead of a
factorization step: walk what the step executes and charge each part to
the machine description.  A step is (i) optional chunked-prefill work —
dense matmuls over the chunk plus attention against the cache so far —
and (ii) one batched decode — dense matmuls over one token per live
request plus attention against each request's context — and the step
time is the roofline max of the flop term (at the efficiency the
blocking earns, paper Fig. 1 curves) and the HBM traffic term (weights
read once per step *shared by the whole batch* — the economy of scale
continuous batching exists to exploit), plus a fixed per-step dispatch
overhead.

Calibration mirrors PR 4: predictions carry multiplicative phase scales
plus the overhead constant (:class:`ServeScales`), re-fitted from
telemetry ``serve_step`` records by :func:`refit_serving` and cached per
``machine.fingerprint()`` — a telemetry refit or drift-detected
``revision`` bump re-keys the fingerprint, so stale scheduler cost
tables are invalidated exactly the way stale tuner plans are.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from ..configs.base import ModelConfig
from ..core.machine import CPU_HOST, Machine
from ..core.perfmodel import HOPPER_EFFICIENCY, TPU_EFFICIENCY

_ITEMSIZE = {"bfloat16": 2, "float16": 2, "float32": 4}

#: seed per-step dispatch overhead [s] per machine name (refit_serving
#: replaces it with the measured intercept).
_DEFAULT_OVERHEAD = {"cpu-host": 3e-4, "tpu-v5e": 5e-5}


@dataclasses.dataclass(frozen=True)
class ServeScales:
    """Calibration state of a serving cost model (never mutated in place)."""

    prefill_scale: float = 1.0
    decode_scale: float = 1.0
    overhead_s: float = 1e-4

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ServeStepCost:
    """Predicted composition of one scheduler step."""

    prefill_s: float
    decode_s: float
    flops: float
    hbm_bytes: float

    @property
    def total_s(self) -> float:
        return self.prefill_s + self.decode_s

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["total_s"] = self.total_s
        return d


def _efficiency_for(machine: Machine):
    return TPU_EFFICIENCY if machine.name.startswith("tpu") \
        else HOPPER_EFFICIENCY


class ServeCostModel:
    """Prefill/decode step-time predictions for one (model cfg, machine)."""

    def __init__(self, cfg: ModelConfig, machine: Machine = CPU_HOST,
                 scales: Optional[ServeScales] = None):
        self.cfg = cfg
        self.machine = machine
        self.efficiency = _efficiency_for(machine)
        self.scales = scales or ServeScales(
            overhead_s=_DEFAULT_OVERHEAD.get(machine.name, 1e-4))
        self._itemsize = _ITEMSIZE.get(cfg.dtype, 4)
        self._params = float(cfg.active_param_count())
        self._param_bytes = self._params * self._itemsize
        kv_hd = cfg.n_kv_heads * cfg.hd
        self._kv_bytes_per_tok = 2.0 * cfg.n_layers * kv_hd * self._itemsize

    # -- raw work summaries (no scales) -------------------------------------
    def _ctx(self, c: float) -> float:
        w = self.cfg.sliding_window
        return min(float(c), float(w)) if w else float(c)

    def _work(self, tokens: float, ctx_avg: float) -> Tuple[float, float]:
        """(flops, kv_bytes) of running ``tokens`` positions with mean
        attended context ``ctx_avg`` for one request."""
        cfg = self.cfg
        dense = 2.0 * self._params * tokens
        attn = 4.0 * cfg.n_layers * cfg.d_model * tokens * self._ctx(ctx_avg)
        # KV traffic: read the attended cache once, write the new tokens
        kv_bytes = self._kv_bytes_per_tok * (self._ctx(ctx_avg) + tokens)
        return dense + attn, kv_bytes

    def _roofline(self, flops: float, bytes_: float, block: float) -> float:
        # the efficiency argument is the *skinny* GEMM dimension of the
        # step: token rows beyond d_model earn nothing (the weight matrix
        # side already limits the blocking), so a >= d_model prefill
        # chunk runs at whole-prompt efficiency — chunking costs only
        # the per-step overhead, which is what makes budget-bounded
        # interleaving competitive with monolithic prefill
        m = self.machine
        eff = self.efficiency["dgemm"](
            max(min(block, float(self.cfg.d_model)), 1.0))
        t_flop = flops / (m.peak_flops_per_unit * eff)
        t_mem = bytes_ / (m.hbm_bandwidth or m.contention_free_bandwidth())
        return max(t_flop, t_mem)

    # -- step phases ---------------------------------------------------------
    def prefill_step(self, chunks: Sequence[Tuple[int, int]]) -> ServeStepCost:
        """One prefill micro-step: ``chunks`` is [(tokens, ctx0), ...] per
        participating request (ctx0 = cache length before the chunk)."""
        if not chunks:
            return ServeStepCost(0.0, 0.0, 0.0, 0.0)
        flops = 0.0
        bytes_ = self._param_bytes          # weights read once, shared
        widest = 1.0
        for t, c0 in chunks:
            f, kv = self._work(float(t), c0 + (float(t) + 1.0) / 2.0)
            flops += f
            bytes_ += kv
            widest = max(widest, float(t))
        t = self._roofline(flops, bytes_, widest) * self.scales.prefill_scale
        return ServeStepCost(t + self.scales.overhead_s, 0.0, flops, bytes_)

    def decode_step(self, contexts: Sequence[int]) -> ServeStepCost:
        """One batched decode micro-step over live contexts (one new token
        per request; the weight read is amortized over the whole batch)."""
        if len(contexts) == 0:
            return ServeStepCost(0.0, 0.0, 0.0, 0.0)
        flops = 0.0
        bytes_ = self._param_bytes
        for c in contexts:
            f, kv = self._work(1.0, float(c))
            flops += f
            bytes_ += kv
        t = self._roofline(flops, bytes_, float(len(contexts))) \
            * self.scales.decode_scale
        return ServeStepCost(0.0, t + self.scales.overhead_s, flops, bytes_)

    def predict_step(self, prefill: Sequence[Tuple[int, int]],
                     decode_contexts: Sequence[int]) -> ServeStepCost:
        """Full scheduler step = prefill micro-step + decode micro-step."""
        pf = self.prefill_step(prefill)
        dc = self.decode_step(decode_contexts)
        return ServeStepCost(pf.prefill_s, dc.decode_s,
                             pf.flops + dc.flops, pf.hbm_bytes + dc.hbm_bytes)

    # -- whole-request aggregates (policy ordering / SLO math) ---------------
    def request_prefill_cost(self, prompt_len: int,
                             chunk: Optional[int] = None) -> float:
        """Predicted seconds to prefill a whole prompt, chunked."""
        chunk = int(chunk or prompt_len) or 1
        total, done = 0.0, 0
        while done < prompt_len:
            t = min(chunk, prompt_len - done)
            total += self.prefill_step([(t, done)]).prefill_s
            done += t
        return total

    def request_decode_cost(self, prompt_len: int, new_tokens: int,
                            batch: int = 1) -> float:
        """Predicted seconds of decode for one request riding in a batch of
        ``batch`` peers (its share of each step)."""
        if new_tokens <= 1:
            return 0.0
        total = 0.0
        for i in range(new_tokens - 1):
            step = self.decode_step([prompt_len + 1 + i] * max(batch, 1))
            total += step.decode_s / max(batch, 1)
        return total

    def with_scales(self, scales: ServeScales) -> "ServeCostModel":
        return ServeCostModel(self.cfg, self.machine, scales)


def predict_serve_step(cfg: ModelConfig, *,
                       prefill: Sequence[Tuple[int, int]] = (),
                       decode_contexts: Sequence[int] = (),
                       machine: Machine = CPU_HOST,
                       scales: Optional[ServeScales] = None) -> ServeStepCost:
    """One-shot API: predicted cost of a serve step composed of chunked
    prefill entries ``(tokens, ctx0)`` and a decode batch at the given
    per-request context lengths."""
    return ServeCostModel(cfg, machine, scales).predict_step(
        prefill, decode_contexts)


# ---------------------------------------------------------------------------
# fingerprint-keyed cost-table cache (the scheduler's analog of the tuner
# plan cache): refits install fitted scales under the machine fingerprint,
# drift's revision bump re-keys the fingerprint and so starts clean.
# ---------------------------------------------------------------------------

_CACHE: Dict[tuple, ServeCostModel] = {}
_CACHE_LOCK = threading.Lock()


def _cfg_key(cfg: ModelConfig) -> tuple:
    return (cfg.name, cfg.n_layers, cfg.d_model, cfg.n_heads,
            cfg.n_kv_heads, cfg.vocab_size, cfg.dtype)


def cost_model_for(cfg: ModelConfig,
                   machine: Machine = CPU_HOST) -> ServeCostModel:
    """The cached cost model for (cfg, machine-at-current-revision)."""
    key = (machine.fingerprint(), _cfg_key(cfg))
    with _CACHE_LOCK:
        cm = _CACHE.get(key)
        if cm is None:
            cm = ServeCostModel(cfg, machine)
            _CACHE[key] = cm
        return cm


def install_scales(cfg: ModelConfig, machine: Machine,
                   scales: ServeScales) -> ServeCostModel:
    """Install refit scales for (cfg, machine) under the current
    fingerprint; returns the new cached model."""
    key = (machine.fingerprint(), _cfg_key(cfg))
    cm = ServeCostModel(cfg, machine, scales)
    with _CACHE_LOCK:
        _CACHE[key] = cm
    return cm


def cost_cache_keys() -> List[tuple]:
    with _CACHE_LOCK:
        return list(_CACHE)


# ---------------------------------------------------------------------------
# refit from telemetry serve_step records (PR-4 style, serving tier)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServingRefit:
    scales: ServeScales
    n_rows: int
    mean_rel_err_before: float
    mean_rel_err_after: float

    def to_dict(self) -> dict:
        return {"scales": self.scales.to_dict(), "n_rows": self.n_rows,
                "mean_rel_err_before": self.mean_rel_err_before,
                "mean_rel_err_after": self.mean_rel_err_after}


def _phase_rows(records: Iterable, phase: str) -> List[Tuple[float, float]]:
    rows = []
    for r in records:
        if getattr(r, "kind", "") != "serve_step":
            continue
        meas = r.phases.get(phase)
        pred = (r.predicted or {}).get(phase)
        if meas and pred and meas > 0 and pred > 0:
            rows.append((float(pred), float(meas)))
    return rows


def _fit_affine(rows: List[Tuple[float, float]]) -> Tuple[float, float]:
    """measured ~= a * predicted + b, robust to a few outliers: try the
    plain ratio (a = exp(median log-ratio), b = 0) and the ridge affine
    fit, keep whichever has lower mean relative error."""
    import numpy as np

    from ..core.fitting import ridge_lstsq

    pred = np.array([p for p, _ in rows])
    meas = np.array([m for _, m in rows])
    a_ratio = float(np.exp(np.median(np.log(meas / pred))))
    cands = [(a_ratio, 0.0)]
    if len(rows) >= 8 and float(pred.std()) > 1e-12 * float(pred.mean()):
        A = np.stack([pred, np.ones_like(pred)], axis=1)
        a, b = ridge_lstsq(A, meas, lam=1e-12)
        if a > 0:
            cands.append((float(a), float(max(b, 0.0))))

    def err(ab):
        a, b = ab
        return float(np.mean(np.abs(a * pred + b - meas) / meas))

    return min(cands, key=err)


def refit_serving(records: Iterable, cost_model: ServeCostModel,
                  *, install: bool = False) -> ServingRefit:
    """Fit per-phase scales from recorded (predicted, measured) serve
    steps and return the calibrated model state.

    The fit composes with whatever scales produced the recorded
    predictions: measured ~= a * pred + b updates ``scale' = a * scale``
    and ``overhead' = a * overhead + b`` per phase (the overhead constant
    is shared; the decode fit wins it since decode steps dominate).
    ``install=True`` also caches the result under the current machine
    fingerprint (:func:`install_scales`)."""
    import numpy as np

    recs = list(records)
    old = cost_model.scales
    fits = {}
    all_rows: List[Tuple[float, float]] = []
    for phase in ("prefill", "decode"):
        rows = _phase_rows(recs, phase)
        all_rows.extend(rows)
        if len(rows) >= 3:
            fits[phase] = _fit_affine(rows)
    if not all_rows:
        return ServingRefit(old, 0, float("nan"), float("nan"))

    a_pf, b_pf = fits.get("prefill", (1.0, 0.0))
    a_dc, b_dc = fits.get("decode", fits.get("prefill", (1.0, 0.0)))
    new = ServeScales(
        prefill_scale=old.prefill_scale * a_pf,
        decode_scale=old.decode_scale * a_dc,
        overhead_s=max(a_dc * old.overhead_s + b_dc, 0.0))

    pred = np.array([p for p, _ in all_rows])
    meas = np.array([m for _, m in all_rows])
    before = float(np.mean(np.abs(pred - meas) / meas))

    def after_err(phase, a, b):
        rows = _phase_rows(recs, phase)
        if not rows:
            return []
        p = np.array([x for x, _ in rows])
        m = np.array([x for _, x in rows])
        return list(np.abs(a * p + b - m) / m)

    errs = after_err("prefill", a_pf, b_pf) + after_err("decode", a_dc, b_dc)
    after = float(np.mean(errs)) if errs else before
    if install:
        install_scales(cost_model.cfg, cost_model.machine, new)
    return ServingRefit(new, len(all_rows), before, after)
