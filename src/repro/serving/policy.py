"""Batch-composition policies: what runs in the next scheduler step.

Two policies share one interface (``admit`` + ``compose``):

* :class:`FIFOPolicy` — the baseline every serving paper measures against
  (rtp-llm's FIFOScheduler lifecycle): requests are admitted strictly in
  arrival order, a pending prefill is run *whole* and ahead of decode, so
  a long prompt head-of-line-blocks both the queue behind it and the
  decode streams already running.

* :class:`ModelGuidedPolicy` — the paper's thesis applied online: the
  serving cost model (:mod:`repro.serving.cost`) predicts what every
  candidate composition costs, and the policy (i) admits the cheapest
  predicted prefills first (aged so nothing starves), (ii) always keeps
  the decode batch running, and (iii) interleaves prefill *chunks* sized
  by ``Tuner.serve_chunk`` so the predicted step time stays inside the
  step budget — SLO-aware packing instead of arrival order.

Policies are deliberately stateful-but-tiny objects; the scheduler hands
them its live view (waiting queue, active set, block pool, cost model)
each step.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from .cost import ServeCostModel
from .kvblocks import BlockManager


@dataclasses.dataclass
class StepPlan:
    """One scheduler step: prefill chunk entries + the decode batch."""

    prefill: List[Tuple[str, int]]          # (rid, tokens this step)
    decode: List[str]                       # rids decoding one token

    @property
    def empty(self) -> bool:
        return not self.prefill and not self.decode


class Policy:
    """Interface; see module docstring."""

    name = "base"

    def admit(self, waiting: List, blocks: BlockManager,
              cost: ServeCostModel, *, clock: float,
              active: int, max_active: int) -> List:
        """Subset of ``waiting`` (scheduler RequestStates, arrival order)
        to admit now.  The scheduler verifies capacity again at
        allocation time; policies should only propose what fits."""
        raise NotImplementedError

    def compose(self, active: List, cost: ServeCostModel, *,
                max_batch: int) -> StepPlan:
        """The next step over the active set (RequestStates)."""
        raise NotImplementedError


class FIFOPolicy(Policy):
    """Arrival order; whole-prompt prefill ahead of decode."""

    name = "fifo"

    def admit(self, waiting, blocks, cost, *, clock, active, max_active):
        out = []
        free = blocks.free_blocks
        for r in sorted(waiting, key=lambda r: (r.arrival_s, r.rid)):
            if active + len(out) >= max_active:
                break
            need = r.blocks_needed(blocks.block_size)
            if need > free:
                break                      # strict FIFO: no bypass
            free -= need
            out.append(r)
        return out

    def compose(self, active, cost, *, max_batch):
        pending = [r for r in active if r.prefill_remaining > 0]
        if pending:
            r = min(pending, key=lambda r: (r.admitted_s, r.rid))
            return StepPlan(prefill=[(r.rid, r.prefill_remaining)], decode=[])
        ready = sorted((r for r in active if r.decode_ready),
                       key=lambda r: (r.admitted_s, r.rid))[:max_batch]
        return StepPlan(prefill=[], decode=[r.rid for r in ready])


class ModelGuidedPolicy(Policy):
    """Cost-model-driven SLO-aware packing (see module docstring).

    ``step_budget_s`` bounds the *predicted* step time; ``aging_s`` is
    the wait after which an expensive prefill outranks a cheap newcomer
    (halves its effective cost per multiple).  Prefill can never starve:
    each step grants it at least a budget floor proportional to the
    decode load (so prefill throughput tracks decode throughput even
    when the configured budget is too tight), and failing even that, one
    minimum-granularity chunk is forced through per step."""

    name = "model"

    def __init__(self, step_budget_s: float = 0.05, *, aging_s: float = 1.0,
                 tuner=None):
        self.step_budget_s = float(step_budget_s)
        self.aging_s = float(aging_s)
        self._tuner = tuner

    def _effective_cost(self, r, cost: ServeCostModel, clock: float) -> float:
        c = cost.request_prefill_cost(r.prompt_len)
        age = max(clock - r.arrival_s, 0.0) / self.aging_s
        return c / (1.0 + age)

    def admit(self, waiting, blocks, cost, *, clock, active, max_active):
        ranked = sorted(
            waiting,
            key=lambda r: (self._effective_cost(r, cost, clock),
                           r.arrival_s, r.rid))
        out, free = [], blocks.free_blocks
        for r in ranked:
            if active + len(out) >= max_active:
                break
            need = r.blocks_needed(blocks.block_size)
            if need <= free:               # cheapest-first, bypass allowed
                free -= need
                out.append(r)
        return out

    def compose(self, active, cost, *, max_batch):
        ready = sorted((r for r in active if r.decode_ready),
                       key=lambda r: (r.admitted_s, r.rid))[:max_batch]
        decode = [r.rid for r in ready]
        decode_ctx = [r.context_len for r in ready]
        pending = sorted((r for r in active if r.prefill_remaining > 0),
                         key=lambda r: (cost.request_prefill_cost(
                             r.prefill_remaining), r.admitted_s, r.rid))
        if not decode:
            # no TPOT to protect: run the cheapest pending prompt whole,
            # at full blocking efficiency (chunking would only cost
            # throughput here)
            if not pending:
                return StepPlan(prefill=[], decode=[])
            r = pending[0]
            return StepPlan(prefill=[(r.rid, r.prefill_remaining)], decode=[])

        decode_s = cost.decode_step(decode_ctx).decode_s
        # progress floor: prefill always earns at least the decode
        # micro-step's own time, whatever the configured budget says
        # (equal-share interleaving; prefill can never starve)
        budget = max(self.step_budget_s - decode_s, decode_s)
        prefill: List[Tuple[str, int]] = []
        chunks_ctx: List[Tuple[int, int]] = []
        for r in pending:
            n = self._chunk_within(cost, r, chunks_ctx, budget)
            if n <= 0:
                continue
            prefill.append((r.rid, n))
            chunks_ctx.append((n, r.prefill_pos))
            budget -= (cost.prefill_step(chunks_ctx).prefill_s
                       - cost.prefill_step(chunks_ctx[:-1]).prefill_s)

        if pending and not prefill:
            # last resort: one minimum chunk for the cheapest pending
            # prefill, budget or not — starvation is never an option
            r = pending[0]
            g = self._granularity(r)
            prefill = [(r.rid, min(g, r.prefill_remaining))]
        return StepPlan(prefill=prefill, decode=decode)

    # -- chunk sizing via the tuner -----------------------------------------
    def _granularity(self, r) -> int:
        if self._tuner is None:
            from ..tuner import default_tuner
            self._tuner = default_tuner()
        return max(1, self._tuner.prefill_chunk(r.prompt_len))

    def _chunk_within(self, cost, r, other_chunks, budget_s) -> int:
        if budget_s <= 0:
            return 0
        if self._tuner is None:
            from ..tuner import default_tuner
            self._tuner = default_tuner()
        base = cost.prefill_step(other_chunks).prefill_s if other_chunks \
            else 0.0
        return self._tuner.serve_chunk(
            r.prefill_remaining, ctx0=r.prefill_pos, cost=cost,
            budget_s=budget_s, base_prefill=other_chunks,
            base_prefill_s=base, granularity=self._granularity(r))


class DegradationController:
    """Graceful degradation: shrink the prefill step budget under SLO burn.

    Wraps a policy that exposes ``step_budget_s`` (the model-guided
    policy's predicted-step-time bound).  Each scheduler step hands the
    controller the current SLO burn-rate alerts
    (:meth:`repro.obs.slo.SLOWatcher.check`); while any alert fires the
    budget shrinks multiplicatively (``shrink`` per step, floored at
    ``floor_frac`` of the configured budget), trading prefill throughput
    for decode latency exactly where the burn is.  When the alerts clear
    the budget recovers geometrically (``recover`` per step) back to the
    base — no oscillating bang-bang, no permanent penalty.

    For policies without a step budget (e.g. FIFO) the controller is a
    recording no-op: ``update`` returns None and changes nothing.
    """

    def __init__(self, policy: Policy, *, floor_frac: float = 0.25,
                 shrink: float = 0.5, recover: float = 1.2):
        if not 0.0 < floor_frac <= 1.0:
            raise ValueError("floor_frac must be in (0, 1]")
        if not 0.0 < shrink < 1.0:
            raise ValueError("shrink must be in (0, 1)")
        if recover <= 1.0:
            raise ValueError("recover must be > 1")
        self.policy = policy
        self.floor_frac = float(floor_frac)
        self.shrink = float(shrink)
        self.recover = float(recover)
        self.base_budget_s: Optional[float] = None
        budget = getattr(policy, "step_budget_s", None)
        if budget is not None:
            self.base_budget_s = float(budget)
        self.events: List[dict] = []

    @property
    def degraded(self) -> bool:
        cur = getattr(self.policy, "step_budget_s", None)
        return (self.base_budget_s is not None and cur is not None
                and cur < self.base_budget_s)

    def update(self, alerts) -> Optional[float]:
        """Apply one step of shrink/recover; returns the current budget
        (None when the policy has no step budget to govern)."""
        if self.base_budget_s is None:
            return None
        cur = float(self.policy.step_budget_s)
        if alerts:
            new = max(cur * self.shrink, self.base_budget_s * self.floor_frac)
        else:
            new = min(cur * self.recover, self.base_budget_s)
        if new != cur:
            self.events.append({
                "action": "shrink" if new < cur else "recover",
                "budget_s": new,
                "alerts": [getattr(a, "rule", str(a)) for a in alerts or ()]})
            self.policy.step_budget_s = new
        return new


def make_policy(name: str, *, step_budget_s: Optional[float] = None,
                tuner=None) -> Policy:
    """Factory: ``"fifo"`` or ``"model"``."""
    if name == "fifo":
        return FIFOPolicy()
    if name == "model":
        return ModelGuidedPolicy(step_budget_s if step_budget_s is not None
                                 else 0.05, tuner=tuner)
    raise ValueError(f"unknown policy {name!r} (fifo | model)")
