"""Single-call generation facade over the continuous-batching scheduler.

``Engine.generate`` is now a thin wrapper: each prompt row becomes one
:class:`~.scheduler.Request`, the batch is submitted to a
:class:`~.scheduler.Scheduler` over a :class:`~.scheduler.ModelBackend`
(per-request caches, vmapped batched decode), and the scheduler's
admission/compose/evict loop runs it to completion.  One code path
serves both the one-shot API and the streaming trace-replay harness, so
the single-request semantics the tests pin down (greedy determinism,
chunked-prefill equivalence, ring-buffer safety) are exactly the
semantics of the continuous-batching engine.

Generation for a request ends at ``max_new_tokens`` or earlier on an
EOS / stop token (``ServeConfig.eos_id`` / ``stop_ids``); early-stopped
rows are right-padded so the output shape stays ``(B, S + max_new)``.
No decode step runs after a request's last token — the scheduler evicts
on completion instead of stepping once more and discarding the logits.

With telemetry recording on (``REPRO_TELEMETRY=1`` /
``repro.telemetry.enable()``) every ``generate`` call emits one measured
run — prefill and decode as separate phases, blocked to completion — and
the scheduler additionally emits one ``serve_step`` record per step with
the cost model's prediction attached, feeding the refit loop."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0           # 0 = greedy
    max_cache_len: int = 4096
    prefill_chunk: Optional[int] = None  # None: ask the tuner; 1: per-token
    eos_id: Optional[int] = None       # generation stops when sampled
    stop_ids: Tuple[int, ...] = ()     # additional per-request stop tokens
    pad_id: Optional[int] = None       # fill for early-stopped rows
                                       # (default: eos_id, else 0)


def make_serve_step(model: Model):
    """The jittable decode step: (params, tokens, caches, memory) ->
    (logits, new_caches).  tokens is (B, 1) for generation or (B, chunk)
    during chunked prefill."""

    def serve_step(params, tokens, caches, memory=None):
        return model.decode_step(params, tokens, caches, memory)

    return serve_step


class Engine:
    def __init__(self, model: Model, params,
                 cfg: Optional[ServeConfig] = None):
        self.model = model
        self.params = params
        self.cfg = cfg if cfg is not None else ServeConfig()
        self._step = jax.jit(make_serve_step(model))
        self._backend = None           # built lazily, reused across calls

    def _prefill_chunk(self, seq_len: int) -> int:
        # architecture gate first: recurrent decode paths and sliding-window
        # ring buffers are strictly one-token, whatever the config asks for
        if not self.model.supports_chunked_prefill:
            return 1
        if self.cfg.prefill_chunk is not None:
            return max(1, self.cfg.prefill_chunk)
        from ..tuner import default_tuner
        return default_tuner().prefill_chunk(seq_len)

    def _timer(self, seq_len: int):
        """A telemetry PhaseTimer tagged for this engine, or None when
        recording is off (the only cost paid on the fast path)."""
        from .. import telemetry
        if not telemetry.enabled():
            return None
        from ..tuner.plan import machine_fingerprint
        from ..tuner.registry import DEFAULT_REGISTRY, machine_for_platform
        devs = jax.devices()
        platform = devs[0].platform
        name = machine_for_platform(platform)
        try:
            profile = DEFAULT_REGISTRY.machine(name).machine
        except KeyError:
            profile = name
        fp = machine_fingerprint(profile, platform,
                                 getattr(devs[0], "device_kind", platform),
                                 len(devs))
        arch = getattr(getattr(self.model, "cfg", None), "name",
                       type(self.model).__name__)
        return telemetry.PhaseTimer(
            "serve", variant=str(arch), n=seq_len,
            p=len(devs), machine=name, fingerprint=fp, kind="serve",
            meta={"max_new_tokens": self.cfg.max_new_tokens})

    def _make_scheduler(self, batch: int, phase_timer):
        from ..core.machine import CPU_HOST
        from .cost import cost_model_for
        from .policy import FIFOPolicy
        from .scheduler import ModelBackend, Scheduler, SchedulerConfig

        if self._backend is None:
            self._backend = ModelBackend(
                self.model, self.params,
                max_cache_len=self.cfg.max_cache_len,
                prefill_chunk=self.cfg.prefill_chunk, step=self._step)
        cost = cost_model_for(self.model.cfg, CPU_HOST)
        scfg = SchedulerConfig(max_cache_len=self.cfg.max_cache_len,
                               max_batch=max(batch, 1),
                               max_active=max(batch, 1))
        return Scheduler(self._backend, cost, scfg, policy=FIFOPolicy(),
                         phase_timer=phase_timer)

    def generate(self, prompts: jax.Array, *,
                 batch_inputs: Optional[Dict[str, Any]] = None,
                 seed: int = 0) -> jax.Array:
        """prompts: (B, S) int32.  Returns (B, S + max_new) tokens;
        rows that hit an EOS/stop token early are padded to shape."""
        from .scheduler import Request

        b, s = prompts.shape
        cfg = self.cfg
        if cfg.max_new_tokens <= 0:
            return prompts
        pt = self._timer(s)
        memory = None
        if batch_inputs:
            memory = self.model.encode_memory(self.params, batch_inputs)

        sched = self._make_scheduler(b, pt)
        rids = []
        for i in range(b):
            rids.append(sched.submit(Request(
                rid=f"g{i}", prompt=prompts[i:i + 1],
                max_new_tokens=cfg.max_new_tokens,
                eos_id=cfg.eos_id, stop_ids=tuple(cfg.stop_ids),
                memory=None if memory is None else memory[i:i + 1],
                temperature=cfg.temperature, seed=seed + i)))
        sched.run()
        if pt is not None:
            pt.emit()

        pad = cfg.pad_id if cfg.pad_id is not None \
            else (cfg.eos_id if cfg.eos_id is not None else 0)
        rows = []
        for rid in rids:
            toks = sched.finished[rid].out
            gen = jnp.concatenate(
                [jnp.asarray(t, jnp.int32).reshape(1, 1) for t in toks],
                axis=1)
            if gen.shape[1] < cfg.max_new_tokens:
                gen = jnp.pad(gen,
                              ((0, 0), (0, cfg.max_new_tokens - gen.shape[1])),
                              constant_values=pad)
            rows.append(gen)
        return jnp.concatenate([prompts, jnp.concatenate(rows, axis=0)],
                               axis=1)
