"""Batched decode engine: prompt ingestion + token-by-token generation over
the uniform Model facade (KV caches for attention archs, recurrent state
for SSM/hybrid).  Used by the serving example and the decode-shape
benchmark; the dry-run lowers ``serve_step`` (one new token against a full
cache) directly.

With telemetry recording on (``REPRO_TELEMETRY=1`` /
``repro.telemetry.enable()``) every ``generate`` call emits one measured
run — prefill and decode as separate phases, blocked to completion — so
the serving path feeds the same measured-run loop as linalg dispatch."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0           # 0 = greedy
    max_cache_len: int = 4096
    prefill_chunk: Optional[int] = None  # None: ask the tuner; 1: per-token


def make_serve_step(model: Model):
    """The jittable decode step: (params, tokens, caches, memory) ->
    (logits, new_caches).  tokens is (B, 1) for generation or (B, chunk)
    during chunked prefill."""

    def serve_step(params, tokens, caches, memory=None):
        return model.decode_step(params, tokens, caches, memory)

    return serve_step


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._step = jax.jit(make_serve_step(model))

    def _prefill_chunk(self, seq_len: int) -> int:
        # architecture gate first: recurrent decode paths and sliding-window
        # ring buffers are strictly one-token, whatever the config asks for
        if not self.model.supports_chunked_prefill:
            return 1
        if self.cfg.prefill_chunk is not None:
            return max(1, self.cfg.prefill_chunk)
        from ..tuner import default_tuner
        return default_tuner().prefill_chunk(seq_len)

    def _ingest(self, prompts: jax.Array, caches, memory):
        """Cache-filling prefill: chunked when the architecture allows it
        (two compiled shapes total — the chunk and the 1-token remainder),
        token-by-token otherwise.

        A chunk must never touch the KV ring-buffer boundary
        (attention_decode's precondition): chunked steps stop at
        ``max_cache_len`` and the tail falls back to single-token steps,
        whose ring-wrap semantics are well defined."""
        b, s = prompts.shape
        chunk = self._prefill_chunk(s)
        limit = self.cfg.max_cache_len
        logits = None
        i = 0
        while chunk > 1 and s - i >= chunk and i + chunk <= limit:
            logits, caches = self._step(self.params, prompts[:, i:i + chunk],
                                        caches, memory)
            i += chunk
        for j in range(i, s):
            logits, caches = self._step(self.params, prompts[:, j:j + 1],
                                        caches, memory)
        return logits, caches

    def _timer(self, seq_len: int):
        """A telemetry PhaseTimer tagged for this engine, or None when
        recording is off (the only cost paid on the fast path)."""
        from .. import telemetry
        if not telemetry.enabled():
            return None
        from ..tuner.plan import machine_fingerprint
        from ..tuner.registry import DEFAULT_REGISTRY, machine_for_platform
        devs = jax.devices()
        platform = devs[0].platform
        name = machine_for_platform(platform)
        try:
            profile = DEFAULT_REGISTRY.machine(name).machine
        except KeyError:
            profile = name
        fp = machine_fingerprint(profile, platform,
                                 getattr(devs[0], "device_kind", platform),
                                 len(devs))
        arch = getattr(getattr(self.model, "cfg", None), "name",
                       type(self.model).__name__)
        return telemetry.PhaseTimer(
            "serve", variant=str(arch), n=seq_len,
            p=len(devs), machine=name, fingerprint=fp, kind="serve",
            meta={"max_new_tokens": self.cfg.max_new_tokens})

    def generate(self, prompts: jax.Array, *, batch_inputs: Optional[Dict[str, Any]] = None,
                 seed: int = 0) -> jax.Array:
        """prompts: (B, S) int32.  Returns (B, S + max_new) tokens."""
        b, s = prompts.shape
        pt = self._timer(s)
        memory = None
        if batch_inputs:
            memory = self.model.encode_memory(self.params, batch_inputs)
        caches = self.model.init_cache(b, self.cfg.max_cache_len)
        from ..telemetry import phase_scope
        with phase_scope(pt, "prefill"):
            logits, caches = self._ingest(prompts, caches, memory)
            if pt is not None:
                jax.block_until_ready(logits)
        key = jax.random.PRNGKey(seed)
        out = [prompts]
        tok = None
        with phase_scope(pt, "decode"):
            for t in range(self.cfg.max_new_tokens):
                if self.cfg.temperature > 0:
                    key, sub = jax.random.split(key)
                    tok = jax.random.categorical(
                        sub, logits[:, -1] / self.cfg.temperature)[:, None]
                else:
                    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                out.append(tok.astype(jnp.int32))
                logits, caches = self._step(self.params, tok.astype(jnp.int32),
                                            caches, memory)
            if pt is not None:
                jax.block_until_ready(logits)
        if pt is not None:
            pt.emit()
        return jnp.concatenate(out, axis=1)
