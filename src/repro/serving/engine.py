"""Batched decode engine: prompt ingestion + token-by-token generation over
the uniform Model facade (KV caches for attention archs, recurrent state
for SSM/hybrid).  Used by the serving example and the decode-shape
benchmark; the dry-run lowers ``serve_step`` (one new token against a full
cache) directly."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0           # 0 = greedy
    max_cache_len: int = 4096


def make_serve_step(model: Model):
    """The jittable one-token step: (params, tok, caches, memory) ->
    (next_tok_logits, new_caches)."""

    def serve_step(params, tokens, caches, memory=None):
        return model.decode_step(params, tokens, caches, memory)

    return serve_step


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._step = jax.jit(make_serve_step(model))

    def _ingest(self, prompts: jax.Array, caches, memory):
        """Feed prompt tokens one at a time (cache-filling prefill)."""
        b, s = prompts.shape
        logits = None
        for i in range(s):
            logits, caches = self._step(self.params, prompts[:, i:i + 1],
                                        caches, memory)
        return logits, caches

    def generate(self, prompts: jax.Array, *, batch_inputs: Optional[Dict[str, Any]] = None,
                 seed: int = 0) -> jax.Array:
        """prompts: (B, S) int32.  Returns (B, S + max_new) tokens."""
        b, s = prompts.shape
        memory = None
        if batch_inputs:
            memory = self.model.encode_memory(self.params, batch_inputs)
        caches = self.model.init_cache(b, self.cfg.max_cache_len)
        logits, caches = self._ingest(prompts, caches, memory)
        key = jax.random.PRNGKey(seed)
        out = [prompts]
        tok = None
        for t in range(self.cfg.max_new_tokens):
            if self.cfg.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / self.cfg.temperature)[:, None]
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(tok.astype(jnp.int32))
            logits, caches = self._step(self.params, tok.astype(jnp.int32),
                                        caches, memory)
        return jnp.concatenate(out, axis=1)
