"""Continuous-batching request scheduler driven by the serving cost model.

The scheduler owns the request lifecycle (rtp-llm's FIFOScheduler shape):
an admission queue gated by KV block capacity (:mod:`.kvblocks`), an
active set stepped by a batch-composition policy (:mod:`.policy`), and
per-step join/evict — new requests join the running batch between steps,
finished requests (EOS / stop token / max-tokens) are evicted and their
blocks freed immediately.  A step is a prefill micro-batch of chunked
prompt slices interleaved with one batched decode over every live stream.

Execution is pluggable:

* :class:`ModelBackend` runs the real jitted ``decode_step`` — each
  request owns its cache pytree (so join/evict never perturbs another
  stream's state; per-request token streams are bit-exact against a
  single-stream ``Engine.generate``), and the decode batch is executed
  with one vmapped step over the stacked caches, padded to power-of-two
  batch buckets so compile-shape count stays logarithmic.
* :class:`SimBackend` advances a virtual clock by the cost model's
  predicted step times instead of executing — the trace-replay harness
  (:mod:`.trace`) schedules tens of thousands of requests this way.

With telemetry on, every real step emits a ``kind="serve_step"`` record
carrying measured prefill/decode phases *and* the prediction it was
scheduled under, so the PR-4 residual/refit/drift loop covers the
scheduler path: ``telemetry.residuals.join`` self-joins these records,
``cost.refit_serving`` recalibrates the scales, and a drift-bumped
machine revision re-keys the cost table cache.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .cost import ServeCostModel, ServeStepCost, cost_model_for
from .kvblocks import BlockManager, blocks_for
from .policy import FIFOPolicy, Policy, StepPlan, make_policy
from .. import obs


def token_int(tok) -> int:
    """A generated token as a Python int, whether the backend produced a
    plain int (simulation) or a (1, 1) device array (real decode)."""
    if isinstance(tok, int):
        return tok
    import numpy as np
    return int(np.asarray(tok).reshape(-1)[0])


@dataclasses.dataclass
class Request:
    """One submission.  ``prompt`` is a (1, S) int32 array for real
    execution, or None for cost-model-driven simulation (then
    ``prompt_len`` stands alone).  ``max_new_tokens`` bounds generation;
    EOS/stop tokens end it early."""

    rid: str
    prompt: Optional[Any] = None
    prompt_len: int = 0
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    stop_ids: Tuple[int, ...] = ()
    arrival_s: Optional[float] = None      # None: "now" (scheduler clock)
    memory: Optional[Any] = None           # cross-attention row (1, M, D)
    temperature: float = 0.0
    seed: int = 0
    output_len: Optional[int] = None       # sim: tokens until synthetic EOS
    deadline_s: Optional[float] = None     # seconds after arrival; expired
    #                                        requests are evicted, not served

    def __post_init__(self):
        if self.prompt is not None and not self.prompt_len:
            self.prompt_len = int(self.prompt.shape[-1])


class RequestState:
    """Scheduler-internal view of one request's progress."""

    def __init__(self, req: Request, token_budget: int):
        self.req = req
        self.token_budget = token_budget   # KV slots reserved at admission
        self.prefill_pos = 0
        self.out: List[Any] = []           # generated tokens (ints or 0-d arrays)
        self.admitted_s: float = float("nan")
        self.first_token_s: Optional[float] = None
        self.finish_s: Optional[float] = None
        self.finish_reason: Optional[str] = None

    # -- identity ----------------------------------------------------------
    @property
    def rid(self) -> str:
        return self.req.rid

    @property
    def arrival_s(self) -> float:
        return self.req.arrival_s or 0.0

    @property
    def prompt_len(self) -> int:
        return self.req.prompt_len

    @property
    def deadline_at(self) -> Optional[float]:
        """Absolute clock time this request expires (None = no deadline)."""
        if self.req.deadline_s is None:
            return None
        return self.arrival_s + self.req.deadline_s

    # -- progress ----------------------------------------------------------
    @property
    def prefill_remaining(self) -> int:
        return self.req.prompt_len - self.prefill_pos

    @property
    def decode_ready(self) -> bool:
        return (self.prefill_remaining == 0 and self.finish_s is None
                and len(self.out) < self.req.max_new_tokens)

    @property
    def context_len(self) -> int:
        return self.prefill_pos + len(self.out)

    def blocks_needed(self, block_size: int) -> int:
        return blocks_for(self.token_budget, block_size)

    def finish(self, clock: float, reason: str) -> None:
        self.finish_s = clock
        self.finish_reason = reason

    def metrics(self) -> Dict[str, float]:
        ft = self.first_token_s if self.first_token_s is not None \
            else self.finish_s
        n = len(self.out)
        return {
            "rid": self.rid, "prompt_len": self.prompt_len, "n_out": n,
            "arrival_s": self.arrival_s, "admitted_s": self.admitted_s,
            "first_token_s": ft, "finish_s": self.finish_s,
            "ttft_s": (ft - self.arrival_s) if ft is not None else None,
            "tpot_s": ((self.finish_s - ft) / (n - 1)
                       if ft is not None and self.finish_s is not None
                       and n > 1 else 0.0),
            "finish_reason": self.finish_reason,
        }


@dataclasses.dataclass
class StepExec:
    """What a backend did for one step: the new token per touched request
    plus measured wall phases (real backend; zeros for simulation)."""

    tokens: Dict[str, Any]
    prefill_s: float = 0.0
    decode_s: float = 0.0


@dataclasses.dataclass
class StepReport:
    step: int
    clock: float
    plan: StepPlan
    predicted: ServeStepCost
    measured_prefill_s: float
    measured_decode_s: float
    admitted: List[str]
    finished: List[str]
    # post-step system state (queue/KV/batch composition — what the obs
    # gauges and the serving trace's counter tracks are drawn from)
    queue_depth: int = 0
    active: int = 0
    kv_blocks_used: int = 0
    kv_blocks_total: int = 0
    prefill_tokens: int = 0
    decode_batch: int = 0


@dataclasses.dataclass
class SchedulerConfig:
    max_cache_len: int = 4096        # ring capacity per request (tokens)
    block_size: int = 16             # KV block granularity (tokens)
    num_blocks: Optional[int] = None  # pool size; default fits max_active rings
    max_batch: int = 16              # decode batch cap
    max_active: Optional[int] = None  # admission cap; default max_batch
    max_queue: Optional[int] = None  # waiting-queue bound; overflow is shed
    #                                  by predicted cost (None = unbounded)

    def resolve(self) -> "SchedulerConfig":
        out = dataclasses.replace(self)
        if out.max_active is None:
            out.max_active = out.max_batch
        if out.num_blocks is None:
            out.num_blocks = out.max_active * blocks_for(
                out.max_cache_len, out.block_size)
        return out


class Scheduler:
    def __init__(self, backend, cost: ServeCostModel,
                 cfg: Optional[SchedulerConfig] = None, *,
                 policy: Optional[Policy] = None,
                 phase_timer=None, metrics=None,
                 ttft_slo_s: Optional[float] = None,
                 tpot_slo_s: Optional[float] = None,
                 slo_watcher=None, degradation=None):
        self.backend = backend
        self.cost = cost
        self.cfg = (cfg or SchedulerConfig()).resolve()
        self.blocks = BlockManager(self.cfg.num_blocks, self.cfg.block_size)
        self.policy = policy if policy is not None else FIFOPolicy()
        self.waiting: List[RequestState] = []
        self.active: Dict[str, RequestState] = {}
        self.finished: Dict[str, RequestState] = {}
        self.clock = 0.0
        self.steps = 0
        self._arrivals: List[Tuple[float, int, RequestState]] = []  # heap
        self._seq = itertools.count()
        self._outer_pt = phase_timer      # engine-level serve record
        # metrics: an explicit registry wins; else the obs default when
        # tracing is on; else nothing (zero overhead)
        self.metrics = metrics
        self.ttft_slo_s = ttft_slo_s
        self.tpot_slo_s = tpot_slo_s
        # optional obs.watch.SLOWatcher: per-evict good/bad outcomes plus
        # a burn-rate check per step, on the scheduler's own clock (the
        # simulated clock under trace replay)
        self.slo_watcher = slo_watcher
        # optional policy.DegradationController: burn-rate alerts shrink
        # the policy's prefill step budget, healthy checks recover it
        self.degradation = degradation
        self._mh: Dict[str, object] = {}  # cached metric handles

    # -- submission ---------------------------------------------------------
    def submit(self, req: Request) -> str:
        if (req.rid in self.active or req.rid in self.finished
                or any(w.rid == req.rid for w in self.waiting)):
            raise KeyError(f"duplicate request id {req.rid!r}")
        if req.arrival_s is None:
            req = dataclasses.replace(req, arrival_s=self.clock)
        budget = min(req.prompt_len + req.max_new_tokens,
                     self.cfg.max_cache_len)
        rs = RequestState(req, budget)
        if req.arrival_s <= self.clock:
            self.waiting.append(rs)
        else:
            heapq.heappush(self._arrivals,
                           (req.arrival_s, next(self._seq), rs))
        return req.rid

    def _drain_arrivals(self) -> None:
        while self._arrivals and self._arrivals[0][0] <= self.clock:
            self.waiting.append(heapq.heappop(self._arrivals)[2])

    @property
    def idle(self) -> bool:
        return not (self.waiting or self.active or self._arrivals)

    # -- one step ------------------------------------------------------------
    def step(self) -> Optional[StepReport]:
        """Admit, compose, execute, account, evict.  Returns None when
        there is nothing at all left to do."""
        tr = obs.tracer() if obs.enabled() else None
        return self._step_impl(tr)

    def _step_impl(self, tr) -> Optional[StepReport]:
        self._drain_arrivals()
        self._enforce_deadlines()
        self._shed_overflow()
        # one logical step = one root span (the fast-forward recursion
        # below closes its own zero-duration marker first)
        sp = None
        if tr is not None:
            sp = tr.begin("serve:step", cat="serve_step",
                          args={"step": self.steps,
                                "policy": self.policy.name})
        try:
            t_adm = time.perf_counter()
            admitted = self._admit()
            if tr is not None:
                tr.complete("admit", time.perf_counter() - t_adm,
                            cat="serve",
                            args={"n_admitted": len(admitted),
                                  "queue_depth": len(self.waiting)})
            t_cmp = time.perf_counter()
            plan = self.policy.compose(list(self.active.values()), self.cost,
                                       max_batch=self.cfg.max_batch)
            if tr is not None:
                tr.complete(
                    "compose", time.perf_counter() - t_cmp, cat="serve",
                    args={"prefill_tokens": sum(n for _, n in plan.prefill),
                          "decode_batch": len(plan.decode)})
            if plan.empty:
                if self._arrivals:          # fast-forward to next arrival
                    if sp is not None:
                        sp.args["fast_forward"] = True
                        tr.end(sp, dur_s=0.0)
                        sp = None           # closed; recursion owns its own
                    self.clock = self._arrivals[0][0]
                    return self._step_impl(tr)
                if sp is not None:
                    sp.args["idle"] = True
                    tr.end(sp, dur_s=0.0)
                return None

            prefill_entries = [(n, self.active[rid].prefill_pos)
                               for rid, n in plan.prefill]
            decode_ctx = [self.active[rid].context_len for rid in plan.decode]
            predicted = self.cost.predict_step(prefill_entries, decode_ctx)

            timed = self._timed()
            t0 = time.perf_counter()
            ex = self.backend.execute(plan, self.active, timed=timed)
            wall = time.perf_counter() - t0

            # clock: measured wall for real execution, prediction for
            # simulation
            if self.backend.measures:
                advance = (ex.prefill_s + ex.decode_s) if timed else wall
            else:
                advance = predicted.total_s
            self.clock += advance

            # account prefill progress, then tokens / completions
            for rid, n in plan.prefill:
                rs = self.active[rid]
                rs.prefill_pos += n
                self.blocks.append_tokens(rid, n)
            finished: List[str] = []
            for rid, tok in ex.tokens.items():
                rs = self.active[rid]
                rs.out.append(tok)
                self.blocks.append_tokens(rid, 1)
                if rs.first_token_s is None:
                    rs.first_token_s = self.clock
                self._maybe_finish(rs, tok)
                if rs.finish_s is not None:
                    finished.append(rid)
            for rid in finished:
                self._evict(rid)

            self.steps += 1
            if self.slo_watcher is not None:
                self.slo_watcher.check(self.clock)
                if self.degradation is not None:
                    # feed the firing *level*, not check()'s edge-triggered
                    # alerts: the budget stays shrunk while the burn lasts
                    budget = self.degradation.update(
                        self.slo_watcher.firing())
                    reg = self._registry()
                    if reg is not None and budget is not None:
                        self._ensure_handles(reg)["budget"].set(budget)
            self._record(plan, predicted, ex, timed)
            rep = StepReport(
                self.steps - 1, self.clock, plan, predicted,
                ex.prefill_s, ex.decode_s,
                [r.rid for r in admitted], finished,
                queue_depth=len(self.waiting), active=len(self.active),
                kv_blocks_used=self.blocks.used_blocks,
                kv_blocks_total=self.blocks.num_blocks,
                prefill_tokens=sum(n for _, n in plan.prefill),
                decode_batch=len(plan.decode))
            self._observe_step(rep)
            if tr is not None:
                # per-phase children pair with the cost model's split; the
                # root pairs with the predicted step total.  Simulated
                # phases measure as their predictions (residual 0) — real
                # backends carry true residuals.
                meas = self.backend.measures
                pf = ex.prefill_s if meas else predicted.prefill_s
                dc = ex.decode_s if meas else predicted.decode_s
                if plan.prefill:
                    tr.complete("prefill", pf, cat="serve_step",
                                predicted_s=predicted.prefill_s,
                                args={"tokens": rep.prefill_tokens})
                if plan.decode:
                    tr.complete("decode", dc, cat="serve_step",
                                predicted_s=predicted.decode_s,
                                args={"batch": rep.decode_batch})
                sp.predicted_s = predicted.total_s
                sp.args.update(admitted=len(admitted),
                               finished=len(finished),
                               decode_batch=rep.decode_batch,
                               prefill_tokens=rep.prefill_tokens)
                tr.end(sp, dur_s=advance)
            return rep
        except BaseException:
            if sp is not None:
                tr.end(sp, error=True)
            raise

    def run(self, max_steps: Optional[int] = None) -> List[StepReport]:
        reports = []
        while max_steps is None or len(reports) < max_steps:
            rep = self.step()
            if rep is None:
                break
            reports.append(rep)
        return reports

    def request_metrics(self) -> List[Dict[str, float]]:
        return [rs.metrics() for rs in self.finished.values()]

    # -- robustness -----------------------------------------------------------
    def _drop_waiting(self, rs: RequestState, reason: str) -> None:
        """Retire a never-admitted request: it was not served, so it is a
        bad SLO outcome and does NOT count in ``serve_finished_total``
        (which tracks requests the scheduler actually ran)."""
        rs.finish(self.clock, reason)
        self.finished[rs.rid] = rs
        if self.slo_watcher is not None:
            self.slo_watcher.record_outcomes(self.clock, ttft=False,
                                             goodput=False)

    def _enforce_deadlines(self) -> None:
        """Evict every request whose absolute deadline has passed —
        waiting requests are dropped unserved, active ones are evicted
        mid-stream (their blocks freed for live work)."""
        expired = [rs for rs in self.waiting
                   if rs.deadline_at is not None
                   and self.clock > rs.deadline_at]
        reg = self._registry()
        for rs in expired:
            self.waiting.remove(rs)
            self._drop_waiting(rs, "deadline")
        n = len(expired)
        for rid in [rid for rid, rs in self.active.items()
                    if rs.deadline_at is not None
                    and self.clock > rs.deadline_at]:
            self.active[rid].finish(self.clock, "deadline")
            self._evict(rid)
            n += 1
        if n and reg is not None:
            self._ensure_handles(reg)["deadline"].inc(n)

    def _shed_overflow(self) -> None:
        """Predicted-cost-aware load shedding: when the admission queue
        overflows ``cfg.max_queue``, keep the cheapest requests (by the
        cost model's predicted prefill time, FIFO-tie-broken) and shed
        the expensive tail — bounding queue growth under overload at the
        smallest loss of predicted goodput."""
        mq = self.cfg.max_queue
        if mq is None or len(self.waiting) <= mq:
            return
        ranked = sorted(
            self.waiting,
            key=lambda rs: (self.cost.request_prefill_cost(rs.prompt_len),
                            rs.arrival_s, rs.rid))
        shed = ranked[mq:]
        keep = set(id(rs) for rs in ranked[:mq])
        self.waiting = [rs for rs in self.waiting if id(rs) in keep]
        for rs in shed:
            self._drop_waiting(rs, "shed")
        reg = self._registry()
        if reg is not None:
            self._ensure_handles(reg)["shed"].inc(len(shed))

    # -- internals ------------------------------------------------------------
    def _admit(self) -> List[RequestState]:
        chosen = self.policy.admit(
            self.waiting, self.blocks, self.cost, clock=self.clock,
            active=len(self.active), max_active=self.cfg.max_active)
        admitted = []
        for rs in chosen:
            if not self.blocks.can_admit(rs.token_budget):
                continue                   # policy raced capacity; re-queue
            self.blocks.allocate(rs.rid, rs.token_budget)
            rs.admitted_s = self.clock
            self.active[rs.rid] = rs
            self.waiting.remove(rs)
            self.backend.admit(rs)
            admitted.append(rs)
        return admitted

    def _maybe_finish(self, rs: RequestState, tok) -> None:
        req = rs.req
        if req.eos_id is not None or req.stop_ids:
            t = token_int(tok)      # host sync; only when stops configured
            if t == req.eos_id or t in req.stop_ids:
                rs.finish(self.clock, "stop")
                return
        if len(rs.out) >= req.max_new_tokens:
            rs.finish(self.clock, "length")

    def _evict(self, rid: str) -> None:
        rs = self.active.pop(rid)
        self.blocks.free(rid)
        self.backend.release(rid)
        self.finished[rid] = rs
        reg = self._registry()
        m = rs.metrics() if (reg is not None
                             or self.slo_watcher is not None) else None
        if reg is not None:
            h = self._ensure_handles(reg)
            h["finished"].inc()
            h["tokens"].inc(m["n_out"])
            h["last_finish"].set(rs.finish_s)
            if m["ttft_s"] is not None:
                h["ttft"].observe(m["ttft_s"])
            if m["n_out"] > 1:
                h["tpot"].observe(m["tpot_s"])
            if self.ttft_slo_s is not None:
                met = (m["ttft_s"] is not None
                       and m["ttft_s"] <= self.ttft_slo_s
                       and (m["n_out"] <= 1 or self.tpot_slo_s is None
                            or m["tpot_s"] <= self.tpot_slo_s))
                if met:
                    h["slo_met"].inc()
        if self.slo_watcher is not None:
            ttft_ok = (self.ttft_slo_s is None
                       or (m["ttft_s"] is not None
                           and m["ttft_s"] <= self.ttft_slo_s))
            tpot_ok = (self.tpot_slo_s is None or m["n_out"] <= 1
                       or m["tpot_s"] <= self.tpot_slo_s)
            self.slo_watcher.record_outcomes(
                self.clock, ttft=ttft_ok, tpot=tpot_ok,
                goodput=ttft_ok and tpot_ok)

    # -- metrics --------------------------------------------------------------
    def _registry(self):
        if self.metrics is not None:
            return self.metrics
        if obs.enabled():
            return obs.default_registry()
        return None

    def _ensure_handles(self, reg) -> Dict[str, object]:
        h = self._mh
        if h.get("_reg") is not reg:
            pol = self.policy.name
            h.clear()
            h["_reg"] = reg
            h["steps"] = reg.counter("serve_steps_total", policy=pol)
            h["finished"] = reg.counter("serve_finished_total", policy=pol)
            h["tokens"] = reg.counter("serve_tokens_out_total", policy=pol)
            h["slo_met"] = reg.counter("serve_slo_met_total", policy=pol)
            h["deadline"] = reg.counter("serve_deadline_missed_total",
                                        policy=pol)
            h["shed"] = reg.counter("serve_shed_total", policy=pol)
            h["budget"] = reg.gauge("serve_step_budget_s", policy=pol)
            h["queue"] = reg.gauge("serve_queue_depth", policy=pol)
            h["active"] = reg.gauge("serve_active_requests", policy=pol)
            h["kv_used"] = reg.gauge("serve_kv_blocks_used", policy=pol)
            h["kv_util"] = reg.gauge("serve_kv_utilization", policy=pol)
            h["batch"] = reg.gauge("serve_decode_batch", policy=pol)
            h["pf_tok"] = reg.gauge("serve_prefill_tokens", policy=pol)
            h["last_finish"] = reg.gauge("serve_last_finish_s", policy=pol)
            # keep_values: exact nearest-rank percentiles, so the replay
            # report and the obs summary agree by construction
            h["ttft"] = reg.histogram("serve_ttft_s", keep_values=True,
                                      policy=pol)
            h["tpot"] = reg.histogram("serve_tpot_s", keep_values=True,
                                      policy=pol)
        return h

    def _observe_step(self, rep: StepReport) -> None:
        reg = self._registry()
        if reg is None:
            return
        h = self._ensure_handles(reg)
        h["steps"].inc()
        h["queue"].set(rep.queue_depth)
        h["active"].set(rep.active)
        h["kv_used"].set(rep.kv_blocks_used)
        h["kv_util"].set(rep.kv_blocks_used / rep.kv_blocks_total
                         if rep.kv_blocks_total else 0.0)
        h["batch"].set(rep.decode_batch)
        h["pf_tok"].set(rep.prefill_tokens)

    def _timed(self) -> bool:
        if not self.backend.measures:
            return False
        if self._outer_pt is not None:
            return True
        from .. import telemetry
        return telemetry.enabled()

    def _record(self, plan: StepPlan, predicted: ServeStepCost,
                ex: StepExec, timed: bool) -> None:
        if self._outer_pt is not None:
            if ex.prefill_s > 0:
                self._outer_pt.add("prefill", ex.prefill_s)
            if ex.decode_s > 0:
                self._outer_pt.add("decode", ex.decode_s)
        if not (timed and self.backend.measures):
            return
        from .. import telemetry
        if not telemetry.enabled():
            return
        m = self.cost.machine
        pt = telemetry.PhaseTimer(
            "serve_step", variant=self.policy.name,
            n=sum(n for _, n in plan.prefill) + len(plan.decode),
            p=len(plan.decode) or 1, machine=m.name,
            fingerprint=m.fingerprint(), kind="serve_step",
            predicted={"prefill": predicted.prefill_s,
                       "decode": predicted.decode_s,
                       "total": predicted.total_s},
            meta={"prefill_tokens": sum(n for _, n in plan.prefill),
                  "decode_batch": len(plan.decode),
                  "arch": getattr(self.cost.cfg, "name", "")})
        if ex.prefill_s > 0:
            pt.add("prefill", ex.prefill_s)
        if ex.decode_s > 0:
            pt.add("decode", ex.decode_s)
        pt.emit()


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class SimBackend:
    """Cost-model-driven execution: no arrays move; the scheduler's clock
    advances by predicted step time.  Token identity is synthetic (0), so
    requests end by ``max_new_tokens`` — trace replay sets that to the
    trace's output length (``Request.output_len`` is honored the same
    way when given, by emitting ``eos_id`` at the end)."""

    measures = False

    def admit(self, rs: RequestState) -> None:  # noqa: D401 - interface
        pass

    def release(self, rid: str) -> None:
        pass

    def execute(self, plan: StepPlan, states: Dict[str, RequestState],
                *, timed: bool = False) -> StepExec:
        tokens: Dict[str, Any] = {}
        for rid, n in plan.prefill:
            rs = states[rid]
            if rs.prefill_remaining - n <= 0:
                tokens[rid] = self._token(rs)
        for rid in plan.decode:
            tokens[rid] = self._token(states[rid])
        return StepExec(tokens=tokens)

    @staticmethod
    def _token(rs: RequestState):
        req = rs.req
        if (req.output_len is not None and req.eos_id is not None
                and len(rs.out) + 1 >= req.output_len):
            return req.eos_id
        return 0


class ModelBackend:
    """Real execution over per-request caches (see module docstring)."""

    measures = True

    def __init__(self, model, params, *, max_cache_len: int,
                 prefill_chunk: Optional[int] = None, step=None, tuner=None):
        import jax

        self.model = model
        self.params = params
        self.max_cache_len = int(max_cache_len)
        self.prefill_chunk = prefill_chunk
        self._tuner = tuner
        from .engine import make_serve_step
        self._step = step if step is not None \
            else jax.jit(make_serve_step(model))
        self._vstep_cache: Dict[bool, Any] = {}
        self._state: Dict[str, Dict[str, Any]] = {}
        self._dummy: Optional[Dict[str, Any]] = None

    # -- lifecycle ----------------------------------------------------------
    def admit(self, rs: RequestState) -> None:
        import jax

        req = rs.req
        key = jax.random.PRNGKey(req.seed)
        self._state[rs.rid] = {
            "caches": self.model.init_cache(1, self.max_cache_len),
            "logits": None, "next_tok": None, "memory": req.memory,
            "key": key,
        }

    def release(self, rid: str) -> None:
        self._state.pop(rid, None)

    # -- chunk sizing (engine semantics) -------------------------------------
    def chunk_granularity(self, seq_len: int) -> int:
        if not self.model.supports_chunked_prefill:
            return 1
        if self.prefill_chunk is not None:
            return max(1, self.prefill_chunk)
        if self._tuner is None:
            from ..tuner import default_tuner
            self._tuner = default_tuner()
        return self._tuner.prefill_chunk(seq_len)

    # -- execution ------------------------------------------------------------
    def execute(self, plan: StepPlan, states: Dict[str, RequestState],
                *, timed: bool = False) -> StepExec:
        import jax

        tokens: Dict[str, Any] = {}
        prefill_s = decode_s = 0.0

        if plan.prefill:
            t0 = time.perf_counter()
            last = None
            for rid, n in plan.prefill:
                last = self._prefill_one(states[rid], n, tokens)
            if timed and last is not None:
                jax.block_until_ready(last)
            prefill_s = time.perf_counter() - t0

        if plan.decode:
            t0 = time.perf_counter()
            out = self._decode_batch(plan.decode, states)
            tokens.update(out)
            if timed:
                jax.block_until_ready([self._state[r]["next_tok"]
                                       for r in plan.decode])
            decode_s = time.perf_counter() - t0

        return StepExec(tokens=tokens, prefill_s=prefill_s,
                        decode_s=decode_s)

    def _prefill_one(self, rs: RequestState, n: int, tokens: Dict[str, Any]):
        """Advance one request's prefill by ``n`` prompt tokens: chunked at
        the engine granularity, ring-boundary-safe, per-token tail (the
        exact ``Engine._ingest`` stepping, per request)."""
        st = self._state[rs.rid]
        prompt = rs.req.prompt
        chunk = self.chunk_granularity(rs.prompt_len)
        limit = self.max_cache_len
        i, end = rs.prefill_pos, rs.prefill_pos + n
        logits, caches = st["logits"], st["caches"]
        while chunk > 1 and end - i >= chunk and i + chunk <= limit:
            logits, caches = self._step(self.params, prompt[:, i:i + chunk],
                                        caches, st["memory"])
            i += chunk
        for j in range(i, end):
            logits, caches = self._step(self.params, prompt[:, j:j + 1],
                                        caches, st["memory"])
        st["logits"], st["caches"] = logits, caches
        if end >= rs.prompt_len:           # prompt done: first token now
            tok = self._sample(rs, logits)
            tokens[rs.rid] = tok
            st["next_tok"] = tok
        return logits

    def _decode_batch(self, rids: Sequence[str],
                      states: Dict[str, RequestState]) -> Dict[str, Any]:
        import jax
        import jax.numpy as jnp

        mems = [self._state[r]["memory"] for r in rids]
        if any(m is not None for m in mems):
            # cross-attention rows may differ in width; take the simple
            # per-request path (correctness first; encdec serving is rare)
            out = {}
            for rid in rids:
                st = self._state[rid]
                tok = jnp.asarray(st["next_tok"], jnp.int32).reshape(1, 1)
                logits, st["caches"] = self._step(self.params, tok,
                                                  st["caches"], st["memory"])
                st["logits"] = logits
                new = self._sample(states[rid], logits)
                st["next_tok"] = new
                out[rid] = new
            return out

        n = len(rids)
        n_pad = 1 << (n - 1).bit_length()       # power-of-two batch bucket
        toks = [jnp.asarray(self._state[r]["next_tok"],
                            jnp.int32).reshape(1, 1) for r in rids]
        caches = [self._state[r]["caches"] for r in rids]
        if n_pad > n:
            dummy = self._dummy_state()
            toks += [dummy["tok"]] * (n_pad - n)
            caches += [dummy["caches"]] * (n_pad - n)
        stacked_t = jnp.stack(toks)             # (N, 1, 1)
        stacked_c = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
        vstep = self._vstep()
        logits, new_c = vstep(self.params, stacked_t, stacked_c)
        out = {}
        for i, rid in enumerate(rids):
            st = self._state[rid]
            st["caches"] = jax.tree.map(lambda x, i=i: x[i], new_c)
            st["logits"] = logits[i]
            tok = self._sample(states[rid], logits[i])
            st["next_tok"] = tok
            out[rid] = tok
        return out

    def _vstep(self):
        import jax

        fn = self._vstep_cache.get(True)
        if fn is None:
            def step(params, tok, caches):
                return self.model.decode_step(params, tok, caches, None)
            fn = jax.jit(jax.vmap(step, in_axes=(None, 0, 0)))
            self._vstep_cache[True] = fn
        return fn

    def _dummy_state(self):
        import jax.numpy as jnp

        if self._dummy is None:
            self._dummy = {
                "caches": self.model.init_cache(1, self.max_cache_len),
                "tok": jnp.zeros((1, 1), jnp.int32),
            }
        return self._dummy

    def _sample(self, rs: RequestState, logits):
        """Next token from the last position's logits (greedy, or
        per-request keyed sampling when the request asks for heat)."""
        import jax
        import jax.numpy as jnp

        req = rs.req
        if req.temperature > 0:
            st = self._state[rs.rid]
            st["key"], sub = jax.random.split(st["key"])
            tok = jax.random.categorical(
                sub, logits[:, -1] / req.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        return tok.astype(jnp.int32)


def build_scheduler(model=None, params=None, *, cfg_model=None,
                    machine=None, scheduler_cfg: Optional[SchedulerConfig] = None,
                    policy: str = "fifo", step_budget_s: Optional[float] = None,
                    backend: Optional[Any] = None, tuner=None,
                    phase_timer=None, metrics=None,
                    ttft_slo_s: Optional[float] = None,
                    tpot_slo_s: Optional[float] = None,
                    slo_watcher=None, degradation=None) -> Scheduler:
    """Convenience constructor.  With ``model``/``params``: real execution
    (:class:`ModelBackend`); without: cost-model simulation
    (:class:`SimBackend`).  ``cfg_model`` is the ModelConfig the cost
    model describes (defaults to ``model.cfg``)."""
    from ..core.machine import CPU_HOST

    mcfg = cfg_model if cfg_model is not None else getattr(model, "cfg", None)
    if mcfg is None:
        raise ValueError("need cfg_model (or a model with .cfg)")
    cost = cost_model_for(mcfg, machine or CPU_HOST)
    scfg = (scheduler_cfg or SchedulerConfig()).resolve()
    if backend is None:
        if model is not None:
            backend = ModelBackend(model, params,
                                   max_cache_len=scfg.max_cache_len,
                                   tuner=tuner)
        else:
            backend = SimBackend()
    pol = make_policy(policy, step_budget_s=step_budget_s, tuner=tuner)
    if degradation is True:
        from .policy import DegradationController
        degradation = DegradationController(pol)
    return Scheduler(backend, cost, scfg, policy=pol,
                     phase_timer=phase_timer, metrics=metrics,
                     ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s,
                     slo_watcher=slo_watcher, degradation=degradation)
