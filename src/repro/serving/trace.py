"""Synthetic request traces and cost-model-driven replay.

The paper evaluates its models by predicting whole runs before executing
them; the serving analog is *trace replay*: generate a request arrival
trace (skewed prompt/output length mixture over a Poisson arrival
process — the shape every serving benchmark uses), drive the scheduler's
full admission/compose/evict loop over a :class:`~.scheduler.SimBackend`
whose clock advances by the cost model's predicted step times, and
report the latency distribution each policy would deliver:

* **TTFT** — time to first token (arrival -> first sampled token),
* **TPOT** — time per output token after the first,
* **goodput** — requests meeting their TTFT/TPOT SLOs per second of
  makespan (the number a capacity planner actually buys hardware by).

Because replay is pure accounting, tens of thousands of requests run in
seconds on the CPU host — large enough for p99 tails to mean something —
and because both policies replay the *same* trace under the *same* cost
model, the comparison isolates scheduling policy from prediction error.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.machine import CPU_HOST, Machine
from ..obs import MetricsRegistry
from .cost import ServeCostModel, cost_model_for
from .policy import make_policy
from .scheduler import (Request, Scheduler, SchedulerConfig, SimBackend,
                        StepReport)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs of the synthetic workload (defaults give the skewed mixture
    the model-guided policy is designed for: mostly short interactive
    prompts with a heavy tail of long documents)."""

    n_requests: int = 1000
    seed: int = 0
    arrival_rate: float = 8.0          # mean requests/second (Poisson)
    short_prompt: tuple = (16, 96)     # uniform range, the bulk
    long_prompt: tuple = (512, 1536)   # uniform range, the tail
    long_fraction: float = 0.1
    mean_output: int = 48              # geometric mean of output lengths
    max_output: int = 256
    eos_id: int = 1


def synthesize_trace(cfg: TraceConfig) -> List[Request]:
    """Deterministic (seeded) arrival trace of prompt-only requests."""
    rng = random.Random(cfg.seed)
    out: List[Request] = []
    t = 0.0
    for i in range(cfg.n_requests):
        t += rng.expovariate(cfg.arrival_rate)
        lo, hi = (cfg.long_prompt if rng.random() < cfg.long_fraction
                  else cfg.short_prompt)
        prompt_len = rng.randint(lo, hi)
        n_out = min(1 + int(rng.expovariate(1.0 / cfg.mean_output)),
                    cfg.max_output)
        out.append(Request(
            rid=f"r{i:06d}", prompt_len=prompt_len, arrival_s=t,
            max_new_tokens=n_out, output_len=n_out, eos_id=cfg.eos_id))
    return out


def _percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile; 0 for an empty sample."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[k])


@dataclasses.dataclass
class ReplayReport:
    policy: str
    n_requests: int
    n_finished: int
    makespan_s: float
    steps: int
    tokens_out: int
    ttft_p50_s: float
    ttft_p95_s: float
    ttft_p99_s: float
    tpot_p50_s: float
    tpot_p95_s: float
    goodput_rps: float                 # SLO-met requests / makespan
    throughput_tok_s: float
    slo_met_fraction: float
    ttft_slo_s: float
    tpot_slo_s: float
    n_shed: int = 0                    # load-shed before ever running
    n_deadline_missed: int = 0         # dropped/evicted past deadline

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def replay_traced(trace: Sequence[Request], cost: ServeCostModel, *,
                  policy: str = "fifo",
                  scheduler_cfg: Optional[SchedulerConfig] = None,
                  step_budget_s: Optional[float] = None,
                  ttft_slo_s: Optional[float] = None,
                  tpot_slo_s: Optional[float] = None,
                  max_steps: Optional[int] = None,
                  metrics: Optional[MetricsRegistry] = None,
                  slo_watcher=None, degrade: bool = False,
                  ) -> Tuple[ReplayReport, List[StepReport],
                             MetricsRegistry]:
    """:func:`replay`, returning also the per-step reports and the
    metrics registry the run was accounted through — the inputs
    ``obs.serving_trace`` / ``obs.summary`` want.

    SLO defaults are derived from the cost model so they track the
    machine: TTFT SLO = predicted whole-prefill time of a tail-length
    prompt plus slack; TPOT SLO = 6x a lightly-batched decode step.
    They are resolved *before* the run so the scheduler streams the
    SLO-met accounting into the registry as requests finish."""
    if ttft_slo_s is None:
        tail = max((r.prompt_len for r in trace), default=256)
        ttft_slo_s = 2.0 * cost.request_prefill_cost(tail) + 0.5
    if tpot_slo_s is None:
        # tolerate budget-bounded interleaving (a decode stream's token
        # time is the whole step it rides in), punish whole-prompt stalls
        tpot_slo_s = 6.0 * cost.decode_step([256] * 8).decode_s
    reg = metrics if metrics is not None else MetricsRegistry()
    pol = make_policy(policy, step_budget_s=step_budget_s)
    degradation = None
    if degrade:
        # graceful degradation needs a burn-rate signal; build a watcher
        # when the caller did not bring one
        if slo_watcher is None:
            from ..obs.watch.slo import SLOWatcher
            slo_watcher = SLOWatcher()
        from .policy import DegradationController
        degradation = DegradationController(pol)
    sched = Scheduler(SimBackend(), cost,
                      scheduler_cfg or SchedulerConfig(), policy=pol,
                      metrics=reg, ttft_slo_s=ttft_slo_s,
                      tpot_slo_s=tpot_slo_s, slo_watcher=slo_watcher,
                      degradation=degradation)
    for req in trace:
        sched.submit(dataclasses.replace(req))
    reports = sched.run(max_steps=max_steps)

    # the report is read *from the registry* — the same counters and
    # keep_values histograms the obs summary exposes, so the two cannot
    # disagree
    name = pol.name
    ttft_h = reg.histogram("serve_ttft_s", keep_values=True, policy=name)
    tpot_h = reg.histogram("serve_tpot_s", keep_values=True, policy=name)
    n_finished = int(reg.counter("serve_finished_total", policy=name).value)
    tokens_out = int(reg.counter("serve_tokens_out_total", policy=name).value)
    met = int(reg.counter("serve_slo_met_total", policy=name).value)
    last = reg.gauge("serve_last_finish_s", policy=name)
    makespan = last.max_value if last.max_value > -math.inf else 0.0
    rep = ReplayReport(
        policy=name, n_requests=len(trace), n_finished=n_finished,
        makespan_s=makespan, steps=len(reports), tokens_out=tokens_out,
        # empty-histogram percentiles are None (no requests finished);
        # the report's float fields keep the historical 0.0 convention
        ttft_p50_s=ttft_h.percentile(50) or 0.0,
        ttft_p95_s=ttft_h.percentile(95) or 0.0,
        ttft_p99_s=ttft_h.percentile(99) or 0.0,
        tpot_p50_s=tpot_h.percentile(50) or 0.0,
        tpot_p95_s=tpot_h.percentile(95) or 0.0,
        goodput_rps=met / makespan if makespan > 0 else 0.0,
        throughput_tok_s=tokens_out / makespan if makespan > 0 else 0.0,
        slo_met_fraction=met / n_finished if n_finished else 0.0,
        ttft_slo_s=ttft_slo_s, tpot_slo_s=tpot_slo_s,
        n_shed=int(reg.counter("serve_shed_total", policy=name).value),
        n_deadline_missed=int(
            reg.counter("serve_deadline_missed_total", policy=name).value))
    return rep, reports, reg


def replay(trace: Sequence[Request], cost: ServeCostModel,
           **kwargs) -> ReplayReport:
    """Replay ``trace`` under ``policy`` on a simulated clock; see
    :func:`replay_traced` (this is its report-only form)."""
    return replay_traced(trace, cost, **kwargs)[0]


def compare_policies(trace: Sequence[Request], cost: ServeCostModel, *,
                     policies: Sequence[str] = ("fifo", "model"),
                     scheduler_cfg: Optional[SchedulerConfig] = None,
                     step_budget_s: Optional[float] = None,
                     **slo) -> Dict[str, ReplayReport]:
    """Replay the same trace under each policy; same cost model, same
    SLOs (pinned from the first replay so the comparison is fair)."""
    out: Dict[str, ReplayReport] = {}
    for name in policies:
        rep = replay(trace, cost, policy=name,
                     scheduler_cfg=scheduler_cfg,
                     step_budget_s=step_budget_s, **slo)
        out[name] = rep
        slo.setdefault("ttft_slo_s", rep.ttft_slo_s)
        slo.setdefault("tpot_slo_s", rep.tpot_slo_s)
    return out


def replay_for(cfg_model, *, machine: Machine = CPU_HOST,
               trace_cfg: Optional[TraceConfig] = None,
               **kwargs) -> Dict[str, ReplayReport]:
    """One-call comparison: synthesize a trace for ``cfg_model`` on
    ``machine`` and replay it under every policy."""
    cost = cost_model_for(cfg_model, machine)
    trace = synthesize_trace(trace_cfg or TraceConfig())
    return compare_policies(trace, cost, **kwargs)
