"""LM substrate: layers, attention (GQA/cross/decode), MoE, SSM blocks and
the per-architecture assembly (transformer.py / encdec.py) behind the
uniform Model facade (model.py)."""

from .model import Model, build_model
