"""Model facade: uniform init / loss / decode API over every architecture.

``build_model(cfg)`` returns a ``Model`` whose methods are pure functions
(params explicit), ready for jax.jit / jax.grad / the launcher:

    model.init(key)                          -> params
    model.loss(params, batch)                -> (scalar, metrics)
    model.encode_memory(params, batch)       -> cross-attn memory or None
    model.init_cache(batch, max_len)         -> decode caches
    model.decode_step(params, tok, caches, memory) -> (logits, caches)

Batches are dicts: tokens/labels (LM), frames (whisper), images (vlm).
The loss computes cross-entropy in seq-chunks (cfg.logits_chunk) so the
(B, S, V) logits tensor is never fully materialized — essential for the
long-sequence dry-run cells.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from . import encdec as ed
from . import transformer as tf


def _chunked_ce(params, cfg, hidden, labels, chunk: int):
    """Mean CE over tokens, computed chunk-by-chunk along the sequence."""
    b, s, d = hidden.shape
    chunk = chunk or s
    chunk = min(chunk, s)
    while s % chunk != 0:
        chunk //= 2
    n = s // chunk

    def one(carry, idx):
        total, count = carry
        h = jax.lax.dynamic_slice(hidden, (0, idx * chunk, 0), (b, chunk, d))
        y = jax.lax.dynamic_slice(labels, (0, idx * chunk), (b, chunk))
        logits = tf.lm_logits(params, cfg, h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        valid = (y >= 0).astype(jnp.float32)
        total = total + jnp.sum((logz - gold) * valid)
        count = count + jnp.sum(valid)
        return (total, count), None

    # remat the chunk body: the (B, chunk, V) logits are recomputed in the
    # backward pass instead of being stashed once per chunk
    (total, count), _ = jax.lax.scan(
        jax.checkpoint(one),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    return total / jnp.maximum(count, 1.0)


@dataclasses.dataclass
class Model:
    cfg: ModelConfig

    # -- init ----------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        if self.cfg.block_pattern == "encdec":
            return ed.init_encdec_params(key, self.cfg)
        return tf.init_decoder_params(key, self.cfg)

    # -- training ------------------------------------------------------------
    def loss(self, params, batch) -> tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        labels = batch["labels"]
        if cfg.block_pattern == "encdec":
            hidden, aux = ed.encdec_forward_train(
                params, cfg, batch["frames"], batch["tokens"])
        elif cfg.block_pattern == "vlm":
            memory = self.encode_memory(params, batch)
            hidden, aux = tf.decoder_forward_train(
                params, cfg, batch["tokens"], memory=memory)
        else:
            hidden, aux = tf.decoder_forward_train(params, cfg,
                                                   batch["tokens"])
        ce = _chunked_ce(params, cfg, hidden, labels, cfg.logits_chunk)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    # -- memory (cross-attention context) -------------------------------------
    def encode_memory(self, params, batch) -> Optional[jax.Array]:
        cfg = self.cfg
        if cfg.block_pattern == "encdec":
            return ed.encode(params, cfg, batch["frames"])
        if cfg.block_pattern == "vlm":
            # patch-embedding frontend stub: precomputed (B, N_img, D)
            return constrain(batch["images"], "batch", "frames", "dmodel")
        return None

    # -- decode ----------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int):
        if self.cfg.block_pattern == "encdec":
            return ed.init_encdec_cache(self.cfg, batch, max_len)
        return tf.init_decode_cache(self.cfg, batch, max_len)

    def decode_step(self, params, tokens, caches, memory=None):
        if self.cfg.block_pattern == "encdec":
            return ed.encdec_decode_step(params, self.cfg, tokens, caches,
                                         memory)
        return tf.decoder_decode_step(params, self.cfg, tokens, caches,
                                      memory=memory)

    @property
    def supports_chunked_prefill(self) -> bool:
        """True when decode_step accepts multi-token chunks: every layer is
        attention-shaped (the recurrent SSM/LSTM decode paths are strictly
        one-token) and the KV ring buffer cannot wrap inside a chunk (no
        sliding window)."""
        if self.cfg.sliding_window:
            return False
        if self.cfg.block_pattern == "encdec":
            return True
        kinds = {k for _, _, ks in tf.stack_plan(self.cfg) for k in ks}
        return kinds <= {"dense", "vlm_self", "moe", "cross"}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
