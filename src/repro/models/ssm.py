"""SSM / linear-recurrence blocks: the xLSTM pair (mLSTM, sLSTM) and the
Mamba-style SSD head used by hymba.

The shared engine is *decayed linear attention*,

    S_t = a_t S_{t-1} + k_t v_t^T ,   y_t = q_t . S_t  (+ normalizer),

computed in chunked form (sub-quadratic: O(S*chunk + S*D^2/chunk)) — the
same math as the Pallas ssm_scan kernel, expressed in jnp so GSPMD can
shard it for the dry-run; on hardware the kernel slots in behind shard_map.

mLSTM (xLSTM): q,k,v heads with exponential input gate folded into k·v and
sigmoid forget gate a_t; normalizer n_t = a_t n_{t-1} + i_t k_t gives
y = (q.S) / max(|q.n|, 1).  sLSTM: a true nonlinear recurrence (scalar
memory per head) — not chunkable, runs as lax.scan over time; its state is
O(d) so decode is cheap.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dtype_of, init_linear, linear, rmsnorm, init_norm


# ---------------------------------------------------------------------------
# chunked decayed linear attention (jnp mirror of kernels/ssm_scan)
# ---------------------------------------------------------------------------


def decayed_linear_attention(q, k, v, log_a, *, chunk: int = 256):
    """q,k: (B,H,S,DK); v: (B,H,S,DV); log_a: (B,H,S) <= 0.
    Returns (y, final_state) with y: (B,H,S,DV), state: (B,H,DK,DV)."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    c = min(chunk, s)
    while s % c != 0:
        c //= 2
    n = s // c

    qc = q.reshape(b, h, n, c, dk)
    kc = k.reshape(b, h, n, c, dk)
    vc = v.reshape(b, h, n, c, dv)
    lac = log_a.reshape(b, h, n, c).astype(jnp.float32)
    A = jnp.cumsum(lac, axis=-1)                        # inclusive
    total = A[..., -1]                                  # (B,H,N)

    rows = jnp.arange(c)[:, None]
    cols = jnp.arange(c)[None, :]
    tri = rows >= cols

    # intra-chunk
    rel = A[..., :, None] - A[..., None, :]             # (B,H,N,C,C)
    dec = jnp.where(tri, jnp.exp(rel), 0.0)
    scores = jnp.einsum("bhncd,bhnld->bhncl", qc.astype(jnp.float32),
                        kc.astype(jnp.float32)) * dec
    y_intra = jnp.einsum("bhncl,bhnlv->bhncv", scores, vc.astype(jnp.float32))

    # inter-chunk: scan over chunk states
    k_dec = kc.astype(jnp.float32) * jnp.exp(total[..., None, None]
                                             - A[..., None])
    chunk_state = jnp.einsum("bhncd,bhncv->bhndv", k_dec, vc.astype(jnp.float32))

    def scan_fn(S, inp):
        cs, tot = inp
        S_new = S * jnp.exp(tot)[..., None, None] + cs
        return S_new, S                                  # emit state *before*

    S0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    # move chunk axis to front for scan
    cs_t = jnp.moveaxis(chunk_state, 2, 0)
    tot_t = jnp.moveaxis(total, 2, 0)
    S_final, S_prevs = jax.lax.scan(scan_fn, S0, (cs_t, tot_t))
    S_prevs = jnp.moveaxis(S_prevs, 0, 2)                # (B,H,N,DK,DV)

    q_dec = qc.astype(jnp.float32) * jnp.exp(A[..., None])
    y_inter = jnp.einsum("bhncd,bhndv->bhncv", q_dec, S_prevs)
    y = (y_intra + y_inter).reshape(b, h, s, dv)
    return y.astype(q.dtype), S_final


def decayed_linear_attention_step(q, k, v, log_a, state):
    """One decode step.  q,k: (B,H,DK); v: (B,H,DV); log_a: (B,H);
    state: (B,H,DK,DV).  Returns (y, new_state)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    state = state * a + k.astype(jnp.float32)[..., :, None] \
        * v.astype(jnp.float32)[..., None, :]
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return y.astype(q.dtype), state


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    hd = cfg.hd
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 7)
    return {
        "wq": init_linear(ks[0], d, h * hd, dt),
        "wk": init_linear(ks[1], d, h * hd, dt),
        "wv": init_linear(ks[2], d, h * hd, dt),
        "wf": init_linear(ks[3], d, h, jnp.float32),   # forget gate
        "wi": init_linear(ks[4], d, h, jnp.float32),   # input gate
        "wo_gate": init_linear(ks[5], d, h * hd, dt),  # output gate
        "wo": init_linear(ks[6], h * hd, d, dt),
    }


class SSMState(NamedTuple):
    S: jax.Array       # (B, H, DK, DV) matrix memory
    n: jax.Array       # (B, H, DK) normalizer
    length: jax.Array


def init_ssm_state(batch: int, heads: int, dk: int, dv: int):
    return SSMState(jnp.zeros((batch, heads, dk, dv), jnp.float32),
                    jnp.zeros((batch, heads, dk), jnp.float32),
                    jnp.zeros((), jnp.int32))


def _heads(x, h, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, h, hd).transpose(0, 2, 1, 3)


def mlstm_train(p, cfg, x, *, chunk: int = 256):
    """Full-sequence mLSTM (chunked linear attention + normalizer)."""
    h, hd = cfg.n_heads, cfg.hd
    b, s, d = x.shape
    q = _heads(linear(p["wq"], x), h, hd) * hd ** -0.5
    k = _heads(linear(p["wk"], x), h, hd) * hd ** -0.5
    v = _heads(linear(p["wv"], x), h, hd)
    log_f = jax.nn.log_sigmoid(
        linear(p["wf"], x).astype(jnp.float32)).transpose(0, 2, 1)  # (B,H,S)
    log_i = jax.nn.log_sigmoid(
        linear(p["wi"], x).astype(jnp.float32)).transpose(0, 2, 1)
    k = k * jnp.exp(log_i).astype(k.dtype)[..., None]    # fold input gate
    # normalizer via ones-column augmentation
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    y_aug, _ = decayed_linear_attention(q, k, v_aug, log_f, chunk=chunk)
    y, denom = y_aug[..., :hd], y_aug[..., hd:]
    y = y / jnp.maximum(jnp.abs(denom), 1.0)
    o_gate = jax.nn.sigmoid(linear(p["wo_gate"], x))
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * hd) * o_gate
    return linear(p["wo"], y)


def mlstm_decode(p, cfg, x, state: SSMState):
    """x: (B, 1, D)."""
    h, hd = cfg.n_heads, cfg.hd
    b = x.shape[0]
    xt = x[:, 0]
    q = linear(p["wq"], x)[:, 0].reshape(b, h, hd) * hd ** -0.5
    k = linear(p["wk"], x)[:, 0].reshape(b, h, hd) * hd ** -0.5
    v = linear(p["wv"], x)[:, 0].reshape(b, h, hd)
    log_f = jax.nn.log_sigmoid(linear(p["wf"], x)[:, 0].astype(jnp.float32))
    log_i = jax.nn.log_sigmoid(linear(p["wi"], x)[:, 0].astype(jnp.float32))
    k = k * jnp.exp(log_i).astype(k.dtype)[..., None]
    a = jnp.exp(log_f)[..., None, None]
    S = state.S * a + k.astype(jnp.float32)[..., :, None] \
        * v.astype(jnp.float32)[..., None, :]
    n = state.n * a[..., 0] + k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), S)
    den = jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n)
    y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
    o_gate = jax.nn.sigmoid(linear(p["wo_gate"], x)[:, 0])
    y = (y.reshape(b, h * hd) * o_gate.astype(jnp.float32)).astype(x.dtype)
    return linear(p["wo"], y)[:, None, :], SSMState(S, n, state.length + 1)


# ---------------------------------------------------------------------------
# sLSTM block (true nonlinear recurrence; lax.scan over time)
# ---------------------------------------------------------------------------


def init_slstm(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    hd = cfg.hd
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    return {
        "wz": init_linear(ks[0], d, h * hd, dt),
        "wi": init_linear(ks[1], d, h * hd, jnp.float32),
        "wf": init_linear(ks[2], d, h * hd, jnp.float32),
        "wog": init_linear(ks[3], d, h * hd, jnp.float32),
        "wo": init_linear(ks[4], h * hd, d, dt),
    }


class SLSTMState(NamedTuple):
    c: jax.Array       # (B, H*hd) cell
    n: jax.Array       # (B, H*hd) normalizer
    m: jax.Array       # (B, H*hd) stabilizer (log-space max)


def init_slstm_state(batch: int, width: int):
    z = jnp.zeros((batch, width), jnp.float32)
    return SLSTMState(z, z, z - 1e30 * 0.0)


def _slstm_step(state: SLSTMState, zi, ii, fi, oi):
    """Stabilized exponential-gating sLSTM cell (per feature)."""
    log_f = jax.nn.log_sigmoid(fi)
    m_new = jnp.maximum(log_f + state.m, ii)
    i_st = jnp.exp(ii - m_new)
    f_st = jnp.exp(log_f + state.m - m_new)
    c = f_st * state.c + i_st * jnp.tanh(zi)
    n = f_st * state.n + i_st
    y = jax.nn.sigmoid(oi) * c / jnp.maximum(n, 1.0)
    return SLSTMState(c, n, m_new), y


def slstm_train(p, cfg, x):
    b, s, d = x.shape
    width = cfg.n_heads * cfg.hd
    z = linear(p["wz"], x).astype(jnp.float32)
    i = linear(p["wi"], x).astype(jnp.float32)
    f = linear(p["wf"], x).astype(jnp.float32)
    o = linear(p["wog"], x).astype(jnp.float32)

    def scan_fn(st, inp):
        zt, it, ft, ot = inp
        st, y = _slstm_step(st, zt, it, ft, ot)
        return st, y

    st0 = init_slstm_state(b, width)
    xs = (z.transpose(1, 0, 2), i.transpose(1, 0, 2),
          f.transpose(1, 0, 2), o.transpose(1, 0, 2))
    _, ys = jax.lax.scan(scan_fn, st0, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    return linear(p["wo"], y)


def slstm_decode(p, cfg, x, state: SLSTMState):
    z = linear(p["wz"], x)[:, 0].astype(jnp.float32)
    i = linear(p["wi"], x)[:, 0].astype(jnp.float32)
    f = linear(p["wf"], x)[:, 0].astype(jnp.float32)
    o = linear(p["wog"], x)[:, 0].astype(jnp.float32)
    state, y = _slstm_step(state, z, i, f, o)
    return linear(p["wo"], y.astype(x.dtype))[:, None, :], state


# ---------------------------------------------------------------------------
# Mamba-style SSD head for hymba (input-dependent decay, conv stub folded
# into projections; state_dim = cfg.ssm.state_dim per head)
# ---------------------------------------------------------------------------


def init_ssd(key, cfg):
    d = cfg.d_model
    h = cfg.ssm.n_ssm_heads or cfg.n_heads
    st = cfg.ssm.state_dim
    hd = cfg.hd
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 5)
    return {
        "wB": init_linear(ks[0], d, h * st, dt),        # input->state (k-like)
        "wC": init_linear(ks[1], d, h * st, dt),        # state->out (q-like)
        "wx": init_linear(ks[2], d, h * hd, dt),        # value path
        "wdt": init_linear(ks[3], d, h, jnp.float32),   # decay gate
        "wo": init_linear(ks[4], h * hd, d, dt),
    }


def ssd_train(p, cfg, x, *, chunk: int = 256):
    h = cfg.ssm.n_ssm_heads or cfg.n_heads
    st, hd = cfg.ssm.state_dim, cfg.hd
    b, s, d = x.shape
    Bm = _heads(linear(p["wB"], x), h, st)
    Cm = _heads(linear(p["wC"], x), h, st)
    v = _heads(linear(p["wx"], x), h, hd)
    log_a = -jax.nn.softplus(
        linear(p["wdt"], x).astype(jnp.float32)).transpose(0, 2, 1)
    y, _ = decayed_linear_attention(Cm, Bm, v, log_a, chunk=chunk)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return linear(p["wo"], y)


def ssd_decode(p, cfg, x, state: SSMState):
    h = cfg.ssm.n_ssm_heads or cfg.n_heads
    st, hd = cfg.ssm.state_dim, cfg.hd
    b = x.shape[0]
    Bm = linear(p["wB"], x)[:, 0].reshape(b, h, st)
    Cm = linear(p["wC"], x)[:, 0].reshape(b, h, st)
    v = linear(p["wx"], x)[:, 0].reshape(b, h, hd)
    log_a = -jax.nn.softplus(linear(p["wdt"], x)[:, 0].astype(jnp.float32))
    y, S = decayed_linear_attention_step(Cm, Bm, v, log_a, state.S)
    y = y.reshape(b, h * hd)
    out = linear(p["wo"], y.astype(x.dtype))[:, None, :]
    return out, SSMState(S, state.n, state.length + 1)
