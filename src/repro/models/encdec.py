"""Whisper-style encoder-decoder.

Per the assignment the conv audio frontend is a STUB: the model consumes
precomputed frame embeddings (B, n_frames, D) from input_specs().  The
encoder is a bidirectional dense transformer over frames; the decoder is a
dense causal transformer with cross-attention to encoder states in every
layer (standard whisper layout), learned positions on both sides.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .attention import attention_train, cross_attention, init_attention
from .layers import (dtype_of, init_embedding, init_mlp, init_norm,
                     init_linear, linear, mlp, rmsnorm)
from .transformer import (apply_layer_decode, init_layer, lm_logits)
from .attention import attention_decode, init_kv_cache


def init_encdec_params(key, cfg):
    dt = dtype_of(cfg.dtype)
    enc = cfg.encoder
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_norm(cfg.d_model, dt),
        "enc_final_norm": init_norm(cfg.d_model, dt),
        "pos_table": (jax.random.normal(ks[1], (cfg.max_position, cfg.d_model),
                                        jnp.float32) * 0.01).astype(dt),
        "enc_pos_table": (jax.random.normal(ks[2], (enc.n_frames, cfg.d_model),
                                            jnp.float32) * 0.01).astype(dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(ks[3], cfg.d_model, cfg.vocab_size, dt)

    # encoder layers: dense bidirectional
    enc_keys = jax.random.split(ks[4], enc.n_layers)
    params["enc_layers"] = jax.vmap(
        lambda k: init_layer(k, cfg, "dense"))(enc_keys)

    # decoder layers: self + cross + mlp (whisper decoder block)
    def init_dec_layer(k):
        k1, k2, k3, k4 = jax.random.split(k, 4)
        return {
            "norm1": init_norm(cfg.d_model, dt),
            "attn": init_attention(k1, cfg),
            "norm_x": init_norm(cfg.d_model, dt),
            "cross": init_attention(k2, cfg, cross=True),
            "norm2": init_norm(cfg.d_model, dt),
            "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dt, cfg.gated_mlp),
        }

    dec_keys = jax.random.split(ks[5], cfg.n_layers)
    params["dec_layers"] = jax.vmap(init_dec_layer)(dec_keys)
    return params


def encode(params, cfg, frames):
    """frames: (B, n_frames, D) precomputed embeddings (frontend stub)."""
    x = frames + params["enc_pos_table"][None, :frames.shape[1]]
    x = constrain(x, "batch", "frames", "dmodel")
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, p):
        h = attention_train(p["attn"], cfg,
                            rmsnorm(p["norm1"], x, cfg.norm_eps),
                            positions, causal=False)
        x = x + h
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                    cfg.activation)
        return constrain(x, "batch", "frames", "dmodel"), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


def _dec_layer_train(p, cfg, x, positions, memory):
    h = attention_train(p["attn"], cfg, rmsnorm(p["norm1"], x, cfg.norm_eps),
                        positions, causal=True)
    x = x + h
    x = x + cross_attention(p["cross"], cfg,
                            rmsnorm(p["norm_x"], x, cfg.norm_eps), memory)
    x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps), cfg.activation)
    return constrain(x, "batch", "seq", "dmodel")


def encdec_forward_train(params, cfg, frames, tokens):
    """Returns (hidden, aux) on the decoder side."""
    memory = encode(params, cfg, frames)
    x = params["embed"]["w"][tokens]
    b, s = x.shape[:2]
    x = x + params["pos_table"][None, :s]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(x, p):
        if cfg.remat:
            x = jax.checkpoint(
                lambda xx, pp: _dec_layer_train(pp, cfg, xx, positions, memory)
            )(x, p)
        else:
            x = _dec_layer_train(p, cfg, x, positions, memory)
        return x, None

    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32)


def init_encdec_cache(cfg, batch: int, max_len: int):
    dt = dtype_of(cfg.dtype)
    unit = {"kv": init_kv_cache(batch, cfg.n_kv_heads, max_len, cfg.hd, dt)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), unit)


def encdec_decode_step(params, cfg, tokens, cache, memory):
    """tokens: (B, s1) — one new token or a chunked-prefill chunk;
    memory: encoder output.  Returns (logits, cache)."""
    x = params["embed"]["w"][tokens]
    s1 = tokens.shape[1]
    length = jax.tree.leaves(cache)[-1]
    pos = length[0] if length.ndim else length
    x = x + jax.lax.dynamic_slice(params["pos_table"], (pos, 0),
                                  (s1, cfg.d_model))[None]

    def body(x, pc):
        p, c = pc
        h, kv = attention_decode(p["attn"], cfg,
                                 rmsnorm(p["norm1"], x, cfg.norm_eps),
                                 c["kv"])
        x = x + h
        x = x + cross_attention(p["cross"], cfg,
                                rmsnorm(p["norm_x"], x, cfg.norm_eps), memory)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                    cfg.activation)
        return x, {"kv": kv}

    x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, cfg, x), new_cache
