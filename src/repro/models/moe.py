"""Mixture-of-experts block: top-k router with capacity-based dispatch
einsums (Mesh-TF / GShard style — the formulation GSPMD shards well:
experts over the 'model' axis = expert parallelism, tokens over 'data').

Supports the two assigned MoE flavors:
* arctic-480b:   128 routed experts top-2  +  a parallel *dense residual*
                 FFN added to every token;
* qwen2-moe:     60 routed top-4  +  always-on shared experts.

Returns the router load-balance auxiliary loss (Switch/GShard LB loss) for
the training objective.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .layers import ACTIVATIONS, dtype_of, init_linear, init_mlp, linear, mlp


def init_moe(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    dt = dtype_of(cfg.dtype)
    ks = jax.random.split(key, 6)
    mult = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    def expert_bank(k, d_in, d_out):
        w = jax.random.normal(k, (m.n_experts, d_in, d_out), jnp.float32)
        return (w * (1.0 / d_in ** 0.5)).astype(dt)

    p = {
        "router": init_linear(ks[0], d, m.n_experts, jnp.float32),
        "w_up": expert_bank(ks[1], d, m.d_ff_expert),
        "w_down": expert_bank(ks[2], m.d_ff_expert, d),
    }
    if cfg.gated_mlp:
        p["w_gate"] = expert_bank(ks[3], d, m.d_ff_expert)
    if m.d_ff_shared:
        p["shared"] = init_mlp(ks[4], d, m.d_ff_shared, dt, cfg.gated_mlp)
    if m.dense_residual:
        p["dense"] = init_mlp(ks[5], d, m.d_ff_dense or cfg.d_ff, dt,
                              cfg.gated_mlp)
    return p


#: tokens per routing group — fixes the dispatch-tensor size per token
#: (B*S*gs*k*cf elements total) independent of sequence length
GROUP_SIZE = 2048


def moe_block(p, cfg, x):
    """x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    act = ACTIVATIONS[cfg.activation]
    bsz, seq, d = x.shape
    # regroup tokens into fixed-size routing groups so expert capacity (and
    # the dispatch one-hots) don't scale with sequence length
    gs = min(GROUP_SIZE, seq)
    while seq % gs != 0:
        gs //= 2
    x_in = x
    x = x.reshape(bsz * (seq // gs), gs, d)
    b, s, _ = x.shape
    e = m.n_experts
    capacity = max(1, int(s * m.top_k * m.capacity_factor / e))

    logits = linear(p["router"], x.astype(jnp.float32))          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k gates
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)          # (B,S,K)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # When experts divide the TP axis, EP handles layout (constraining the
    # token dim would fight the all-to-all — measured +11 GB on arctic);
    # when they don't (qwen2-moe: 60 experts), shard the dispatch one-hots
    # over the token dim instead (measured -3 GB).  §Perf iteration.
    from ..distributed import sharding as shd
    ctx = shd.active()
    ep_works = True
    if ctx is not None:
        mesh, rules = ctx
        ax = rules.get("experts")
        ep_works = bool(ax) and ax in mesh.shape and e % mesh.shape[ax] == 0
    tok_axes = (("batch", "kv_seq", None, None) if not ep_works
                else (None, None, None, None))

    def tok_constrain(t):
        return constrain(t, *tok_axes) if not ep_works else t

    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)      # (B,S,K,E)
    onehot = tok_constrain(onehot)
    flat = onehot.reshape(b, s * m.top_k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(b, s, m.top_k, e)
    pos = jnp.einsum("bske,bske->bsk", pos, onehot)              # (B,S,K)
    keep = pos < capacity
    gate_vals = gate_vals * keep

    # dispatch (B,S,E,C) / combine tensors
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)    # (B,S,K,C)
    dispatch = jnp.einsum("bske,bskc->bsec", onehot * keep[..., None], pos_oh)
    dispatch = tok_constrain(dispatch)
    combine = jnp.einsum("bske,bskc,bsk->bsec", onehot, pos_oh, gate_vals)
    combine = tok_constrain(combine)

    # expert-parallel layout: tokens routed to an expert live on its shard
    # (the all-to-all GSPMD inserts here is the EP dispatch)
    xe = jnp.einsum("bsec,bsd->becd", dispatch.astype(x.dtype), x)  # (B,E,C,D)
    xe = constrain(xe, "batch", "experts", None, None)
    h = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    if "w_gate" in p:
        h = h * act(jnp.einsum("becd,edf->becf", xe, p["w_gate"]))
    else:
        h = act(h)
    h = constrain(h, "batch", "experts", None, None)
    ye = jnp.einsum("becf,efd->becd", h, p["w_down"])            # (B,E,C,D)
    ye = constrain(ye, "batch", "experts", None, None)
    y = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), ye)

    if "shared" in p:
        y = y + mlp(p["shared"], x, cfg.activation)
    if "dense" in p:
        y = y + mlp(p["dense"], x, cfg.activation)

    # Switch-style load-balance loss: E * sum_e f_e * P_e
    f = jnp.mean(onehot.sum(2), axis=(0, 1))                     # fraction routed
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(f * pmean) * m.router_aux_weight
    return y.reshape(bsz, seq, d), aux
