"""Shared layer primitives (pure functional: init_* returns param pytrees,
apply functions take them explicitly)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_linear(key, d_in: int, d_out: int, dtype, bias: bool = False,
                scale: float | None = None):
    scale = scale if scale is not None else (1.0 / np.sqrt(d_in))
    w = (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def init_norm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def init_embedding(key, vocab: int, d: int, dtype):
    w = (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
    return {"w": w}


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------


def linear(p, x):
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {"silu": jax.nn.silu, "gelu": gelu, "relu": jax.nn.relu}


def init_mlp(key, d: int, d_ff: int, dtype, gated: bool):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": init_linear(k1, d, d_ff, dtype),
         "down": init_linear(k2, d_ff, d, dtype)}
    if gated:
        p["gate"] = init_linear(k3, d, d_ff, dtype)
    return p


def mlp(p, x, activation: str = "silu"):
    act = ACTIVATIONS[activation]
    h = linear(p["up"], x)
    if "gate" in p:
        h = h * act(linear(p["gate"], x))
    else:
        h = act(h)
    return linear(p["down"], h)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
