"""GQA attention: training (causal / bidirectional / sliding-window),
decode with KV cache, and cross-attention — all sharding-friendly einsum
formulations that GSPMD partitions over (data=batch, model=heads).

The Pallas flash kernel (repro.kernels.flash_attention) is a drop-in for
the prefill path on real TPUs (behind shard_map); the einsum path is what
the multi-pod dry-run lowers, so collectives are visible to GSPMD.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..distributed import sharding as shd
from .layers import apply_rope, init_linear, linear

NEG_INF = -1e30


def _attn_constrain(x, *, batch_dim=0, kvh_dim=1, seq_dim=3):
    """Shard (B, KVH, G, Sq, ...) attention internals: batch over the data
    axes always; the model axis goes to KV-heads when divisible, else to
    the q-sequence dim (sequence-parallel attention — softmax is over the
    *last* (kv) dim, so no extra collectives), else stays replicated."""
    ctx = shd.active()
    if ctx is None:
        return x
    mesh, rules = ctx
    data = rules.get("batch") or rules.get("batch_nopod")
    model = rules.get("heads")
    spec = [None] * x.ndim
    data_axes = data if isinstance(data, tuple) else (data,) if data else ()
    dsize = 1
    for a in data_axes:
        dsize *= mesh.shape[a]
    if data_axes and x.shape[batch_dim] % dsize == 0:
        spec[batch_dim] = data
    if model and model in mesh.shape:
        msize = mesh.shape[model]
        if x.shape[kvh_dim] % msize == 0:
            spec[kvh_dim] = model
        elif x.ndim > seq_dim and x.shape[seq_dim] % msize == 0:
            spec[seq_dim] = model
    from jax.sharding import NamedSharding, PartitionSpec as P
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def init_attention(key, cfg, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.hd
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    from .layers import dtype_of
    dt = dtype_of(cfg.dtype)
    return {
        "wq": init_linear(ks[0], d, h * hd, dt, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, kv * hd, dt, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, kv * hd, dt, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], h * hd, d, dt),
    }


class KVCache(NamedTuple):
    k: jax.Array          # (B, KV, S_max, hd)
    v: jax.Array
    length: jax.Array     # scalar int32: tokens already cached


def init_kv_cache(batch: int, kv_heads: int, max_len: int, hd: int, dtype):
    z = jnp.zeros((batch, kv_heads, max_len, hd), dtype)
    return KVCache(z, z, jnp.zeros((), jnp.int32))


def _split_heads(x, n, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n, hd).transpose(0, 2, 1, 3)   # (B, n, S, hd)


def _merge_heads(x):
    b, n, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, n * hd)


def _sdpa(q, k, v, mask, scale):
    """q: (B,H,Sq,hd); k,v: (B,KV,Skv,hd); GQA via reshape-grouping."""
    b, h, sq, hd = q.shape
    kvh = k.shape[1]
    g = h // kvh
    qg = _attn_constrain(q.reshape(b, kvh, g, sq, hd))
    s = jnp.einsum("bkgqd,bkld->bkgql", qg.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    s = _attn_constrain(s)
    if mask is not None:
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgql,bkld->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, hd).astype(q.dtype)


def _sdpa_chunked(q, k, v, *, causal: bool, window: int, scale: float,
                  chunk: int = 1024):
    """Flash-style online-softmax attention over KV chunks in plain jnp —
    the (Sq, Skv) score matrix is never materialized beyond (Sq, chunk).
    The per-chunk body is jax.checkpoint'ed so scan's reverse pass
    recomputes scores instead of stashing them (memory ~ O(S*chunk)).

    This is the GSPMD-visible twin of kernels/flash_attention (used for
    the dry-run and CPU runs); the Pallas kernel replaces it on hardware.
    """
    b, h, sq, hd = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    while skv % chunk != 0:
        chunk //= 2
    n = skv // chunk
    qg = (q.astype(jnp.float32) * scale).reshape(b, kvh, g, sq, hd)
    qg = _attn_constrain(qg)
    rows = jnp.arange(sq)[:, None]                      # q index == kv index

    def body(carry, i):
        o, m, l = carry
        kb = jax.lax.dynamic_slice(k, (0, 0, i * chunk, 0),
                                   (b, kvh, chunk, hd)).astype(jnp.float32)
        vb = jax.lax.dynamic_slice(v, (0, 0, i * chunk, 0),
                                   (b, kvh, chunk, hd)).astype(jnp.float32)
        s = jnp.einsum("bkgqd,bkld->bkgql", qg, kb)
        cols = i * chunk + jnp.arange(chunk)[None, :]
        if causal:
            valid = rows >= cols
            if window:
                valid &= cols > rows - window
            s = jnp.where(valid[None, None, None], s, NEG_INF)
        s = _attn_constrain(s)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        o_new = o * alpha[..., None] + jnp.einsum("bkgql,bkld->bkgqd", p, vb)
        return (_attn_constrain(o_new), m_new, l_new), None

    o0 = _attn_constrain(jnp.zeros((b, kvh, g, sq, hd), jnp.float32))
    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    (o, m, l), _ = jax.lax.scan(jax.checkpoint(body), (o0, m0, l0),
                                jnp.arange(n))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(b, h, sq, hd).astype(q.dtype)


#: sequences longer than this use the chunked path in attention_train
CHUNKED_ATTN_THRESHOLD = 2048


def causal_mask(sq: int, skv: int, window: int = 0, offset: int = 0):
    """(1, Sq, Skv) bool; offset = start position of q within kv timeline."""
    rows = offset + jnp.arange(sq)[:, None]
    cols = jnp.arange(skv)[None, :]
    m = rows >= cols
    if window:
        m = m & (cols > rows - window)
    return m[None]


def attention_train(p, cfg, x, positions, *, causal: bool = True,
                    window: int = 0):
    """Full-sequence attention (train / prefill)."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _split_heads(linear(p["wq"], x), h, hd)
    k = _split_heads(linear(p["wk"], x), kv, hd)
    v = _split_heads(linear(p["wv"], x), kv, hd)
    if cfg.positions == "rope":
        q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
        k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta).transpose(0, 2, 1, 3)
    sq = x.shape[1]
    if sq > CHUNKED_ATTN_THRESHOLD:
        o = _sdpa_chunked(q, k, v, causal=causal, window=window,
                          scale=hd ** -0.5)
    else:
        mask = causal_mask(sq, sq, window) if causal else None
        o = _sdpa(q, k, v, mask, hd ** -0.5)
    return linear(p["wo"], _merge_heads(o))


def attention_decode(p, cfg, x, cache: KVCache, *, window: int = 0):
    """Decode step of ``s1 >= 1`` new tokens against a KV cache.

    The cache is a ring buffer of capacity ``smax``: for full attention
    smax >= total length so the write index ``length % smax`` equals
    ``length``; for sliding-window attention smax == window, old entries
    are overwritten, and validity masking keeps exactly the last ``window``
    positions — attention is permutation-invariant over KV slots because
    RoPE is applied at *write* time with absolute positions.

    ``s1 > 1`` is the chunked-prefill path: the chunk is written
    contiguously and masked causally within itself.  Callers must keep a
    chunk from wrapping the ring buffer (``length % smax + s1 <= smax``) —
    the serving engine falls back to single-token steps near the window
    edge.

    x: (B, s1, D).  Returns (out, new_cache)."""
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    b, s1, _ = x.shape
    pos = cache.length + jnp.arange(s1)                   # (s1,)
    q = _split_heads(linear(p["wq"], x), h, hd)
    k_new = _split_heads(linear(p["wk"], x), kvh, hd)
    v_new = _split_heads(linear(p["wv"], x), kvh, hd)
    if cfg.positions == "rope":
        posb = jnp.broadcast_to(pos[None], (b, s1))
        q = apply_rope(q.transpose(0, 2, 1, 3), posb, cfg.rope_theta).transpose(0, 2, 1, 3)
        k_new = apply_rope(k_new.transpose(0, 2, 1, 3), posb, cfg.rope_theta).transpose(0, 2, 1, 3)
    smax = cache.k.shape[2]
    write_idx = cache.length % smax
    k = jax.lax.dynamic_update_slice(
        cache.k, k_new.astype(cache.k.dtype), (0, 0, write_idx, 0))
    v = jax.lax.dynamic_update_slice(
        cache.v, v_new.astype(cache.v.dtype), (0, 0, write_idx, 0))
    cols = jnp.arange(smax)[None, :]                      # (1, smax)
    # slots < length+s1 hold data; once wrapped, every slot is valid
    valid = cols < jnp.minimum(cache.length + s1, smax)
    # within the just-written chunk, query i must not see slots j > i
    off = cols - write_idx                                # slot offset in chunk
    future = (off > jnp.arange(s1)[:, None]) & (off < s1)
    mask = valid & ~future                                # (s1, smax)
    o = _sdpa(q, k, v, mask[None], hd ** -0.5)
    out = linear(p["wo"], _merge_heads(o))
    return out, KVCache(k, v, cache.length + s1)


# ---------------------------------------------------------------------------
# paged KV pool shim (serving)
# ---------------------------------------------------------------------------
# The serving scheduler accounts KV capacity in fixed-size blocks
# (repro.serving.kvblocks); physically the pool is one array of shape
# (num_blocks, KV, block_size, hd) per k/v.  A real paged-attention
# Pallas kernel would consume the block table directly; until then these
# two functions are the documented bridge: scatter a request's
# contiguous ring cache into its table's blocks, and gather a table back
# into the contiguous KVCache that attention_decode consumes.  The
# round trip is exact (property-tested), so the block manager can defrag
# or swap blocks without touching attention math.

def paged_kv_pool(num_blocks: int, block_size: int, kv_heads: int, hd: int,
                  dtype=jnp.float32):
    """Zeroed physical pool: (pool_k, pool_v), each
    (num_blocks, KV, block_size, hd)."""
    z = jnp.zeros((num_blocks, kv_heads, block_size, hd), dtype)
    return z, z


def scatter_block_kv(pool_k, pool_v, cache: KVCache, block_table):
    """Write a single-request contiguous cache into its pool blocks.

    cache.k/v: (1, KV, S, hd) with S <= len(block_table) * block_size
    (short caches are zero-padded into the last block).  Returns the
    updated (pool_k, pool_v)."""
    table = jnp.asarray(block_table, jnp.int32)
    nb, bs = table.shape[0], pool_k.shape[2]
    kvh, s, hd = cache.k.shape[1], cache.k.shape[2], cache.k.shape[3]
    if s > nb * bs:
        raise ValueError(f"cache length {s} exceeds table capacity {nb * bs}")

    def to_blocks(x):
        x = x[0]                                       # (KV, S, hd)
        x = jnp.pad(x, ((0, 0), (0, nb * bs - s), (0, 0)))
        return x.reshape(kvh, nb, bs, hd).transpose(1, 0, 2, 3)

    return (pool_k.at[table].set(to_blocks(cache.k).astype(pool_k.dtype)),
            pool_v.at[table].set(to_blocks(cache.v).astype(pool_v.dtype)))


def gather_block_kv(pool_k, pool_v, block_table, length) -> KVCache:
    """Assemble the contiguous (1, KV, nb * block_size, hd) cache a block
    table denotes — the gather a paged attention kernel makes implicit."""
    table = jnp.asarray(block_table, jnp.int32)
    nb, kvh, bs, hd = pool_k.shape
    nt = table.shape[0]

    def from_blocks(pool):
        x = pool[table]                                # (nt, KV, bs, hd)
        return x.transpose(1, 0, 2, 3).reshape(kvh, nt * bs, hd)[None]

    return KVCache(from_blocks(pool_k), from_blocks(pool_v),
                   jnp.asarray(length, jnp.int32))


def cross_attention(p, cfg, x, memory):
    """x: (B, S, D) attends to memory (B, M, D) (encoder states / image
    patch embeddings).  No positions on q/k (whisper & llama-vision style
    use their own; stubbed as none for the cross path)."""
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = _split_heads(linear(p["wq"], x), h, hd)
    k = _split_heads(linear(p["wk"], memory), kvh, hd)
    v = _split_heads(linear(p["wv"], memory), kvh, hd)
    o = _sdpa(q, k, v, None, hd ** -0.5)
    return linear(p["wo"], _merge_heads(o))
