"""Decoder-only LM assembly: scan-over-layers, all assigned block patterns.

Block patterns
  dense        — GQA attention + (gated) MLP                       [granite,
                 qwen1.5-4b/110b, starcoder2]
  moe          — GQA attention + MoE FFN (+ shared/dense residual) [arctic,
                 qwen2-moe]
  mlstm_slstm  — alternating mLSTM / sLSTM pairs, no FFN           [xlstm]
  hymba        — parallel attention + SSD heads, then MLP          [hymba]
  vlm          — dense blocks with a cross-attention block every
                 ``vision.cross_attn_every`` layers                [llama-vision]

Whisper's encoder-decoder lives in encdec.py and reuses these blocks.

Everything is scanned over layers (compile time ~O(1) in depth) with
optional jax.remat per layer; caches for decode are stacked along the layer
dimension and threaded through the scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .attention import (KVCache, attention_decode, attention_train,
                        cross_attention, init_attention, init_kv_cache)
from .layers import dtype_of, init_embedding, init_mlp, init_norm, linear, mlp, rmsnorm
from .moe import init_moe, moe_block
from .ssm import (SSMState, init_mlstm, init_slstm, init_ssd, init_ssm_state,
                  init_slstm_state, mlstm_decode, mlstm_train, slstm_decode,
                  slstm_train, ssd_decode, ssd_train, SLSTMState)


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------


def init_layer(key, cfg, kind: str):
    ks = jax.random.split(key, 6)
    dt = dtype_of(cfg.dtype)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": init_norm(d, dt)}
    if kind == "dense" or kind == "vlm_self":
        p["attn"] = init_attention(ks[0], cfg)
        p["norm2"] = init_norm(d, dt)
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dt, cfg.gated_mlp)
    elif kind == "moe":
        p["attn"] = init_attention(ks[0], cfg)
        p["norm2"] = init_norm(d, dt)
        p["moe"] = init_moe(ks[1], cfg)
    elif kind == "mlstm":
        p["mlstm"] = init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["slstm"] = init_slstm(ks[0], cfg)
    elif kind == "hymba":
        p["attn"] = init_attention(ks[0], cfg)
        p["ssd"] = init_ssd(ks[1], cfg)
        p["norm2"] = init_norm(d, dt)
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, dt, cfg.gated_mlp)
    elif kind == "cross":
        p["cross"] = init_attention(ks[0], cfg, cross=True)
        p["norm2"] = init_norm(d, dt)
        p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dt, cfg.gated_mlp)
    else:
        raise ValueError(kind)
    return p


def apply_layer_train(p, cfg, kind: str, x, positions, memory=None):
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    window = cfg.sliding_window
    if kind in ("dense", "vlm_self", "moe"):
        h = attention_train(p["attn"], cfg, rmsnorm(p["norm1"], x, cfg.norm_eps),
                            positions, causal=True, window=window)
        x = x + h
        x = constrain(x, "batch", "seq", "dmodel")
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if kind == "moe":
            y, aux = moe_block(p["moe"], cfg, h2)
        else:
            y = mlp(p["mlp"], h2, cfg.activation)
        x = x + y
    elif kind == "mlstm":
        x = x + mlstm_train(p["mlstm"], cfg,
                            rmsnorm(p["norm1"], x, cfg.norm_eps),
                            chunk=cfg.ssm.chunk if cfg.ssm else 256)
    elif kind == "slstm":
        x = x + slstm_train(p["slstm"], cfg,
                            rmsnorm(p["norm1"], x, cfg.norm_eps))
    elif kind == "hymba":
        h2 = rmsnorm(p["norm1"], x, cfg.norm_eps)
        attn_out = attention_train(p["attn"], cfg, h2, positions,
                                   causal=True, window=window)
        ssd_out = ssd_train(p["ssd"], cfg, h2,
                            chunk=cfg.ssm.chunk if cfg.ssm else 256)
        x = x + 0.5 * (attn_out + ssd_out)        # hymba head fusion (mean)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                    cfg.activation)
    elif kind == "cross":
        x = x + cross_attention(p["cross"], cfg,
                                rmsnorm(p["norm1"], x, cfg.norm_eps), memory)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                    cfg.activation)
    else:
        raise ValueError(kind)
    x = constrain(x, "batch", "seq", "dmodel")
    return x, aux


def apply_layer_decode(p, cfg, kind: str, x, cache, memory=None):
    """x: (B,1,D).  Returns (x, new_cache)."""
    window = cfg.sliding_window
    if kind in ("dense", "vlm_self", "moe"):
        h, cache_kv = attention_decode(
            p["attn"], cfg, rmsnorm(p["norm1"], x, cfg.norm_eps),
            cache["kv"], window=window)
        x = x + h
        h2 = rmsnorm(p["norm2"], x, cfg.norm_eps)
        if kind == "moe":
            y, _ = moe_block(p["moe"], cfg, h2)
        else:
            y = mlp(p["mlp"], h2, cfg.activation)
        x = x + y
        return x, {**cache, "kv": cache_kv}
    if kind == "mlstm":
        h, st = mlstm_decode(p["mlstm"], cfg,
                             rmsnorm(p["norm1"], x, cfg.norm_eps), cache["ssm"])
        return x + h, {**cache, "ssm": st}
    if kind == "slstm":
        h, st = slstm_decode(p["slstm"], cfg,
                             rmsnorm(p["norm1"], x, cfg.norm_eps), cache["sl"])
        return x + h, {**cache, "sl": st}
    if kind == "hymba":
        h2 = rmsnorm(p["norm1"], x, cfg.norm_eps)
        a, cache_kv = attention_decode(p["attn"], cfg, h2, cache["kv"],
                                       window=window)
        s, st = ssd_decode(p["ssd"], cfg, h2, cache["ssm"])
        x = x + 0.5 * (a + s)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                    cfg.activation)
        return x, {**cache, "kv": cache_kv, "ssm": st}
    if kind == "cross":
        x = x + cross_attention(p["cross"], cfg,
                                rmsnorm(p["norm1"], x, cfg.norm_eps), memory)
        x = x + mlp(p["mlp"], rmsnorm(p["norm2"], x, cfg.norm_eps),
                    cfg.activation)
        return x, cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# layer-stack plans: (kind, count) groups scanned independently
# ---------------------------------------------------------------------------


def stack_plan(cfg):
    """Layer grouping for scan: list of (kind, n_repeats, inner_kinds).
    inner_kinds is the heterogeneous unit scanned n_repeats times."""
    if cfg.block_pattern == "dense":
        return [("unit", cfg.n_layers, ("dense",))]
    if cfg.block_pattern == "moe":
        return [("unit", cfg.n_layers, ("moe",))]
    if cfg.block_pattern == "mlstm_slstm":
        assert cfg.n_layers % 2 == 0
        return [("unit", cfg.n_layers // 2, ("mlstm", "slstm"))]
    if cfg.block_pattern == "hymba":
        return [("unit", cfg.n_layers, ("hymba",))]
    if cfg.block_pattern == "vlm":
        e = cfg.vision.cross_attn_every
        assert cfg.n_layers % e == 0
        return [("unit", cfg.n_layers // e,
                 tuple(["vlm_self"] * (e - 1) + ["cross"]))]
    raise ValueError(cfg.block_pattern)


def init_decoder_params(key, cfg):
    """Embeddings + stacked layer groups + final norm + head."""
    dt = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": init_norm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        from .layers import init_linear
        params["lm_head"] = init_linear(keys[1], cfg.d_model, cfg.vocab_size,
                                        dt)
    if cfg.positions == "learned":
        params["pos_table"] = (jax.random.normal(
            keys[2], (cfg.max_position, cfg.d_model), jnp.float32) * 0.01
        ).astype(dt)
    plan = stack_plan(cfg)
    groups = []
    gkey = keys[3]
    for (name, n, kinds) in plan:
        gkey, sub = jax.random.split(gkey)
        layer_keys = jax.random.split(sub, n)

        def init_unit(k, kinds=kinds):
            uks = jax.random.split(k, len(kinds))
            return tuple(init_layer(uk, cfg, kind)
                         for uk, kind in zip(uks, kinds))

        groups.append(jax.vmap(init_unit)(layer_keys))
    params["groups"] = groups
    return params


def _unit_train(cfg, kinds, unit_params, x, positions, memory):
    aux = jnp.zeros((), jnp.float32)
    for kind, p in zip(kinds, unit_params):
        x, a = apply_layer_train(p, cfg, kind, x, positions, memory)
        aux = aux + a
    return x, aux


def decoder_forward_train(params, cfg, tokens, *, memory=None,
                          embeds=None):
    """tokens: (B, S) int32 (or embeds (B,S,D)).  Returns (logits, aux)."""
    if embeds is None:
        x = params["embed"]["w"][tokens]
    else:
        x = embeds
    x = constrain(x, "batch", "seq", "dmodel")
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.positions == "learned":
        x = x + params["pos_table"][:s][None]
    aux_total = jnp.zeros((), jnp.float32)
    for (name, n, kinds), stacked in zip(stack_plan(cfg), params["groups"]):
        def body(carry, unit_params, kinds=kinds):
            x, aux = carry
            fn = _unit_train
            if cfg.remat:
                fn = jax.checkpoint(
                    functools.partial(_unit_train, cfg, kinds),
                    static_argnums=())
                x, a = fn(unit_params, x, positions, memory)
            else:
                x, a = _unit_train(cfg, kinds, unit_params, x, positions,
                                   memory)
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stacked)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, aux_total


def lm_logits(params, cfg, x):
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"])
    else:
        logits = linear(params["lm_head"], x)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------


def init_decode_cache(cfg, batch: int, max_len: int):
    """Stacked caches per layer group, matching stack_plan order."""
    dt = dtype_of(cfg.dtype)
    kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    caches = []
    for (name, n, kinds) in stack_plan(cfg):
        def one(kind):
            c = {}
            if kind in ("dense", "vlm_self", "moe", "hymba"):
                c["kv"] = init_kv_cache(batch, cfg.n_kv_heads, kv_len,
                                        cfg.hd, dt)
            if kind in ("hymba",):
                h = cfg.ssm.n_ssm_heads or cfg.n_heads
                c["ssm"] = init_ssm_state(batch, h, cfg.ssm.state_dim, cfg.hd)
            if kind == "mlstm":
                c["ssm"] = init_ssm_state(batch, cfg.n_heads, cfg.hd, cfg.hd)
            if kind == "slstm":
                c["sl"] = init_slstm_state(batch, cfg.n_heads * cfg.hd)
            return c
        unit = tuple(one(k) for k in kinds)
        stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape),
                               unit)
        caches.append(stacked)
    return caches


def decoder_decode_step(params, cfg, tokens, caches, *, memory=None):
    """tokens: (B, s1) — one new token, or a chunked-prefill chunk.
    Returns (logits, new_caches)."""
    x = params["embed"]["w"][tokens]
    s1 = tokens.shape[1]
    if cfg.positions == "learned":
        # positions = current cache length .. length+s1 (uniform across layers)
        pos = caches_length(caches)
        x = x + jax.lax.dynamic_slice(params["pos_table"],
                                      (pos, 0), (s1, cfg.d_model))[None]
    new_caches = []
    for (name, n, kinds), stacked_p, stacked_c in zip(
            stack_plan(cfg), params["groups"], caches):
        def body(x, pc, kinds=kinds):
            unit_p, unit_c = pc
            new_unit_c = []
            for kind, p, c in zip(kinds, unit_p, unit_c):
                x, nc = apply_layer_decode(p, cfg, kind, x, c, memory)
                new_unit_c.append(nc)
            return x, tuple(new_unit_c)

        x, new_c = jax.lax.scan(body, x, (stacked_p, stacked_c))
        new_caches.append(new_c)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, cfg, x), new_caches


def caches_length(caches) -> jax.Array:
    """Current decode position (scalar) from the first stateful cache."""
    for leaf_path, leaf in _iter_named(caches):
        if leaf_path.endswith("length"):
            return leaf[0] if leaf.ndim else leaf
    return jnp.zeros((), jnp.int32)


def _iter_named(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _iter_named(v, f"{prefix}/{k}")
    elif hasattr(tree, "_fields"):
        for k in tree._fields:
            yield from _iter_named(getattr(tree, k), f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_named(v, f"{prefix}/{i}")
    else:
        yield prefix, tree
