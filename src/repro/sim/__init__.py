"""repro.sim — per-rank discrete-event simulation of cost-IR programs on
explicit network topologies.

The closed-form evaluator (``repro.perf.evaluate``) collapses network
contention into one calibrated scalar per phase; this package replays the
*same* IR programs rank-by-rank on a link-level topology model, so
contention emerges from where traffic actually collides:

  topology.py   Torus (k-ary n-cube, dimension-ordered routing) and the
                contention-free Crossbar baseline; CSR ``ShiftPlan``
                link-incidence arrays per shift pattern; ``topology_for``
                sizes (and memoizes) a torus for a machine
  fold.py       rank-symmetry folding: color refinement finds the
                coarsest equitable partition of a pattern, so one
                representative transfer per class is simulated with
                multiplicity-weighted link loads (exact; DESIGN.md §7)
  network.py    the fluid max-rate link engine: a transfer's rate is
                1 / (beta * max instantaneous load over its links);
                folded sparse event loop, plus the PR-3 per-transfer
                loop as ``engine="reference"`` (the agreement oracle)
  executor.py   ``simulate_program``: walks an IR program per rank —
                collectives expand step-by-step, Overlap branches race,
                Loop/ramp forms unroll; ``simulate_programs`` batches
                scenarios over shared route/fold caches
  result.py     ``SimResult`` (per-rank phases, critical path, link
                utilization, overlap efficiency) + Chrome-trace emission
                under ``artifacts/traces/``
  calibrate.py  ``derive_calibration``: C_avg / C_max tables from
                simulated link loads
  faults.py     declarative fault injection: ``FaultSpec`` bundles slow
                ranks (compute multipliers), degraded links (per-link
                beta multipliers) and dead links (reroute-or-
                unreachable), with optional onsets; applied inside
                ``Network``/``ProgramSimulator`` via ``faults=``

On a contention-free topology the simulated makespan equals the
closed-form ``est_NoCal`` estimate to float round-off (gated in CI); on a
torus it adds what the calibration factors only approximate — *where* the
contention happens and which rank carries the critical path.  The tuner
uses it as an opt-in second planning stage: ``Tuner.plan(...,
refine="sim")`` re-ranks the closed-form shortlist by simulated time.
"""

from .topology import Crossbar, ShiftPlan, Topology, Torus, topology_for
from .fold import Fold, build_fold, refine_partition, trivial_fold
from .faults import (DeadLink, DegradedLink, FaultSpec, FaultyTopology,
                     SlowRank, UnreachableError, torus_link)
from .network import LinkStats, Network, Transfer
from .executor import (MAX_UNROLL, ProgramSimulator, simulate_program,
                       simulate_programs)
from .result import RankPhase, SimResult, traces_dir
from .calibrate import (derive_calibration, hopper_like_topology,
                        shift_factors, v5e_pod_topology)

__all__ = [
    "Crossbar", "ShiftPlan", "Topology", "Torus", "topology_for",
    "Fold", "build_fold", "refine_partition", "trivial_fold",
    "DeadLink", "DegradedLink", "FaultSpec", "FaultyTopology",
    "SlowRank", "UnreachableError", "torus_link",
    "LinkStats", "Network", "Transfer",
    "MAX_UNROLL", "ProgramSimulator", "simulate_program",
    "simulate_programs",
    "RankPhase", "SimResult", "traces_dir",
    "derive_calibration", "hopper_like_topology", "shift_factors",
    "v5e_pod_topology",
]
