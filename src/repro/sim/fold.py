"""Rank-symmetry folding: lump transfers with provably identical dynamics.

The paper's traffic patterns — every rank shifting to ``rank + d`` — are
(near-)vertex-transitive on a torus, so most of the ``p`` transfers in a
pattern are indistinguishable: their routes see the same link loads at
every instant and they complete at exactly the same time.  Folding finds
those groups *structurally* and simulates one representative per group.

The grouping is the **coarsest equitable partition** of the bipartite
transfer/link incidence graph (seeded by the per-rank clock classes),
computed by classic color refinement (1-WL): alternately relabel links by
the multiset of their incident transfer classes and transfers by the
multiset of their route's link classes, until neither side splits.
Multisets are compared with random-linear-sum fingerprints (four
independent 32-bit draws per class, summed exactly in float64), the
standard collision-safe trick for vectorizing refinement.

Equitability is exactly the lumpability condition of the fluid max-rate
dynamics: every link of class ``m`` is crossed by the same number
``a[k, m]`` of class-``k`` transfers, and every class-``k`` transfer
crosses the same multiset of link classes — so if all members of a class
share a start time and message size (guaranteed by the clock-class seed),
their remaining words, rates and completion times stay identical for all
time, and the folded system

    load(m) = sum_k active(k) * a[k, m]
    rate(k) = 1 / (beta * max over route link classes m of load(m))

reproduces the unfolded solution exactly.  Two integrality checks
(``a[k, m]`` and the per-transfer route counts must be whole numbers)
reject the astronomically unlikely fingerprint collision — and any such
rejection falls back to the trivial partition, which is always equitable:
folding degrades to the plain vectorized sparse engine, never to a wrong
answer.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from .topology import ShiftPlan

#: refinement rounds before giving up on folding (each non-final round
#: must split at least one class, so symmetric patterns converge in a
#: handful; hitting the cap means the pattern is effectively asymmetric
#: and the trivial partition is used instead).
MAX_REFINE_ROUNDS = 48

#: independent 32-bit fingerprint draws per class per round.  Four give
#: 128 bits: a multiset collision that survives a round is ~2^-128, and
#: the integrality checks below catch stragglers.
_FINGERPRINT_WORDS = 4


@dataclasses.dataclass
class Fold:
    """A lumped view of one transfer pattern.

    ``t_class`` maps each of the ``T`` transfers to one of ``K`` classes;
    ``rep`` picks a representative transfer per class and ``mult`` counts
    members.  ``row_*`` is a CSR matrix over (class, link-class) pairs
    whose values ``a[k, m]`` are *per-physical-link* crossing counts;
    its row sparsity doubles as the representative's route in link-class
    space (the bottleneck max runs over it).  ``l_class`` classifies the
    pattern's ``L`` distinct physical links so per-class stats expand
    back to real links.
    """

    t_class: np.ndarray         # (T,) transfer -> class
    K: int
    M: int
    mult: np.ndarray            # (K,) members per class
    rep: np.ndarray             # (K,) representative transfer index
    row_ptr: np.ndarray         # (K+1,) CSR over classes
    row_m: np.ndarray           # (nnz_f,) link-class column ids
    row_a: np.ndarray           # (nnz_f,) a[k, m] per-link crossing counts
    entry_k: np.ndarray         # (nnz_f,) row id per CSR entry
    l_class: np.ndarray         # (L,) unique physical link -> link class
    nonempty: np.ndarray        # (K,) rows with at least one link

    @property
    def folded(self) -> bool:
        return self.K < self.t_class.size


def _fingerprints(n_labels: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(0xF01D ^ seed)
    # float64 so bincount-weight sums stay exact: values < 2^32 and
    # nnz < 2^21 keep every sum below 2^53.
    return rng.integers(0, 1 << 32, size=(n_labels, _FINGERPRINT_WORDS)
                        ).astype(np.float64)


_FNV = np.uint64(0x100000001B3)
_SALT = np.uint64(0x9E3779B97F4A7C15)


def _canon(parts) -> Tuple[np.ndarray, int]:
    """Dense 0..K-1 relabeling of row-tuples.  ``parts`` is a sequence of
    equal-length integer-valued arrays (one column each); rows are mixed
    into a single uint64 key (FNV-style, vectorized) so the relabeling is
    one cheap 1-D ``np.unique`` instead of a structured-dtype sort.  A
    key collision can only *merge* classes — which the equitability
    integrality check in :func:`build_fold` then rejects."""
    h = np.full(parts[0].shape[0], _SALT, dtype=np.uint64)
    with np.errstate(over="ignore"):
        for arr in parts:
            h = (h ^ (arr.astype(np.uint64) + _SALT)) * _FNV
    _, inv = np.unique(h, return_inverse=True)
    inv = inv.astype(np.int64).ravel()
    return inv, (int(inv.max()) + 1 if inv.size else 0)


def refine_partition(owner: np.ndarray, lid: np.ndarray, T: int, L: int,
                     init_labels: np.ndarray,
                     indptr: Optional[np.ndarray] = None,
                     static_load: Optional[np.ndarray] = None,
                     link_seed: Optional[np.ndarray] = None
                     ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Color-refine the transfer/link incidence to its coarsest equitable
    partition.  Returns ``(t_class, l_class)`` or None when the round cap
    is hit (caller falls back to the trivial partition).

    ``indptr``/``static_load``, when given, enrich the seeds with what the
    first rounds would otherwise spend bincounts discovering: links start
    split by static load, transfers by (seed, hop count, static
    bottleneck) — refinement only ever *splits*, so a finer valid seed
    changes nothing but the round count.

    ``link_seed`` is an optional per-unique-link integer label folded into
    the initial link partition — fault injection seeds degraded links into
    their own classes here, so the equitability the fluid engine relies on
    also covers the per-link beta scales (a class never mixes scales)."""
    if indptr is not None and static_load is not None:
        l_parts = [static_load] if link_seed is None \
            else [static_load, link_seed]
        l_lab, M = _canon(l_parts)
        hops = np.diff(indptr)
        bneck = np.zeros(T, dtype=np.int64)
        routed = hops > 0
        if routed.any():
            bneck[routed] = np.maximum.reduceat(
                static_load[lid], indptr[:-1][routed])
        t_lab, K = _canon([init_labels, hops, bneck])
    else:
        t_lab, K = _canon([init_labels])
        if link_seed is not None and L:
            l_lab, M = _canon([link_seed])
        else:
            l_lab = np.zeros(L, dtype=np.int64)
            M = 1 if L else 0
    sums = np.empty((L, _FINGERPRINT_WORDS))
    tsum = np.empty((T, _FINGERPRINT_WORDS))
    for rnd in range(MAX_REFINE_ROUNDS):
        # links <- multiset of incident transfer classes
        tv = _fingerprints(K, 2 * rnd)
        tw = tv[t_lab[owner]]
        for w in range(_FINGERPRINT_WORDS):
            sums[:, w] = np.bincount(lid, weights=tw[:, w], minlength=L)
        l_lab, M_new = _canon([l_lab] + [sums[:, w]
                                         for w in range(_FINGERPRINT_WORDS)])
        # transfers <- multiset of route link classes
        lv = _fingerprints(M_new, 2 * rnd + 1)
        lw = lv[l_lab[lid]]
        for w in range(_FINGERPRINT_WORDS):
            tsum[:, w] = np.bincount(owner, weights=lw[:, w], minlength=T)
        t_lab, K_new = _canon([t_lab] + [tsum[:, w]
                                         for w in range(_FINGERPRINT_WORDS)])
        if K_new == K and M_new == M:
            return t_lab, l_lab
        K, M = K_new, M_new
    return None


def trivial_fold(plan_T: int, indptr: np.ndarray, link_idx: np.ndarray,
                 owner: np.ndarray, L: int) -> Fold:
    """The finest partition — every transfer its own class.  Always
    equitable; this is the plain vectorized sparse engine."""
    T = plan_T
    return Fold(
        t_class=np.arange(T, dtype=np.int64), K=T, M=L,
        mult=np.ones(T, dtype=np.int64),
        rep=np.arange(T, dtype=np.int64),
        row_ptr=indptr.copy(), row_m=link_idx, row_a=np.ones(link_idx.size),
        entry_k=owner, l_class=np.arange(L, dtype=np.int64),
        nonempty=np.diff(indptr) > 0)


def build_fold(plan: ShiftPlan, init_labels: np.ndarray,
               link_seed: Optional[np.ndarray] = None) -> Fold:
    """Fold a shift pattern given per-transfer seed labels (clock classes;
    callers must also fold message size into the seed when it varies).
    ``link_seed`` pre-splits the link partition (per-unique-link labels,
    e.g. fault-injection beta-scale classes); see
    :func:`refine_partition`."""
    T, L = plan.p, plan.uniq_links.size
    owner, lid = plan.owner, plan.link_idx
    fallback = lambda: trivial_fold(T, plan.indptr, lid, owner, L)  # noqa: E731
    refined = refine_partition(owner, lid, T, L, init_labels,
                               indptr=plan.indptr,
                               static_load=plan.static_load,
                               link_seed=link_seed)
    if refined is None:
        return fallback()
    t_lab, l_lab = refined
    K = int(t_lab.max()) + 1 if T else 0
    M = int(l_lab.max()) + 1 if L else 0
    if K >= T:
        return fallback()  # nothing folded; skip the bookkeeping
    # a[k, m]: class-k transfers crossing ONE physical link of class m
    pairs = t_lab[owner] * np.int64(M) + l_lab[lid]
    uniq_pairs, cnt = np.unique(pairs, return_counts=True)
    k_arr, m_arr = uniq_pairs // M, uniq_pairs % M
    links_per_class = np.bincount(l_lab, minlength=M)
    a = cnt / links_per_class[m_arr]
    mult = np.bincount(t_lab, minlength=K)
    b = cnt / mult[k_arr]  # class-m links on one class-k route
    # integrality is the equitability witness; a fingerprint collision
    # that merged distinguishable classes breaks it -> refuse to fold.
    # Absolute tolerance only: a relative one would wave through the
    # small fractional deviations (~1/mult) a bad merge produces.
    if not (np.allclose(a, np.rint(a), rtol=0.0, atol=1e-9)
            and np.allclose(b, np.rint(b), rtol=0.0, atol=1e-9)):
        return fallback()
    rep = np.full(K, T, dtype=np.int64)
    np.minimum.at(rep, t_lab, np.arange(T, dtype=np.int64))
    row_ptr = np.zeros(K + 1, dtype=np.int64)
    np.cumsum(np.bincount(k_arr, minlength=K), out=row_ptr[1:])
    return Fold(
        t_class=t_lab, K=K, M=M, mult=mult, rep=rep,
        row_ptr=row_ptr, row_m=m_arr, row_a=np.rint(a), entry_k=k_arr,
        l_class=l_lab, nonempty=np.diff(row_ptr) > 0)
