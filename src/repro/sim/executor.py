"""Per-rank discrete-event execution of cost-IR programs.

``simulate_program`` replays a :class:`repro.perf.Program` on an explicit
:class:`~repro.sim.topology.Topology`: every rank runs the same SPMD
program, and each communication leaf becomes the paper's calibration
traffic pattern — all ``p`` ranks simultaneously transferring to the rank
at the node's communication distance — delivered by the link-contention
:class:`~repro.sim.network.Network`.  Node semantics:

* ``Compute``      — the fitted efficiency curves, exactly the closed-form
                     ``T_rout`` (one busy interval per rank);
* ``P2P``/``SyncP2P`` — a shift-by-``dist`` pattern; a rank proceeds when
                     both its outgoing and incoming message are delivered
                     (synchronization is *emergent*, not a ``C_max``
                     factor);
* ``Collective``   — expanded step-by-step via
                     ``repro.perf.collective_schedule``, each step its own
                     shift pattern;
* ``Loop``         — unrolled, with steady-state fast-forwarding: once an
                     iteration's per-rank clock delta repeats, the rest
                     advance analytically (exact in lockstep execution);
                     the fractional part of a collapsed closed-form count
                     runs once with leaf costs scaled, and pure-compute
                     bodies collapse analytically;
* ``Overlap``      — both branches race from the same per-rank start
                     clocks and join at the elementwise max; the ramp form
                     unrolls iteration ``m`` with comm scaled by ``m`` and
                     comp by ``m^2``.

Contention scope is *per pattern* (the paper's calibration benchmark
semantics): messages of one communication step contend with each other —
at per-rank staggered start times once ranks have drifted — but not with
messages of other steps.  On a contention-free topology every transfer
takes its ideal alpha-beta time and ranks stay in lockstep, so the
simulated makespan equals the closed-form ``est_NoCal`` estimate to float
round-off — the cross-validation gate in ``tests/test_sim.py``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import numpy as np

from ..core.perfmodel import ROUTINE_FLOPS
from ..perf import collective_schedule
from ..perf.ir import (Collective, Compute, Loop, Node, Overlap, P2P, Program,
                       Seq, SyncP2P)
from .faults import FaultSpec
from .network import Network
from .result import RankPhase, SimResult
from .topology import Topology, topology_for

#: hard ceiling on unrolled iterations of a single Loop/Overlap node —
#: a guard rail against accidentally simulating a million-step program,
#: not a tuning knob (the paper-scale programs unroll a few hundred).
MAX_UNROLL = 200_000


class ProgramSimulator:
    """One simulation of ``program`` for a scalar scenario on a topology."""

    def __init__(self, program: Program, ctx, topology: Topology,
                 n: float, p: int, c: float = 1, r: float = 1,
                 *, fold: bool = True, engine: str = "vector",
                 faults: Optional[FaultSpec] = None):
        p = int(p)
        if p < 1:
            raise ValueError(f"need p >= 1, got {p}")
        if p > topology.n_nodes:
            raise ValueError(f"p={p} exceeds topology size "
                             f"{topology.n_nodes} ({topology!r})")
        self.program = program
        self.topology = topology
        self.p = p
        self.env = {"n": float(n), "p": float(p), "c": float(c),
                    "r": float(r),
                    "t": float(ctx.comp.machine.threads_per_unit)}
        self.comp_machine = ctx.comp.machine
        self.efficiency = ctx.comp.efficiency
        self.latency = ctx.comm.machine.latency
        self.beta = ctx.comm.machine.inv_bandwidth
        self.faults = faults if faults is not None and not faults.empty \
            else None
        self._max_onset = self.faults.max_onset_s if self.faults else 0.0
        self.net = Network(topology, self.latency, self.beta,
                           fold=fold, engine=engine, faults=self.faults)
        self.compute_events = 0
        self.phases: Dict[str, RankPhase] = {}

    # -- leaf costs ----------------------------------------------------------
    def _t_rout(self, node: Compute) -> float:
        """Identical math to the closed-form evaluator's ``_t_rout``."""
        block = float(node.block.ev(self.env))
        if block <= 0:
            return 0.0
        m = self.comp_machine
        t = (m.threads_per_unit if node.threads is None
             else float(node.threads.ev(self.env)))
        t = min(max(t, 1.0), float(m.threads_per_unit))
        flops = ROUTINE_FLOPS[node.routine](block)
        eff = float(self.efficiency[node.routine].ev(block))
        return flops / (m.peak_flops_per_thread * t * eff)

    def _shift(self, clocks: np.ndarray, words: float, dist: float,
               scale: float) -> Tuple[np.ndarray, np.ndarray]:
        """All p ranks transfer ``words`` to rank+round(dist) starting at
        their current clocks; a rank's clock advances to the max of its
        outgoing and incoming delivery.  Returns (clocks', exposed)."""
        p = self.p
        d = int(round(float(dist))) % p
        w = float(words) * scale
        lat = self.latency * scale
        if d == 0:
            # local copy (or p == 1): ideal time, never contended
            done = clocks + (lat + self.beta * w)
            self.net.events += 2 * p
            return done, done - clocks
        done = self.net.deliver_shift(clocks, w, d, lat)
        rolled = np.empty_like(done)  # roll(done, d)[r] = done[r - d]
        rolled[:d] = done[p - d:]
        rolled[d:] = done[:p - d]
        new = np.maximum(done, rolled)
        return new, new - clocks

    # -- walk ----------------------------------------------------------------
    def _zeros(self) -> np.ndarray:
        return np.zeros(self.p)

    def _compute_only_seconds(self, node: Node) -> Optional[float]:
        """Unscaled seconds of a communication-free subtree, or None.
        Pure-compute loops advance every rank identically, so they collapse
        to ``count * body`` without unrolling (exactly the closed form)."""
        if isinstance(node, Compute):
            return self._t_rout(node)
        if isinstance(node, Seq):
            total = 0.0
            for _label, ch in node.children:
                s = self._compute_only_seconds(ch)
                if s is None:
                    return None
                total += s
            return total
        if isinstance(node, Loop):
            s = self._compute_only_seconds(node.body)
            if s is None:
                return None
            return max(float(node.count.ev(self.env)), 0.0) * s
        return None

    def _walk(self, node: Node, clocks: np.ndarray, scale: float
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Advance per-rank ``clocks`` through ``node``; returns
        (clocks', comm_ledger_delta, comp_ledger_delta)."""
        if isinstance(node, Compute):
            dur = self._t_rout(node) * scale
            self.compute_events += self.p
            if self.faults is not None:
                rs = self.faults.compute_scales(clocks)
                if rs is not None:
                    dvec = dur * rs
                    return clocks + dvec, self._zeros(), dvec
            return clocks + dur, self._zeros(), np.full(self.p, dur)
        if isinstance(node, (P2P, SyncP2P)):
            new, exposed = self._shift(clocks, node.words.ev(self.env),
                                       node.dist.ev(self.env), scale)
            return new, exposed, self._zeros()
        if isinstance(node, Collective):
            return self._collective(node, clocks, scale)
        if isinstance(node, Seq):
            cm, cp = self._zeros(), self._zeros()
            for _label, ch in node.children:
                clocks, a, b = self._walk(ch, clocks, scale)
                cm, cp = cm + a, cp + b
            return clocks, cm, cp
        if isinstance(node, Loop):
            return self._loop(node, clocks, scale)
        if isinstance(node, Overlap):
            return self._overlap(node, clocks, scale)
        raise TypeError(f"unknown IR node {type(node).__name__}")

    def _collective(self, node: Collective, clocks: np.ndarray, scale: float
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        q = float(node.q.ev(self.env))
        w = float(node.words.ev(self.env))
        d = float(node.dist.ev(self.env))
        cm = self._zeros()
        if node.kind == "inirepl":
            # initial c-fold replication: two transfers at distance
            # (c-1)*p/c (q carries c), zero when unreplicated
            if q > 1:
                dist = (q - 1.0) * self.env["p"] / q
                for _ in range(2):
                    clocks, exposed = self._shift(clocks, w, dist, scale)
                    cm = cm + exposed
            return clocks, cm, self._zeros()
        for step in collective_schedule(node.kind, q, w, d):
            clocks, exposed = self._shift(clocks, step.words, step.dist, scale)
            cm = cm + exposed
        return clocks, cm, self._zeros()

    def _split_count(self, count: float) -> Tuple[int, float]:
        count = max(float(count), 0.0)
        whole = int(math.floor(count + 1e-9))
        frac = max(count - whole, 0.0)
        if whole > MAX_UNROLL:
            raise ValueError(f"loop count {count:g} exceeds MAX_UNROLL="
                             f"{MAX_UNROLL}; not simulatable")
        return whole, frac

    def _iterate(self, body_fn, clocks: np.ndarray, whole: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Run ``whole`` identical iterations of ``body_fn`` with
        steady-state fast-forwarding: once the per-rank clock delta of an
        iteration matches the previous one (to 1e-9 relative), the
        remaining repetitions advance analytically as ``k * delta``.

        In lockstep (contention-free) execution the delta is constant from
        the first iteration, so the fast-forward is exact — it reproduces
        the closed form's linear ``count * body`` charging.  Under
        contention the schedule settles into a periodic steady state after
        a few iterations and the extrapolation preserves it."""
        cm, cp = self._zeros(), self._zeros()
        prev_delta = None
        i = 0
        while i < whole:
            before = clocks
            snap = (self.net.stats.snapshot(), self.net.events,
                    self.compute_events)
            clocks, a, b = body_fn(clocks)
            cm, cp = cm + a, cp + b
            i += 1
            delta = clocks - before
            # fast-forwarding is unsafe while a fault onset is still ahead
            # of any rank: the iteration just simulated is not yet the
            # steady state the extrapolation would repeat
            ff_ok = self.faults is None \
                or float(before.min()) >= self._max_onset
            if ff_ok and prev_delta is not None and i < whole and np.allclose(
                    delta, prev_delta, rtol=1e-9,
                    atol=1e-12 * (float(np.abs(delta).max()) + 1e-300)):
                k = whole - i
                clocks = clocks + k * delta
                cm, cp = cm + k * a, cp + k * b
                # the skipped iterations carry the same traffic/events as
                # the one just simulated — keep the diagnostics honest
                self.net.stats.amplify_since(snap[0], k)
                self.net.events += k * (self.net.events - snap[1])
                self.compute_events += k * (self.compute_events - snap[2])
                break
            prev_delta = delta
        return clocks, cm, cp

    def _loop(self, node: Loop, clocks: np.ndarray, scale: float
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        count = max(float(node.count.ev(self.env)), 0.0)
        pure = self._compute_only_seconds(node.body)
        if pure is not None and self.faults is not None \
                and self.faults.slow_ranks:
            pure = None  # slow ranks break the all-ranks-identical collapse
        if pure is not None:
            dur = pure * scale * count
            self.compute_events += self.p
            return clocks + dur, self._zeros(), np.full(self.p, dur)
        whole, frac = self._split_count(count)
        clocks, cm, cp = self._iterate(
            lambda c: self._walk(node.body, c, scale), clocks, whole)
        if frac > 1e-12:
            clocks, a, b = self._walk(node.body, clocks, scale * frac)
            cm, cp = cm + a, cp + b
        return clocks, cm, cp

    def _overlap_once(self, node: Overlap, clocks: np.ndarray,
                      cscale: float, pscale: float
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        ca_clk, ca_cm, ca_cp = self._walk(node.comm, clocks, cscale)
        cb_clk, cb_cm, cb_cp = self._walk(node.comp, clocks, pscale)
        return (np.maximum(ca_clk, cb_clk), ca_cm + cb_cm, ca_cp + cb_cp)

    def _overlap(self, node: Overlap, clocks: np.ndarray, scale: float
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        cm, cp = self._zeros(), self._zeros()
        if node.ramp is not None:
            # right-looking ramp: trailing size m shrinks k-1 .. 1; comm is
            # linear in m, the update quadratic (see perf.ir.Overlap)
            k = int(np.rint(float(node.ramp.ev(self.env))))
            if k - 1 > MAX_UNROLL:
                raise ValueError(f"ramp of {k} iterations exceeds "
                                 f"MAX_UNROLL={MAX_UNROLL}")
            for m in range(k - 1, 0, -1):
                clocks, a, b = self._overlap_once(node, clocks,
                                                 scale * m, scale * m * m)
                cm, cp = cm + a, cp + b
            return clocks, cm, cp
        whole, frac = self._split_count(float(node.count.ev(self.env)))
        clocks, cm, cp = self._iterate(
            lambda c: self._overlap_once(node, c, scale, scale), clocks, whole)
        if frac > 1e-12:
            clocks, a, b = self._overlap_once(node, clocks,
                                              scale * frac, scale * frac)
            cm, cp = cm + a, cp + b
        return clocks, cm, cp

    # -- entry point ---------------------------------------------------------
    def _record(self, label: str, start, exposed, cm, cp) -> None:
        ph = self.phases.get(label)
        if ph is None:
            self.phases[label] = RankPhase(start, exposed, cm, cp)
        else:
            ph.exposed = ph.exposed + exposed
            ph.comm = ph.comm + cm
            ph.comp = ph.comp + cp

    def run(self) -> SimResult:
        """Simulate the program; top-level phases follow the evaluator's
        convention (only the root Seq's direct children are phases)."""
        clocks = self._zeros()
        tot_cm, tot_cp = self._zeros(), self._zeros()
        root = self.program.root
        children = (root.children if isinstance(root, Seq)
                    else ((None, root),))
        for i, (label, child) in enumerate(children):
            before = clocks
            clocks, cm, cp = self._walk(child, clocks, 1.0)
            tot_cm, tot_cp = tot_cm + cm, tot_cp + cp
            name = label if label is not None else (
                f"phase{i}" if isinstance(root, Seq) else "total")
            self._record(name, before, clocks - before, cm, cp)
        return SimResult(
            algo=self.program.algo, variant=self.program.variant,
            n=self.env["n"], p=self.p, c=self.env["c"], r=self.env["r"],
            topology=repr(self.topology),
            total=float(clocks.max()), per_rank=clocks,
            comm=tot_cm, comp=tot_cp, phases=self.phases,
            link_stats=self.net.stats,
            events=self.net.events + self.compute_events,
            engine=self.net.engine)


def simulate_program(program: Program, ctx, topology: Topology,
                     n: float, p: int, c: float = 1, r: float = 1,
                     *, fold: bool = True, engine: str = "vector",
                     faults: Optional[FaultSpec] = None) -> SimResult:
    """Simulate one scalar scenario of ``program`` on ``topology`` using
    the machine surfaces of ``ctx`` (the same ``AlgoContext`` the
    closed-form evaluator takes).  Ranks 0..p-1 map to topology nodes
    0..p-1.

    ``fold=False`` opts out of rank-symmetry folding (still the
    vectorized sparse engine) for traffic the class detector cannot lump;
    ``engine="reference"`` replays through the PR-3 per-transfer event
    loop — the agreement oracle the CI gate compares against;
    ``faults`` injects per-component degradation
    (:class:`~repro.sim.faults.FaultSpec`)."""
    return ProgramSimulator(program, ctx, topology, n, p, c, r,
                            fold=fold, engine=engine, faults=faults).run()


def simulate_programs(programs, ctx, scenarios, *, topology=None,
                      machine=None, fold: bool = True,
                      engine: str = "vector", strict: bool = True,
                      faults: Optional[FaultSpec] = None):
    """Batch simulation: replay ``programs`` over ``scenarios`` in one
    call, sharing every route/fold cache across runs.

    ``programs`` is one :class:`~repro.perf.ir.Program` (broadcast over
    all scenarios) or a sequence zipped 1:1 with ``scenarios``; each
    scenario is a ``{"n": ..., "p": ..., "c": ..., "r": ...}`` mapping
    (``c``/``r`` default to 1).  ``topology`` pins one explicit topology
    for every run; otherwise each run gets ``topology_for(machine, p)``
    — memoized, so same-``p`` candidates share one instance and its
    caches.  ``strict=False`` turns per-run failures into ``None``
    entries instead of raising (the telemetry join uses this: one bad
    scenario must not sink the batch).

    This is the tuner's shortlist re-rank and telemetry's ``include_sim``
    entry point: the expensive artifacts — CSR link-incidence plans and
    symmetry folds — are keyed on the topology instance, so simulating k
    candidates costs one route construction, not k.
    """
    if topology is None and machine is None:
        raise ValueError("pass topology= or machine= (a machine profile "
                         "with torus_dims); otherwise every scenario would "
                         "silently simulate contention-free")
    scenarios = list(scenarios)
    if isinstance(programs, Program):
        programs = [programs] * len(scenarios)
    else:
        programs = list(programs)
        if len(programs) != len(scenarios):
            raise ValueError(f"{len(programs)} programs vs "
                             f"{len(scenarios)} scenarios")
    results = []
    for prog, scen in zip(programs, scenarios):
        try:
            p = int(scen["p"])
            topo = topology if topology is not None \
                else topology_for(machine, p)
            results.append(ProgramSimulator(
                prog, ctx, topo, float(scen["n"]), p,
                float(scen.get("c", 1)), float(scen.get("r", 1)),
                fold=fold, engine=engine, faults=faults).run())
        except Exception:
            if strict:
                raise
            results.append(None)
    return results
