"""Deriving ``C_avg`` / ``C_max`` calibration surfaces from simulated link
loads — the planning surface for machines we cannot benchmark (the paper's
extrapolation use-case).

Two derivation modes over the same topology layer:

* ``"static"`` (default) — the calibration factor of a rank is the peak
  load on its own DOR path when all ``p`` ranks shift simultaneously
  (serialization on the most-contended link).  This reproduces the
  pre-PR-3 ``core.calibration.ContentionSimulator`` numbers bit-for-bit,
  so tables consumed by the LM-step model and the tuner were unchanged by
  the migration.
* ``"des"`` — run the shift pattern through the fluid max-rate
  :class:`~repro.sim.network.Network` and read the factor off the actual
  completion times (``C = t / t_ideal``).  Dynamic factors are <= the
  static ones because link rates recover as competing transfers drain.
"""

from __future__ import annotations

from typing import Dict, Sequence, Tuple

import numpy as np

from ..core.perfmodel import CalibrationTable
from .network import Network, Transfer
from .topology import Topology, Torus


def hopper_like_topology() -> Torus:
    """A Gemini-like 3D torus sized for 4096 processes (Hopper scale)."""
    return Torus((16, 16, 16))


def v5e_pod_topology() -> Torus:
    """A v5e pod: 16x16 2D ICI torus (256 chips)."""
    return Torus((16, 16))


def shift_factors(topology: Topology, p: int, distance: int,
                  *, mode: str = "static") -> Tuple[float, float]:
    """(C_avg, C_max) when all ``p`` ranks send rank -> rank+distance."""
    p = min(int(p), topology.n_nodes)
    if mode == "static":
        paths = [topology.route(src, (src + distance) % p) for src in range(p)]
        load: Dict[int, int] = {}
        for path in paths:
            for link in path:
                load[link] = load.get(link, 0) + 1
        per_rank = [float(max((load[l] for l in path), default=1.0))
                    for path in paths]
        return float(np.mean(per_rank)), float(np.max(per_rank))
    if mode == "des":
        # unit-words transfers at beta=1, L=0: completion time IS the
        # effective serialization factor of each rank's message
        net = Network(topology, latency=0.0, beta=1.0)
        done = net.deliver([Transfer(src, (src + distance) % p, 1.0, 0.0)
                            for src in range(p)])
        done = np.maximum(done, 1.0)
        return float(done.mean()), float(done.max())
    raise ValueError(f"mode must be 'static' or 'des', got {mode!r}")


def derive_calibration(topology: Topology, ps: Sequence[int],
                       distances: Sequence[int],
                       *, mode: str = "static") -> CalibrationTable:
    """Build a :class:`~repro.core.perfmodel.CalibrationTable` from
    simulated link loads on ``topology``, over a grid of process counts and
    shift distances.  Mirrors the paper's Fig. 3-4 aggregation: ``C_avg``
    is averaged over ``p`` (the paper finds it ~independent of p) while
    ``C_max`` keeps the full (p, d) surface."""
    avg: Dict[float, float] = {}
    mx: Dict[Tuple[float, float], float] = {}
    for d in distances:
        avgs = []
        for p in ps:
            a, m = shift_factors(topology, p, d, mode=mode)
            mx[(float(p), float(d))] = m
            avgs.append(a)
        avg[float(d)] = float(np.mean(avgs))
    return CalibrationTable(avg=avg, mx=mx)
