"""Structured simulation output and the Chrome-trace emitter.

A :class:`SimResult` is the simulator's analog of ``repro.perf.EvalResult``
with the scenario axis replaced by the *rank* axis: per-rank per-phase
times, the critical rank/path, per-link utilization, and the achieved
overlap efficiency.  ``dump_chrome_trace`` writes a ``chrome://tracing`` /
Perfetto-loadable JSON timeline (one track per rank) under
``artifacts/traces/``.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from .network import LinkStats


def traces_dir() -> str:
    # deferred: core.calibration owns the artifacts-root resolution (and
    # pulls jax-adjacent modules we don't want at sim import time)
    from ..core.calibration import ARTIFACTS_DIR
    return os.path.join(os.path.abspath(ARTIFACTS_DIR), "traces")


@dataclasses.dataclass
class RankPhase:
    """One top-level phase: per-rank start / exposed seconds plus the
    serialized comm/comp ledgers (arrays of shape ``(p,)``)."""

    start: np.ndarray
    exposed: np.ndarray
    comm: np.ndarray
    comp: np.ndarray


@dataclasses.dataclass
class SimResult:
    """Per-rank discrete-event execution of one cost-IR program."""

    algo: str
    variant: str
    n: float
    p: int
    c: float
    r: float
    topology: str
    total: float                    # makespan: max over ranks
    per_rank: np.ndarray            # final clock per rank, shape (p,)
    comm: np.ndarray                # serialized comm seconds per rank
    comp: np.ndarray                # serialized comp seconds per rank
    phases: Dict[str, RankPhase]    # insertion-ordered top-level phases
    link_stats: LinkStats
    events: int
    engine: str = "vector"          # "vector" (folded sparse) | "reference"

    @property
    def critical_rank(self) -> int:
        return int(np.argmax(self.per_rank))

    @property
    def critical_path(self) -> List[Tuple[str, float]]:
        """(phase, exposed seconds) on the critical rank, in program order."""
        cr = self.critical_rank
        return [(name, float(ph.exposed[cr])) for name, ph in self.phases.items()]

    @property
    def overlap_efficiency(self) -> float:
        """Achieved / ideal hidden time, averaged over ranks: 1.0 when every
        overlappable second was hidden, 0.0 when nothing overlapped (and by
        convention 1.0 for programs with no overlap headroom)."""
        hidden = self.comm + self.comp - self.per_rank
        ideal = np.minimum(self.comm, self.comp)
        ok = ideal > 0
        if not ok.any():
            return 1.0
        return float(np.mean(np.clip(hidden[ok] / ideal[ok], 0.0, 1.0)))

    def utilization_histogram(self, bins: int = 8) -> Dict[str, list]:
        return self.link_stats.utilization_histogram(self.total, bins=bins)

    def summary(self) -> dict:
        return {
            "algo": self.algo, "variant": self.variant,
            "n": float(self.n), "p": int(self.p),
            "c": float(self.c), "r": float(self.r),
            "topology": self.topology,
            "total_s": float(self.total),
            "critical_rank": self.critical_rank,
            "overlap_efficiency": self.overlap_efficiency,
            "events": int(self.events),
            "engine": self.engine,
            "link_utilization": self.utilization_histogram(),
        }

    # -- Chrome trace --------------------------------------------------------
    def chrome_trace(self, max_ranks: int = 64, eval_result=None) -> dict:
        """Trace-event JSON through the unified obs exporter: one ``tid``
        per rank (phases as complete events), plus process metadata.
        Capping at ``max_ranks`` tracks is *announced*: a warning is
        logged and ``otherData`` carries ``ranks_shown``/``ranks_dropped``.
        With ``eval_result`` (the model's :class:`~repro.perf.evaluate`
        ``EvalResult`` for the same scenario) predicted per-phase spans
        appear on a paired track, flow-linked to the critical rank with
        signed residual annotations."""
        from ..obs import sim_trace
        return sim_trace(self, max_ranks=max_ranks, eval_result=eval_result)

    def dump_chrome_trace(self, path: Optional[str] = None,
                          max_ranks: int = 64, eval_result=None) -> str:
        """Write the trace under ``artifacts/traces/`` (or ``path``) and
        return the file path."""
        if path is None:
            safe_v = self.variant.replace(".", "")
            path = os.path.join(
                traces_dir(),
                f"{self.algo}_{safe_v}_n{int(self.n)}_p{self.p}.json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(max_ranks=max_ranks,
                                        eval_result=eval_result), f)
        return path
