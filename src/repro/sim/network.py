"""The link-contention network engine: fluid max-rate transfers on a topology.

Each :class:`Transfer` occupies every directed link on its topology route.
At any instant a transfer progresses at

    rate = 1 / (beta * max over its links of (instantaneous link load))

— the link-level max-rate model (Bienz et al.): the bottleneck link of the
path serializes the messages sharing it, and the rate *recovers* as
competing transfers drain.  The engine is a discrete-event loop over the
times at which the active set changes (a transfer starts or completes);
between events every rate is constant, so the fluid advance is exact.

When no link is ever shared (a crossbar, or a collision-free pattern on a
torus) every transfer completes at ``start + latency + beta * words`` —
exactly the ideal alpha-beta time the closed-form ``est_NoCal`` evaluator
charges, which anchors the cross-validation gate.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .topology import Topology


@dataclasses.dataclass
class Transfer:
    """One message: ``words`` from node ``src`` to node ``dst``, injected at
    absolute time ``start``; ``latency`` is added once end-to-end."""

    src: int
    dst: int
    words: float
    start: float
    latency: float = 0.0


@dataclasses.dataclass
class LinkStats:
    """Per-link accounting accumulated across every delivery of a run."""

    words: Dict[int, float] = dataclasses.field(default_factory=dict)
    busy: Dict[int, float] = dataclasses.field(default_factory=dict)
    peak_load: Dict[int, int] = dataclasses.field(default_factory=dict)

    def _fold(self, link: int, words: float, busy: float, load: int) -> None:
        if words:
            self.words[link] = self.words.get(link, 0.0) + words
        if busy:
            self.busy[link] = self.busy.get(link, 0.0) + busy
        if load > self.peak_load.get(link, 0):
            self.peak_load[link] = load

    def snapshot(self) -> Tuple[Dict[int, float], Dict[int, float]]:
        """Checkpoint of the words/busy counters (peak loads are maxima and
        need no delta accounting)."""
        return dict(self.words), dict(self.busy)

    def amplify_since(self, snap: Tuple[Dict[int, float], Dict[int, float]],
                      k: float) -> None:
        """Repeat the traffic accumulated since ``snap`` another ``k``
        times — the stats-side counterpart of the executor's steady-state
        loop fast-forward (the skipped iterations carry the same per-link
        traffic as the last simulated one)."""
        words0, busy0 = snap
        for l, v in self.words.items():
            self.words[l] = v + k * (v - words0.get(l, 0.0))
        for l, v in self.busy.items():
            self.busy[l] = v + k * (v - busy0.get(l, 0.0))

    def utilization_histogram(self, total_time: float,
                              bins: int = 8) -> Dict[str, list]:
        """Histogram of per-link utilization (busy seconds / makespan)."""
        if not self.busy or total_time <= 0:
            return {"edges": [0.0, 1.0], "counts": [0]}
        util = np.clip(np.array(list(self.busy.values())) / total_time, 0, 1)
        counts, edges = np.histogram(util, bins=bins, range=(0.0, 1.0))
        return {"edges": [float(e) for e in edges],
                "counts": [int(c) for c in counts]}


class Network:
    """Delivers batches of transfers on a topology, accumulating link stats
    and an event count across batches."""

    def __init__(self, topology: Topology, latency: float, beta: float):
        self.topology = topology
        self.latency = float(latency)
        self.beta = float(beta)
        self.stats = LinkStats()
        self.events = 0

    def deliver(self, transfers: Sequence[Transfer]) -> np.ndarray:
        """Completion time of every transfer (same order as input)."""
        T = len(transfers)
        if T == 0:
            return np.zeros(0)
        starts = np.array([tr.start for tr in transfers], dtype=float)
        words = np.array([max(tr.words, 0.0) for tr in transfers], dtype=float)
        lats = np.array([tr.latency for tr in transfers], dtype=float)
        paths = [self.topology.route(tr.src, tr.dst) for tr in transfers]
        flat_n = sum(len(p) for p in paths)
        owner = np.fromiter((i for i, p in enumerate(paths) for _ in p),
                            dtype=np.intp, count=flat_n)
        flat = np.fromiter((l for p in paths for l in p),
                           dtype=np.intp, count=flat_n)
        nl = int(flat.max()) + 1 if flat_n else 1

        # Collision-free fast path: if no link is shared even with every
        # transfer simultaneously active, each completes at the ideal time.
        if flat_n == 0 or int(np.bincount(flat, minlength=nl).max()) <= 1:
            self.events += 2 * T
            done = starts + lats + self.beta * words
            for i, p in enumerate(paths):
                for l in p:
                    self.stats._fold(l, words[i], self.beta * words[i], 1)
            return done

        plen = np.array([len(p) for p in paths], dtype=np.intp)
        return self._deliver_contended(starts, words, lats, owner, flat, nl,
                                       plen)

    def _deliver_contended(self, starts, words, lats, owner, flat, nl, plen):
        T = starts.size
        done = np.full(T, np.inf)
        rem = words.copy()
        zero = rem <= 0.0
        done[zero] = starts[zero] + lats[zero]
        live = ~zero
        # reduceat segments: flat is laid out path-by-path in transfer order
        routed = plen > 0
        offsets = np.concatenate(([0], np.cumsum(plen[routed])))[:-1]
        t = float(starts[live].min())
        active = live & (starts <= t)
        pending = live & ~active
        link_words = np.zeros(nl)
        link_busy = np.zeros(nl)
        link_peak = np.zeros(nl, dtype=np.intp)
        while active.any() or pending.any():
            if not active.any():
                t = float(starts[pending].min())
                started = pending & (starts <= t)
                active |= started
                pending &= ~started
                continue
            amask = active[owner]
            loads = np.bincount(flat[amask], minlength=nl)
            np.maximum(link_peak, loads, out=link_peak)
            bottleneck = np.ones(T)
            bottleneck[routed] = np.maximum.reduceat(loads[flat], offsets)
            bottleneck = np.maximum(bottleneck, 1.0)
            rate = np.where(active, 1.0 / (self.beta * bottleneck), 0.0)
            fin = np.where(active, t + rem * (self.beta * bottleneck), np.inf)
            t_next = float(fin[active].min())
            if pending.any():
                t_next = min(t_next, float(starts[pending].min()))
            # Retire everything whose estimated finish coincides with this
            # event (clock-resolution epsilon): float cancellation in
            # (t + x) - t must not strand a transfer in endless sub-rounds.
            eps = 1e-12 * (abs(t_next) + 1.0)
            finished = active & (fin <= t_next + eps)
            dt = t_next - t
            if dt > 0:
                moved = np.where(finished, rem, rate * dt)
                rem = np.where(active, np.maximum(rem - moved, 0.0), rem)
                link_words += np.bincount(flat[amask], minlength=nl,
                                          weights=moved[owner[amask]])
                link_busy[loads > 0] += dt
            t = t_next
            self.events += 1
            done[finished] = fin[finished] + lats[finished]
            active &= ~finished
            started = pending & (starts <= t)
            active |= started
            pending &= ~started
        touched = np.flatnonzero((link_words > 0) | (link_busy > 0)
                                 | (link_peak > 0))
        for l in touched:
            self.stats._fold(int(l), float(link_words[l]),
                             float(link_busy[l]), int(link_peak[l]))
        return done
