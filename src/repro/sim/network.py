"""The link-contention network engine: fluid max-rate transfers on a topology.

Each transfer occupies every directed link on its topology route.  At any
instant a transfer progresses at

    rate = 1 / (beta * max over its links of (instantaneous link load))

— the link-level max-rate model (Bienz et al.): the bottleneck link of the
path serializes the messages sharing it, and the rate *recovers* as
competing transfers drain.  The engine is a discrete-event loop over the
times at which the active set changes (a transfer starts or completes);
between events every rate is constant, so the fluid advance is exact.

Two implementations share that model:

* ``engine="vector"`` (default) — the sparse folded engine.  Routes come
  from CSR :class:`~repro.sim.topology.ShiftPlan` link-incidence arrays
  (no per-transfer Python objects), transfers are lumped into symmetry
  classes by :mod:`repro.sim.fold`, and the event loop advances whole
  classes with multiplicity-weighted link loads — ``O(classes)`` per
  event instead of ``O(ranks x links)``.
* ``engine="reference"`` — the PR-3 per-transfer event loop, kept
  verbatim as the agreement oracle: CI gates the vector engine against it
  at 1e-6 relative on all paper programs.

When no link is ever shared (a crossbar, or a collision-free pattern on a
torus) every transfer completes at ``start + latency + beta * words`` —
exactly the ideal alpha-beta time the closed-form ``est_NoCal`` evaluator
charges, which anchors the cross-validation gate.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .fold import Fold, build_fold, trivial_fold
from .faults import FaultSpec, FaultyTopology
from .topology import ShiftPlan, Topology


@dataclasses.dataclass
class Transfer:
    """One message: ``words`` from node ``src`` to node ``dst``, injected at
    absolute time ``start``; ``latency`` is added once end-to-end."""

    src: int
    dst: int
    words: float
    start: float
    latency: float = 0.0


class LinkStats:
    """Per-link accounting accumulated across every delivery of a run.

    Internally dense numpy arrays indexed by physical link id (grown on
    demand); the ``words`` / ``busy`` / ``peak_load`` dict views preserve
    the sparse mapping older call sites and the trace emitter read."""

    def __init__(self):
        self._words = np.zeros(0)
        self._busy = np.zeros(0)
        self._peak = np.zeros(0, dtype=np.int64)

    def _ensure(self, n: int) -> None:
        if n > self._words.size:
            grow = max(n, 2 * self._words.size)
            for name in ("_words", "_busy", "_peak"):
                old = getattr(self, name)
                new = np.zeros(grow, dtype=old.dtype)
                new[:old.size] = old
                setattr(self, name, new)

    def add(self, links: np.ndarray, words, busy, peak) -> None:
        """Vectorized accumulation over *distinct* physical link ids
        (scalars broadcast)."""
        if links.size == 0:
            return
        self._ensure(int(links.max()) + 1)
        self._words[links] += words
        self._busy[links] += busy
        self._peak[links] = np.maximum(self._peak[links], peak)

    # -- sparse dict views (read-only; compat with the pre-fold layout) -----
    @property
    def words(self) -> Dict[int, float]:
        nz = np.flatnonzero(self._words)
        return dict(zip(nz.tolist(), self._words[nz].tolist()))

    @property
    def busy(self) -> Dict[int, float]:
        nz = np.flatnonzero(self._busy)
        return dict(zip(nz.tolist(), self._busy[nz].tolist()))

    @property
    def peak_load(self) -> Dict[int, int]:
        nz = np.flatnonzero(self._peak)
        return dict(zip(nz.tolist(), self._peak[nz].tolist()))

    def snapshot(self) -> Tuple[np.ndarray, np.ndarray]:
        """Checkpoint of the words/busy counters (peak loads are maxima and
        need no delta accounting)."""
        return self._words.copy(), self._busy.copy()

    def amplify_since(self, snap: Tuple[np.ndarray, np.ndarray],
                      k: float) -> None:
        """Repeat the traffic accumulated since ``snap`` another ``k``
        times — the stats-side counterpart of the executor's steady-state
        loop fast-forward (the skipped iterations carry the same per-link
        traffic as the last simulated one)."""
        words0, busy0 = snap
        self._words[:words0.size] += k * (self._words[:words0.size] - words0)
        self._words[words0.size:] *= 1.0 + k
        self._busy[:busy0.size] += k * (self._busy[:busy0.size] - busy0)
        self._busy[busy0.size:] *= 1.0 + k

    def utilization_histogram(self, total_time: float,
                              bins: int = 8) -> Dict[str, list]:
        """Histogram of per-link utilization (busy seconds / makespan)."""
        busy = self._busy[self._busy > 0]
        if busy.size == 0 or total_time <= 0:
            return {"edges": [0.0, 1.0], "counts": [0]}
        util = np.clip(busy / total_time, 0, 1)
        counts, edges = np.histogram(util, bins=bins, range=(0.0, 1.0))
        return {"edges": [float(e) for e in edges],
                "counts": [int(c) for c in counts]}


class Network:
    """Delivers batches of transfers on a topology, accumulating link stats
    and an event count across batches.

    ``fold=False`` opts out of symmetry folding (the engine still runs the
    vectorized sparse event loop over the trivial partition) — for
    asymmetric traffic where class detection cannot pay off.  ``events``
    counts logical transfer endpoints (one start + one completion per
    message, including messages simulated by a folded representative).

    ``faults`` injects per-component degradation (:mod:`repro.sim.faults`):
    degraded links multiply the effective load the bottleneck max sees on
    that link, dead links reroute the pattern through a private
    :class:`~repro.sim.faults.FaultyTopology` view (own plan/fold caches —
    the shared memoized topology is never poisoned), and fault onsets are
    evaluated per pattern (active iff the pattern's earliest start has
    reached the onset).  Both engines apply the same math, so the 1e-6
    agreement gate carries over to faulted runs.
    """

    def __init__(self, topology: Topology, latency: float, beta: float,
                 *, fold: bool = True, engine: str = "vector",
                 faults: Optional[FaultSpec] = None):
        if engine not in ("vector", "reference"):
            raise ValueError(f"engine must be 'vector' or 'reference', "
                             f"got {engine!r}")
        self.topology = topology
        self.latency = float(latency)
        self.beta = float(beta)
        self.fold = bool(fold)
        self.engine = engine
        self.faults = faults if faults is not None and not faults.empty \
            else None
        self.stats = LinkStats()
        self.events = 0
        # one FaultyTopology per active dead-link set ("epoch"): route/plan/
        # fold caches are per-epoch, keyed by which links are gone
        self._fault_topos: Dict[frozenset, FaultyTopology] = {}

    # -- fault plumbing ------------------------------------------------------
    def _topology_at(self, t: float) -> Topology:
        """The routing view active at pattern time ``t`` (the base topology
        until a dead-link onset passes)."""
        if self.faults is None or not self.faults.dead_links:
            return self.topology
        dead = self.faults.active_dead(t)
        if not dead:
            return self.topology
        topo = self._fault_topos.get(dead)
        if topo is None:
            topo = FaultyTopology(self.topology, dead)
            self._fault_topos[dead] = topo
        return topo

    def _link_scales(self, links: np.ndarray, t: float
                     ) -> Optional[np.ndarray]:
        if self.faults is None:
            return None
        return self.faults.link_scales(links, t)

    @staticmethod
    def _route_bneck(indptr: np.ndarray, link_idx: np.ndarray,
                     scales: np.ndarray, T: int) -> np.ndarray:
        """Per-transfer max link scale over its route (>= 1) — the ideal
        alpha-beta slowdown of a collision-free pattern under degraded
        links."""
        b = np.ones(T)
        routed = np.diff(indptr) > 0
        if routed.any():
            b[routed] = np.maximum.reduceat(scales[link_idx],
                                            indptr[:-1][routed])
        return np.maximum(b, 1.0)

    # -- the executor's fast path: one whole shift pattern -------------------
    def deliver_shift(self, starts: np.ndarray, words: float, d: int,
                      latency: float) -> np.ndarray:
        """Completion time per rank for the pattern ``rank -> rank + d``
        (all ``p`` ranks, ``words`` each, injected at ``starts``)."""
        p = starts.size
        self.events += 2 * p
        w = max(float(words), 0.0)
        t0 = float(starts.min()) if p else 0.0
        topo = self._topology_at(t0)
        plan = topo.shift_plan(p, d)
        scales = self._link_scales(plan.uniq_links, t0)
        if w <= 0.0:
            if plan.max_static_load <= 1:
                self.stats.add(plan.uniq_links, 0.0, 0.0, 1)
            return starts + latency
        if self.engine == "reference":
            self.events -= 2 * p  # the reference engine counts its own
            return self._reference_from_plan(
                starts, np.full(p, w), np.full(p, latency), plan, scales)
        if plan.max_static_load <= 1:
            # collision-free for any start times: ideal alpha-beta, times
            # the worst degraded-link scale on each route (if any)
            if scales is None:
                self.stats.add(plan.uniq_links, w, self.beta * w, 1)
                return starts + (latency + self.beta * w)
            bneck = self._route_bneck(plan.indptr, plan.link_idx, scales, p)
            self.stats.add(plan.uniq_links, w, self.beta * w * scales, 1)
            return starts + (latency + self.beta * w * bneck)
        fold = self._shift_fold(plan, starts, topo=topo, scales=scales)
        scale_m = self._fold_scales(fold, scales)
        done_k = self._solve(starts[fold.rep], np.full(fold.K, w), fold,
                             plan.uniq_links, scale_m)
        return done_k[fold.t_class] + latency

    @staticmethod
    def _fold_scales(fold: Fold, scales: Optional[np.ndarray]
                     ) -> Optional[np.ndarray]:
        """Per-link-class scale vector.  Valid because the fold was seeded
        by the scale classes (or is trivial), so a class never mixes
        scales — the scatter below assigns each class one value."""
        if scales is None:
            return None
        scale_m = np.ones(fold.M)
        scale_m[fold.l_class] = scales
        return scale_m

    def _shift_fold(self, plan: ShiftPlan, starts: np.ndarray,
                    topo: Optional[Topology] = None,
                    scales: Optional[np.ndarray] = None) -> Fold:
        """The cached symmetry fold of a shift pattern, seeded by the
        per-rank clock classes (equal-clock ranks may share a class;
        folding is keyed on the class *structure*, not the clock values,
        so a steady-state loop reuses one fold across iterations).  Link
        beta scales join both the seed and the cache key: the same
        (p, d, clocks) pattern folds differently before and after a fault
        onset."""
        if topo is None:
            topo = self.topology
        if starts.size and starts[0] == starts[-1] \
                and float(starts.min()) == float(starts.max()):
            labels = np.zeros(starts.size, dtype=np.int64)  # lockstep
        else:
            labels = np.unique(starts, return_inverse=True)[1]
            labels = labels.astype(np.int64).ravel()
        if not self.fold:
            return trivial_fold(plan.p, plan.indptr, plan.link_idx,
                                plan.owner, plan.uniq_links.size)
        link_seed = None
        sig = b""
        if scales is not None:
            link_seed = np.unique(scales, return_inverse=True)[1]
            link_seed = link_seed.astype(np.int64).ravel()
            sig = hashlib.blake2b(scales.tobytes(), digest_size=16).digest()
        key = (plan.p, plan.d,
               hashlib.blake2b(labels.tobytes(), digest_size=16).digest(),
               sig)
        fold = topo.fold_get(key)
        if fold is None:
            fold = build_fold(plan, labels, link_seed=link_seed)
            topo.fold_put(key, fold)
        return fold

    # -- generic transfer lists (tests, calibration, ad-hoc patterns) --------
    def deliver(self, transfers: Sequence[Transfer]) -> np.ndarray:
        """Completion time of every transfer (same order as input)."""
        T = len(transfers)
        if T == 0:
            return np.zeros(0)
        starts = np.array([tr.start for tr in transfers], dtype=float)
        words = np.array([max(tr.words, 0.0) for tr in transfers], dtype=float)
        lats = np.array([tr.latency for tr in transfers], dtype=float)
        t0 = float(starts.min())
        topo = self._topology_at(t0)
        paths = [topo.route(tr.src, tr.dst) for tr in transfers]
        lens = np.fromiter((len(pa) for pa in paths), dtype=np.int64, count=T)
        indptr = np.zeros(T + 1, dtype=np.int64)
        np.cumsum(lens, out=indptr[1:])
        flat = np.fromiter((l for pa in paths for l in pa),
                           dtype=np.int64, count=int(indptr[-1]))
        owner = np.repeat(np.arange(T, dtype=np.int64), lens)
        if self.engine == "reference":
            nl = int(flat.max()) + 1 if flat.size else 1
            dense = None
            if flat.size:
                uniq_l = np.unique(flat)
                s = self._link_scales(uniq_l, t0)
                if s is not None:
                    dense = np.ones(nl)
                    dense[uniq_l] = s
            return self._deliver_reference(starts, words, lats, owner, flat,
                                           nl, lens, link_scales=dense)
        self.events += 2 * T
        uniq, link_idx = np.unique(flat, return_inverse=True)
        link_idx = link_idx.astype(np.int64).ravel()
        scales = self._link_scales(uniq, t0) if uniq.size else None
        if flat.size == 0 or int(np.bincount(link_idx).max()) <= 1:
            # collision-free even with every transfer active: ideal times
            self.stats.add(flat, words[owner], self.beta * words[owner], 1)
            if scales is None:
                return starts + lats + self.beta * words
            bneck = self._route_bneck(indptr, link_idx, scales, T)
            return starts + lats + self.beta * words * bneck
        done = np.empty(T)
        live = words > 0.0
        done[~live] = starts[~live] + lats[~live]
        if not live.any():
            return done
        if live.all():
            sub_ptr, sub_idx, sub_owner, sub_uniq = \
                indptr, link_idx, owner, uniq
            idx_map = np.arange(T)
        else:
            idx_map = np.flatnonzero(live)
            keep = live[owner]
            sub_lens = lens[idx_map]
            sub_ptr = np.zeros(idx_map.size + 1, dtype=np.int64)
            np.cumsum(sub_lens, out=sub_ptr[1:])
            sub_uniq, sub_idx = np.unique(flat[keep], return_inverse=True)
            sub_idx = sub_idx.astype(np.int64).ravel()
            sub_owner = np.repeat(np.arange(idx_map.size, dtype=np.int64),
                                  sub_lens)
        static = np.bincount(sub_idx, minlength=sub_uniq.size)
        plan = ShiftPlan(
            p=idx_map.size, d=-1, indptr=sub_ptr,
            links=sub_uniq[sub_idx], uniq_links=sub_uniq, link_idx=sub_idx,
            owner=sub_owner, static_load=static,
            max_static_load=int(static.max()) if static.size else 0)
        seeds = np.unique(np.column_stack([starts[idx_map], words[idx_map]]),
                          axis=0, return_inverse=True)[1]
        sub_scales = scales if sub_uniq is uniq \
            else self._link_scales(sub_uniq, t0)
        link_seed = None
        if sub_scales is not None:
            link_seed = np.unique(sub_scales, return_inverse=True)[1]
            link_seed = link_seed.astype(np.int64).ravel()
        fold = build_fold(plan, seeds.astype(np.int64).ravel(),
                          link_seed=link_seed) if self.fold \
            else trivial_fold(plan.p, sub_ptr, sub_idx, sub_owner,
                              sub_uniq.size)
        scale_m = self._fold_scales(fold, sub_scales)
        done_k = self._solve(starts[idx_map][fold.rep],
                             words[idx_map][fold.rep], fold, sub_uniq,
                             scale_m)
        done[idx_map] = done_k[fold.t_class] + lats[idx_map]
        return done

    # -- the folded fluid event loop -----------------------------------------
    def _solve(self, starts: np.ndarray, words: np.ndarray,
               fold: Fold, uniq_links: np.ndarray,
               scale_m: Optional[np.ndarray] = None) -> np.ndarray:
        """Fluid completion times per class (latency excluded).  One event
        per change of the active class set; between events every class
        rate is constant, so the advance is exact.  ``scale_m`` multiplies
        the effective load per link *class* (degraded-link injection); raw
        loads still feed the peak/stats accounting."""
        K, M = fold.K, fold.M
        row_m, row_a, entry_k = fold.row_m, fold.row_a, fold.entry_k
        starts_ok = fold.nonempty  # classes with a route
        if K == 1:
            # one class in lockstep: a single fluid interval at the static
            # bottleneck — the event loop closed-form
            ra = row_a if scale_m is None else row_a * scale_m[row_m]
            bneck = max(float(ra.max()) if ra.size else 1.0, 1.0)
            w = float(words[0])
            dur = w * self.beta * bneck
            words_dep = np.zeros(M)
            busy_m = np.zeros(M)
            peak_m = np.zeros(M)
            words_dep[row_m] = row_a * w
            busy_m[row_m] = dur
            peak_m[row_m] = row_a
            self.stats.add(uniq_links, words_dep[fold.l_class],
                           busy_m[fold.l_class],
                           np.rint(peak_m[fold.l_class]).astype(np.int64))
            return starts + dur
        rem = words.astype(float).copy()
        done = np.full(K, np.inf)
        beta = self.beta
        t = float(starts.min())
        active = starts <= t
        pending = ~active
        words_dep = np.zeros(M)
        busy_m = np.zeros(M)
        peak_m = np.zeros(M)
        starts_view = starts
        while active.any() or pending.any():
            if not active.any():
                t = float(starts_view[pending].min())
                started = pending & (starts_view <= t)
                active |= started
                pending &= ~started
                continue
            act = active.astype(float)
            loads = np.bincount(row_m, weights=row_a * act[entry_k],
                                minlength=M)
            np.maximum(peak_m, loads, out=peak_m)
            eff = loads if scale_m is None else loads * scale_m
            bneck = np.ones(K)
            if starts_ok.any():
                seg_starts = fold.row_ptr[:-1][starts_ok]
                bneck[starts_ok] = np.maximum.reduceat(eff[row_m],
                                                       seg_starts)
            bneck = np.maximum(bneck, 1.0)
            fin = np.where(active, t + rem * (beta * bneck), np.inf)
            t_next = float(fin[active].min())
            if pending.any():
                t_next = min(t_next, float(starts_view[pending].min()))
            # Retire everything whose estimated finish coincides with this
            # event (clock-resolution epsilon): float cancellation in
            # (t + x) - t must not strand a class in endless sub-rounds.
            eps = 1e-12 * (abs(t_next) + 1.0)
            finished = active & (fin <= t_next + eps)
            dt = t_next - t
            if dt > 0:
                rate = 1.0 / (beta * bneck)
                moved = np.where(finished, rem, rate * dt) * act
                rem = np.where(active, np.maximum(rem - moved, 0.0), rem)
                words_dep += np.bincount(row_m,
                                         weights=row_a * moved[entry_k],
                                         minlength=M)
                busy_m[loads > 0] += dt
            t = t_next
            done[finished] = fin[finished]
            active &= ~finished
            started = pending & (starts_view <= t)
            active |= started
            pending &= ~started
        self.stats.add(uniq_links, words_dep[fold.l_class],
                       busy_m[fold.l_class],
                       np.rint(peak_m[fold.l_class]).astype(np.int64))
        return done

    # -- the PR-3 per-transfer engine (agreement oracle) ---------------------
    def _reference_from_plan(self, starts, words, lats, plan: ShiftPlan,
                             scales_u=None) -> np.ndarray:
        nl = int(plan.links.max()) + 1 if plan.links.size else 1
        if plan.links.size == 0 or plan.max_static_load <= 1:
            self.events += 2 * plan.p
            if scales_u is None:
                done = starts + lats + self.beta * words
            else:
                b = self._route_bneck(plan.indptr, plan.link_idx,
                                      scales_u, plan.p)
                done = starts + lats + self.beta * words * b
            self.stats.add(plan.links, words[plan.owner],
                           self.beta * words[plan.owner], 1)
            return done
        dense = None
        if scales_u is not None:
            dense = np.ones(nl)
            dense[plan.uniq_links] = scales_u
        return self._deliver_reference(starts, words, lats, plan.owner,
                                       plan.links, nl, np.diff(plan.indptr),
                                       link_scales=dense)

    def _deliver_reference(self, starts, words, lats, owner, flat, nl, plen,
                           link_scales=None):
        """The pre-fold engine, one event per active-set change over
        individual transfers — kept as the cross-validation oracle.
        ``link_scales`` is a dense per-physical-link effective-load
        multiplier (degraded-link injection)."""
        T = starts.size
        if flat.size == 0 or int(np.bincount(flat, minlength=nl).max()) <= 1:
            self.events += 2 * T
            if link_scales is None:
                done = starts + lats + self.beta * words
            else:
                b = np.ones(T)
                routed = plen > 0
                if routed.any():
                    offs = np.concatenate(
                        ([0], np.cumsum(plen[routed])))[:-1]
                    b[routed] = np.maximum.reduceat(link_scales[flat], offs)
                done = starts + lats + self.beta * words * np.maximum(b, 1.0)
            self.stats.add(flat, words[owner], self.beta * words[owner], 1)
            return done
        done = np.full(T, np.inf)
        rem = words.copy()
        zero = rem <= 0.0
        done[zero] = starts[zero] + lats[zero]
        live = ~zero
        if not live.any():
            return done
        # reduceat segments: flat is laid out path-by-path in transfer order
        routed = plen > 0
        offsets = np.concatenate(([0], np.cumsum(plen[routed])))[:-1]
        t = float(starts[live].min())
        active = live & (starts <= t)
        pending = live & ~active
        link_words = np.zeros(nl)
        link_busy = np.zeros(nl)
        link_peak = np.zeros(nl, dtype=np.intp)
        while active.any() or pending.any():
            if not active.any():
                t = float(starts[pending].min())
                started = pending & (starts <= t)
                active |= started
                pending &= ~started
                continue
            amask = active[owner]
            loads = np.bincount(flat[amask], minlength=nl)
            np.maximum(link_peak, loads, out=link_peak)
            eff = loads if link_scales is None else loads * link_scales
            bottleneck = np.ones(T)
            bottleneck[routed] = np.maximum.reduceat(eff[flat], offsets)
            bottleneck = np.maximum(bottleneck, 1.0)
            rate = np.where(active, 1.0 / (self.beta * bottleneck), 0.0)
            fin = np.where(active, t + rem * (self.beta * bottleneck), np.inf)
            t_next = float(fin[active].min())
            if pending.any():
                t_next = min(t_next, float(starts[pending].min()))
            eps = 1e-12 * (abs(t_next) + 1.0)
            finished = active & (fin <= t_next + eps)
            dt = t_next - t
            if dt > 0:
                moved = np.where(finished, rem, rate * dt)
                rem = np.where(active, np.maximum(rem - moved, 0.0), rem)
                link_words += np.bincount(flat[amask], minlength=nl,
                                          weights=moved[owner[amask]])
                link_busy[loads > 0] += dt
            t = t_next
            self.events += 1
            done[finished] = fin[finished] + lats[finished]
            active &= ~finished
            started = pending & (starts <= t)
            active |= started
            pending &= ~started
        touched = np.flatnonzero((link_words > 0) | (link_busy > 0)
                                 | (link_peak > 0))
        self.stats.add(touched, link_words[touched], link_busy[touched],
                       link_peak[touched])
        return done
