"""Explicit network topologies for the per-rank simulator.

A :class:`Topology` maps an ordered pair of node indices to the sequence of
*directed links* a message traverses.  Two concrete topologies:

* :class:`Torus` — a k-ary n-cube with dimension-ordered routing (DOR),
  the shape of the paper's Gemini 3D torus and of TPU ICI meshes.  Routing
  is identical to the pre-PR-3 ``core.calibration.ContentionSimulator``
  (shortest wraparound direction per dimension, ties broken forward), so
  calibration tables derived through this layer reproduce the old numbers
  bit-for-bit.
* :class:`Crossbar` — a flat, fully-connected baseline where every ordered
  pair owns a dedicated channel.  No two distinct messages ever share a
  link, so simulation on a crossbar is *contention-free by construction*
  — the cross-validation anchor against the closed-form ``est_NoCal``
  evaluator.

Because the executor's only traffic shape is the paper's calibration
pattern — all ``p`` ranks shifting to ``rank + d`` — topologies also serve
precomputed :class:`ShiftPlan` objects: CSR-style link-incidence arrays
for the whole pattern at once (``Torus`` builds them with closed-form
numpy, no per-pair Python walk), plus the pattern's static link loads.
Plans and the symmetry :class:`~repro.sim.fold.Fold` structures derived
from them are cached per ``(p, d)`` on the topology instance, so repeated
collective steps, loop iterations, shortlist candidates and batched
scenarios all share one route construction.

Link ids are small integers local to a topology instance; ``link_name``
renders them for traces and utilization reports.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: entries kept in each per-topology plan/fold cache (LRU) — a paper-scale
#: program touches a few dozen distinct (p, d) patterns; the cap only
#: guards against unbounded growth across many unrelated simulations.
CACHE_CAP = 128

#: one lock for every plan/fold/instance cache: topology instances are
#: shared (memoized) and the Tuner plans from multiple threads — held
#: only around dict operations, never while building a plan or fold.
_CACHE_LOCK = threading.Lock()


@dataclasses.dataclass
class ShiftPlan:
    """CSR link-incidence of one shift pattern: rank ``i`` sends to
    ``(i + d) % p`` along ``links[indptr[i]:indptr[i+1]]`` (DOR order).

    ``uniq_links``/``link_idx`` compress the touched physical link ids to
    a dense ``0..L-1`` space (``links == uniq_links[link_idx]``); the
    static load is the per-unique-link crossing count with every transfer
    active — ``max_static_load <= 1`` certifies the pattern collision-free
    for *any* start times.
    """

    p: int
    d: int
    indptr: np.ndarray          # (p+1,) int64
    links: np.ndarray           # (nnz,) physical link ids
    uniq_links: np.ndarray      # (L,) distinct physical link ids
    link_idx: np.ndarray        # (nnz,) indices into uniq_links
    owner: np.ndarray           # (nnz,) transfer index per incidence
    static_load: np.ndarray     # (L,) crossings per unique link
    max_static_load: int


class Topology:
    """Interface: node count plus directed-link routing."""

    n_nodes: int

    def route(self, src: int, dst: int) -> Tuple[int, ...]:
        """Directed link ids traversed by a ``src -> dst`` message (empty
        for ``src == dst``)."""
        raise NotImplementedError

    def hops(self, src: int, dst: int) -> int:
        return len(self.route(src, dst))

    def link_name(self, link: int) -> str:
        raise NotImplementedError

    # -- shift-pattern plans -------------------------------------------------
    def _build_shift_routes(self, p: int, d: int
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """(indptr, links) CSR of the ``rank -> rank + d (mod p)`` pattern.
        Generic fallback walks ``route`` per pair; ``Torus`` overrides with
        a closed-form vectorized construction."""
        paths = [self.route(rk, (rk + d) % p) for rk in range(p)]
        lens = np.fromiter((len(pa) for pa in paths), dtype=np.int64, count=p)
        indptr = np.zeros(p + 1, dtype=np.int64)
        np.cumsum(lens, out=indptr[1:])
        links = np.fromiter((l for pa in paths for l in pa),
                            dtype=np.int64, count=int(indptr[-1]))
        return indptr, links

    def shift_plan(self, p: int, d: int) -> ShiftPlan:
        """The cached :class:`ShiftPlan` for a ``(p, d)`` shift pattern."""
        key = (int(p), int(d))
        with _CACHE_LOCK:
            cache: OrderedDict = self.__dict__.setdefault(
                "_shift_plans", OrderedDict())
            plan = cache.get(key)
            if plan is not None:
                cache.move_to_end(key)
                return plan
        # built outside the lock; a concurrent duplicate build is benign
        indptr, links = self._build_shift_routes(int(p), int(d))
        uniq, link_idx = np.unique(links, return_inverse=True)
        link_idx = link_idx.astype(np.int64).ravel()
        owner = np.repeat(np.arange(p, dtype=np.int64), np.diff(indptr))
        static = np.bincount(link_idx, minlength=uniq.size)
        plan = ShiftPlan(
            p=int(p), d=int(d), indptr=indptr, links=links,
            uniq_links=uniq, link_idx=link_idx, owner=owner,
            static_load=static,
            max_static_load=int(static.max()) if static.size else 0)
        with _CACHE_LOCK:
            cache[key] = plan
            if len(cache) > CACHE_CAP:
                cache.popitem(last=False)
        return plan

    def fold_get(self, key):
        """Cached :class:`~repro.sim.fold.Fold` for ``key`` (pattern +
        clock-class signature, assigned by the network layer), or None."""
        with _CACHE_LOCK:
            cache: OrderedDict = self.__dict__.setdefault(
                "_fold_cache", OrderedDict())
            fold = cache.get(key)
            if fold is not None:
                cache.move_to_end(key)
            return fold

    def fold_put(self, key, fold) -> None:
        with _CACHE_LOCK:
            cache: OrderedDict = self.__dict__.setdefault(
                "_fold_cache", OrderedDict())
            cache[key] = fold
            if len(cache) > CACHE_CAP:
                cache.popitem(last=False)


class Torus(Topology):
    """k-ary n-cube with dimension-ordered routing.

    Nodes are numbered in mixed radix over ``shape`` (dimension 0 fastest,
    matching the legacy contention simulator).  Each node owns ``2 * ndim``
    outgoing links (one per dimension per direction).
    """

    def __init__(self, shape: Sequence[int]):
        self.shape = tuple(int(k) for k in shape)
        if not self.shape or any(k < 1 for k in self.shape):
            raise ValueError(f"invalid torus shape {shape!r}")
        self.ndim = len(self.shape)
        n = 1
        for k in self.shape:
            n *= k
        self.n_nodes = n
        self._cache: Dict[Tuple[int, int], Tuple[int, ...]] = {}

    def coords(self, node: int) -> Tuple[int, ...]:
        c = []
        for k in self.shape:
            c.append(node % k)
            node //= k
        return tuple(c)

    def node(self, coords: Sequence[int]) -> int:
        idx, stride = 0, 1
        for x, k in zip(coords, self.shape):
            idx += (int(x) % k) * stride
            stride *= k
        return idx

    def _link_id(self, coords: Sequence[int], dim: int, step: int) -> int:
        return (self.node(coords) * self.ndim + dim) * 2 + (0 if step > 0 else 1)

    def route(self, src: int, dst: int) -> Tuple[int, ...]:
        key = (src, dst)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        cs, cd = list(self.coords(src)), list(self.coords(dst))
        links: List[int] = []
        for dim, k in enumerate(self.shape):
            while cs[dim] != cd[dim]:
                fwd = (cd[dim] - cs[dim]) % k
                step = 1 if fwd <= k - fwd else -1  # tie -> forward (legacy)
                links.append(self._link_id(cs, dim, step))
                cs[dim] = (cs[dim] + step) % k
        path = tuple(links)
        self._cache[key] = path
        return path

    def _build_shift_routes(self, p: int, d: int
                            ) -> Tuple[np.ndarray, np.ndarray]:
        """Closed-form CSR construction of the whole shift pattern.

        DOR fixes the step direction per dimension up front (the shortest
        wraparound side never flips while walking), so every rank's route
        is three vectorizable pieces per dimension: a base node (lower
        dims already at the destination digit, higher dims still at the
        source digit), a stride walk of ``min(fwd, k - fwd)`` hops, and a
        direction bit.  Bit-identical to ``route`` per pair (tested)."""
        ndim = len(self.shape)
        shape = np.array(self.shape, dtype=np.int64)
        strides = np.ones(ndim, dtype=np.int64)
        for m in range(1, ndim):
            strides[m] = strides[m - 1] * shape[m - 1]
        src = np.arange(p, dtype=np.int64)
        dst = (src + d) % p

        def _coords(v: np.ndarray) -> np.ndarray:
            out = np.empty((v.size, ndim), dtype=np.int64)
            x = v.copy()
            for m in range(ndim):
                out[:, m] = x % shape[m]
                x //= shape[m]
            return out

        cs, cd = _coords(src), _coords(dst)
        fwd = (cd - cs) % shape[None, :]
        step = np.where(fwd * 2 <= shape[None, :], 1, -1)  # tie -> forward
        nst = np.where(step > 0, fwd, shape[None, :] - fwd)
        nst = np.where(fwd == 0, 0, nst)
        base = np.zeros((p, ndim), dtype=np.int64)
        for m in range(ndim):
            for i in range(ndim):
                if i < m:
                    base[:, m] += cd[:, i] * strides[i]
                elif i > m:
                    base[:, m] += cs[:, i] * strides[i]
        counts = nst.ravel()  # rank-major, dimension-minor == DOR order
        tot = int(counts.sum())
        indptr = np.zeros(p + 1, dtype=np.int64)
        np.cumsum(nst.sum(axis=1), out=indptr[1:])
        grp = np.repeat(np.arange(p * ndim, dtype=np.int64), counts)
        offs = np.repeat(np.cumsum(counts) - counts, counts)
        j = np.arange(tot, dtype=np.int64) - offs
        rk, dm = grp // ndim, grp % ndim
        x = (cs[rk, dm] + step[rk, dm] * j) % shape[dm]
        links = ((base[rk, dm] + x * strides[dm]) * ndim + dm) * 2 \
            + (step[rk, dm] < 0)
        return indptr, links

    def link_name(self, link: int) -> str:
        node, rest = divmod(link, self.ndim * 2)
        dim, sign = divmod(rest, 2)
        return f"{self.coords(node)}.d{dim}{'+' if sign == 0 else '-'}"

    def __repr__(self):
        return f"Torus{self.shape}"


class Crossbar(Topology):
    """Fully-connected baseline: a dedicated channel per ordered pair.

    Channel ids are assigned lazily on first route so a large crossbar does
    not materialize ``n^2`` links up front.
    """

    def __init__(self, n_nodes: int):
        if n_nodes < 1:
            raise ValueError(f"need >= 1 node, got {n_nodes}")
        self.n_nodes = int(n_nodes)
        self._ids: Dict[Tuple[int, int], int] = {}
        self._names: List[Tuple[int, int]] = []

    def route(self, src: int, dst: int) -> Tuple[int, ...]:
        if src == dst:
            return ()
        key = (src, dst)
        link = self._ids.get(key)
        if link is None:
            link = len(self._names)
            self._ids[key] = link
            self._names.append(key)
        return (link,)

    def link_name(self, link: int) -> str:
        src, dst = self._names[link]
        return f"{src}->{dst}"

    def __repr__(self):
        return f"Crossbar({self.n_nodes})"


def _balanced_factorization(p: int, dims: int) -> Optional[Tuple[int, ...]]:
    """The most balanced ordered factorization of ``p`` into ``dims``
    factors, or None when every factorization is badly skewed (max/min
    ratio > 4) — a shift pattern on a degenerate ``(p, 1, 1)`` torus has
    nothing in common with the machine it stands for."""
    divisors = [f for f in range(1, int(p ** 0.5) + 1) if p % f == 0]
    divisors = sorted(set(divisors + [p // f for f in divisors]))

    best: Optional[Tuple[int, ...]] = None

    def rec(rem: int, left: int, picked: Tuple[int, ...]) -> None:
        nonlocal best
        if left == 1:
            cand = tuple(sorted(picked + (rem,)))
            if best is None or max(cand) / min(cand) < \
                    max(best) / min(best):
                best = cand
            return
        for f in divisors:
            if rem % f == 0:
                rec(rem // f, left - 1, picked + (f,))

    rec(p, dims, ())
    if best is None or max(best) / max(min(best), 1) > 4:
        return None
    return best


#: memoized topology instances (each pins its own LRU-capped plan/fold
#: caches, so the instance cache is itself a small LRU).
_TOPOLOGY_CACHE: "OrderedDict[tuple, Topology]" = OrderedDict()
_TOPOLOGY_CACHE_CAP = 16


def topology_for(machine, p: int) -> Topology:
    """The torus of ``machine.torus_dims`` dimensions for ``p`` ranks —
    an exact balanced factorization of ``p`` when one exists (so every
    rank owns a node and shift patterns keep their full translation
    symmetry for folding), else the smallest balanced ``k^dims`` holding
    ``p``.  Machines without a torus get a crossbar.  Instances are
    memoized so batched simulations share one route/fold cache."""
    dims = int(getattr(machine, "torus_dims", 0) or 0)
    p = max(1, int(p))
    if dims < 1:
        key = ("crossbar", p)
    else:
        shape = _balanced_factorization(p, dims)
        if shape is None:
            k = 1
            while k ** dims < p:
                k += 1
            shape = (k,) * dims
        key = ("torus", shape)
    with _CACHE_LOCK:
        topo = _TOPOLOGY_CACHE.get(key)
        if topo is None:
            topo = Crossbar(p) if key[0] == "crossbar" else Torus(key[1])
            _TOPOLOGY_CACHE[key] = topo
            if len(_TOPOLOGY_CACHE) > _TOPOLOGY_CACHE_CAP:
                _TOPOLOGY_CACHE.popitem(last=False)
        else:
            _TOPOLOGY_CACHE.move_to_end(key)
        return topo
