"""Explicit network topologies for the per-rank simulator.

A :class:`Topology` maps an ordered pair of node indices to the sequence of
*directed links* a message traverses.  Two concrete topologies:

* :class:`Torus` — a k-ary n-cube with dimension-ordered routing (DOR),
  the shape of the paper's Gemini 3D torus and of TPU ICI meshes.  Routing
  is identical to the pre-PR-3 ``core.calibration.ContentionSimulator``
  (shortest wraparound direction per dimension, ties broken forward), so
  calibration tables derived through this layer reproduce the old numbers
  bit-for-bit.
* :class:`Crossbar` — a flat, fully-connected baseline where every ordered
  pair owns a dedicated channel.  No two distinct messages ever share a
  link, so simulation on a crossbar is *contention-free by construction*
  — the cross-validation anchor against the closed-form ``est_NoCal``
  evaluator.

Link ids are small integers local to a topology instance; ``link_name``
renders them for traces and utilization reports.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple


class Topology:
    """Interface: node count plus directed-link routing."""

    n_nodes: int

    def route(self, src: int, dst: int) -> Tuple[int, ...]:
        """Directed link ids traversed by a ``src -> dst`` message (empty
        for ``src == dst``)."""
        raise NotImplementedError

    def hops(self, src: int, dst: int) -> int:
        return len(self.route(src, dst))

    def link_name(self, link: int) -> str:
        raise NotImplementedError


class Torus(Topology):
    """k-ary n-cube with dimension-ordered routing.

    Nodes are numbered in mixed radix over ``shape`` (dimension 0 fastest,
    matching the legacy contention simulator).  Each node owns ``2 * ndim``
    outgoing links (one per dimension per direction).
    """

    def __init__(self, shape: Sequence[int]):
        self.shape = tuple(int(k) for k in shape)
        if not self.shape or any(k < 1 for k in self.shape):
            raise ValueError(f"invalid torus shape {shape!r}")
        self.ndim = len(self.shape)
        n = 1
        for k in self.shape:
            n *= k
        self.n_nodes = n
        self._cache: Dict[Tuple[int, int], Tuple[int, ...]] = {}

    def coords(self, node: int) -> Tuple[int, ...]:
        c = []
        for k in self.shape:
            c.append(node % k)
            node //= k
        return tuple(c)

    def node(self, coords: Sequence[int]) -> int:
        idx, stride = 0, 1
        for x, k in zip(coords, self.shape):
            idx += (int(x) % k) * stride
            stride *= k
        return idx

    def _link_id(self, coords: Sequence[int], dim: int, step: int) -> int:
        return (self.node(coords) * self.ndim + dim) * 2 + (0 if step > 0 else 1)

    def route(self, src: int, dst: int) -> Tuple[int, ...]:
        key = (src, dst)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        cs, cd = list(self.coords(src)), list(self.coords(dst))
        links: List[int] = []
        for dim, k in enumerate(self.shape):
            while cs[dim] != cd[dim]:
                fwd = (cd[dim] - cs[dim]) % k
                step = 1 if fwd <= k - fwd else -1  # tie -> forward (legacy)
                links.append(self._link_id(cs, dim, step))
                cs[dim] = (cs[dim] + step) % k
        path = tuple(links)
        self._cache[key] = path
        return path

    def link_name(self, link: int) -> str:
        node, rest = divmod(link, self.ndim * 2)
        dim, sign = divmod(rest, 2)
        return f"{self.coords(node)}.d{dim}{'+' if sign == 0 else '-'}"

    def __repr__(self):
        return f"Torus{self.shape}"


class Crossbar(Topology):
    """Fully-connected baseline: a dedicated channel per ordered pair.

    Channel ids are assigned lazily on first route so a large crossbar does
    not materialize ``n^2`` links up front.
    """

    def __init__(self, n_nodes: int):
        if n_nodes < 1:
            raise ValueError(f"need >= 1 node, got {n_nodes}")
        self.n_nodes = int(n_nodes)
        self._ids: Dict[Tuple[int, int], int] = {}
        self._names: List[Tuple[int, int]] = []

    def route(self, src: int, dst: int) -> Tuple[int, ...]:
        if src == dst:
            return ()
        key = (src, dst)
        link = self._ids.get(key)
        if link is None:
            link = len(self._names)
            self._ids[key] = link
            self._names.append(key)
        return (link,)

    def link_name(self, link: int) -> str:
        src, dst = self._names[link]
        return f"{src}->{dst}"

    def __repr__(self):
        return f"Crossbar({self.n_nodes})"


def topology_for(machine, p: int) -> Topology:
    """The smallest balanced torus of ``machine.torus_dims`` dimensions
    holding ``p`` ranks (the tuner's default when refining plans by
    simulation).  Machines without a torus get a crossbar."""
    dims = int(getattr(machine, "torus_dims", 0) or 0)
    if dims < 1:
        return Crossbar(max(1, p))
    k = 1
    while k ** dims < p:
        k += 1
    return Torus((k,) * dims)
