"""Fault & degradation injection for the per-rank simulator.

Real machines degrade: a sick node computes slowly, a congested or
flapping link delivers at a fraction of its bandwidth, a dead link drops
out of the routing fabric entirely.  The paper's C_max/C_avg gap *is* a
degradation signature — this module makes those signatures injectable so
the detect -> diagnose -> re-plan loop can be exercised end to end.

A :class:`FaultSpec` is declarative and immutable:

* :class:`SlowRank`     — per-rank compute-time multiplier (``scale > 1``
                          means slower), applied at every ``Compute`` leaf
                          the executor charges to that rank;
* :class:`DegradedLink` — per-link beta multiplier: traffic crossing the
                          link behaves as if the link's instantaneous
                          load were ``scale`` times higher, so a lone
                          transfer on a degraded link takes ``scale``
                          times its ideal alpha-beta time and contention
                          on it is amplified by the same factor;
* :class:`DeadLink`     — the link is removed from routing.  A torus
                          reroutes dimension-by-dimension along the other
                          ring direction (the only alternative a
                          deterministic DOR router has); when both
                          directions are dead — or the topology has no
                          alternative path, e.g. a crossbar channel —
                          :class:`UnreachableError` is raised rather than
                          silently mis-routing.

Every fault carries an optional ``onset_s``.  Onset semantics are
*pattern-granular*: a link fault is active for a delivery iff the
pattern's earliest start time has reached the onset, and a compute fault
is active for a leaf iff the rank's clock has.  This keeps the folded
vector engine and the PR-3 reference engine trivially in agreement (both
evaluate the same predicate on the same inputs), so the existing 1e-6
agreement gate extends to faulted runs unchanged.

Interaction with rank-symmetry folding (DESIGN.md §7): per-link beta
scales are folded into the *seed* of the color refinement, so faulted
links land in their own link classes and slowed transfers split off by
their clock classes — the coarsest equitable partition respects the
fault structure by construction.  Where refinement cannot converge the
engine falls back to the trivial partition (stand-down): folding under
faults degrades to the plain vectorized engine, never to a wrong answer.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Dict, FrozenSet, Iterable, List, Optional, Tuple

import numpy as np

from .topology import Topology, Torus


class UnreachableError(RuntimeError):
    """No route exists between two nodes once dead links are removed."""


@dataclasses.dataclass(frozen=True)
class SlowRank:
    """Rank ``rank`` computes ``scale`` times slower from ``onset_s``."""

    rank: int
    scale: float
    onset_s: float = 0.0

    def __post_init__(self):
        if self.rank < 0:
            raise ValueError(f"rank must be >= 0, got {self.rank}")
        if not self.scale > 0:
            raise ValueError(f"compute scale must be > 0, got {self.scale}")
        if self.onset_s < 0:
            raise ValueError(f"onset_s must be >= 0, got {self.onset_s}")


@dataclasses.dataclass(frozen=True)
class DegradedLink:
    """Link ``link`` behaves ``scale`` times slower from ``onset_s``.

    ``scale >= 1``: this models degradation (the fluid engine's rate
    floor assumes effective loads never drop below the true load)."""

    link: int
    scale: float
    onset_s: float = 0.0

    def __post_init__(self):
        if self.link < 0:
            raise ValueError(f"link must be >= 0, got {self.link}")
        if not self.scale >= 1.0:
            raise ValueError(f"link scale must be >= 1, got {self.scale}")
        if self.onset_s < 0:
            raise ValueError(f"onset_s must be >= 0, got {self.onset_s}")


@dataclasses.dataclass(frozen=True)
class DeadLink:
    """Link ``link`` is removed from the routing fabric at ``onset_s``."""

    link: int
    onset_s: float = 0.0

    def __post_init__(self):
        if self.link < 0:
            raise ValueError(f"link must be >= 0, got {self.link}")
        if self.onset_s < 0:
            raise ValueError(f"onset_s must be >= 0, got {self.onset_s}")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A declarative bundle of injected degradations (see module doc)."""

    slow_ranks: Tuple[SlowRank, ...] = ()
    degraded_links: Tuple[DegradedLink, ...] = ()
    dead_links: Tuple[DeadLink, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "slow_ranks", tuple(self.slow_ranks))
        object.__setattr__(self, "degraded_links",
                           tuple(self.degraded_links))
        object.__setattr__(self, "dead_links", tuple(self.dead_links))

    # -- queries -------------------------------------------------------------
    @property
    def empty(self) -> bool:
        return not (self.slow_ranks or self.degraded_links or self.dead_links)

    @property
    def max_onset_s(self) -> float:
        """Latest onset across every fault (0 for an empty/immediate spec);
        once the simulation clock passes it the fault set is static and
        steady-state fast-forwarding is safe again."""
        onsets = [f.onset_s for f in
                  (*self.slow_ranks, *self.degraded_links, *self.dead_links)]
        return max(onsets) if onsets else 0.0

    def active_dead(self, t: float) -> FrozenSet[int]:
        """Physical link ids dead at pattern time ``t``."""
        return frozenset(f.link for f in self.dead_links if t >= f.onset_s)

    def link_scales(self, links: np.ndarray, t: float
                    ) -> Optional[np.ndarray]:
        """Per-entry beta multipliers for physical link ids ``links`` at
        pattern time ``t`` — or None when no active degraded fault touches
        any of them (the caller keeps its unscaled fast path)."""
        active = [f for f in self.degraded_links if t >= f.onset_s]
        if not active:
            return None
        scales = np.ones(links.size)
        touched = False
        for f in active:
            m = links == f.link
            if m.any():
                scales[m] *= f.scale
                touched = True
        return scales if touched else None

    def compute_scales(self, clocks: np.ndarray) -> Optional[np.ndarray]:
        """Per-rank compute-time multipliers given per-rank clocks (a slow
        rank counts once its own clock has reached the onset), or None
        when no slow rank is active."""
        if not self.slow_ranks:
            return None
        v: Optional[np.ndarray] = None
        p = clocks.size
        for f in self.slow_ranks:
            if f.rank < p and clocks[f.rank] >= f.onset_s:
                if v is None:
                    v = np.ones(p)
                v[f.rank] *= f.scale
        return v

    # -- identity ------------------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def fingerprint(self) -> str:
        blob = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:12]


def torus_link(topo: Torus, node: int, dim: int, step: int) -> int:
    """The physical id of ``node``'s outgoing link along ``dim`` in
    direction ``step`` (+1 forward / -1 backward) — the handle fault
    specs and tests name links by."""
    if not isinstance(topo, Torus):
        raise TypeError(f"torus_link needs a Torus, got {topo!r}")
    return topo._link_id(topo.coords(node), dim, 1 if step > 0 else -1)


class FaultyTopology(Topology):
    """Routing view of ``base`` with a set of dead links removed.

    A fresh instance per active dead set: route/plan/fold caches are
    private (never the memoized shared instance's), so fault scenarios
    cannot poison healthy simulations.  Torus bases reroute per DOR
    dimension by flipping to the other ring direction; any other base —
    or a torus with both directions dead — raises
    :class:`UnreachableError`.
    """

    def __init__(self, base: Topology, dead: Iterable[int]):
        self.base = base
        self.dead = frozenset(int(l) for l in dead)
        self.n_nodes = base.n_nodes
        self._routes: Dict[Tuple[int, int], Tuple[int, ...]] = {}

    def link_name(self, link: int) -> str:
        return self.base.link_name(link)

    def route(self, src: int, dst: int) -> Tuple[int, ...]:
        key = (src, dst)
        hit = self._routes.get(key)
        if hit is not None:
            return hit
        if isinstance(self.base, Torus):
            path = self._torus_route(src, dst)
        else:
            path = self.base.route(src, dst)
            bad = [l for l in path if l in self.dead]
            if bad:
                raise UnreachableError(
                    f"{src} -> {dst} crosses dead link(s) "
                    f"{bad} on {self.base!r} (no alternate route)")
        self._routes[key] = path
        return path

    def _torus_route(self, src: int, dst: int) -> Tuple[int, ...]:
        t = self.base
        cs, cd = list(t.coords(src)), list(t.coords(dst))
        links: List[int] = []
        for dim, k in enumerate(t.shape):
            fwd = (cd[dim] - cs[dim]) % k
            if fwd == 0:
                continue
            pref = 1 if 2 * fwd <= k else -1  # tie -> forward (DOR legacy)
            for step in (pref, -pref):
                hops = self._ring_hops(cs, dim, step,
                                       fwd if step > 0 else k - fwd)
                if hops is not None:
                    links.extend(hops)
                    cs[dim] = cd[dim]
                    break
            else:
                raise UnreachableError(
                    f"{src} -> {dst}: both ring directions of dim {dim} "
                    f"cross dead links on {t!r}")
        return tuple(links)

    def _ring_hops(self, cs: List[int], dim: int, step: int,
                   nhops: int) -> Optional[List[int]]:
        t = self.base
        k = t.shape[dim]
        cur = list(cs)
        out: List[int] = []
        for _ in range(nhops):
            lid = t._link_id(cur, dim, step)
            if lid in self.dead:
                return None
            out.append(lid)
            cur[dim] = (cur[dim] + step) % k
        return out

    def __repr__(self):
        return f"Faulty({self.base!r}, dead={sorted(self.dead)})"
