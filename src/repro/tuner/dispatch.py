"""Model-guided dispatch: the executable back half of the tuner.

``matmul`` / ``trsm`` / ``cholesky`` take *global* (unsharded) operands,
ask the :class:`~repro.tuner.autotune.Tuner` for an
:class:`~repro.tuner.plan.ExecutionPlan`, build the planned 2D / 2.5D
process-grid mesh, block-distribute the operands (padding to the grid where
needed — identity-extended for triangular/SPD structure), and run the
chosen ``shard_map`` variant with the planned local kernels:

* ``local_kernel="pallas"`` wires the Pallas kernels
  (``kernels.matmul/trsm/cholesky``) in as the local matmul / triangular
  solve / diagonal factor (interpret-mode off TPU);
* ``local_kernel="jnp"`` (the CPU default) uses the ``jnp.dot`` /
  ``jax.scipy`` locals.

Meshes and compiled executors are memoized per (grid, devices, variant,
kernel), so a cache-hit call pays only plan lookup + padding + dispatch.

When telemetry recording is on (``REPRO_TELEMETRY=1`` /
``repro.telemetry.enable()`` / per-call ``observe=True``) every dispatch
emits one measured :class:`~repro.telemetry.RunRecord` with per-phase
wall times (plan / distribute / execute, the execute phase blocked to
completion) tagged by the plan's machine fingerprint — the raw material
of the measured-run feedback loop.  With recording off the only added
cost is one boolean check per call, and results stay unblocked.
"""

from __future__ import annotations

import threading
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..kernels.cholesky.ops import cholesky as _kchol
from ..kernels.common import TilePlan
from ..kernels.matmul.ops import matmul as _kmm
from ..kernels.trsm.ops import trsm as _ktrsm
# NB: import the factories, not the modules — the linalg package shadows
# the trsm/cholesky module attributes with the dispatch wrappers.
from ..linalg.cannon import make as _make_cannon
from ..linalg.cholesky import make as _make_cholesky
from ..linalg.grid import distribute, make_grid_mesh
from ..linalg.summa import make as _make_summa
from ..linalg.trsm import make as _make_trsm
from .. import obs
from .autotune import Tuner, default_tuner
from .plan import ExecutionPlan

_LOCK = threading.Lock()
_MESHES: Dict[tuple, jax.sharding.Mesh] = {}
_EXECUTORS: Dict[tuple, object] = {}


# -- local kernel hooks -----------------------------------------------------
# Hook closures are built per (algo, kernel, interpret, tiles) executor key
# — the memo in _executor keeps their identity stable, so shard_map never
# re-traces for a configuration it has already compiled.

def _tiles_key(tiles: Dict[str, Dict[str, int]]) -> tuple:
    """Canonical hashable form of a plan's tiles map (executor memo key)."""
    return tuple(sorted((fam, tuple(sorted(blocks.items())))
                        for fam, blocks in (tiles or {}).items()))


def _tile_plans(tiles: Dict[str, Dict[str, int]]) -> Dict[str, TilePlan]:
    """The plan's JSON tile map as jit-static TilePlan objects."""
    return {fam: TilePlan.from_blocks(fam, blocks, source="plan")
            for fam, blocks in (tiles or {}).items()}


def _local_hooks(algo: str, local_kernel: str, interpret: bool,
                 tiles: Optional[Dict[str, Dict[str, int]]] = None) -> dict:
    if local_kernel != "pallas":
        return {}
    plans = _tile_plans(tiles)
    mm_tp = plans.get("matmul")
    trsm_tp = plans.get("trsm")
    chol_tp = plans.get("cholesky")

    def local_mm(a, b):
        return _kmm(a, b, interpret=interpret, out_dtype=a.dtype,
                    tiles=mm_tp)

    if algo in ("cannon", "summa"):
        return {"local_mm": local_mm}
    if algo == "trsm":
        def local_solve(b, u):
            return _ktrsm(u, b, interpret=interpret, tiles=trsm_tp,
                          mm_tiles=mm_tp)
        return {"local_mm": local_mm, "local_solve": local_solve}
    if algo == "cholesky":
        def local_chol(a):
            return _kchol(a, interpret=interpret, tiles=chol_tp,
                          mm_tiles=mm_tp)

        def local_panel_solve(a, ljj):
            # panel width is fixed by the diagonal factor's extent; only
            # the dgemm tail inherits a tile choice here
            return _ktrsm(ljj.T, a, interpret=interpret, mm_tiles=mm_tp)
        return {"local_mm": local_mm, "local_chol": local_chol,
                "local_solve": local_panel_solve}
    raise ValueError(algo)


_MAKERS = {"cannon": _make_cannon, "summa": _make_summa, "trsm": _make_trsm,
           "cholesky": _make_cholesky}


def _mesh_for(g: int, c: int, devices: Tuple) -> jax.sharding.Mesh:
    key = (g, c, tuple(d.id for d in devices))
    with _LOCK:
        mesh = _MESHES.get(key)
    if mesh is None:
        mesh = make_grid_mesh(g, g, layers=c, devices=list(devices))
        with _LOCK:
            _MESHES[key] = mesh
    return mesh


def _executor(plan: ExecutionPlan, mesh, devices: Tuple, interpret: bool):
    key = (plan.algo, plan.variant, plan.g, plan.c,
           tuple(d.id for d in devices), plan.local_kernel, interpret,
           _tiles_key(plan.tiles))
    with _LOCK:
        fn = _EXECUTORS.get(key)
    if fn is None:
        hooks = _local_hooks(plan.algo, plan.local_kernel, interpret,
                             plan.tiles)
        fn = _MAKERS[plan.algo](mesh, plan.variant, **hooks)
        with _LOCK:
            if len(_EXECUTORS) > 64:
                _EXECUTORS.clear()
            _EXECUTORS[key] = fn
    return fn


# -- padding ----------------------------------------------------------------

def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_zero(x, rows: int, cols: int):
    return jnp.pad(x, ((0, rows - x.shape[0]), (0, cols - x.shape[1])))


def _pad_eye(x, size: int):
    """blockdiag(x, I): structure-preserving pad for triangular/SPD args."""
    n = x.shape[0]
    if size == n:
        return x
    out = _pad_zero(x, size, size)
    idx = jnp.arange(n, size)
    return out.at[idx, idx].set(jnp.ones((), x.dtype))


def _check_square(name: str, x) -> int:
    if x.ndim != 2 or x.shape[0] != x.shape[1]:
        raise ValueError(f"{name} must be square 2-D, got {x.shape} "
                         "(the paper's algorithms are square-grid)")
    return int(x.shape[0])


def _dtype_key(x) -> str:
    """Plan-cache dtype key without staging the operand to device (x64
    inputs canonicalize the same way jnp.asarray would convert them)."""
    return str(jax.dtypes.canonicalize_dtype(np.result_type(x)))


# -- execution --------------------------------------------------------------

def _resolve(devices: Optional[Sequence], plan_p: int) -> Tuple:
    devices = list(devices) if devices is not None else jax.devices()
    if len(devices) < plan_p:
        raise ValueError(f"plan needs {plan_p} devices, have {len(devices)}")
    return tuple(devices[:plan_p])


def execute(plan: ExecutionPlan, *operands,
            devices: Optional[Sequence] = None, observe: bool = False,
            store=None, _plan_seconds: float = 0.0):
    """Run an already-resolved plan on its operands (benchmarks use this to
    force specific — including deliberately bad — variants).

    ``observe=True`` records this run's measured phases into the telemetry
    store even when global recording is off; ``store`` routes the record
    (default: the global default store).  ``_plan_seconds`` lets the
    model-guided wrappers account the planning time they already spent."""
    from .. import telemetry
    from ..telemetry import phase_scope as _phase
    devs = _resolve(devices, plan.p)
    interpret = devs[0].platform != "tpu"
    mesh = _mesh_for(plan.g, plan.c, devs)
    fn = _executor(plan, mesh, devs, interpret)
    pt = None
    if observe or telemetry.enabled() or obs.enabled():
        pt = telemetry.timer_for_plan(plan, kind="dispatch")
        if _plan_seconds > 0.0:
            pt.add("plan", _plan_seconds)
    n = plan.n
    g, c = plan.g, plan.c
    # root span for the whole dispatch; the phase() children underneath
    # (distribute/execute) carry the predicted durations and pair up
    with obs.maybe_span(f"dispatch:{plan.algo}", cat="dispatch_root",
                        algo=plan.algo, variant=plan.variant, n=n,
                        p=plan.p, c=c,
                        predicted_total_s=plan.predicted.get("total")):
        if plan.algo in ("cannon", "summa"):
            a, b = (jnp.asarray(x) for x in operands)
            m = _round_up(n, g)
            with _phase(pt, "distribute"):
                ad = distribute(_pad_zero(a, m, m), mesh, P("row", "col"))
                bd = distribute(_pad_zero(b, m, m), mesh, P("row", "col"))
            with _phase(pt, "execute"):
                out = fn(ad, bd)[:n, :n]
                if pt is not None:
                    jax.block_until_ready(out)
        elif plan.algo == "trsm":
            u, b = (jnp.asarray(x) for x in operands)
            m = _round_up(n, g)
            mb = _round_up(n, c * g)
            bx_spec = P(("lyr", "row"), "col") if c > 1 else P("row", "col")
            with _phase(pt, "distribute"):
                ud = distribute(_pad_eye(u, m), mesh, P("row", "col"))
                bd = distribute(_pad_zero(b, mb, m), mesh, bx_spec)
            with _phase(pt, "execute"):
                out = fn(ud, bd)[:n, :n]
                if pt is not None:
                    jax.block_until_ready(out)
        elif plan.algo == "cholesky":
            (a,) = (jnp.asarray(x) for x in operands)
            m = _round_up(n, g)
            with _phase(pt, "distribute"):
                ad = distribute(_pad_eye(a, m), mesh, P("row", "col"))
            with _phase(pt, "execute"):
                out = fn(ad)[:n, :n]
                if pt is not None:
                    jax.block_until_ready(out)
        else:
            raise ValueError(f"unknown algo {plan.algo!r}")
    if pt is not None:
        pt.emit(store=store, force=observe)
    return out


def matmul(A, B, *, devices: Optional[Sequence] = None,
           tuner: Optional[Tuner] = None,
           local_kernel: Optional[str] = None,
           observe: bool = False):
    """C = A @ B, model-guided: the tuner races the Cannon and SUMMA models
    over every realizable 2D/2.5D grid and executes the winner."""
    n = _check_square("A", A)
    if tuple(B.shape) != tuple(A.shape):
        raise ValueError(f"A {A.shape} and B {B.shape} must match")
    t = tuner or default_tuner()
    devs = list(devices) if devices is not None else jax.devices()
    t0 = time.perf_counter()
    with obs.maybe_span("plan", cat="dispatch", op="matmul", n=n):
        plan = t.plan("matmul", n, devices=devs, dtype=_dtype_key(A),
                      local_kernel=local_kernel, observe=observe)
    return execute(plan, A, B, devices=devs, observe=observe, store=t.store,
                   _plan_seconds=time.perf_counter() - t0)


def trsm(U, B, *, devices: Optional[Sequence] = None,
         tuner: Optional[Tuner] = None,
         local_kernel: Optional[str] = None,
         observe: bool = False):
    """Solve X U = B (U upper-triangular), model-guided."""
    n = _check_square("U", U)
    if tuple(B.shape) != tuple(U.shape):
        raise ValueError(f"U {U.shape} and B {B.shape} must match")
    t = tuner or default_tuner()
    devs = list(devices) if devices is not None else jax.devices()
    t0 = time.perf_counter()
    with obs.maybe_span("plan", cat="dispatch", op="trsm", n=n):
        plan = t.plan("trsm", n, devices=devs, dtype=_dtype_key(U),
                      local_kernel=local_kernel, observe=observe)
    return execute(plan, U, B, devices=devs, observe=observe, store=t.store,
                   _plan_seconds=time.perf_counter() - t0)


def cholesky(A, *, devices: Optional[Sequence] = None,
             tuner: Optional[Tuner] = None,
             local_kernel: Optional[str] = None,
             observe: bool = False):
    """L with A = L L^T (A SPD), model-guided."""
    n = _check_square("A", A)
    t = tuner or default_tuner()
    devs = list(devices) if devices is not None else jax.devices()
    t0 = time.perf_counter()
    with obs.maybe_span("plan", cat="dispatch", op="cholesky", n=n):
        plan = t.plan("cholesky", n, devices=devs, dtype=_dtype_key(A),
                      local_kernel=local_kernel, observe=observe)
    return execute(plan, A, devices=devs, observe=observe, store=t.store,
                   _plan_seconds=time.perf_counter() - t0)
