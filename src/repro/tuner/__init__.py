"""repro.tuner — model-guided autotuning dispatch.

The paper's headline application, closed end-to-end: the analytic
performance models (``repro.core``) select the 2D/2.5D ±overlap variant,
replication factor and grid for a scenario, and the executable
``shard_map`` algorithms (``repro.linalg``) run the winner with the Pallas
kernels (``repro.kernels``) as local compute.

Layout:
  registry.py   PerfModelRegistry — one query surface over the algorithm
                models, collective models, and machine surfaces
  plan.py       ExecutionPlan + persistent JSON PlanCache (artifacts/plans/)
  autotune.py   Tuner — feasible-grid enumeration + model selection +
                LM-layer consultations (fsdp layout, prefill chunking)
  dispatch.py   linalg.matmul/trsm/cholesky execution of resolved plans
"""

from .registry import (DEFAULT_REGISTRY, MachineSurface, PerfModelRegistry,
                       build_default_registry, machine_for_platform)
from .plan import (ExecutionPlan, PlanCache, default_plan_dir,
                   machine_fingerprint, plan_key)
from .autotune import OP_ALGOS, Tuner, default_tuner, feasible_grids

__all__ = [
    "DEFAULT_REGISTRY", "MachineSurface", "PerfModelRegistry",
    "build_default_registry", "machine_for_platform",
    "ExecutionPlan", "PlanCache", "default_plan_dir", "machine_fingerprint",
    "plan_key",
    "OP_ALGOS", "Tuner", "default_tuner", "feasible_grids",
]
