"""The model-guided autotuner: plans end-to-end execution from the models.

``Tuner.plan(op, n, devices=...)`` answers "how should this operation run
on this device pool?" by

1. enumerating the process-grid configurations the pool can actually
   realize (2D ``g x g`` grids and 2.5D ``c x g x g`` grids — the
   executable 2.5D matmuls need ``c | g``, and replication is capped at
   ``c <= g`` so every layer owns work);
2. evaluating every candidate (algo, variant, c) through the registry's
   analytic models via ``core.predictor`` — the paper's §VI selection,
   restricted to realizable configurations;
3. freezing the argmin into an :class:`ExecutionPlan` and persisting it in
   the plan cache, so the next call with the same (machine fingerprint,
   op, n, p, dtype) never touches the models again.

``plan(..., refine="sim")`` inserts an opt-in second stage between 2 and
3: the closed-form evaluator shortlists the top-k grids, then the
per-rank discrete-event simulator (``repro.sim``) replays each candidate
on the machine's topology and the argmin is taken over *simulated*
makespans (DESIGN.md §4.4).

The same Tuner also serves the LM layers: ``recommend_fsdp`` consults the
LM-step model for the parameter-sharding layout choice, and
``prefill_chunk`` sizes the serving engine's chunked prefill.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import predictor
from ..core.algorithms import result_from_eval
from ..perf.kernel import tiles_for_plan
from .plan import (ExecutionPlan, PlanCache, machine_fingerprint, plan_key)
from .registry import DEFAULT_REGISTRY, PerfModelRegistry, machine_for_platform

#: public operation -> candidate algorithm models (matmul races Cannon
#: against SUMMA; the factorizations map one-to-one).  "lu" plans through
#: the models only (no executable dispatch yet).
OP_ALGOS: Dict[str, Tuple[str, ...]] = {
    "matmul": ("cannon", "summa"),
    "cannon": ("cannon",),
    "summa": ("summa",),
    "trsm": ("trsm",),
    "cholesky": ("cholesky",),
    "lu": ("lu",),
}


def feasible_grids(device_count: int, algo: str) -> List[Tuple[int, int, int]]:
    """Realizable (p, c, g) grid configurations for a device pool.

    2D: the largest square ``g*g <= device_count`` (one entry).
    2.5D: every power-of-two ``c`` with ``c * g*g <= device_count``,
    ``c <= g`` (each layer must own columns / steps), and — for the
    shift/broadcast matmuls — ``c | g`` (each layer executes a contiguous
    chunk of ``g/c`` steps).
    """
    out: List[Tuple[int, int, int]] = []
    g2 = int(math.isqrt(device_count))
    if g2 >= 1:
        out.append((g2 * g2, 1, g2))
    c = 2
    while c * c * c <= device_count:  # c <= g implies c^3 <= c*g*g <= D
        g = int(math.isqrt(device_count // c))
        while g >= c:
            if algo in ("cannon", "summa") and g % c != 0:
                g -= 1
                continue
            out.append((c * g * g, c, g))
            break
        c *= 2
    return out


class Tuner:
    """Registry + plan cache + selection policy, behind one object."""

    def __init__(self, registry: Optional[PerfModelRegistry] = None,
                 cache: Optional[PlanCache] = None,
                 plan_dir: Optional[str] = None,
                 store=None):
        self.registry = registry or DEFAULT_REGISTRY
        self.cache = cache or PlanCache(plan_dir)
        self.store = store      # telemetry RunStore for observe=True records
        self.stats = {"model_evals": 0, "cache_hits": 0}
        self._lm_cal = None
        self._lock = threading.Lock()

    # -- linalg planning -----------------------------------------------------
    def plan(self, op: str, n: int, *,
             devices: Optional[Sequence] = None,
             device_count: Optional[int] = None,
             platform: Optional[str] = None,
             device_kind: Optional[str] = None,
             dtype: str = "float32",
             machine: Optional[str] = None,
             local_kernel: Optional[str] = None,
             use_cache: bool = True,
             refine: Optional[str] = None,
             shortlist: int = 4,
             observe: bool = False) -> ExecutionPlan:
        """Resolve (or recall) the best execution plan for ``op`` at size
        ``n`` on the given device pool.

        Pass real ``devices`` for dispatch, or ``device_count``/``platform``
        alone to ask hypothetical questions ("what would 4096 Hopper
        processes run?") without touching jax device state.

        ``refine="sim"`` adds the opt-in second planning stage: the
        vectorized closed-form evaluator shortlists the ``shortlist`` best
        grids, then the per-rank discrete-event simulator (``repro.sim``)
        replays each on the machine's topology and the plan is re-ranked
        by *simulated* time (``predicted["sim_total"]``).  Refined plans
        cache under their own key, so closed-form plans are never
        shadowed.

        ``observe=True`` records the planning decision (chosen variant +
        predicted timing) into the telemetry run store, so the measured
        feedback loop can later compare what the model promised with what
        dispatch delivered — it records regardless of the global
        ``REPRO_TELEMETRY`` switch (an explicit per-call opt-in).
        """
        if refine not in (None, "sim"):
            raise ValueError(f"refine must be None or 'sim', got {refine!r}")
        if devices is not None:
            devices = list(devices)
            device_count = len(devices)
            platform = platform or devices[0].platform
            device_kind = device_kind or getattr(devices[0], "device_kind",
                                                 platform)
        if device_count is None:
            import jax
            devices = list(jax.devices())
            device_count = len(devices)
            platform = platform or devices[0].platform
            device_kind = device_kind or getattr(devices[0], "device_kind",
                                                 platform)
        platform = platform or "cpu"
        device_kind = device_kind or platform
        machine = machine or machine_for_platform(platform)
        # A degraded surface (diagnosis attached a FaultSpec) demands the
        # simulator: only the per-rank engine sees link granularity, so
        # closed-form-only planning would ignore the fault entirely.
        try:
            _surface = self.registry.machine(machine)
        except KeyError:
            _surface = None
        if refine is None and _surface is not None \
                and getattr(_surface, "faults", None) is not None:
            refine = "sim"
        if local_kernel not in (None, "pallas", "jnp"):
            raise ValueError(f"local_kernel must be 'pallas' or 'jnp', "
                             f"got {local_kernel!r}")
        local_kernel = local_kernel or ("pallas" if platform == "tpu" else "jnp")

        # Key plans by the registered Machine *profile* (its fingerprint
        # hashes every field, incl. the telemetry-bumped revision), not the
        # bare name — refits and drift invalidation change the key.
        try:
            profile = self.registry.machine(machine).machine
        except KeyError:
            profile = machine
        fp = machine_fingerprint(profile, platform, device_kind, device_count)
        # refine and shortlist both shape the refined decision, so they are
        # part of the cache identity (closed-form plans keep their old keys)
        key = plan_key(fp, op if refine is None
                       else f"{op}@{refine}{int(shortlist)}",
                       n, device_count, dtype)
        if use_cache:
            hit = self.cache.get(key)
            if hit is not None:
                try:
                    plan = ExecutionPlan.from_dict(hit)
                except (ValueError, TypeError):
                    self.cache.invalidate(key)
                else:
                    with self._lock:
                        self.stats["cache_hits"] += 1
                    if plan.local_kernel != local_kernel:
                        # kernel choice is an execution detail, not a model
                        # decision — honor the caller without re-planning
                        import dataclasses
                        plan = dataclasses.replace(plan,
                                                   local_kernel=local_kernel)
                    if observe:
                        self._observe(plan)
                    return plan

        plan = self._build_plan(op, n, device_count, machine, dtype,
                                local_kernel, fp, refine=refine,
                                shortlist=shortlist)
        with self._lock:
            self.stats["model_evals"] += 1
        if use_cache:
            self.cache.put(key, plan.to_dict())
        if observe:
            self._observe(plan)
        return plan

    def _observe(self, plan: ExecutionPlan) -> None:
        from ..telemetry import observe_plan
        observe_plan(plan, store=self.store)
        with self._lock:
            self.stats["observed"] = self.stats.get("observed", 0) + 1

    def _build_plan(self, op: str, n: int, device_count: int, machine: str,
                    dtype: str, local_kernel: str, fp: str,
                    refine: Optional[str] = None,
                    shortlist: int = 4) -> ExecutionPlan:
        try:
            algos = OP_ALGOS[op]
        except KeyError:
            raise ValueError(f"unknown op {op!r}; known: {sorted(OP_ALGOS)}") \
                from None
        ctx = self.registry.context(machine)
        # Enumerate every realizable (algo, variant, p, c, g) candidate in
        # selection-priority order, then score them with ONE vectorized
        # model evaluation per (algo, variant) instead of a scalar
        # predictor.select call per grid (the executables use r=1).
        cands: List[Tuple[str, str, int, int, int]] = []
        for algo in algos:
            all_variants = self.registry.variants(algo)
            for p, c, g in feasible_grids(device_count, algo):
                kind = "2d" if c == 1 else "2.5d"
                for variant in all_variants:
                    if not variant.startswith(kind):
                        continue
                    if variant.startswith("2.5d") and \
                            not predictor.fits_memory(ctx, algo, n, p, c):
                        continue  # replication at this c exceeds memory
                    cands.append((algo, variant, p, c, g))
        if not cands:
            raise ValueError(f"no feasible grid for {device_count} devices")
        totals = np.empty(len(cands))
        evals: Dict[Tuple[str, str], tuple] = {}
        groups: Dict[Tuple[str, str], List[int]] = {}
        for j, (algo, variant, p, c, g) in enumerate(cands):
            groups.setdefault((algo, variant), []).append(j)
        for (algo, variant), idx in groups.items():
            ps = np.array([cands[j][2] for j in idx], dtype=float)
            cs = np.array([cands[j][3] for j in idx], dtype=float)
            if self.registry.has_program(algo, variant):
                res = self.registry.evaluate_grid(ctx, algo, variant,
                                                  float(n), ps, cs, 1.0)
                evals[(algo, variant)] = (res, idx)
                totals[idx] = res.total
            else:  # legacy scalar ModelFn without a program
                for j in idx:
                    totals[j] = self.registry.evaluate(
                        ctx, algo, variant, n, cands[j][2], c=cands[j][3]).total
        sim_extra: Optional[Dict[str, float]] = None
        if refine == "sim":
            j, sim_extra = self._sim_rerank(cands, totals, machine, n,
                                            shortlist, device_count)
        else:
            j = int(np.argmin(totals))
        algo, variant, p, c, g = cands[j]
        ev = evals.get((algo, variant))
        if ev is not None:
            res = result_from_eval(self.registry.program(algo, variant),
                                   ev[0], n, p, c, 1, idx=ev[1].index(j))
        else:
            res = self.registry.evaluate(ctx, algo, variant, n, p, c=c)
        predicted = {"total": res.total, "comm": res.comm, "comp": res.comp,
                     "pct_peak": predictor.pct_of_peak(ctx, res)}
        if sim_extra is not None:
            predicted.update(sim_extra)
        # the intra-kernel tier: per-family tile plans for the local Pallas
        # kernels this algo will run — model-chosen when the machine profile
        # has kernel constants, today's heuristic blocks otherwise
        try:
            profile = self.registry.machine(machine).machine
        except KeyError:
            profile = None
        tiles = tiles_for_plan(profile, algo, n, g, dtype)
        return ExecutionPlan(
            algo=algo, variant=res.variant, n=n, p=p, c=c, r=res.r, g=g,
            local_kernel=local_kernel, dtype=dtype, machine=machine,
            fingerprint=fp, predicted=predicted, tiles=tiles)

    def _sim_rerank(self, cands, totals, machine: str, n: int,
                    shortlist: int, device_count: Optional[int] = None
                    ) -> Tuple[int, Dict[str, float]]:
        """The opt-in second planning stage: replay the closed-form top-k
        candidates through the per-rank discrete-event simulator on the
        machine's topology and pick the one with the smallest *simulated*
        makespan.  The whole shortlist goes through one
        ``simulate_programs`` batch so candidates at the same ``p`` share
        route/fold caches.  Returns (winning candidate index,
        predicted-dict extras).

        When the surface carries a diagnosed :class:`FaultSpec`, every
        candidate simulates on ONE topology — the full device pool's —
        so the fault's physical link ids mean the same thing for every
        grid (candidates use different ``p``), the fault is injected into
        each run, and a candidate rendered unreachable by dead links is
        skipped rather than sinking the batch."""
        from ..sim import simulate_programs, topology_for
        surface = self.registry.machine(machine)
        ctx = surface.context()
        faults = getattr(surface, "faults", None)
        topo = None
        if faults is not None and device_count is not None:
            topo = topology_for(surface.machine, device_count)
        order = np.argsort(totals)[:max(1, int(shortlist))]
        picked = [int(j) for j in order
                  if self.registry.has_program(*cands[int(j)][:2])]
        # legacy scalar models cannot be simulated; they drop out here
        programs = [self.registry.program(*cands[j][:2]) for j in picked]
        scens = [{"n": float(n), "p": cands[j][2], "c": cands[j][3], "r": 1}
                 for j in picked]
        sims = simulate_programs(programs, ctx, scens,
                                 machine=surface.machine, topology=topo,
                                 faults=faults, strict=(faults is None))
        with self._lock:
            self.stats["sim_evals"] = self.stats.get("sim_evals", 0) \
                + len(sims)
        best_j, best_t = int(order[0]), float("inf")
        extras: Dict[str, float] = {}
        for j, sim in zip(picked, sims):
            if sim is None:
                continue  # e.g. unreachable under dead links
            algo, variant, p, c, _g = cands[j]
            extras[f"sim/{algo}/{variant}@p{p}c{c}"] = float(sim.total)
            if sim.total < best_t:
                best_j, best_t = j, float(sim.total)
        if np.isfinite(best_t):
            extras["sim_total"] = best_t
        return best_j, extras

    # -- LM-layer consultation ----------------------------------------------
    def _lm_calibration_table(self):
        with self._lock:
            cal = self._lm_cal
        if cal is None:
            # build outside the lock: the simulator run is slow and the lock
            # also serializes every plan() stats update
            from ..sim import derive_calibration, v5e_pod_topology
            cal = derive_calibration(v5e_pod_topology(),
                                     ps=[16, 64, 256], distances=[1, 2, 4, 8])
            with self._lock:
                if self._lm_cal is None:
                    self._lm_cal = cal
                cal = self._lm_cal
        return cal

    def recommend_fsdp(self, cfg, shape, mesh_shape: Dict[str, int], *,
                       required: bool = False) -> bool:
        """Parameter-sharding layout choice for a train step: FSDP when the
        memory constraint requires it, else when the LM-step model predicts
        the per-layer all-gathers pay for themselves.  Cached per
        (model, shape, mesh) like any other plan."""
        if required:
            return True
        chips = 1
        for v in mesh_shape.values():
            chips *= int(v)
        name = getattr(cfg, "name", type(cfg).__name__)
        # the parameter count disambiguates same-named configs (reduced()
        # smoke-test shrinks keep the production name)
        params = int(getattr(cfg, "param_count", lambda: 0)())
        fp = machine_fingerprint("tpu-v5e", "plan", "lm", chips)
        mesh_tag = "x".join(f"{k}{v}" for k, v in sorted(mesh_shape.items()))
        key = plan_key(
            fp, f"fsdp-{name}-np{params}-b{shape.global_batch}-{mesh_tag}",
            shape.seq_len, chips, "bf16")
        hit = self.cache.get(key)
        if hit is not None and "fsdp" in hit:
            with self._lock:
                self.stats["cache_hits"] += 1
            return bool(hit["fsdp"])
        from ..core.lm_model import predict_train_step
        cal = self._lm_calibration_table()
        plain = predict_train_step(cfg, shape, mesh_shape, calibration=cal,
                                   fsdp=False)
        fsdp = predict_train_step(cfg, shape, mesh_shape, calibration=cal,
                                  fsdp=True)
        with self._lock:
            self.stats["model_evals"] += 1
        wants = fsdp.total_overlapped < plain.total_overlapped
        self.cache.put(key, {"fsdp": bool(wants),
                             "predicted_plain_s": plain.total_overlapped,
                             "predicted_fsdp_s": fsdp.total_overlapped})
        return wants

    def prefill_chunk(self, seq_len: int, *, max_chunk: int = 128) -> int:
        """Chunk size for the serving engine's prefill: the largest power of
        two that amortizes per-call dispatch overhead without exploding
        compile-shape count (two shapes total: the chunk and the 1-token
        remainder step).  Below 8 tokens chunking cannot win."""
        if seq_len < 8:
            return 1
        chunk = 1
        while chunk * 2 <= min(seq_len, max_chunk):
            chunk *= 2
        return chunk

    def serve_chunk(self, remaining: int, *, ctx0: int, cost, budget_s: float,
                    granularity: int = 8, base_prefill=(),
                    base_prefill_s: Optional[float] = None) -> int:
        """Prefill chunk sizing for the serving scheduler's batch mix: the
        largest token count (a multiple of ``granularity``, so the engine
        keeps its two compiled shapes) whose *marginal* predicted prefill
        time — on top of the chunks already packed into this step
        (``base_prefill``) — fits ``budget_s``.  Returns 0 when even one
        granularity chunk cannot fit; the policy decides whether to force
        progress anyway."""
        if remaining <= 0 or budget_s <= 0:
            return 0
        g = max(1, int(granularity))
        base = list(base_prefill)
        base_s = base_prefill_s if base_prefill_s is not None else (
            cost.prefill_step(base).prefill_s if base else 0.0)
        n = int(remaining)
        while n > 0:
            marginal = cost.prefill_step(base + [(n, ctx0)]).prefill_s - base_s
            if marginal <= budget_s:
                return n
            n = (n // 2) // g * g if n > g else 0
        return 0


_DEFAULT: Optional[Tuner] = None
_DEFAULT_LOCK = threading.Lock()


def default_tuner() -> Tuner:
    """Process-wide Tuner over the default registry and plan directory."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = Tuner()
        return _DEFAULT
