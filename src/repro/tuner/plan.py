"""Execution plans and the persistent plan cache.

An ``ExecutionPlan`` is the tuner's output: everything the dispatch layer
needs to run a distributed linalg call — the chosen algorithm variant, the
replication factor ``c``, block-cyclic ``r``, the process-grid edge ``g``
(mesh shape is ``(c, g, g)``, or ``(g, g)`` at ``c=1``), the local-kernel
choice, and the model's predicted timing for observability.

Plans persist as JSON under ``artifacts/plans/`` keyed by

    (machine fingerprint, algo, n, p, dtype)

so a repeated call — even from a fresh process — skips model evaluation
entirely.  The machine fingerprint hashes the machine-model name, the JAX
backend platform, the device kind and the device count: moving the same
scenario to different hardware (or resizing the pool) invalidates the
cached plan, while re-running on the same pool hits it.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import threading
from typing import Dict, Optional

from ..perf import MODEL_VERSION

#: bump when the plan *schema* (the JSON field set) changes incompatibly —
#: stale cache entries are ignored, not misread.  Schema 2 added the
#: ``model_version`` field; schema 3 added the kernel-tier ``tiles`` map.
PLAN_SCHEMA = 3


def default_plan_dir() -> str:
    env = os.environ.get("REPRO_PLAN_DIR")
    if env:
        return env
    repo = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(repo, "artifacts", "plans")


def machine_fingerprint(machine, platform: str, device_kind: str,
                        device_count: int) -> str:
    """Short stable hash of the execution substrate a plan was tuned for.

    ``machine`` is a :class:`~repro.core.machine.Machine` (preferred: its
    own ``fingerprint()`` — a hash of every profile field including the
    telemetry-bumped ``revision`` — becomes part of the key, so refits and
    drift invalidation retire stale plans automatically) or a plain string
    tag for non-profile keys like the LM fsdp recommendation."""
    tag = machine.fingerprint() if hasattr(machine, "fingerprint") \
        else str(machine)
    blob = f"{tag}|{platform}|{device_kind}|{device_count}"
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def plan_key(fingerprint: str, algo: str, n: int, p: int, dtype: str) -> str:
    return f"{fingerprint}-{algo}-n{n}-p{p}-{dtype}"


@dataclasses.dataclass
class ExecutionPlan:
    """A fully-resolved decision for one (machine, algo, n, p, dtype) cell."""

    algo: str               # "cannon" | "summa" | "trsm" | "cholesky"
    variant: str            # "2d" | "2d_ovlp" | "2.5d" | "2.5d_ovlp"
    n: int                  # global problem size
    p: int                  # processes used (c * g * g)
    c: int                  # replication factor (1 for 2D)
    r: int                  # block-cyclic factor (executables use 1)
    g: int                  # grid edge: mesh is (c, g, g)
    local_kernel: str       # "pallas" | "jnp"
    dtype: str
    machine: str            # machine-model name the prediction used
    fingerprint: str
    predicted: Dict[str, float]  # {"total": s, "comm": s, "comp": s}
    # kernel family -> block dict, e.g. {"matmul": {"bm": 256, ...}} —
    # resolved by the kernel-tier model (perf.kernel.tiles_for_plan), or
    # the heuristic blocks when the machine has no kernel_constants
    tiles: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["schema"] = PLAN_SCHEMA
        d["model_version"] = MODEL_VERSION
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ExecutionPlan":
        """Raises ValueError on schema *or* model-version mismatch: a plan
        picked by older model equations must be re-planned, not silently
        served (callers treat the ValueError as a cache miss)."""
        d = dict(d)
        if d.pop("schema", None) != PLAN_SCHEMA:
            raise ValueError("plan schema mismatch")
        if d.pop("model_version", None) != MODEL_VERSION:
            raise ValueError("plan model-version mismatch")
        return cls(**d)


class PlanCache:
    """Two-layer (memory + JSON-on-disk) cache of plan payloads.

    Payloads are plain dicts (``ExecutionPlan.to_dict`` for linalg plans;
    other tuner decisions, e.g. the LM fsdp recommendation, store their own
    small dicts).  Corrupt or schema-mismatched files read as misses.
    """

    def __init__(self, directory: Optional[str] = None):
        self.directory = directory or default_plan_dir()
        self._mem: Dict[str, dict] = {}
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0

    def _path(self, key: str) -> str:
        safe = re.sub(r"[^A-Za-z0-9._-]", "_", key)
        return os.path.join(self.directory, f"{safe}.json")

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            if key in self._mem:
                self.hits += 1
                return self._mem[key]
        path = self._path(key)
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            with self._lock:
                self.misses += 1
            return None
        with self._lock:
            self._mem[key] = payload
            self.hits += 1
            self.disk_hits += 1
        return payload

    def put(self, key: str, payload: dict) -> None:
        with self._lock:
            self._mem[key] = payload
        os.makedirs(self.directory, exist_ok=True)
        path = self._path(key)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        os.replace(tmp, path)  # atomic: concurrent readers never see partial JSON

    def invalidate(self, key: str) -> None:
        with self._lock:
            self._mem.pop(key, None)
        try:
            os.remove(self._path(key))
        except OSError:
            pass

    def clear_memory(self) -> None:
        """Drop the in-process layer (tests use this to prove disk hits)."""
        with self._lock:
            self._mem.clear()
