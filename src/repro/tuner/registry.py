"""One query surface over every analytic performance model in the repo.

Before this module existed the model layers were islands: the 16
algorithm-variant models lived in ``core.algorithms.MODELS``, the collective
models were free functions in ``core.collectives``, and the machine /
calibration surfaces were assembled ad hoc at every call site
(``AlgoContext(CommModel(HOPPER, ...), ComputeModel(HOPPER, ...))``).  The
``PerfModelRegistry`` unifies them:

* **algorithm models** — ``(algo, variant) -> Program`` (cost-IR, see
  ``repro.perf``) with registration, enumeration, scalar ``evaluate`` and
  vectorized ``evaluate_grid``; plain scalar ``ModelFn`` registration is
  kept as a legacy path;
* **collective models** — name -> analytic collective, so consumers (the
  tuner benchmark, the LM-step models) can enumerate and cross-check them;
* **machine surfaces** — machine constants + routine-efficiency curves +
  contention calibration bundled per machine name, with ``context()``
  building the ``AlgoContext`` every model evaluation needs.

``core.predictor`` sits on top of this registry (it no longer hard-codes
the ALGOS/VARIANTS tuples), and ``repro.tuner.autotune`` uses it to plan
end-to-end execution.  ``DEFAULT_REGISTRY`` is pre-populated with
everything the repo ships; tests may build private registries.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Sequence, Tuple

from ..core import algorithms as alg
from ..core import collectives as coll
from ..core.machine import CPU_HOST, HOPPER, MACHINES, TPU_V5E, Machine
from ..core.perfmodel import (Calibration, CommModel, ComputeModel,
                              EfficiencyCurve, HOPPER_EFFICIENCY,
                              ParametricCalibration, TPU_EFFICIENCY)
from ..perf import EvalOptions, EvalResult, Program, evaluate_program
from ..perf.models import PROGRAMS


@dataclasses.dataclass
class MachineSurface:
    """Everything needed to evaluate models for one machine: the constants,
    the local-routine efficiency curves (paper Fig. 1) and the contention
    calibration (paper Figs. 3-4).

    ``faults`` is an optional :class:`repro.sim.faults.FaultSpec` (typed
    loosely to keep this module free of a sim import): a *degraded* surface
    emitted by diagnosis carries the localized fault here, and the tuner's
    sim-refined planning stage injects it into every candidate simulation.
    It deliberately lives outside :class:`~repro.core.machine.Machine` —
    the machine fingerprint (and thus plan-cache keys) changes via the
    revision bump that accompanies every degraded-profile emission."""

    machine: Machine
    efficiency: Mapping[str, EfficiencyCurve]
    calibration: Calibration
    faults: Optional[object] = None

    def context(self, calibration: Optional[Calibration] = None) -> alg.AlgoContext:
        cal = calibration if calibration is not None else self.calibration
        return alg.AlgoContext(comm=CommModel(self.machine, cal),
                               comp=ComputeModel(self.machine, self.efficiency))


class PerfModelRegistry:
    """Unified registry of algorithm models, collective models and machine
    surfaces behind one query interface."""

    def __init__(self):
        self._algo_models: Dict[Tuple[str, str], alg.ModelFn] = {}
        self._programs: Dict[Tuple[str, str], Program] = {}
        self._collectives: Dict[str, Callable] = {}
        self._machines: Dict[str, MachineSurface] = {}

    # -- registration --------------------------------------------------------
    def register_algorithm(self, algo: str, variant: str, fn: alg.ModelFn,
                           *, overwrite: bool = False) -> None:
        """Register a plain scalar ModelFn (legacy path: no vectorized
        evaluation; batch consumers fall back to per-scenario calls).
        Prefer :meth:`register_program`."""
        key = (algo, variant)
        if key in self._algo_models and not overwrite:
            raise ValueError(f"model for {key} already registered")
        self._algo_models[key] = fn

    def register_program(self, program: Program,
                         *, overwrite: bool = False) -> None:
        """Register a cost-IR :class:`~repro.perf.Program`: the model gains
        vectorized grid evaluation and a scalar shim in one step."""
        key = program.key
        if (key in self._algo_models or key in self._programs) \
                and not overwrite:
            raise ValueError(f"model for {key} already registered")
        self._programs[key] = program
        self._algo_models[key] = alg.scalar_shim(program)

    def register_collective(self, name: str, fn: Callable,
                            *, overwrite: bool = False) -> None:
        if name in self._collectives and not overwrite:
            raise ValueError(f"collective {name!r} already registered")
        self._collectives[name] = fn

    def register_machine(self, machine: Machine,
                         efficiency: Mapping[str, EfficiencyCurve],
                         calibration: Optional[Calibration] = None,
                         *, overwrite: bool = False,
                         faults=None) -> None:
        if machine.name in self._machines and not overwrite:
            raise ValueError(f"machine {machine.name!r} already registered")
        self._machines[machine.name] = MachineSurface(
            machine, efficiency, calibration or ParametricCalibration(),
            faults=faults)

    # -- queries -------------------------------------------------------------
    def algos(self) -> Tuple[str, ...]:
        return tuple(dict.fromkeys(a for a, _ in self._algo_models))

    def variants(self, algo: str) -> Tuple[str, ...]:
        out = tuple(v for a, v in self._algo_models if a == algo)
        if not out:
            raise KeyError(f"no models registered for algo {algo!r} "
                           f"(have: {self.algos()})")
        return out

    def model(self, algo: str, variant: str) -> alg.ModelFn:
        try:
            return self._algo_models[(algo, variant)]
        except KeyError:
            raise KeyError(f"no model for ({algo!r}, {variant!r}); "
                           f"registered: {sorted(self._algo_models)}") from None

    def has_program(self, algo: str, variant: str) -> bool:
        return (algo, variant) in self._programs

    def program(self, algo: str, variant: str) -> Program:
        try:
            return self._programs[(algo, variant)]
        except KeyError:
            raise KeyError(f"no cost-IR program for ({algo!r}, {variant!r}); "
                           f"registered: {sorted(self._programs)}") from None

    def collective(self, name: str) -> Callable:
        return self._collectives[name]

    def collectives(self) -> Tuple[str, ...]:
        return tuple(self._collectives)

    def machine(self, name: str) -> MachineSurface:
        try:
            return self._machines[name]
        except KeyError:
            raise KeyError(f"unknown machine {name!r}; registered: "
                           f"{sorted(self._machines)}") from None

    def machines(self) -> Tuple[str, ...]:
        return tuple(self._machines)

    def context(self, machine: str,
                calibration: Optional[Calibration] = None) -> alg.AlgoContext:
        return self.machine(machine).context(calibration)

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, ctx: alg.AlgoContext, algo: str, variant: str,
                 n: int, p: int, c: int = 1, r: int = 1,
                 options: Optional[EvalOptions] = None) -> alg.ModelResult:
        fn = self.model(algo, variant)
        if options is not None:
            return fn(ctx, n, p, c=c, r=r, options=options)
        return fn(ctx, n, p, c=c, r=r)

    def evaluate_grid(self, ctx: alg.AlgoContext, algo: str, variant: str,
                      n, p, c=1, r=1,
                      options: Optional[EvalOptions] = None) -> EvalResult:
        """Vectorized evaluation over numpy arrays of scenarios — one pass
        for a whole ``(n, p, c, r)`` grid (arrays broadcast)."""
        return evaluate_program(self.program(algo, variant), ctx, n, p, c, r,
                                options=options)


def build_default_registry() -> PerfModelRegistry:
    """A fresh registry with everything the repo ships.  ``DEFAULT_REGISTRY``
    is one of these; telemetry tests build private copies so refits and
    drift-bumped machine revisions never leak across tests."""
    reg = PerfModelRegistry()
    for program in PROGRAMS.values():
        reg.register_program(program)
    for name in ("t_redsca_sync", "t_scatter_sync", "t_gather", "t_allgather",
                 "t_allgather_sync", "t_reduce", "t_bcast", "t_bcast_sync",
                 "t_inirepl", "t_ring_allgather", "t_ring_reducescatter",
                 "t_ring_allreduce", "t_all_to_all"):
        reg.register_collective(name, getattr(coll, name))
    # CPU host reuses the Hopper efficiency shapes until measured curves are
    # fitted (core.calibration.measured_compute_model replaces them).
    for machine, eff in ((HOPPER, HOPPER_EFFICIENCY),
                         (TPU_V5E, TPU_EFFICIENCY),
                         (CPU_HOST, HOPPER_EFFICIENCY)):
        reg.register_machine(machine, eff)
    return reg


DEFAULT_REGISTRY = build_default_registry()


#: machine chosen per JAX backend platform when the caller does not name one
PLATFORM_MACHINES = {
    "cpu": CPU_HOST.name,
    "tpu": TPU_V5E.name,
}


def machine_for_platform(platform: str) -> str:
    """Best-match registered machine for a jax device platform string."""
    return PLATFORM_MACHINES.get(platform, CPU_HOST.name)
