"""Version-portability shims for the JAX APIs this repo uses.

The codebase is written against the modern spellings (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``lax.pcast``); this module maps
them onto older releases (0.4.x) where they live under ``jax.experimental``
or do not exist yet.  Import from here instead of feature-testing at each
call site.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax import lax


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where supported."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                                 axis_types=(axis_type.Auto,) * len(axis_shapes),
                                 devices=devices)
        except TypeError:
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         devices=devices)


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:  # jax < 0.5: experimental module, and no pcast-aware rep checker
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        del check_vma  # the old rep checker predates varying-marking
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)


def pcast_varying(x, axes):
    """Mark ``x`` as varying over ``axes`` for the replication checker.

    A no-op on releases without ``lax.pcast`` — there the checker that
    needs the marking does not exist either (shard_map runs check_rep=False).
    """
    pcast = getattr(lax, "pcast", None)
    if pcast is None:
        return x
    return pcast(x, axes, to="varying")
