"""Architecture & shape configs: one module-level entry per assigned arch
(see registry.py), the reduced smoke variants, and the paper's own dense
linear algebra problem configs (paper_problems.py)."""

from .base import (EncoderConfig, ModelConfig, MoEConfig, SHAPES, ShapeConfig,
                   SSMConfig, VisionConfig)
from .registry import ALL_CELLS, ARCHS, cells, get
