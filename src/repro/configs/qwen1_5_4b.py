"""--arch config module; canonical definition in registry.py."""

from .registry import QWEN15_4B

CONFIG = QWEN15_4B
