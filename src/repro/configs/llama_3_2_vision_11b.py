"""--arch config module; canonical definition in registry.py."""

from .registry import LLAMA32_VISION_11B

CONFIG = LLAMA32_VISION_11B
