"""--arch config module; canonical definition in registry.py."""

from .registry import QWEN15_110B

CONFIG = QWEN15_110B
