"""Architecture config schema.

Every assigned architecture is expressed as a ``ModelConfig``; reduced
smoke-test versions come from ``ModelConfig.reduced()``.  Configs are plain
frozen dataclasses — no framework magic — and are the single source of
truth for parameter shapes, sharding rules and the dry-run input specs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0          # always-on experts (qwen2-moe)
    d_ff_shared: int = 0               # total shared width
    dense_residual: bool = False       # parallel dense FFN (arctic)
    d_ff_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16                # per-head SSD/conv state
    n_ssm_heads: int = 0               # 0 -> same as n_heads
    conv_kernel: int = 4
    chunk: int = 256                   # chunked-scan block


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (the conv/patch frontend is a stub: the dry-run
    feeds precomputed frame/patch embeddings via input_specs)."""
    n_layers: int
    n_frames: int = 1500               # post-conv audio frames / patches
    bidirectional: bool = True


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """Llama-3.2-Vision-style cross-attention to stubbed patch embeddings."""
    n_image_tokens: int = 1601
    cross_attn_every: int = 5          # a cross-attn layer every N layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    gated_mlp: bool = True             # SwiGLU vs plain GELU
    activation: str = "silu"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    positions: str = "rope"            # rope | learned | none
    max_position: int = 0              # for learned positions
    sliding_window: int = 0            # 0 = full attention
    block_pattern: str = "dense"       # dense|moe|mlstm_slstm|hymba|encdec|vlm
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    # runtime knobs
    dtype: str = "bfloat16"
    remat: bool = True
    logits_chunk: int = 0              # chunked loss (0 = whole)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def full_attention(self) -> bool:
        """True when the arch has no sub-quadratic path (long_500k skip)."""
        return self.family in ("dense", "moe", "audio", "vlm") and \
            self.sliding_window == 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        hd = self.hd
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
            + hd * self.n_heads * d
        if self.block_pattern == "mlstm_slstm":
            blk = 8 * d * d  # q,k,v,o + gates, rough
        else:
            mlp_mult = 3 if self.gated_mlp else 2
            mlp = mlp_mult * d * self.d_ff
            if self.moe:
                m = self.moe
                mlp = m.n_experts * mlp_mult * d * m.d_ff_expert \
                    + mlp_mult * d * m.d_ff_shared \
                    + (mlp_mult * d * m.d_ff_dense if m.dense_residual else 0) \
                    + d * m.n_experts
            blk = attn + mlp
        enc = 0
        if self.encoder:
            enc = self.encoder.n_layers * (attn + (2 if not self.gated_mlp
                                                   else 3) * d * self.d_ff)
        return int(emb + L * blk + enc)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        mlp_mult = 3 if self.gated_mlp else 2
        full = self.param_count()
        routed = self.n_layers * m.n_experts * mlp_mult * self.d_model * m.d_ff_expert
        active = self.n_layers * m.top_k * mlp_mult * self.d_model * m.d_ff_expert
        return int(full - routed + active)

    def reduced(self, *, n_layers: int = 2, d_model: int = 64,
                n_heads: int = 4, vocab: int = 256) -> "ModelConfig":
        """Same-family tiny config for CPU smoke tests."""
        kv = max(1, min(self.n_kv_heads, n_heads)
                 if self.n_kv_heads < self.n_heads else n_heads)
        changes = dict(
            n_layers=n_layers, d_model=d_model, n_heads=n_heads,
            n_kv_heads=kv, head_dim=d_model // n_heads,
            d_ff=0 if self.d_ff == 0 else d_model * 4 if not self.gated_mlp
            else int(d_model * 8 / 3) // 8 * 8,
            vocab_size=vocab, max_position=max(self.max_position and 512, 0),
            dtype="float32", remat=False,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(8, self.moe.n_experts),
                top_k=min(2, self.moe.top_k), d_ff_expert=d_model * 2,
                d_ff_shared=d_model * 2 if self.moe.d_ff_shared else 0,
                d_ff_dense=d_model * 2 if self.moe.dense_residual else 0)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(self.ssm, state_dim=8, chunk=32)
        if self.encoder:
            changes["encoder"] = dataclasses.replace(
                self.encoder, n_layers=n_layers, n_frames=16)
        if self.vision:
            changes["vision"] = dataclasses.replace(
                self.vision, n_image_tokens=17, cross_attn_every=2)
        if self.sliding_window:
            changes["sliding_window"] = 16
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
