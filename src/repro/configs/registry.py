"""The 10 assigned architectures (exact figures from the assignment table)
plus the paper's own dense-linear-algebra problem configs.

Sources are cited per entry ([arXiv/hf; tier] from the assignment).  Every
config is selectable via ``--arch <id>`` in the launchers.
"""

from __future__ import annotations

from .base import (EncoderConfig, ModelConfig, MoEConfig, SHAPES, ShapeConfig,
                   SSMConfig, VisionConfig)

ARCHS: dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


# --- dense LM-family -------------------------------------------------------

# [arXiv:2405.04324; hf] llama-arch code model, MQA (kv=1)
GRANITE_20B = _register(ModelConfig(
    name="granite-20b", family="dense", n_layers=52, d_model=6144,
    n_heads=48, n_kv_heads=1, d_ff=24576, vocab_size=49152,
    gated_mlp=False, activation="gelu", positions="rope",
    block_pattern="dense", logits_chunk=512,
))

# [hf:Qwen/Qwen1.5-0.5B family; hf] QKV bias, MHA (kv=heads)
QWEN15_4B = _register(ModelConfig(
    name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
    n_heads=20, n_kv_heads=20, d_ff=6912, vocab_size=151936,
    qkv_bias=True, gated_mlp=True, activation="silu", positions="rope",
    block_pattern="dense", logits_chunk=512,
))

# [arXiv:2402.19173; hf] GQA kv=2, RoPE, plain MLP
STARCODER2_3B = _register(ModelConfig(
    name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
    n_heads=24, n_kv_heads=2, d_ff=12288, vocab_size=49152,
    qkv_bias=True, gated_mlp=False, activation="gelu", positions="rope",
    block_pattern="dense", logits_chunk=512,
))

# [hf:Qwen/Qwen1.5-110B; hf] QKV bias, GQA kv=8
QWEN15_110B = _register(ModelConfig(
    name="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=49152, vocab_size=152064,
    qkv_bias=True, gated_mlp=True, activation="silu", positions="rope",
    block_pattern="dense", logits_chunk=256,
))

# --- audio enc-dec -----------------------------------------------------------

# [arXiv:2212.04356; unverified] enc-dec, conv frontend STUBBED
WHISPER_TINY = _register(ModelConfig(
    name="whisper-tiny", family="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51865,
    gated_mlp=False, activation="gelu", positions="learned",
    max_position=33280,      # extended for the decode_32k dry-run cell
    block_pattern="encdec",
    encoder=EncoderConfig(n_layers=4, n_frames=1500), logits_chunk=512,
))

# --- ssm ---------------------------------------------------------------------

# [arXiv:2405.04517; unverified] alternating sLSTM + mLSTM, no FFN
XLSTM_350M = _register(ModelConfig(
    name="xlstm-350m", family="ssm", n_layers=24, d_model=1024,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    gated_mlp=False, activation="gelu", positions="none",
    block_pattern="mlstm_slstm", ssm=SSMConfig(state_dim=16, chunk=256),
    tie_embeddings=True, logits_chunk=512,
))

# --- vlm ---------------------------------------------------------------------

# [hf:meta-llama/Llama-3.2-11B-Vision; unverified] cross-attn image layers,
# patch frontend STUBBED
LLAMA32_VISION_11B = _register(ModelConfig(
    name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=128256,
    gated_mlp=True, activation="silu", positions="rope",
    block_pattern="vlm", vision=VisionConfig(n_image_tokens=1601,
                                             cross_attn_every=5),
    logits_chunk=512,
))

# --- moe ---------------------------------------------------------------------

# [hf:Snowflake/snowflake-arctic-base; hf] 128 experts top-2 + dense residual
ARCTIC_480B = _register(ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab_size=32000,
    gated_mlp=True, activation="silu", positions="rope",
    block_pattern="moe",
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True, d_ff_dense=4864),
    logits_chunk=512,
))

# [hf:Qwen/Qwen1.5-MoE-A2.7B; hf] 60 routed top-4 + 4 shared experts
QWEN2_MOE_A27B = _register(ModelConfig(
    name="qwen2-moe-a2.7b", family="moe", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab_size=151936,
    qkv_bias=True, gated_mlp=True, activation="silu", positions="rope",
    block_pattern="moe",
    moe=MoEConfig(n_experts=60, top_k=4, d_ff_expert=1408,
                  n_shared_experts=4, d_ff_shared=4 * 1408),
    logits_chunk=512,
))

# --- hybrid --------------------------------------------------------------------

# [arXiv:2411.13676; hf] parallel attn+mamba heads, SWA + SSD (sub-quadratic)
HYMBA_15B = _register(ModelConfig(
    name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
    n_heads=25, n_kv_heads=5, d_ff=5504, vocab_size=32001,
    gated_mlp=True, activation="silu", positions="rope",
    sliding_window=1024, block_pattern="hymba",
    ssm=SSMConfig(state_dim=16, chunk=256), head_dim=64,
    logits_chunk=512,
))


# --- shape cells & skips -----------------------------------------------------

def cells(arch: str):
    """The shape cells that apply to this arch (assignment skip rules)."""
    cfg = ARCHS[arch]
    out = []
    for shape in SHAPES.values():
        if shape.name == "long_500k" and cfg.full_attention:
            continue  # pure full-attention: mandated skip (DESIGN.md §5)
        out.append(shape)
    return out


ALL_CELLS = [(a, s.name) for a in ARCHS for s in cells(a)]


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
