"""--arch config module; canonical definition in registry.py."""

from .registry import HYMBA_15B

CONFIG = HYMBA_15B
