"""--arch config module; canonical definition in registry.py."""

from .registry import GRANITE_20B

CONFIG = GRANITE_20B
