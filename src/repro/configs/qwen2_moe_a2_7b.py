"""--arch config module; canonical definition in registry.py."""

from .registry import QWEN2_MOE_A27B

CONFIG = QWEN2_MOE_A27B
