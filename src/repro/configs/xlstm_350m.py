"""--arch config module; canonical definition in registry.py."""

from .registry import XLSTM_350M

CONFIG = XLSTM_350M
