"""--arch config module; canonical definition in registry.py."""

from .registry import STARCODER2_3B

CONFIG = STARCODER2_3B
