"""--arch config module; canonical definition in registry.py."""

from .registry import WHISPER_TINY

CONFIG = WHISPER_TINY
