"""--arch config module; canonical definition in registry.py."""

from .registry import ARCTIC_480B

CONFIG = ARCTIC_480B
