"""Fault & degradation injection: faulted-engine agreement against the
per-transfer reference oracle, dead-link rerouting, onset semantics, the
detect -> diagnose -> re-plan loop, and serving overload robustness."""

import dataclasses
import tempfile

import numpy as np
import pytest

from repro.perf import PROGRAMS
from repro.sim import (Crossbar, DeadLink, DegradedLink, FaultSpec,
                       FaultyTopology, Network, SlowRank, Torus,
                       UnreachableError, simulate_program, simulate_programs,
                       topology_for, torus_link)
from repro.telemetry import (Diagnosis, emit_degraded_profile, localize_rank,
                             probe_links)
from repro.tuner import DEFAULT_REGISTRY, Tuner
from repro.tuner.registry import build_default_registry

TOL = 1e-6


@pytest.fixture(scope="module")
def ctx():
    return DEFAULT_REGISTRY.context("hopper-cray-xe6")


@pytest.fixture(scope="module")
def machine():
    return DEFAULT_REGISTRY.machine("hopper-cray-xe6").machine


def _rel(a, b):
    return abs(a - b) / max(abs(b), 1e-30)


# ---------------------------------------------------------------------------
# FaultSpec semantics
# ---------------------------------------------------------------------------


class TestFaultSpec:
    def test_empty_and_fingerprint(self):
        assert FaultSpec().empty
        fs = FaultSpec(degraded_links=(DegradedLink(3, 4.0),))
        assert not fs.empty
        assert fs.fingerprint() != FaultSpec().fingerprint()
        assert fs.fingerprint() == FaultSpec(
            degraded_links=(DegradedLink(3, 4.0),)).fingerprint()

    def test_validation(self):
        with pytest.raises(ValueError):
            DegradedLink(0, 0.5)          # a degraded link can't be faster
        with pytest.raises(ValueError):
            SlowRank(0, 0.0)

    def test_link_scales_respect_onset(self):
        fs = FaultSpec(degraded_links=(DegradedLink(2, 4.0, onset_s=10.0),))
        links = np.array([1, 2, 3])
        assert fs.link_scales(links, 0.0) is None     # not yet active
        sc = fs.link_scales(links, 10.0)
        assert sc is not None and sc[1] == 4.0 and sc[0] == sc[2] == 1.0

    def test_compute_scales_per_rank_onset(self):
        fs = FaultSpec(slow_ranks=(SlowRank(1, 3.0, onset_s=5.0),))
        sc = fs.compute_scales(np.array([0.0, 4.0, 6.0]))
        assert sc is None                              # rank 1 not yet slow
        sc = fs.compute_scales(np.array([0.0, 5.0, 6.0]))
        assert sc[1] == 3.0 and sc[0] == sc[2] == 1.0


# ---------------------------------------------------------------------------
# Faulted engine vs the per-transfer reference oracle (<= 1e-6)
# ---------------------------------------------------------------------------


class TestFaultedAgreement:
    @pytest.mark.parametrize("fold", [True, False])
    def test_degraded_link_and_slow_rank_match_reference(self, ctx, fold):
        topo = Torus((4, 4, 4))
        fs = FaultSpec(
            degraded_links=(DegradedLink(torus_link(topo, 8, 2, +1), 6.0),),
            slow_ranks=(SlowRank(11, 2.5),))
        prog = PROGRAMS[("lu", "2d")]
        kw = dict(n=4096.0, p=64, c=1, faults=fs)
        vec = simulate_program(prog, ctx, topo, fold=fold, **kw)
        ref = simulate_program(prog, ctx, topo, engine="reference", **kw)
        assert _rel(vec.total, ref.total) <= TOL
        # and the fault actually costs something
        healthy = simulate_program(prog, ctx, topo, fold=fold,
                                   n=4096.0, p=64, c=1)
        assert vec.total > healthy.total

    def test_dead_link_reroute_matches_reference(self, ctx):
        topo = Torus((4, 4, 4))
        fs = FaultSpec(dead_links=(DeadLink(torus_link(topo, 5, 0, +1)),))
        prog = PROGRAMS[("cannon", "2d")]
        kw = dict(n=2048.0, p=64, c=1, faults=fs)
        vec = simulate_program(prog, ctx, topo, **kw)
        ref = simulate_program(prog, ctx, topo, engine="reference", **kw)
        assert _rel(vec.total, ref.total) <= TOL

    def test_future_onset_equals_healthy(self, ctx):
        topo = Torus((4, 4, 4))
        fs = FaultSpec(degraded_links=(
            DegradedLink(torus_link(topo, 8, 2, +1), 6.0, onset_s=1e9),))
        prog = PROGRAMS[("summa", "2d")]
        healthy = simulate_program(prog, ctx, topo, n=2048.0, p=64, c=1)
        faulted = simulate_program(prog, ctx, topo, n=2048.0, p=64, c=1,
                                   faults=fs)
        assert faulted.total == healthy.total

    def test_degraded_crossbar_channel(self, machine):
        # crossbar channels never collide; the per-route scale path
        xb = Crossbar(8)
        link = xb.route(0, 1)[0]
        fs = FaultSpec(degraded_links=(DegradedLink(link, 5.0),))
        net = Network(xb, machine.latency, machine.inv_bandwidth, faults=fs)
        healthy = Network(xb, machine.latency, machine.inv_bandwidth)
        w = 1e6
        done_f = net.deliver_shift(np.zeros(8), w, 1, machine.latency)
        done_h = healthy.deliver_shift(np.zeros(8), w, 1, machine.latency)
        assert done_f[0] == pytest.approx(
            machine.latency + 5.0 * w * machine.inv_bandwidth)
        np.testing.assert_allclose(done_f[1:], done_h[1:], rtol=1e-12)


# ---------------------------------------------------------------------------
# Dead links: reroute or refuse
# ---------------------------------------------------------------------------


class TestDeadLinks:
    def test_faulty_topology_reroutes_around_dead_link(self):
        topo = Torus((4, 4, 4))
        dead = torus_link(topo, 5, 0, +1)
        ft = FaultyTopology(topo, frozenset([dead]))
        route = ft.route(5, 6)
        assert dead not in route
        assert len(route) >= len(topo.route(5, 6))   # detour can't be shorter

    def test_both_directions_dead_is_unreachable(self):
        topo = Torus((2, 2))                 # k=2: only one ring direction
        dead = {torus_link(topo, 0, 0, +1), torus_link(topo, 0, 0, -1)}
        ft = FaultyTopology(topo, frozenset(dead))
        with pytest.raises(UnreachableError):
            ft.route(0, 1)

    def test_dead_crossbar_channel_is_unreachable(self):
        xb = Crossbar(4)
        dead = xb.route(0, 1)[0]
        ft = FaultyTopology(xb, frozenset([dead]))
        with pytest.raises(UnreachableError):
            ft.route(0, 1)
        assert ft.route(0, 2) == xb.route(0, 2)

    def test_network_strict_false_skips_unreachable(self, ctx):
        topo = Torus((2, 2))
        fs = FaultSpec(dead_links=(
            DeadLink(torus_link(topo, 0, 0, +1)),
            DeadLink(torus_link(topo, 0, 0, -1))))
        prog = PROGRAMS[("cannon", "2d")]
        out = simulate_programs(prog, ctx, [{"n": 512.0, "p": 4, "c": 1}],
                                topology=topo, faults=fs, strict=False)
        assert out[0] is None


# ---------------------------------------------------------------------------
# Detect -> diagnose -> re-plan (the ISSUE's end-to-end criterion)
# ---------------------------------------------------------------------------


class TestDiagnoseReplan:
    def test_probe_localizes_injected_link(self, machine):
        topo = topology_for(machine, 64)
        link = torus_link(topo, 8, 2, +1)
        fs = FaultSpec(degraded_links=(DegradedLink(link, 8.0),))
        measured = Network(topo, machine.latency, machine.inv_bandwidth,
                           faults=fs)
        diag = probe_links(measured)
        assert diag.kind == "degraded_link"
        assert diag.component == link
        assert 2.0 < diag.severity <= 8.0

    def test_probe_healthy_network_stays_healthy(self, machine):
        topo = topology_for(machine, 64)
        net = Network(topo, machine.latency, machine.inv_bandwidth)
        assert probe_links(net).healthy

    def test_localize_rank(self):
        times = np.ones(16)
        times[7] = 4.0
        d = localize_rank(times)
        assert d.kind == "slow_rank" and d.component == 7
        assert localize_rank(np.ones(16)).healthy

    def test_degraded_profile_replan_beats_stale_plan(self):
        # full loop on a private registry: inject -> probe -> emit degraded
        # revision -> tuner cache-misses and picks a plan that routes
        # around the sick link -> the new plan beats the stale one when
        # both are simulated under the fault
        reg = build_default_registry()
        surf = reg.machine("hopper-cray-xe6")
        topo = topology_for(surf.machine, 64)
        link = torus_link(topo, 8, 2, +1)
        fs = FaultSpec(degraded_links=(DegradedLink(link, 8.0),))
        measured = Network(topo, surf.machine.latency,
                           surf.machine.inv_bandwidth, faults=fs)
        diag = probe_links(measured)
        assert diag.component == link

        with tempfile.TemporaryDirectory() as td:
            tuner = Tuner(registry=reg, plan_dir=td)
            kw = dict(device_count=64, platform="cpu",
                      machine="hopper-cray-xe6")
            healthy = tuner.plan("matmul", 8192, refine="sim", **kw)
            rev0 = surf.machine.revision
            mach = emit_degraded_profile(reg, "hopper-cray-xe6",
                                         diag.to_fault_spec(),
                                         diagnosis=diag)
            assert mach.revision == rev0 + 1
            # refine defaults to "sim" on a faulted surface; the bumped
            # fingerprint guarantees a cache miss
            degraded = tuner.plan("matmul", 8192, **kw)
            assert "sim_total" in degraded.predicted
            assert ((healthy.algo, healthy.variant, healthy.c)
                    != (degraded.algo, degraded.variant, degraded.c))

            surf2 = reg.machine("hopper-cray-xe6")
            totals = {}
            for name, pl in (("stale", healthy), ("replan", degraded)):
                sim = simulate_programs(
                    reg.program(pl.algo, pl.variant), surf2.context(),
                    [{"n": 8192.0, "p": pl.p, "c": pl.c, "r": 1}],
                    topology=topology_for(surf2.machine, 64),
                    faults=diag.to_fault_spec())[0]
                totals[name] = sim.total
            assert totals["replan"] < totals["stale"]

    def test_diagnosis_to_fault_spec_roundtrip(self):
        d = Diagnosis(kind="degraded_link", component=52, severity=7.2)
        fs = d.to_fault_spec()
        assert fs.degraded_links[0].link == 52
        assert fs.degraded_links[0].scale == pytest.approx(7.2)
        assert Diagnosis(kind="healthy").to_fault_spec().empty


# ---------------------------------------------------------------------------
# Serving robustness: deadlines, bounded queue, graceful degradation
# ---------------------------------------------------------------------------


class TestServingRobustness:
    @pytest.fixture(scope="class")
    def cost(self):
        from repro.configs import get
        from repro.core.machine import CPU_HOST
        from repro.serving import cost_model_for
        return cost_model_for(get("qwen1.5-4b").reduced(), CPU_HOST)

    def test_overload_sheds_and_enforces_deadlines(self, cost):
        from repro.serving import (SchedulerConfig, TraceConfig,
                                   replay_traced, synthesize_trace)
        trace = synthesize_trace(TraceConfig(n_requests=400,
                                             arrival_rate=200.0, seed=3))
        trace = [dataclasses.replace(r, deadline_s=2.0) for r in trace]
        rep, _, reg = replay_traced(trace, cost, policy="model",
                                    scheduler_cfg=SchedulerConfig(
                                        max_queue=16),
                                    degrade=True)
        assert rep.n_shed > 0
        assert rep.n_deadline_missed > 0
        # conservation: every request finished, was shed, or was dropped
        # waiting at its deadline (active deadline evictions also count
        # in n_finished — they did run)
        assert rep.n_finished + rep.n_shed <= len(trace)
        assert rep.n_finished + rep.n_shed + rep.n_deadline_missed \
            >= len(trace)

    def test_unbounded_queue_never_sheds(self, cost):
        from repro.serving import TraceConfig, replay_traced, synthesize_trace
        trace = synthesize_trace(TraceConfig(n_requests=60,
                                             arrival_rate=50.0, seed=1))
        rep, _, _ = replay_traced(trace, cost, policy="model")
        assert rep.n_shed == 0 and rep.n_deadline_missed == 0
        assert rep.n_finished == len(trace)

    def test_shedding_keeps_cheapest_predicted(self, cost):
        from repro.serving import (Request, Scheduler, SchedulerConfig,
                                   SimBackend, make_policy)
        sched = Scheduler(SimBackend(), cost,
                          SchedulerConfig(max_queue=2, max_active=1),
                          policy=make_policy("model"))
        # four arrivals against a queue bound of two: the two with the
        # highest predicted prefill cost are shed, the cheap ones kept
        for rid, plen in (("run", 8), ("cheap", 4), ("mid", 64),
                          ("big", 1024)):
            sched.submit(Request(rid=rid, prompt_len=plen, arrival_s=0.0,
                                 max_new_tokens=4, output_len=4))
        sched.step()
        shed = {rid for rid, rs in sched.finished.items()
                if rs.finish_reason == "shed"}
        assert shed == {"big", "mid"}

    def test_degradation_controller_shrinks_to_floor_and_recovers(self):
        from repro.serving import DegradationController, make_policy
        pol = make_policy("model", step_budget_s=0.08)
        ctl = DegradationController(pol, floor_frac=0.25, shrink=0.5,
                                    recover=2.0)
        for _ in range(6):
            ctl.update(["ttft"])
        assert pol.step_budget_s == pytest.approx(0.02)   # floored
        assert ctl.degraded
        for _ in range(6):
            ctl.update([])
        assert pol.step_budget_s == pytest.approx(0.08)   # fully recovered
        assert not ctl.degraded
        acts = [e["action"] for e in ctl.events]
        assert "shrink" in acts and "recover" in acts

    def test_degradation_controller_noop_for_fifo(self):
        from repro.serving import DegradationController, FIFOPolicy
        ctl = DegradationController(FIFOPolicy())
        assert ctl.update(["ttft"]) is None
        assert not ctl.events and not ctl.degraded
