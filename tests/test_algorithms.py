"""Invariant tests for the 16 algorithm-variant models (paper §V)."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (HOPPER, AlgoContext, CommModel, ComputeModel,
                        IdentityCalibration, ParametricCalibration, evaluate,
                        pct_of_peak)
from repro.core.algorithms import ALGOS, MODELS, USEFUL_FLOPS, VARIANTS
from repro.core.perfmodel import HOPPER_EFFICIENCY
from repro.core.predictor import best_variant, legal_c_values, select

CTX = AlgoContext(CommModel(HOPPER, ParametricCalibration()),
                  ComputeModel(HOPPER, HOPPER_EFFICIENCY))
CTX_IDEAL = AlgoContext(CommModel(HOPPER, IdentityCalibration()),
                        ComputeModel(HOPPER, HOPPER_EFFICIENCY))

GRID_P = [64, 256, 1024, 4096]


class TestInvariants:
    @pytest.mark.parametrize("algo", ALGOS)
    @pytest.mark.parametrize("p", GRID_P)
    def test_all_variants_positive_and_decomposed(self, algo, p):
        for variant in VARIANTS:
            r = evaluate(CTX, algo, variant, 32768, p, c=4, r=2)
            assert r.total > 0
            assert r.comm > 0 and r.comp > 0
            # overlap can only help: total <= serialized comm + comp
            assert r.total <= r.comm + r.comp + 1e-12
            assert abs(sum(r.terms.values()) - r.total) < 1e-6 * r.total

    @pytest.mark.parametrize("algo", ALGOS)
    @pytest.mark.parametrize("p", GRID_P)
    def test_overlap_never_slower(self, algo, p):
        """max(comm, comp) composition can't exceed comm+comp (with the same
        thread count; the t-1 penalty can flip it on Hopper, so compare the
        overlapped *bound*, i.e. totals under identical compute terms)."""
        for base, ovlp in (("2d", "2d_ovlp"), ("2.5d", "2.5d_ovlp")):
            r1 = evaluate(CTX_IDEAL, algo, base, 65536, p, c=4, r=2)
            r2 = evaluate(CTX_IDEAL, algo, ovlp, 65536, p, c=4, r=2)
            # comm is never larger in the ovlp variant's serialized ledger
            assert r2.total <= (r1.total + r2.comp - r1.comp) * 1.05 + 1e-9

    def test_cannon_25d_c1_degenerates_to_2d(self):
        r2d = evaluate(CTX_IDEAL, "cannon", "2d", 32768, 1024)
        r25 = evaluate(CTX_IDEAL, "cannon", "2.5d", 32768, 1024, c=1)
        assert r25.total == pytest.approx(r2d.total, rel=0.1)

    def test_more_cores_less_time(self):
        for algo in ALGOS:
            t_small = evaluate(CTX, algo, "2d", 65536, 256, r=2).total
            t_big = evaluate(CTX, algo, "2d", 65536, 4096, r=2).total
            assert t_big < t_small

    @given(n=st.sampled_from([16384, 32768, 65536, 131072]),
           p=st.sampled_from(GRID_P))
    @settings(max_examples=40, deadline=None)
    def test_pct_of_peak_in_range(self, n, p):
        for algo in ALGOS:
            for variant in VARIANTS:
                r = evaluate(CTX, algo, variant, n, p, c=4, r=2)
                pct = pct_of_peak(CTX, r)
                assert 0 < pct <= 100.0

    def test_cannon_flop_conservation(self):
        """Compute time x peak x eff == 2n^3 exactly for Cannon 2D."""
        n, p = 32768, 1024
        r = evaluate(CTX_IDEAL, "cannon", "2d", n, p)
        bs = n / math.sqrt(p)
        eff = HOPPER_EFFICIENCY["dgemm"](bs)
        implied = r.comp * p * HOPPER.peak_flops_per_unit * eff
        assert implied == pytest.approx(2 * n ** 3, rel=1e-6)

    def test_trsm_update_flops_conserved(self):
        """The dominant dgemm term sums to ~n^3/p per process."""
        n, p, r_ = 65536, 1024, 2
        res = evaluate(CTX_IDEAL, "trsm", "2d", n, p, r=r_)
        bs = n / (r_ * math.sqrt(p))
        eff = HOPPER_EFFICIENCY["dgemm"](bs)
        flops = res.terms["update"] * HOPPER.peak_flops_per_unit * eff
        assert flops == pytest.approx(n ** 3 / p, rel=0.05)


class TestPredictor:
    def test_legal_c_values(self):
        import math
        for p in (256, 1024, 4096, 65536):
            cs = legal_c_values(p)
            assert cs, p
            for c in cs:
                g = math.sqrt(p / c)
                assert abs(g - round(g)) < 1e-9      # square grid
                assert c <= max(2, round(p ** (1 / 3)))  # Solomonik bound

    def test_best_variant_structure(self):
        ch = best_variant(CTX, "cannon", 32768, 1024)
        assert set(ch) == set(VARIANTS)
        for v, choice in ch.items():
            assert choice.result.total > 0

    def test_memory_constraint_limits_c(self):
        """At huge n, 2.5D replication must not exceed per-process memory."""
        ch = best_variant(CTX, "cannon", 262144, 1024)
        c = ch["2.5d"].result.c
        words = 3 * 262144 ** 2 * c / 1024
        assert words * 8 <= HOPPER.mem_per_unit * 1.01

    def test_select_returns_fastest(self):
        ch = best_variant(CTX, "summa", 32768, 4096)
        best = select(CTX, "summa", 32768, 4096)
        assert best.result.total == min(c.result.total for c in ch.values())

    def test_communication_avoidance_wins_at_scale(self):
        """The paper's headline: at fixed n, growing p eventually favors
        2.5D over 2D (communication avoidance pays at scale)."""
        n = 32768
        gap_small = (best_variant(CTX, "cannon", n, 256)["2d_ovlp"].result.total
                     / best_variant(CTX, "cannon", n, 256)["2.5d_ovlp"].result.total)
        gap_big = (best_variant(CTX, "cannon", n, 65536)["2d_ovlp"].result.total
                   / best_variant(CTX, "cannon", n, 65536)["2.5d_ovlp"].result.total)
        assert gap_big > gap_small  # 2.5D relatively better at scale
