"""Training substrate: optimizer, data determinism, checkpoint/restart,
fault tolerance, straggler detection."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.training import (AdamWConfig, DataConfig, DataPipeline,
                            FaultInjector, RecoveryPlanner,
                            RescheduleRequested, RestartableLoop,
                            RestartPolicy, StragglerConfig, StragglerMonitor,
                            TrainConfig, Trainer, adamw_update, init_adamw)
from repro.training import checkpoint as ckpt


class TestOptimizer:
    def _setup(self, kind="adamw", state_dtype="float32"):
        params = {"w": jnp.ones((16, 32)), "b": jnp.zeros((32,))}
        cfg = AdamWConfig(lr=1e-2, kind=kind, state_dtype=state_dtype,
                          warmup_steps=0, total_steps=100)
        state = init_adamw(cfg, params)
        grads = {"w": jnp.ones((16, 32)) * 0.1, "b": jnp.ones((32,)) * 0.1}
        return cfg, params, state, grads

    @pytest.mark.parametrize("kind", ["adamw", "adafactor"])
    def test_update_moves_params(self, kind):
        cfg, params, state, grads = self._setup(kind)
        newp, newstate, metrics = adamw_update(cfg, grads, state, params)
        assert float(jnp.abs(newp["w"] - params["w"]).max()) > 0
        assert int(newstate.step) == 1
        assert np.isfinite(metrics["grad_norm"])

    def test_adamw_descends_quadratic(self):
        cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, min_lr_ratio=1.0)
        params = {"x": jnp.asarray([3.0, -2.0])}
        state = init_adamw(cfg, params)
        for _ in range(150):
            g = {"x": 2 * params["x"]}
            params, state, _ = adamw_update(cfg, g, state, params)
        assert float(jnp.abs(params["x"]).max()) < 0.2

    def test_adafactor_state_is_factored(self):
        cfg, params, state, grads = self._setup("adafactor")
        leaves = state.nu["w"]
        assert set(leaves) == {"vr", "vc"}
        assert leaves["vr"].shape == (16,)
        assert leaves["vc"].shape == (32,)

    def test_grad_clip(self):
        cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
        params = {"w": jnp.zeros((8, 8))}
        state = init_adamw(cfg, params)
        huge = {"w": jnp.full((8, 8), 1e6)}
        newp, _, m = adamw_update(cfg, huge, state, params)
        assert np.isfinite(np.asarray(newp["w"])).all()


class TestData:
    def test_deterministic_and_elastic(self):
        cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=8, seed=7)
        pipe = DataPipeline(cfg)
        b1 = pipe.batch_at(5)
        b2 = pipe.batch_at(5)
        assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
        b3 = pipe.batch_at(6)
        assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=500, seq_len=16, global_batch=4)
        b = DataPipeline(cfg).batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (4, 16)
        assert int(b["tokens"].max()) < 500

    def test_learnable_structure(self):
        """The bigram rule makes labels partially predictable."""
        cfg = DataConfig(vocab_size=128, seq_len=256, global_batch=16)
        b = DataPipeline(cfg).batch_at(0)
        rule = (np.asarray(b["tokens"]) * 31 + 7) % 128
        agree = (rule == np.asarray(b["labels"])).mean()
        assert agree > 0.3   # ~half the positions follow the rule


class TestCheckpoint:
    def test_roundtrip_and_latest(self):
        with tempfile.TemporaryDirectory() as d:
            trees = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                     "opt": {"mu": jnp.ones((3, 4))}}
            ckpt.save(d, 3, trees, cursor={"step": 3})
            ckpt.save(d, 7, trees, cursor={"step": 7})
            assert ckpt.latest_step(d) == 7
            out, manifest = ckpt.restore(d, trees)
            assert manifest["cursor"]["step"] == 7
            assert np.array_equal(np.asarray(out["params"]["w"]),
                                  np.arange(12.0).reshape(3, 4))

    def test_gc_keeps_last_k(self):
        with tempfile.TemporaryDirectory() as d:
            trees = {"p": {"w": jnp.zeros(4)}}
            for s in range(6):
                ckpt.save(d, s, trees, keep=2)
            steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
            assert len(steps) == 2
            assert ckpt.latest_step(d) == 5

    def test_commit_is_atomic(self):
        """A stale .tmp directory never shadows a committed step."""
        with tempfile.TemporaryDirectory() as d:
            trees = {"p": {"w": jnp.zeros(4)}}
            os.makedirs(os.path.join(d, "step_00000009.tmp"))
            ckpt.save(d, 9, trees)
            assert ckpt.latest_step(d) == 9

    def test_shape_mismatch_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 1, {"p": {"w": jnp.zeros((2, 2))}})
            with pytest.raises(ValueError):
                ckpt.restore(d, {"p": {"w": jnp.zeros((4, 4))}})


class TestFaultTolerance:
    def test_straggler_monitor_fires(self):
        mon = StragglerMonitor(StragglerConfig(window=10, ratio_threshold=2.0,
                                               sustained=2, min_steps=4))
        event = None
        # healthy baseline, then a sustained degradation onset (stop while
        # the window still straddles the onset so cmax/cavg stays > 1)
        for i in range(16):
            dt = 1.0 if i < 12 else 5.0
            event = mon.record(dt) or event
        assert event is not None and event["type"] == "straggler"
        assert event["ratio"] > 2.0
        assert mon.online_cmax_over_cavg > 2.0

    def test_straggler_monitor_ignores_single_spike(self):
        # one historical spike must not keep firing: the statistic is
        # latest/median, so the window forgets the spike immediately
        mon = StragglerMonitor(StragglerConfig(window=10, ratio_threshold=2.0,
                                               sustained=2, min_steps=4))
        events = [mon.record(5.0 if i == 6 else 1.0) for i in range(20)]
        assert all(e is None for e in events)

    def test_straggler_monitor_default_cfg_not_shared(self):
        a, b = StragglerMonitor(), StragglerMonitor()
        assert a.cfg is not b.cfg

    def test_restartable_loop_resume_never_replays_history(self):
        saved = []
        injector = FaultInjector(fail_at_steps=(7,))

        def step_fn(step):
            injector.maybe_fail(step)
            return {"v": step}

        def save_fn(step):
            saved.append(step)

        def restore_fn():
            return max((s for s in saved), default=0)

        loop = RestartableLoop(policy=RestartPolicy(max_restarts=2),
                               checkpoint_every=5)
        rep = loop.run(n_steps=12, step_fn=step_fn, save_fn=save_fn,
                       restore_fn=restore_fn)
        assert rep["steps"] == 12 and rep["restarts"] == 1
        steps = [h["step"] for h in rep["history"]]
        assert steps == sorted(set(steps)) == list(range(12))

    def test_restartable_loop_exhausted_restarts_raises(self):
        class AlwaysFails(RuntimeError):
            pass

        def step_fn(step):
            raise AlwaysFails("boom")

        loop = RestartableLoop(policy=RestartPolicy(max_restarts=2))
        with pytest.raises(AlwaysFails):
            loop.run(n_steps=4, step_fn=step_fn, save_fn=lambda s: None,
                     restore_fn=lambda: 0)

    def test_restartable_loop_default_policy_not_shared(self):
        a, b = RestartableLoop(), RestartableLoop()
        assert a.policy is not b.policy and a.monitor is not b.monitor

    def test_recovery_planner_decisions(self):
        pl = RecoveryPlanner(1.0, restart_overhead_s=20.0, checkpoint_s=2.0,
                             margin=1.25, degraded_threshold=1.5)
        # mild slowdown, nothing to do
        assert pl.decide(1.2, 100).action == "continue"
        # real slowdown but too little work left to pay the migration
        assert pl.decide(3.0, 5).action == "checkpoint_now"
        # heavy slowdown with lots of work left: migrating wins clearly
        d = pl.decide(4.0, 100)
        assert d.action == "reschedule"
        assert d.reschedule_s * pl.margin < d.continue_s

    def test_restartable_loop_planner_reschedules_after_checkpoint(self):
        # drive the monitor with fake times: healthy then 4x degraded
        mon = StragglerMonitor(StragglerConfig(window=8, ratio_threshold=2.0,
                                               sustained=2, min_steps=4))
        times = iter([1.0] * 8 + [4.0] * 20)
        saved = []
        loop = RestartableLoop(
            monitor=mon,
            planner=RecoveryPlanner(1.0, restart_overhead_s=5.0,
                                    checkpoint_s=1.0),
            checkpoint_every=1000)
        orig = mon.record
        mon.record = lambda _dt: orig(next(times))
        with pytest.raises(RescheduleRequested) as ei:
            loop.run(n_steps=200, step_fn=lambda s: {},
                     save_fn=saved.append, restore_fn=lambda: 0)
        assert ei.value.decision.action == "reschedule"
        assert saved, "must checkpoint before requesting reschedule"
        assert saved[-1] == ei.value.decision.step

    def test_trainer_restart_is_deterministic(self):
        cfg_m = get("qwen1.5-4b").reduced()
        with tempfile.TemporaryDirectory() as d:
            def run(fault):
                tc = TrainConfig(
                    model=cfg_m,
                    opt=AdamWConfig(lr=1e-3, total_steps=30, warmup_steps=2),
                    data=DataConfig(vocab_size=cfg_m.vocab_size, seq_len=32,
                                    global_batch=4),
                    n_steps=30, checkpoint_dir=os.path.join(d, "a" if fault else "b"),
                    checkpoint_every=10, log_every=30)
                tr = Trainer(tc)
                rep = tr.run(FaultInjector(fail_at_steps=(17,) if fault else ()))
                return rep, tr
            rep1, tr1 = run(fault=True)
            rep2, tr2 = run(fault=False)
            assert rep1["restarts"] == 1 and rep2["restarts"] == 0
            # bit-identical final params despite the crash/restore
            for (p1, p2) in zip(jax.tree.leaves(tr1.params),
                                jax.tree.leaves(tr2.params)):
                assert np.array_equal(np.asarray(p1, np.float32),
                                      np.asarray(p2, np.float32))
