"""Test configuration.  NOTE: no XLA_FLAGS here — single-device tests must
see 1 device (multi-device tests spawn subprocesses with their own flags).
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False)


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
