"""Test configuration.  NOTE: no XLA_FLAGS here — single-device tests must
see 1 device (multi-device tests spawn subprocesses with their own flags).

``hypothesis`` is an *optional* dependency: when it is missing we install a
small shim into ``sys.modules`` before any test module imports it.  The
shim degrades ``@given`` property tests to deterministic fixed-example
runs (a handful of boundary/representative samples per strategy) so the
suite still collects and exercises every invariant.
"""
import itertools
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest  # noqa: E402

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import types

    class _Strategy:
        """A fixed, deterministic sample set standing in for a strategy."""

        def __init__(self, samples):
            self.samples = list(samples)

    def _integers(min_value=0, max_value=1 << 16):
        lo, hi = int(min_value), int(max_value)
        mid = lo + (hi - lo) // 2
        samples = sorted({lo, mid, hi, min(lo + 1, hi), max(hi - 1, lo)})
        return _Strategy(samples)

    def _sampled_from(elements):
        return _Strategy(list(elements))

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(sorted({lo, (lo + hi) / 2.0, hi}))

    def _booleans():
        return _Strategy([False, True])

    class _Unsatisfied(Exception):
        """Raised by the shim's assume() to discard the current example."""

    def _given(*gargs, **gkwargs):
        if gargs:
            raise TypeError("hypothesis shim supports keyword strategies only")

        def deco(fn):
            import functools
            import inspect

            names = list(gkwargs)
            pools = [gkwargs[n].samples for n in names]

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # Cap the cartesian product so shimmed runs stay fast.
                for combo in itertools.islice(itertools.product(*pools), 64):
                    try:
                        fn(*args, **dict(zip(names, combo)), **kwargs)
                    except _Unsatisfied:
                        continue  # assume() rejected this example

            # Hide the strategy parameters from pytest's fixture resolution:
            # drop __wrapped__ (inspect.signature follows it) and expose a
            # signature without the @given-supplied names.
            del wrapper.__wrapped__
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for p in sig.parameters.values() if p.name not in names])
            wrapper.hypothesis_shim = True
            return wrapper

        return deco

    def _settings(*_a, **_kw):
        def deco(fn):
            return fn

        return deco

    def _assume(cond):
        if not cond:
            raise _Unsatisfied
        return True

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    _hyp.assume = _assume
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.sampled_from = _sampled_from
    _st.floats = _floats
    _st.booleans = _booleans
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False)


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    skip = pytest.mark.skip(reason="slow; use --run-slow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
