"""Property tests for the collective schedules (paper §V).

Two families of invariants, pinned over the whole parameter space
(hypothesis where available; the conftest shim degrades to fixed samples):

* **traffic conservation** — the recursive schedules move exactly the
  volume the algorithm requires: each phase of ``reduce``/``bcast`` on a
  ``w``-word vector over ``q = 2^k`` processes transfers ``w * (q-1)/q``
  words in total across its steps (recursive halving and binomial
  doubling are different orderings of the same traffic);
* **monotonicity** — calibrated time never decreases in the vector length
  ``w`` or the job size ``p`` (contention factors grow with ``p``).

The step-level view comes from ``repro.perf.collective_schedule``; a glue
test asserts it reproduces the legacy ``core.collectives`` closed forms
exactly, so the properties hold for both implementations.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import CommModel, HOPPER, ParametricCalibration
from repro.core import collectives as coll
from repro.perf import collective_schedule

CM = CommModel(HOPPER, ParametricCalibration())

#: schedule kind -> legacy closed form (signature (cm, p, q, w, d))
LEGACY = {
    "redsca_sync": coll.t_redsca_sync,
    "scatter_sync": coll.t_scatter_sync,
    "allgather_sync": coll.t_allgather_sync,
    "reduce": coll.t_reduce,
    "bcast": coll.t_bcast,
    "bcast_sync": coll.t_bcast_sync,
}


def _time_of_steps(steps, p):
    total = 0.0
    for s in steps:
        if s.sync:
            total += CM.t_comm_sync(p, s.words, s.dist)
        else:
            total += CM.t_comm(s.words, s.dist)
    return total


class TestTrafficConservation:
    @given(k=st.integers(1, 10), w_exp=st.integers(8, 24))
    @settings(max_examples=60, deadline=None)
    def test_reduce_phases_conserve_traffic(self, k, w_exp):
        q, w = 2 ** k, float(2 ** w_exp)
        steps = collective_schedule("reduce", q, w, d=1.0)
        redsca = sum(s.words for s in steps if s.phase == "reduce_scatter")
        gather = sum(s.words for s in steps if s.phase == "gather")
        want = w * (q - 1) / q
        assert redsca == pytest.approx(want, rel=1e-12)
        assert gather == pytest.approx(want, rel=1e-12)

    @given(k=st.integers(1, 10), w_exp=st.integers(8, 24))
    @settings(max_examples=60, deadline=None)
    def test_bcast_phases_conserve_traffic(self, k, w_exp):
        q, w = 2 ** k, float(2 ** w_exp)
        for kind in ("bcast", "bcast_sync"):
            steps = collective_schedule(kind, q, w, d=1.0)
            scatter = sum(s.words for s in steps if s.phase == "scatter")
            allg = sum(s.words for s in steps if s.phase == "allgather")
            want = w * (q - 1) / q
            assert scatter == pytest.approx(want, rel=1e-12)
            assert allg == pytest.approx(want, rel=1e-12)

    @given(k=st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_exactly_one_sync_per_synchronized_phase(self, k):
        q = 2 ** k
        assert sum(s.sync for s in collective_schedule("reduce", q, 1e6)) == 1
        assert sum(s.sync for s in collective_schedule("bcast", q, 1e6)) == 1
        assert sum(s.sync for s in
                   collective_schedule("bcast_sync", q, 1e6)) == 2

    def test_degenerate_group_is_empty(self):
        for kind in ("reduce", "bcast", "bcast_sync"):
            assert collective_schedule(kind, 1, 1e6) == ()

    def test_schedule_expansion_is_memoized(self):
        a = collective_schedule("bcast", 16, 1e6, 2.0)
        b = collective_schedule("bcast", 16, 1e6, 2.0)
        assert a is b  # lru_cache returns the same immutable tuple
        assert isinstance(a, tuple)


class TestMonotonicity:
    @given(k=st.integers(1, 8), d=st.sampled_from([1.0, 8.0, 64.0]))
    @settings(max_examples=40, deadline=None)
    def test_calibrated_time_monotone_in_w(self, k, d):
        q = 2 ** k
        p = 4096
        for kind in ("reduce", "bcast"):
            fn = LEGACY[kind]
            prev = 0.0
            for w_exp in (8, 12, 16, 20, 24):
                t = fn(CM, p, q, float(2 ** w_exp), d)
                assert t >= prev
                prev = t

    @given(k=st.integers(1, 8), w_exp=st.integers(8, 24))
    @settings(max_examples=40, deadline=None)
    def test_calibrated_time_monotone_in_p(self, k, w_exp):
        q, w = 2 ** k, float(2 ** w_exp)
        for kind in ("reduce", "bcast", "bcast_sync"):
            fn = LEGACY[kind]
            prev = 0.0
            for p in (64, 256, 1024, 4096, 65536):
                t = fn(CM, p, q, w, 4.0)
                assert t >= prev, (kind, p)
                prev = t


class TestScheduleMatchesClosedForms:
    @given(k=st.integers(1, 10), w_exp=st.integers(8, 24),
           d=st.sampled_from([1.0, 4.0, 32.0]))
    @settings(max_examples=60, deadline=None)
    def test_step_sum_equals_legacy_time(self, k, w_exp, d):
        """Summing the expanded steps under the calibrated CommModel equals
        the legacy closed forms — the IR Collective node and
        core.collectives cannot drift apart."""
        q, w, p = 2 ** k, float(2 ** w_exp), 4096
        for kind, fn in LEGACY.items():
            steps = collective_schedule(kind, q, w, d)
            assert _time_of_steps(steps, p) == pytest.approx(
                fn(CM, p, q, w, d), rel=1e-12), kind
