"""Multi-device tuner-dispatch validation driver (run in a subprocess with
--xla_force_host_platform_device_count=8).  Prints JSON verdicts."""

import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import linalg  # noqa: E402
from repro.core import predictor  # noqa: E402
from repro.tuner import PlanCache, Tuner, feasible_grids  # noqa: E402


def _rel_err(got, ref):
    got, ref = np.asarray(got), np.asarray(ref)
    return float(np.abs(got - ref).max() / np.abs(ref).max())


def main():
    out = {}
    rng = np.random.default_rng(0)
    n = 96
    devices = jax.devices()
    plan_dir = tempfile.mkdtemp(prefix="plans-")
    tuner = Tuner(cache=PlanCache(plan_dir))

    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    C_ref = np.asarray(A) @ np.asarray(B)
    U = jnp.asarray(np.triu(rng.standard_normal((n, n))) + 3 * np.eye(n),
                    jnp.float32)
    X_ref = np.asarray(B) @ np.linalg.inv(np.asarray(U))
    M = rng.standard_normal((n, n))
    SPD = jnp.asarray(M @ M.T + n * np.eye(n), jnp.float32)
    L_ref = np.linalg.cholesky(np.asarray(SPD))

    # auto-dispatch numerics (jnp local kernels — the CPU default)
    out["matmul_err"] = _rel_err(linalg.matmul(A, B, tuner=tuner), C_ref)
    out["trsm_err"] = _rel_err(linalg.trsm(U, B, tuner=tuner), X_ref)
    out["cholesky_err"] = _rel_err(linalg.cholesky(SPD, tuner=tuner), L_ref)

    # Pallas local kernels agree with the jnp path
    out["matmul_pallas_err"] = _rel_err(
        linalg.matmul(A, B, tuner=tuner, local_kernel="pallas"), C_ref)
    out["trsm_pallas_err"] = _rel_err(
        linalg.trsm(U, B, tuner=tuner, local_kernel="pallas"), X_ref)
    out["cholesky_pallas_err"] = _rel_err(
        linalg.cholesky(SPD, tuner=tuner, local_kernel="pallas"), L_ref)

    # second identical call is served from the plan cache (no model evals)
    evals = tuner.stats["model_evals"]
    linalg.matmul(A, B, tuner=tuner)
    out["repeat_model_evals_delta"] = tuner.stats["model_evals"] - evals
    out["cache_hits"] = tuner.stats["cache_hits"]

    # ...including from a fresh Tuner (persistent JSON on disk)
    fresh = Tuner(cache=PlanCache(plan_dir))
    fresh.cache.clear_memory()
    fresh.plan("matmul", n, devices=devices)
    out["fresh_tuner_model_evals"] = fresh.stats["model_evals"]
    out["fresh_tuner_disk_hits"] = fresh.cache.disk_hits

    # the dispatched variant equals predictor.select over the same
    # realizable configurations
    plan = tuner.plan("matmul", n, devices=devices)
    ctx = tuner.registry.context(plan.machine)
    best = None
    for algo in ("cannon", "summa"):
        for p, c, g in feasible_grids(len(devices), algo):
            kind = "2d" if c == 1 else "2.5d"
            variants = [v for v in tuner.registry.variants(algo)
                        if v.startswith(kind)]
            ch = predictor.select(ctx, algo, n, p, variants=variants,
                                  c_values=[c], r_values=(1,))
            if best is None or ch.result.total < best[0].result.total:
                best = (ch, algo)
    out["plan_matches_select"] = bool(best[1] == plan.algo and
                                      best[0].result.variant == plan.variant)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
