"""Multi-device linalg validation driver (run in a subprocess with
--xla_force_host_platform_device_count=9).  Prints JSON verdicts."""

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.linalg import (ALGORITHMS, cholesky_25d, cholesky_2d, distribute,
                          trsm_25d, trsm_2d)  # noqa: E402
from repro.linalg.grid import make_grid_mesh  # noqa: E402


def main():
    rng = np.random.default_rng(0)
    n = 48
    out = {}
    A = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((n, n)), jnp.float32)
    C_ref = np.asarray(A) @ np.asarray(B)
    U = jnp.asarray(np.triu(rng.standard_normal((n, n))) + 3 * np.eye(n),
                    jnp.float32)
    X_ref = np.asarray(B) @ np.linalg.inv(np.asarray(U))
    M = rng.standard_normal((n, n))
    SPD = jnp.asarray(M @ M.T + n * np.eye(n), jnp.float32)
    L_ref = np.linalg.cholesky(np.asarray(SPD))

    mesh2 = make_grid_mesh(3, 3)
    mesh3 = make_grid_mesh(2, 2, layers=2)

    for (algo, variant), fn in ALGORITHMS.items():
        mesh = mesh3 if variant.startswith("2.5d") else mesh2
        if algo in ("cannon", "summa"):
            args = (distribute(A, mesh, P("row", "col")),
                    distribute(B, mesh, P("row", "col")))
            ref = C_ref
        elif algo == "trsm":
            bspec = P(("lyr", "row"), "col") if variant.startswith("2.5d") \
                else P("row", "col")
            args = (distribute(U, mesh, P("row", "col")),
                    distribute(B, mesh, bspec))
            ref = X_ref
        else:
            args = (distribute(SPD, mesh, P("row", "col")),)
            ref = L_ref
        got = np.asarray(fn(*args, mesh=mesh))
        err = float(np.abs(got - ref).max() / np.abs(ref).max())
        out[f"{algo}_{variant}"] = err

    # Pallas matmul kernel plugged into Cannon (kernels compose with
    # the distributed layer through the local_mm hook)
    from repro.kernels.matmul import matmul_ref
    from repro.linalg import cannon_2d
    got = np.asarray(cannon_2d(distribute(A, mesh2), distribute(B, mesh2),
                               mesh=mesh2, local_mm=matmul_ref))
    out["cannon_2d_kernel_mm"] = float(np.abs(got - C_ref).max()
                                       / np.abs(C_ref).max())
    print(json.dumps(out))


if __name__ == "__main__":
    main()
